//! Bring-your-own-AQL: register custom queries in a catalog, see the
//! merged optimized plan, the partition (paper Fig 1), and the generated
//! accelerator configuration — then stream the log corpus through a
//! `Session` with a typed per-view subscription and a per-query
//! subscription.
//!
//! ```sh
//! cargo run --release --example custom_query
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use boost::coordinator::Engine;
use boost::corpus::CorpusSpec;
use boost::hwcompiler::compile_subgraph;
use boost::partition::{partition, PartitionMode};

fn main() -> anyhow::Result<()> {
    // Error spike detection over machine logs…
    let errors_aql = r#"
        create view Timestamp as
          extract regex /\d{4}-\d{2}-\d{2}T\d{2}:\d{2}:\d{2}/ on d.text as ts
          from Document d;

        create view ErrorWord as
          extract regex /ERROR|WARN/ on d.text as level from Document d;

        create view Ip as
          extract regex /\d{1,3}\.\d{1,3}\.\d{1,3}\.\d{1,3}/ on d.text as addr
          from Document d;

        create view ErrorEvent as
          select t.ts as ts, e.level as level, i.addr as ip,
                 CombineSpans(t.ts, i.addr) as line
          from Timestamp t, ErrorWord e, Ip i
          where FollowsTok(t.ts, e.level, 0, 3) and Follows(e.level, i.addr, 0, 40)
          consolidate on line using 'ContainedWithin';

        output view ErrorEvent;
    "#;
    // …plus a second analysis over the SAME stream. Its Ip extractor is
    // textually identical to the first query's, so the catalog interns it:
    // one machine scans for both queries.
    let audit_aql = r#"
        create view Ip as
          extract regex /\d{1,3}\.\d{1,3}\.\d{1,3}\.\d{1,3}/ on d.text as addr
          from Document d;
        create view Host as
          extract regex /[a-z][a-z0-9\-]+\.(local|internal)/ on d.text as h
          from Document d;
        output view Ip;
        output view Host;
    "#;

    let engine = Engine::builder()
        .register("errors", errors_aql)
        .register("audit", audit_aql)
        .build()?;
    println!("== merged optimized plan ==\n{}", engine.graph().dump());
    println!(
        "extraction leaves after interning: {} (the shared Ip pattern compiled once)\n",
        engine.graph().extraction_leaves()
    );

    let plan = partition(engine.graph(), PartitionMode::SingleSubgraph);
    println!("== partition (Fig 1) ==");
    println!("supergraph:\n{}", plan.supergraph.dump());
    for sg in &plan.subgraphs {
        println!("subgraph #{} body:\n{}", sg.id, sg.body.dump());
        let cfg = compile_subgraph(sg)?;
        println!(
            "accelerator config: {} machines, geometry {}x{} states, artifact {}",
            cfg.machines.len(),
            cfg.geometry.0,
            cfg.geometry.1,
            cfg.artifact_key(16384).file_name()
        );
        for m in &cfg.machines {
            println!(
                "  machine for body node %{}: {:?} ({} states)",
                m.body_node, m.matcher, m.num_states
            );
        }
    }

    // Stream the corpus through ONE session evaluating both queries per
    // document: a typed per-view subscription counts error events, a
    // per-query subscription counts the audit query's tuples.
    let error_event = engine.query("errors")?.view("ErrorEvent")?;
    let audit = engine.query("audit")?;
    let events = Arc::new(AtomicUsize::new(0));
    let audit_rows = Arc::new(AtomicUsize::new(0));
    let (ev, au) = (events.clone(), audit_rows.clone());
    let mut session = engine
        .session()
        .threads(2)
        .queue_depth(4)
        .subscribe(&error_event, move |_doc, rows| {
            ev.fetch_add(rows.len(), Ordering::Relaxed);
        })
        .subscribe_query(&audit, move |_doc, qh, result| {
            au.fetch_add(qh.total_tuples(result), Ordering::Relaxed);
        })
        .start();
    let corpus = CorpusSpec::logs(200, 512).generate();
    for doc in corpus.docs {
        session.push(doc)?;
    }
    let report = session.finish();
    println!(
        "\nstreamed {} log docs in one pass: {} tuples total, {} error events, \
         {} audit rows, {:.2} MB/s",
        report.docs,
        report.tuples,
        events.load(Ordering::Relaxed),
        audit_rows.load(Ordering::Relaxed),
        report.throughput() / 1e6
    );
    Ok(())
}
