//! Small-document workload (the paper's Twitter/RSS scenario, §4.2):
//! 256-byte messages *streamed* through the accelerated engine one push at
//! a time — the firehose the `Session` API exists for. Shows the
//! work-package combining behaviour that Fig 6 quantifies (many small
//! documents per package, throughput well below the large-document peak)
//! and the backpressure counters of the bounded pipeline.
//!
//! ```sh
//! cargo run --release --example tweet_firehose
//! ```

use boost::coordinator::{Engine, EngineConfig};
use boost::corpus::CorpusSpec;
use boost::partition::PartitionMode;
use boost::perfmodel::FpgaModel;
use boost::runtime::EngineSpec;

fn main() -> anyhow::Result<()> {
    let q = boost::queries::builtin("t3").unwrap(); // brand sentiment
    println!("== tweet firehose: {} over streamed messages ==", q.title);

    let model = FpgaModel::paper();
    for &size in &[128usize, 256, 2048] {
        let corpus = CorpusSpec::tweets(1200, size).generate();
        // a one-entry catalog: the same builder that registers T1–T5
        // together serves the single-tenant case too
        let engine = Engine::builder()
            .register_builtin("t3")
            .config(EngineConfig::accelerated(
                PartitionMode::ExtractOnly,
                EngineSpec::Native,
            ))
            .build()?;
        // A deliberately small queue: the producer below is far faster
        // than the workers, so push() throttles it (check the stall
        // counter in the output) while memory stays bounded at
        // queue_depth + threads documents.
        let mut session = engine.session().threads(4).queue_depth(8).start();
        for doc in corpus.docs {
            session.push(doc)?;
        }
        let queue = session.queue_snapshot();
        let report = session.finish();
        let snap = engine.accel_snapshot().unwrap();
        println!(
            "{size:5} B docs: {:6.2} MB/s wall | {} pkgs, {:5.1} docs/pkg | {} push stalls | modeled FPGA {:5.0} MB/s (paper-shape: peak/{:.0})",
            report.throughput() / 1e6,
            snap.packages,
            snap.docs_per_package(),
            queue.stalls,
            model.throughput(size, 16384) / 1e6,
            model.peak / model.throughput(size, 16384),
        );
        engine.shutdown();
    }
    println!("\nthe >1000 B combining rule keeps small-doc throughput an order of");
    println!("magnitude above per-document transfers, but still below the 2 kB peak");
    Ok(())
}
