//! End-to-end driver (EXPERIMENTS.md E5): the full system on a real small
//! workload, proving all layers compose —
//!
//!   T1–T5 registered in ONE catalog → merged AOG supergraph (interned
//!   extraction leaves) → optimizer → maximal-convex partition →
//!   hardware compile (one shared DFA-table image) → AOT Pallas kernel
//!   via PJRT → multi-threaded communication interface → per-query
//!   annotations, all in a single pass per document,
//!
//! with a software baseline run for correctness comparison and the
//! paper-calibrated Eq. 1 estimate for the headline speedup. Both runs
//! stream through the `Session` pipeline, so the software workers and the
//! accelerator submissions share the bounded-queue scheduler.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use boost::coordinator::{CatalogBuilder, Engine, EngineConfig};
use boost::corpus::CorpusSpec;
use boost::partition::{partition, PartitionMode};
use boost::perfmodel::FpgaModel;
use boost::runtime::EngineSpec;

const QUERIES: [&str; 5] = ["t1", "t2", "t3", "t4", "t5"];

fn catalog() -> CatalogBuilder {
    let mut b = Engine::builder();
    for q in QUERIES {
        b = b.register_builtin(q);
    }
    b
}

fn main() -> anyhow::Result<()> {
    println!("== T1-T5 as one catalog: one engine, one image, one pass ==");

    // 1. software baseline + profile: every query evaluated per document
    //    in a single pass over the merged supergraph
    let corpus = CorpusSpec::news(400, 2048).generate();
    let sw = catalog().build()?;
    let single_leaves: usize = QUERIES
        .iter()
        .map(|q| {
            Engine::compile_aql(&boost::queries::builtin(q).unwrap().aql)
                .map(|e| e.graph().extraction_leaves())
        })
        .sum::<Result<usize, _>>()?;
    println!(
        "catalog:      {} queries, {} extraction leaves merged from {} ({} interned away)",
        sw.queries().len(),
        sw.graph().extraction_leaves(),
        single_leaves,
        single_leaves - sw.graph().extraction_leaves(),
    );
    let mut sw_session = sw.session().threads(1).queue_depth(2).start();
    sw_session.push_batch(corpus.docs.iter().cloned())?;
    let sw_report = sw_session.finish();
    let profile = sw.profile();
    println!(
        "software:     {:7.1} ms, {:6.2} MB/s, {} tuples, extraction {:.0}%",
        sw_report.wall.as_secs_f64() * 1e3,
        sw_report.throughput() / 1e6,
        sw_report.tuples,
        profile.fraction_extraction() * 100.0
    );

    // 2. accelerated run through the real PJRT path (falls back to the
    //    native engine when artifacts/ is missing)
    let engine_spec = if std::path::Path::new("artifacts/dfa_m16_s256_b16384.hlo.txt").exists() {
        EngineSpec::Pjrt {
            artifacts_dir: "artifacts".into(),
        }
    } else {
        eprintln!("NOTE: artifacts/ missing — using the native package engine");
        EngineSpec::Native
    };
    let hw = catalog()
        .config(EngineConfig::accelerated(
            PartitionMode::ExtractOnly,
            engine_spec,
        ))
        .build()?;
    println!(
        "hw image:     {} subgraph(s), shared artifact set [{}]",
        hw.plan().map(|p| p.subgraphs.len()).unwrap_or(0),
        hw.artifact_keys()
            .iter()
            .map(|k| k.file_name())
            .collect::<Vec<_>>()
            .join(", "),
    );
    let mut hw_session = hw.session().threads(4).queue_depth(8).start();
    hw_session.push_batch(corpus.docs.iter().cloned())?;
    let hw_report = hw_session.finish();
    println!(
        "accelerated:  {:7.1} ms, {:6.2} MB/s, {} tuples",
        hw_report.wall.as_secs_f64() * 1e3,
        hw_report.throughput() / 1e6,
        hw_report.tuples,
    );
    let snap = hw.accel_snapshot().unwrap();
    println!(
        "accel detail: {} packages, {:.1} docs/package, {} hit events, modeled FPGA {:.0} MB/s",
        snap.packages,
        snap.docs_per_package(),
        snap.hits,
        snap.modeled_throughput() / 1e6,
    );

    // 3. correctness: identical per-query annotation counts (one shared
    //    device pass must serve every query exactly)
    let probe = &corpus.docs[0];
    let (sw_result, hw_result) = (sw.run_doc(probe), hw.run_doc(probe));
    for q in QUERIES {
        let (a, b) = (sw.query(q)?, hw.query(q)?);
        assert_eq!(
            a.total_tuples(&sw_result),
            b.total_tuples(&hw_result),
            "query {q} diverged between software and accelerated catalog"
        );
    }
    assert_eq!(
        sw_report.tuples, hw_report.tuples,
        "accelerated path must produce identical annotations"
    );
    println!(
        "correctness:  software and accelerated annotation sets agree ({} tuples, all {} queries)",
        sw_report.tuples,
        QUERIES.len(),
    );

    // 4. the headline estimate (paper Fig 7 / §5): Eq. 1 with the measured
    //    software baseline, the measured offload fraction, and the
    //    paper-calibrated FPGA model.
    let plan = partition(sw.graph(), PartitionMode::MultiSubgraph);
    let offloaded: Vec<usize> = plan
        .subgraphs
        .iter()
        .flat_map(|s| s.orig_nodes.iter().copied())
        .collect();
    let frac = profile.fraction_of_nodes(&offloaded);
    let model = FpgaModel::paper();
    let tp_sw = sw_report.throughput();
    for (label, size) in [("256 B", 256usize), ("2048 B", 2048)] {
        let est = model.estimate(tp_sw, frac, size, 16384, 1);
        println!(
            "Eq.1 estimate @ {label:>6}: {:6.1} MB/s  ({:.1}x over software)  [paper: T1 up to {}]",
            est / 1e6,
            est / tp_sw,
            if size == 2048 { "16x" } else { "10x" },
        );
    }
    hw.shutdown();
    Ok(())
}
