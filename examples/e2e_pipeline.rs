//! End-to-end driver (EXPERIMENTS.md E5): the full system on a real small
//! workload, proving all layers compose —
//!
//!   AQL → operator graph → optimizer → maximal-convex partition →
//!   hardware compile (DFA tables) → AOT Pallas kernel via PJRT →
//!   multi-threaded communication interface → annotations,
//!
//! with a software baseline run for correctness comparison and the
//! paper-calibrated Eq. 1 estimate for the headline speedup. Both runs
//! stream through the `Session` pipeline, so the software workers and the
//! accelerator submissions share the bounded-queue scheduler.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use boost::coordinator::{Engine, EngineConfig};
use boost::corpus::CorpusSpec;
use boost::partition::{partition, PartitionMode};
use boost::perfmodel::FpgaModel;
use boost::runtime::EngineSpec;

fn main() -> anyhow::Result<()> {
    let q = boost::queries::builtin("t1").unwrap();
    println!("== {} ({}) ==", q.name, q.title);

    // 1. software baseline + profile, streamed through a single-worker
    //    session (the Session is the only run surface — run_corpus is a
    //    convenience wrapper over the same pipeline)
    let corpus = CorpusSpec::news(400, 2048).generate();
    let sw = Engine::compile_aql(&q.aql)?;
    let mut sw_session = sw.session().threads(1).queue_depth(2).start();
    sw_session.push_batch(corpus.docs.iter().cloned())?;
    let sw_report = sw_session.finish();
    let profile = sw.profile();
    println!(
        "software:     {:7.1} ms, {:6.2} MB/s, {} tuples, extraction {:.0}%",
        sw_report.wall.as_secs_f64() * 1e3,
        sw_report.throughput() / 1e6,
        sw_report.tuples,
        profile.fraction_extraction() * 100.0
    );

    // 2. accelerated run through the real PJRT path (falls back to the
    //    native engine when artifacts/ is missing)
    let engine_spec = if std::path::Path::new("artifacts/dfa_m8_s256_b16384.hlo.txt").exists() {
        EngineSpec::Pjrt {
            artifacts_dir: "artifacts".into(),
        }
    } else {
        eprintln!("NOTE: artifacts/ missing — using the native package engine");
        EngineSpec::Native
    };
    let hw = Engine::with_config(
        &q.aql,
        EngineConfig::accelerated(PartitionMode::MultiSubgraph, engine_spec),
    )?;
    let mut hw_session = hw.session().threads(4).queue_depth(8).start();
    hw_session.push_batch(corpus.docs.iter().cloned())?;
    let hw_report = hw_session.finish();
    println!(
        "accelerated:  {:7.1} ms, {:6.2} MB/s, {} tuples",
        hw_report.wall.as_secs_f64() * 1e3,
        hw_report.throughput() / 1e6,
        hw_report.tuples,
    );
    let snap = hw.accel_snapshot().unwrap();
    println!(
        "accel detail: {} packages, {:.1} docs/package, {} hit events, modeled FPGA {:.0} MB/s",
        snap.packages,
        snap.docs_per_package(),
        snap.hits,
        snap.modeled_throughput() / 1e6,
    );

    // 3. correctness: identical annotation counts
    assert_eq!(
        sw_report.tuples, hw_report.tuples,
        "accelerated path must produce identical annotations"
    );
    println!("correctness:  software and accelerated annotation sets agree ({} tuples)", sw_report.tuples);

    // 4. the headline estimate (paper Fig 7 / §5): Eq. 1 with the measured
    //    software baseline, the measured offload fraction, and the
    //    paper-calibrated FPGA model.
    let plan = partition(sw.graph(), PartitionMode::MultiSubgraph);
    let offloaded: Vec<usize> = plan
        .subgraphs
        .iter()
        .flat_map(|s| s.orig_nodes.iter().copied())
        .collect();
    let frac = profile.fraction_of_nodes(&offloaded);
    let model = FpgaModel::paper();
    let tp_sw = sw_report.throughput();
    for (label, size) in [("256 B", 256usize), ("2048 B", 2048)] {
        let est = model.estimate(tp_sw, frac, size, 16384, 1);
        println!(
            "Eq.1 estimate @ {label:>6}: {:6.1} MB/s  ({:.1}x over software)  [paper: T1 up to {}]",
            est / 1e6,
            est / tp_sw,
            if size == 2048 { "16x" } else { "10x" },
        );
    }
    hw.shutdown();
    Ok(())
}
