//! Quickstart: register AQL queries in a **catalog**, build one engine
//! that evaluates all of them in a single per-document pass, resolve
//! namespaced view handles, and stream documents through a `Session`.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use boost::prelude::*;

fn main() -> anyhow::Result<()> {
    // Two independent analyses over the same document stream: person-near-
    // organization extraction, and a simple date scan. In the paper's
    // deployment model they share one engine (and, when accelerated, one
    // device image) instead of running as two engines with two passes.
    let people_aql = r#"
        create dictionary Orgs as ('IBM', 'IBM Research', 'Columbia University');

        create view Person as
          extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name
          from Document d;

        create view Org as
          extract dictionary 'Orgs' on d.text as match from Document d;

        create view PersonOrg as
          select p.name as person, o.match as org,
                 CombineSpans(p.name, o.match) as ctx
          from Person p, Org o
          where FollowsTok(p.name, o.match, 0, 5)
          consolidate on ctx using 'ContainedWithin';

        output view PersonOrg;
    "#;
    let dates_aql = r#"
        create view DateIso as
          extract regex /\d{4}-\d{2}-\d{2}/ on d.text as day from Document d;
        output view DateIso;
    "#;

    // One builder, many programs: each registered name becomes the
    // namespace of that query's views ("people.PersonOrg"). The graphs
    // are merged over a shared DocScan, identical extraction patterns are
    // interned, and the optimizer runs once over the merged supergraph.
    let engine = Engine::builder()
        .register("people", people_aql)
        .register("dates", dates_aql)
        .build()?;
    println!("merged operator graph:\n{}", engine.graph().dump());

    // Resolve output views ONCE into typed handles via the query handles:
    // no stringly-typed lookups on the hot path, and the schema travels
    // with the handle.
    let people: QueryHandle = engine.query("people")?;
    let person_org: ViewHandle = people.view("PersonOrg")?;
    let date_iso: ViewHandle = engine.query("dates")?.view("DateIso")?;
    println!(
        "query {:?} outputs {:?}; view {:?} has columns {:?}",
        people.name(),
        people.view_names(),
        person_org.name(),
        person_org
            .schema()
            .fields
            .iter()
            .map(|f| f.name.as_str())
            .collect::<Vec<_>>()
    );

    // One-off, synchronous evaluation runs EVERY registered query:
    let doc = Document::new(0, "Laura Chiticariu works at IBM Research since 2014-06-30.");
    let result = engine.run_doc(&doc);
    println!(
        "sync run: {} PersonOrg rows, {} dates",
        result[&person_org].len(),
        result[&date_iso].len()
    );

    // The streaming path: a Session with a worker pool behind a bounded
    // queue. push() blocks when the pipeline is full (backpressure), and
    // every per-document result is delivered to the sink as it completes.
    let sink = Arc::new(CollectSink::default());
    let mut session = engine
        .session()
        .threads(2)
        .queue_depth(4)
        .sink(sink.clone())
        .start();

    let docs = [
        "Laura Chiticariu works at IBM Research in Almaden.",
        "Eva Sitaridi joined Columbia University on 2019-09-01; Peter Hofstee stayed at IBM.",
        "No entities here, just plain text.",
    ];
    for (i, text) in docs.iter().enumerate() {
        session.push(Document::new(i as u64, *text))?;
    }
    let report = session.finish();

    // workers race, so collected results arrive in completion order —
    // sort by document id for a stable printout
    let mut collected = sink.take();
    collected.sort_by_key(|(doc, _)| doc.id);
    for (doc, result) in collected {
        println!("doc {}: {:?}", doc.id, &*doc.text);
        for row in &result[&person_org] {
            let person = row[0].as_span().text(&doc.text);
            let org = row[1].as_span().text(&doc.text);
            println!("   person={person:?} org={org:?}");
        }
        for row in &result[&date_iso] {
            println!("   date={:?}", row[0].as_span().text(&doc.text));
        }
    }
    println!(
        "{} docs, {} tuples across both queries, {:.2} ms",
        report.docs,
        report.tuples,
        report.wall.as_secs_f64() * 1e3
    );
    Ok(())
}
