//! Quickstart: compile an AQL query, run it over a few documents, print
//! the annotations.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use boost::coordinator::Engine;
use boost::text::Document;

fn main() -> anyhow::Result<()> {
    // An information-extraction query in the AQL subset: find person
    // mentions near organization mentions.
    let aql = r#"
        create dictionary Orgs as ('IBM', 'IBM Research', 'Columbia University');

        create view Person as
          extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name
          from Document d;

        create view Org as
          extract dictionary 'Orgs' on d.text as match from Document d;

        create view PersonOrg as
          select p.name as person, o.match as org,
                 CombineSpans(p.name, o.match) as ctx
          from Person p, Org o
          where FollowsTok(p.name, o.match, 0, 5)
          consolidate on ctx using 'ContainedWithin';

        output view PersonOrg;
    "#;

    let engine = Engine::compile_aql(aql)?;
    println!("compiled operator graph:\n{}", engine.graph().dump());

    let docs = [
        "Laura Chiticariu works at IBM Research in Almaden.",
        "Eva Sitaridi joined Columbia University last fall; Peter Hofstee stayed at IBM.",
        "No entities here, just plain text.",
    ];
    for (i, text) in docs.iter().enumerate() {
        let doc = Document::new(i as u64, *text);
        let out = engine.run_doc(&doc);
        println!("doc {i}: {:?}", text);
        for row in &out.views["PersonOrg"] {
            let person = row[0].as_span().text(text);
            let org = row[1].as_span().text(text);
            println!("   person={person:?} org={org:?}");
        }
    }
    Ok(())
}
