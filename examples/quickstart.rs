//! Quickstart: compile an AQL query, resolve a typed view handle, and
//! stream documents through a `Session` — the push-based pipeline that
//! replaces one-shot corpus runs.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use boost::prelude::*;

fn main() -> anyhow::Result<()> {
    // An information-extraction query in the AQL subset: find person
    // mentions near organization mentions.
    let aql = r#"
        create dictionary Orgs as ('IBM', 'IBM Research', 'Columbia University');

        create view Person as
          extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name
          from Document d;

        create view Org as
          extract dictionary 'Orgs' on d.text as match from Document d;

        create view PersonOrg as
          select p.name as person, o.match as org,
                 CombineSpans(p.name, o.match) as ctx
          from Person p, Org o
          where FollowsTok(p.name, o.match, 0, 5)
          consolidate on ctx using 'ContainedWithin';

        output view PersonOrg;
    "#;

    let engine = Engine::compile_aql(aql)?;
    println!("compiled operator graph:\n{}", engine.graph().dump());

    // Resolve the output view ONCE into a typed handle: no stringly-typed
    // lookups on the hot path, and the schema travels with it.
    let person_org: ViewHandle = engine.view("PersonOrg")?;
    println!(
        "view {:?} has columns: {:?}",
        person_org.name(),
        person_org
            .schema()
            .fields
            .iter()
            .map(|f| f.name.as_str())
            .collect::<Vec<_>>()
    );

    // One-off, synchronous evaluation still works:
    let doc = Document::new(0, "Laura Chiticariu works at IBM Research in Almaden.");
    let result = engine.run_doc(&doc);
    println!("sync run: {} PersonOrg rows", result[&person_org].len());

    // The streaming path: a Session with a worker pool behind a bounded
    // queue. push() blocks when the pipeline is full (backpressure), and
    // every per-document result is delivered to the sink as it completes.
    let sink = Arc::new(CollectSink::default());
    let mut session = engine
        .session()
        .threads(2)
        .queue_depth(4)
        .sink(sink.clone())
        .start();

    let docs = [
        "Laura Chiticariu works at IBM Research in Almaden.",
        "Eva Sitaridi joined Columbia University last fall; Peter Hofstee stayed at IBM.",
        "No entities here, just plain text.",
    ];
    for (i, text) in docs.iter().enumerate() {
        session.push(Document::new(i as u64, *text))?;
    }
    let report = session.finish();

    // workers race, so collected results arrive in completion order —
    // sort by document id for a stable printout
    let mut collected = sink.take();
    collected.sort_by_key(|(doc, _)| doc.id);
    for (doc, result) in collected {
        println!("doc {}: {:?}", doc.id, &*doc.text);
        for row in &result[&person_org] {
            let person = row[0].as_span().text(&doc.text);
            let org = row[1].as_span().text(&doc.text);
            println!("   person={person:?} org={org:?}");
        }
    }
    println!(
        "{} docs, {} tuples, {:.2} ms",
        report.docs,
        report.tuples,
        report.wall.as_secs_f64() * 1e3
    );
    Ok(())
}
