//! Columnar-executor differential suite (ISSUE 4 tentpole).
//!
//! The columnar `TupleBatch` pipeline must be **byte-identical** — same
//! tuples, same values, same order, view by view — to the seed's
//! row-at-a-time `Vec<Tuple>` semantics, which survive behind
//! `ExecStrategy::LegacyRows` exactly for this comparison. Coverage:
//!
//! * every built-in query (T1–T5) × every `PartitionMode` (software
//!   subgraph runners on both sides, so the whole pipeline — supergraph
//!   and subgraph bodies — runs under one strategy) on a randomized
//!   corpus plus handcrafted edge documents;
//! * the merged T1–T5 catalog engine, columnar vs legacy, per query;
//! * with `--features bench-alloc`: the arena-recycling invariants —
//!   after warm-up, steady-state allocations/document on T1 is a small
//!   constant and ≥10× below the legacy pipeline's, and the
//!   **accelerated (sim) route allocates zero fresh column buffers per
//!   document** (ISSUE 5: batches crossing the worker ↔
//!   communication-thread boundary are routed back to their origin arena
//!   shard, with the per-shard cross-return counters proving the
//!   round-trip).
//!
//! The corpus seed is fixed (reproducible CI) but overridable through
//! `BOOST_DIFF_SEED`, like `differential.rs`.

use std::sync::Arc;

use boost::coordinator::{Engine, EngineConfig};
use boost::corpus::CorpusSpec;
use boost::exec::{ExecStrategy, Executor, Profiler};
use boost::partition::{partition, PartitionMode, SoftwareSubgraphRunner};
use boost::text::Document;
use boost::util::Prng;

fn seed() -> u64 {
    std::env::var("BOOST_DIFF_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC011_2026)
}

/// Randomized documents across all corpus flavours plus edge cases.
fn docs() -> Vec<Document> {
    let mut rng = Prng::new(seed());
    let mut texts: Vec<String> = Vec::new();
    for d in CorpusSpec::news(30, 512).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for d in CorpusSpec::tweets(15, 128).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for d in CorpusSpec::logs(10, 256).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for e in [
        "",
        " ",
        "IBM",
        "Laura Chiticariu works at IBM Research in Almaden.",
        "Call (408) 555-9876 or visit http://example.org/x on 2014-06-30.",
        "IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM",
    ] {
        texts.push(e.to_string());
    }
    texts
        .into_iter()
        .enumerate()
        .map(|(i, t)| Document::new(i as u64, t))
        .collect()
}

/// Build the full software pipeline (supergraph + software subgraph
/// runner) for one query/mode under one strategy.
fn pipeline(aql: &str, mode: PartitionMode, strategy: ExecStrategy) -> Executor {
    let g = boost::optimizer::optimize(&boost::aql::compile(aql).unwrap());
    let plan = partition(&g, mode);
    let mut ex = Executor::new(
        Arc::new(plan.supergraph.clone()),
        Arc::new(Profiler::disabled()),
    )
    .with_strategy(strategy);
    if !plan.subgraphs.is_empty() {
        ex = ex.with_subgraph_runner(Arc::new(SoftwareSubgraphRunner::with_strategy(
            &plan, strategy,
        )));
    }
    ex
}

#[test]
fn columnar_is_byte_identical_to_legacy_for_every_query_and_mode() {
    let docs = docs();
    for q in boost::queries::all() {
        for mode in [
            PartitionMode::None,
            PartitionMode::ExtractOnly,
            PartitionMode::SingleSubgraph,
            PartitionMode::MultiSubgraph,
        ] {
            let col = pipeline(&q.aql, mode, ExecStrategy::Columnar);
            let leg = pipeline(&q.aql, mode, ExecStrategy::LegacyRows);
            for d in &docs {
                let a = col.run_doc(d);
                let b = leg.run_doc(d);
                // views(): Vec<Tuple> per view, order-sensitive equality —
                // byte identical content AND order
                assert_eq!(
                    a.views(),
                    b.views(),
                    "query {} mode {:?} doc {} ({:?}) diverged",
                    q.name,
                    mode,
                    d.id,
                    d.text
                );
            }
        }
    }
}

#[test]
fn merged_catalog_columnar_matches_legacy_per_query() {
    let names = ["t1", "t2", "t3", "t4", "t5"];
    let build = |config: EngineConfig| -> Engine {
        let mut b = Engine::builder().config(config);
        for n in names {
            b = b.register_builtin(n);
        }
        b.build().unwrap()
    };
    let col = build(EngineConfig::default());
    let leg = build(EngineConfig::legacy_rows());
    for d in docs().iter().take(30) {
        let a = col.run_doc(d);
        let b = leg.run_doc(d);
        assert_eq!(a.views(), b.views(), "doc {} diverged", d.id);
        for n in names {
            let qa = col.query(n).unwrap();
            let qb = leg.query(n).unwrap();
            assert_eq!(
                qa.total_tuples(&a),
                qb.total_tuples(&b),
                "query {n} count diverged on doc {}",
                d.id
            );
        }
    }
}

#[test]
fn doc_result_round_trips_between_batches_and_rows() {
    let q = boost::queries::builtin("t1").unwrap();
    let engine = Engine::compile_aql(&q.aql).unwrap();
    let d = Document::new(0, "Laura Chiticariu works at IBM Research in Zurich.");
    let r = engine.run_doc(&d);
    // batch-side counts equal the materialized rows
    let batch_total: usize = r.batches().iter().map(|b| b.len()).sum();
    assert_eq!(batch_total, r.total_tuples());
    let row_total: usize = r.views().iter().map(|v| v.len()).sum();
    assert_eq!(batch_total, row_total);
    // per-view: batch to_tuples equals the lazily materialized rows
    for (batch, rows) in r.batches().iter().zip(r.views()) {
        assert_eq!(&batch.to_tuples(), rows);
    }
    // cloning preserves both layouts
    let c = r.clone();
    assert_eq!(c.views(), r.views());
}

/// The arena-recycling invariant, measured with the counting allocator:
/// once warmed up, the columnar pipeline serves a T1 document from
/// recycled buffers — a small constant number of allocations — while the
/// legacy row pipeline allocates per tuple per operator (≥10× more).
#[cfg(feature = "bench-alloc")]
#[test]
fn steady_state_allocations_per_doc_small_and_10x_below_legacy() {
    use boost::util::alloc;

    let q = boost::queries::builtin("t1").unwrap();
    let col = Engine::compile_aql(&q.aql).unwrap();
    let leg = Engine::with_config(&q.aql, EngineConfig::legacy_rows()).unwrap();
    let corpus = CorpusSpec::news(24, 2048).generate();

    // single-threaded run_doc loop through the shared measurement
    // protocol (same one `repro bench` reports): the arena lives on this
    // thread, so warm-up and measurement see the same pools. CI runs this
    // with --test-threads=1 because the counter is process-global.
    let allocs_per_doc = |engine: &Engine| -> f64 {
        alloc::allocations_per_unit(
            || {
                for d in &corpus.docs {
                    let _ = engine.run_doc(d);
                }
            },
            3,
            corpus.docs.len(),
        )
    };

    let legacy = allocs_per_doc(&leg);
    let columnar = allocs_per_doc(&col);
    assert!(
        columnar <= 256.0,
        "steady-state columnar allocations/doc must be a small constant, got {columnar:.0}"
    );
    assert!(
        legacy >= 10.0 * columnar,
        "expected ≥10x allocation reduction: legacy {legacy:.0}/doc vs columnar {columnar:.0}/doc"
    );
}

/// ISSUE 5 tentpole: the accelerated (sim) route must serve warm
/// documents with **zero fresh arena allocations**, matching the software
/// path. Session workers pin stable shards (worker `w` of every session
/// homes on the same shard) and the communication thread pins the
/// reserved comm shard, so buffers that cross the boundary — submission
/// ext streams dropped by the communication thread, reply batches
/// released by workers — are routed back to their origin shard and are
/// available to the next session's checkout. Runs under `bench-alloc`
/// with `--test-threads=1` in CI: the gauges are process-global, so a
/// concurrently allocating test would pollute the measured window.
#[cfg(feature = "bench-alloc")]
#[test]
fn accel_steady_state_zero_fresh_arena_allocs_and_returns_home() {
    let q = boost::queries::builtin("t1").unwrap();
    let engine = Engine::with_config(
        &q.aql,
        EngineConfig::simulated(PartitionMode::SingleSubgraph),
    )
    .unwrap();
    let corpus = CorpusSpec::news(24, 1024).generate();

    let run_session = |engine: &Engine| {
        let mut session = engine.session().threads(2).queue_depth(4).start();
        for d in corpus.docs.iter().cloned() {
            session.push(d).unwrap();
        }
        session.finish()
    };

    // warm-up: two full Session passes. Worker threads die with each
    // session, flushing their local caches to their (stable) shards, so
    // the measured session's checkouts prove the shards really do carry
    // the working set across restarts.
    run_session(&engine);
    run_session(&engine);

    let before = engine.arena_snapshot();
    let before_shards = engine.arena_shards();
    let report = run_session(&engine);
    let after = engine.arena_snapshot();
    let after_shards = engine.arena_shards();

    assert_eq!(report.docs, corpus.docs.len());
    assert!(
        engine.accel_snapshot().unwrap().packages > 0,
        "the accelerated route must actually have run"
    );
    assert!(after.checkouts > before.checkouts, "batches were built");
    assert_eq!(
        after.fresh, before.fresh,
        "accelerated route: warm documents must be served entirely from \
         recycled buffers — zero fresh column allocations per doc \
         (before {before:?}, after {after:?})"
    );
    assert!(
        after.returns_cross > before.returns_cross,
        "batches crossing the worker <-> communication-thread boundary \
         must be routed home, not absorbed by the receiving thread"
    );
    // the communication shard specifically must see its reply batches
    // come home from the workers that released them
    let comm = boost::exec::batch::ArenaId::comm().shard();
    assert!(
        after_shards[comm].returns_cross > before_shards[comm].returns_cross,
        "reply batches (comm-origin) must return to the comm shard: \
         before {:?}, after {:?}",
        before_shards[comm],
        after_shards[comm]
    );
    engine.shutdown();
}

/// Arena gauges: after warm-up, rebuilding the same shapes takes no fresh
/// buffer allocations from this thread's pools.
#[test]
fn arena_fresh_allocations_stop_growing_after_warmup() {
    let q = boost::queries::builtin("t1").unwrap();
    let engine = Engine::compile_aql(&q.aql).unwrap();
    let corpus = CorpusSpec::news(8, 1024).generate();
    for d in &corpus.docs {
        let _ = engine.run_doc(d); // warm this thread's arena
    }
    let before = boost::exec::batch::arena_stats();
    for d in &corpus.docs {
        let _ = engine.run_doc(d);
    }
    let after = boost::exec::batch::arena_stats();
    assert!(after.checkouts > before.checkouts, "batches were built");
    assert_eq!(
        after.fresh, before.fresh,
        "steady state must be served entirely from recycled buffers"
    );
}
