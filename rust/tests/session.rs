//! Integration tests for the streaming `Session` API: equivalence with
//! the corpus facade on every built-in query, the backpressure bound
//! (queue depth Q + T threads documents in flight, no more), sink
//! ordering/termination guarantees, and byte-identical per-document view
//! tuples between the streamed path and `Engine::run_doc`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use boost::coordinator::{
    CallbackSink, CollectSink, Engine, EngineConfig, ResultSink, RunReport,
};
use boost::corpus::CorpusSpec;
use boost::exec::DocResult;
use boost::partition::PartitionMode;
use boost::runtime::EngineSpec;
use boost::text::Document;

#[test]
fn session_matches_run_corpus_on_all_builtin_queries() {
    // T1–T5: pushing one document at a time through a session must count
    // exactly what the corpus facade (and per-document evaluation) counts.
    let corpus = CorpusSpec::news(12, 1024).generate();
    for q in boost::queries::all() {
        let engine = Engine::compile_aql(&q.aql).unwrap();
        let expected: usize = corpus
            .docs
            .iter()
            .map(|d| engine.run_doc(d).total_tuples())
            .sum();

        let corpus_report = engine.run_corpus(&corpus, 4);
        assert_eq!(corpus_report.tuples, expected, "{} run_corpus", q.name);

        let mut session = engine.session().threads(4).queue_depth(2).start();
        for d in &corpus.docs {
            session.push(d.clone()).unwrap();
        }
        let report = session.finish();
        assert_eq!(report.docs, corpus.docs.len(), "{}", q.name);
        assert_eq!(report.bytes, corpus.total_bytes(), "{}", q.name);
        assert_eq!(report.tuples, expected, "{} session", q.name);
    }
}

#[test]
fn streamed_t1_views_byte_identical_to_run_doc() {
    // Acceptance criterion: a streamed run of T1 over the news corpus
    // produces byte-identical view tuples to Engine::run_doc, document by
    // document.
    let q = boost::queries::builtin("t1").unwrap();
    let engine = Engine::compile_aql(&q.aql).unwrap();
    let corpus = CorpusSpec::news(20, 1024).generate();

    let sink = Arc::new(CollectSink::default());
    let mut session = engine
        .session()
        .threads(4)
        .queue_depth(4)
        .sink(sink.clone())
        .start();
    for d in &corpus.docs {
        session.push(d.clone()).unwrap();
    }
    session.finish();

    let collected = sink.take();
    assert_eq!(collected.len(), corpus.docs.len());
    let by_id: HashMap<u64, DocResult> = collected
        .into_iter()
        .map(|(doc, result)| (doc.id, result))
        .collect();
    for d in &corpus.docs {
        let streamed = &by_id[&d.id];
        let sync = engine.run_doc(d);
        // same views in the same order, tuple-for-tuple (Value equality
        // covers spans byte-for-byte)
        assert_eq!(streamed.views(), sync.views(), "doc {}", d.id);
        for (h, rows) in sync.iter() {
            assert_eq!(&streamed[h], rows, "doc {} view {}", d.id, h.name());
        }
    }
}

/// Sink that tracks concurrent `on_result` calls and (doc id, order).
#[derive(Default)]
struct ProbeSink {
    order: Mutex<Vec<u64>>,
    finishes: AtomicUsize,
    finish_saw_all: AtomicBool,
    finish_report_docs: AtomicUsize,
}

impl ResultSink for ProbeSink {
    fn on_result(&self, doc: &Document, result: &DocResult) {
        assert_eq!(doc.id, result.doc_id());
        self.order.lock().unwrap().push(doc.id);
    }

    fn on_finish(&self, report: &RunReport) {
        // on_finish must run exactly once, after every on_result
        self.finishes.fetch_add(1, Ordering::SeqCst);
        self.finish_saw_all.store(
            self.order.lock().unwrap().len() == report.docs,
            Ordering::SeqCst,
        );
        self.finish_report_docs.store(report.docs, Ordering::SeqCst);
    }
}

#[test]
fn callback_order_is_push_order_with_one_worker() {
    let q = boost::queries::builtin("t1").unwrap();
    let engine = Engine::compile_aql(&q.aql).unwrap();
    let sink = Arc::new(ProbeSink::default());
    let mut session = engine
        .session()
        .threads(1)
        .queue_depth(3)
        .sink(sink.clone())
        .start();
    let corpus = CorpusSpec::news(24, 256).generate();
    for d in &corpus.docs {
        session.push(d.clone()).unwrap();
    }
    let report = session.finish();
    assert_eq!(report.docs, 24);
    let order = sink.order.lock().unwrap().clone();
    assert_eq!(
        order,
        (0..24u64).collect::<Vec<_>>(),
        "single-worker sessions must deliver results in push order"
    );
    assert_eq!(sink.finishes.load(Ordering::SeqCst), 1);
    assert!(sink.finish_saw_all.load(Ordering::SeqCst));
    assert_eq!(sink.finish_report_docs.load(Ordering::SeqCst), 24);
}

#[test]
fn sink_sees_each_doc_once_and_terminates_with_many_workers() {
    let q = boost::queries::builtin("t3").unwrap();
    let engine = Engine::compile_aql(&q.aql).unwrap();
    let sink = Arc::new(ProbeSink::default());
    let mut session = engine
        .session()
        .threads(4)
        .queue_depth(2)
        .sink(sink.clone())
        .start();
    let corpus = CorpusSpec::tweets(50, 128).generate();
    for d in &corpus.docs {
        session.push(d.clone()).unwrap();
    }
    session.finish();
    let mut order = sink.order.lock().unwrap().clone();
    order.sort_unstable();
    assert_eq!(order, (0..50u64).collect::<Vec<_>>(), "each doc exactly once");
    assert_eq!(sink.finishes.load(Ordering::SeqCst), 1);
    assert!(sink.finish_saw_all.load(Ordering::SeqCst));
}

#[test]
fn backpressure_bounds_in_flight_to_queue_plus_threads() {
    // Acceptance criterion: with queue depth Q and T worker threads, at
    // most Q + T documents are in flight. A slow sink forces the producer
    // against the bound.
    const Q: usize = 1;
    const T: usize = 4;
    const DOCS: usize = 64;

    let q = boost::queries::builtin("t1").unwrap();
    let engine = Engine::compile_aql(&q.aql).unwrap();
    // track processing concurrency independently of the session's counter
    let concurrent = Arc::new(AtomicI64::new(0));
    let max_concurrent = Arc::new(AtomicI64::new(0));
    let (c, m) = (concurrent.clone(), max_concurrent.clone());
    let slow_sink = Arc::new(CallbackSink::new(move |_doc: &Document, _r: &DocResult| {
        let now = c.fetch_add(1, Ordering::SeqCst) + 1;
        m.fetch_max(now, Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(2));
        c.fetch_sub(1, Ordering::SeqCst);
    }));
    let mut session = engine
        .session()
        .threads(T)
        .queue_depth(Q)
        .sink(slow_sink)
        .start();
    let corpus = CorpusSpec::news(DOCS, 256).generate();
    let mut max_seen = 0;
    for d in &corpus.docs {
        session.push(d.clone()).unwrap();
        max_seen = max_seen.max(session.in_flight());
    }
    // the producer outran the slow workers, so the queue must have filled
    let queue = session.queue_snapshot();
    let high_water = session.max_in_flight();
    let report = session.finish();

    assert_eq!(report.docs, DOCS);
    assert!(
        high_water <= Q + T,
        "in-flight high water {high_water} exceeds queue({Q}) + threads({T})"
    );
    assert!(max_seen <= Q + T, "observed in-flight {max_seen} over bound");
    assert!(
        high_water > Q,
        "with a saturated producer the pipeline should fill past the queue \
         (high water {high_water} <= {Q})"
    );
    assert!(
        queue.stalls > 0,
        "queue depth {Q} with a slow sink must stall the producer at least once"
    );
    assert!(
        max_concurrent.load(Ordering::SeqCst) <= T as i64,
        "no more than {T} documents may be processed concurrently"
    );
}

#[test]
fn accelerated_session_equals_software_session() {
    // HW and SW paths share the bounded-queue scheduler: a session over a
    // partitioned engine (native package engine) must count exactly what
    // the pure-software session counts.
    let q = boost::queries::builtin("t1").unwrap();
    let corpus = CorpusSpec::news(16, 512).generate();
    let sw = Engine::compile_aql(&q.aql).unwrap();
    let hw = Engine::with_config(
        &q.aql,
        EngineConfig::accelerated(PartitionMode::SingleSubgraph, EngineSpec::Native),
    )
    .unwrap();

    let run = |engine: &Engine| {
        let mut session = engine.session().threads(4).queue_depth(2).start();
        for d in &corpus.docs {
            session.push(d.clone()).unwrap();
        }
        session.finish()
    };
    let a = run(&sw);
    let b = run(&hw);
    assert_eq!(a.tuples, b.tuples);
    assert_eq!(b.docs, corpus.docs.len());
    let snap = hw.accel_snapshot().unwrap();
    assert!(snap.packages > 0, "accelerator must have been exercised");
    // the submission queue's gauges come from the same machinery
    let accel_queue = hw.accel_queue_snapshot().unwrap();
    assert_eq!(accel_queue.pushed, snap.docs);
    hw.shutdown();
}

#[test]
fn push_batch_and_counters() {
    let q = boost::queries::builtin("t2").unwrap();
    let engine = Engine::compile_aql(&q.aql).unwrap();
    let corpus = CorpusSpec::logs(30, 256).generate();
    let mut session = engine.session().threads(2).queue_depth(4).start();
    let n = session.push_batch(corpus.docs.iter().cloned()).unwrap();
    assert_eq!(n, 30);
    assert_eq!(session.pushed(), 30);
    let report = session.finish();
    assert_eq!(report.docs, 30);
    assert_eq!(report.threads, 2);
    assert!(report.wall > Duration::ZERO);
}
