//! Multi-query catalog differential tests (ISSUE 3 tentpole).
//!
//! The acceptance bar: registering T1–T5 in ONE catalog engine must be
//! observationally identical to running five independently compiled
//! engines — byte-identical per-query views, document by document, across
//! every `PartitionMode` — while sharing one partition plan, one
//! accelerator artifact set, and interned extraction leaves.
//!
//! The corpus seed is fixed (reproducible CI) but overridable through
//! `BOOST_DIFF_SEED`, like `differential.rs`.

use boost::aog::Value;
use boost::coordinator::{CollectSink, Engine, EngineConfig, QueryHandle};
use boost::corpus::CorpusSpec;
use boost::exec::DocResult;
use boost::partition::PartitionMode;
use boost::text::Document;

const QUERIES: [&str; 5] = ["t1", "t2", "t3", "t4", "t5"];

fn seed() -> u64 {
    std::env::var("BOOST_DIFF_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xCA7A_1063)
}

/// Randomized corpus across all three flavours plus handcrafted edge
/// documents that light up every query's extractors.
fn corpus() -> Vec<Document> {
    let mut texts: Vec<String> = Vec::new();
    for d in CorpusSpec::news(20, 512).with_seed(seed()).generate().docs {
        texts.push(d.text.to_string());
    }
    for d in CorpusSpec::tweets(10, 160)
        .with_seed(seed() ^ 1)
        .generate()
        .docs
    {
        texts.push(d.text.to_string());
    }
    for d in CorpusSpec::logs(8, 320).with_seed(seed() ^ 2).generate().docs {
        texts.push(d.text.to_string());
    }
    for e in [
        "",
        " ",
        "IBM",
        "Laura Chiticariu works at IBM Research in Zurich. Call (408) 555-9876 \
         or mail a.b@c.org; see http://example.org/x on 2014-06-30.",
        "Acme Corp announced a $3.50 million deal (NYSE) on 2020-01-02. \
         Acme Corp was amazing, said Peter Hofstee.",
        "IBM IBM IBM Research IBM IBM Research IBM",
    ] {
        texts.push(e.to_string());
    }
    texts
        .into_iter()
        .enumerate()
        .map(|(i, t)| Document::new(i as u64, t))
        .collect()
}

/// Byte-exact rendering of ONE query's views for one document, with
/// namespace-stripped view names so merged-catalog and single-engine
/// renderings are directly comparable. Lines sorted (insensitive to tuple
/// order within a view, byte-exact in content).
fn render_query(doc: &Document, qh: &QueryHandle, result: &DocResult) -> String {
    let names = qh.view_names();
    let mut lines: Vec<String> = Vec::new();
    for ((_, rows), name) in qh.iter(result).zip(names) {
        for t in rows {
            let mut line = format!("{}|{}|", doc.id, name);
            for v in t {
                match v {
                    Value::Span(s) => {
                        line.push_str(&format!("[{},{})={:?};", s.begin, s.end, s.text(&doc.text)))
                    }
                    other => line.push_str(&format!("{other};")),
                }
            }
            lines.push(line);
        }
    }
    lines.sort();
    lines.join("\n")
}

fn config_for(mode: PartitionMode) -> EngineConfig {
    if mode == PartitionMode::None {
        EngineConfig::default()
    } else {
        EngineConfig::simulated(mode)
    }
}

fn merged_engine(mode: PartitionMode) -> Engine {
    let mut b = Engine::builder().config(config_for(mode));
    for q in QUERIES {
        b = b.register_builtin(q);
    }
    b.build().expect("catalog compiles under every mode")
}

#[test]
fn merged_catalog_matches_independent_engines_across_modes() {
    let docs = corpus();
    for mode in [
        PartitionMode::None,
        PartitionMode::ExtractOnly,
        PartitionMode::SingleSubgraph,
        PartitionMode::MultiSubgraph,
    ] {
        let merged = merged_engine(mode);
        let singles: Vec<Engine> = QUERIES
            .iter()
            .map(|q| {
                Engine::with_config(
                    &boost::queries::builtin(q).unwrap().aql,
                    config_for(mode),
                )
                .unwrap()
            })
            .collect();
        // schemas must survive the merge too: interned leaves must not
        // leak one query's column names into another's views
        for (q, single) in QUERIES.iter().zip(&singles) {
            let qh = merged.query(q).unwrap();
            for (mh, sh) in qh.views().iter().zip(single.views()) {
                assert_eq!(
                    mh.schema(),
                    sh.schema(),
                    "mode {:?}: schema of {q}.{} diverged from the single engine",
                    mode,
                    sh.name()
                );
            }
        }
        for doc in &docs {
            let merged_result = merged.run_doc(doc);
            for (q, single) in QUERIES.iter().zip(&singles) {
                let qh = merged.query(q).unwrap();
                let sh = single.query("default").unwrap();
                let single_result = single.run_doc(doc);
                assert_eq!(
                    render_query(doc, &qh, &merged_result),
                    render_query(doc, &sh, &single_result),
                    "mode {:?}, query {q}, doc {} diverged between the merged \
                     catalog and an independent engine",
                    mode,
                    doc.id
                );
            }
        }
        merged.shutdown();
        for s in singles {
            s.shutdown();
        }
    }
}

#[test]
fn merged_catalog_has_one_plan_one_artifact_set_and_interned_leaves() {
    let merged = merged_engine(PartitionMode::ExtractOnly);

    // ONE partition plan; extract-only folds every deduplicated leaf into
    // ONE hardware subgraph — the paper's single shared FPGA image
    let plan = merged.plan().expect("accelerated catalog has a plan");
    assert_eq!(
        plan.subgraphs.len(),
        1,
        "extract-only must produce a single shared subgraph"
    );

    // interning: merged leaf count < sum of per-query leaf counts
    let merged_leaves = merged.graph().extraction_leaves();
    let single_sum: usize = QUERIES
        .iter()
        .map(|q| {
            Engine::compile_aql(&boost::queries::builtin(q).unwrap().aql)
                .unwrap()
                .graph()
                .extraction_leaves()
        })
        .sum();
    assert!(
        merged_leaves < single_sum,
        "no leaf interning: merged {merged_leaves} vs per-query sum {single_sum}"
    );
    // the shared subgraph carries exactly the merged (deduplicated) leaves
    assert_eq!(plan.subgraphs[0].body.extraction_leaves(), merged_leaves);

    // ONE artifact set, versus one per engine when compiled independently
    let merged_artifacts = merged.artifact_keys().len();
    assert!(merged_artifacts > 0);
    let independent_artifacts: usize = QUERIES
        .iter()
        .map(|q| {
            let e = Engine::with_config(
                &boost::queries::builtin(q).unwrap().aql,
                EngineConfig::simulated(PartitionMode::ExtractOnly),
            )
            .unwrap();
            let n = e.artifact_keys().len();
            e.shutdown();
            n
        })
        .sum();
    assert!(
        merged_artifacts < independent_artifacts,
        "catalog must not multiply artifact sets: merged {merged_artifacts} \
         vs {independent_artifacts} across five engines"
    );
    merged.shutdown();
}

#[test]
fn merged_catalog_session_matches_run_doc() {
    use std::sync::Arc;

    let merged = merged_engine(PartitionMode::ExtractOnly);
    let docs: Vec<Document> = corpus().into_iter().take(20).collect();
    let sink = Arc::new(CollectSink::default());
    let mut session = merged
        .session()
        .threads(4)
        .queue_depth(4)
        .sink(sink.clone())
        .start();
    session.push_batch(docs.iter().cloned()).unwrap();
    session.finish();
    let collected = sink.take();
    assert_eq!(collected.len(), docs.len());
    for (doc, streamed) in collected {
        let sync = merged.run_doc(&doc);
        for q in QUERIES {
            let qh = merged.query(q).unwrap();
            assert_eq!(
                render_query(&doc, &qh, &streamed),
                render_query(&doc, &qh, &sync),
                "query {q}, doc {}: streamed result diverged from run_doc",
                doc.id
            );
        }
    }
    merged.shutdown();
}
