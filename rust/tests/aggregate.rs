//! Oracle-differential and merge-property suite for corpus-level
//! aggregation (ISSUE 10 satellites).
//!
//! The tentpole claim under test: T6 (top-k entities) and T7
//! (per-dictionary document frequency) produce the SAME corpus tables no
//! matter how the corpus is executed — partition mode, software executor
//! strategy (columnar vs legacy rows), worker count, document arrival
//! order, and partial sharding must all be invisible in the final
//! `RunReport::corpus`.
//!
//! Two attack angles:
//!
//!   1. An **independent oracle**: the aggregation clauses are stripped
//!      from the builtin AQL, the raw pre-aggregation rows are collected
//!      per document on a single thread, and the corpus tables are
//!      recomputed with a plain `HashMap` and a full sort — no
//!      `AggPartial`, no merge, no bounded selection. Every engine
//!      configuration must render byte-identically to that reference.
//!   2. **Property tests** over `AggPartial` and `top_k` directly: merge
//!      is associative and commutative, sharding and permutation cannot
//!      change `finish()`, and top-k tie-breaking (score descending, then
//!      row cells ascending by bytes) is a total order, so any input
//!      permutation selects the same rows in the same order.
//!
//! The corpus seed is fixed (reproducible CI) but overridable through the
//! `BOOST_DIFF_SEED` environment variable for fuzzing sessions.

use std::collections::{HashMap, HashSet};

use boost::aog::{AggCol, EvalCtx, Expr, Field, FieldType, Schema, Tuple, Value};
use boost::coordinator::{Engine, EngineConfig};
use boost::corpus::CorpusSpec;
use boost::exec::{top_k, AggPartial, CorpusResult, ExecStrategy, TupleBatch};
use boost::partition::PartitionMode;
use boost::text::{Document, Tokenizer};
use boost::util::{prop, Prng};

/// Fixed default seed; override with BOOST_DIFF_SEED=<u64> to fuzz.
fn seed() -> u64 {
    std::env::var("BOOST_DIFF_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1FF_2026)
}

/// Randomized mixed-flavour corpus plus handcrafted edge documents —
/// empty text, single entities, dense repetition, and deliberate
/// cross-document count ties (the top-k tie-break path).
fn mixed_docs() -> Vec<Document> {
    let mut rng = Prng::new(seed() ^ 0xA66);
    let mut texts: Vec<String> = Vec::new();
    for d in CorpusSpec::news(120, 256).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for d in CorpusSpec::tweets(60, 128).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for d in CorpusSpec::logs(30, 320).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for e in [
        "",
        " ",
        "IBM",
        "Zed Aaa Zed Aaa", // tied mention counts, resolved by term bytes
        "Aaa Zed",
        "IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM",
    ] {
        texts.push(e.to_string());
    }
    texts
        .into_iter()
        .enumerate()
        .map(|(i, t)| Document::new(i as u64, t))
        .collect()
}

/// The builtin's AQL with everything from `marker` on replaced by a plain
/// projection of the pre-aggregation rows — same dictionaries, same
/// extraction and union views, NO group/top clauses.
fn pre_aggregation_aql(builtin: &str, marker: &str, tail: &str) -> String {
    let q = boost::queries::builtin(builtin).unwrap();
    let cut = q
        .aql
        .find(marker)
        .unwrap_or_else(|| panic!("{builtin} AQL no longer contains {marker:?}"));
    format!("{}{tail}", &q.aql[..cut])
}

/// Naive T6 reference: term -> (mentions, documents) via plain maps, then
/// a FULL sort by (count desc, term bytes asc) truncated to k — the
/// quadratic-memory formulation the bounded `top_k` must reproduce.
fn naive_t6(docs: &[Document], k: usize) -> Vec<(String, i64, i64)> {
    let oracle = pre_aggregation_aql(
        "t6",
        "create view TopEntities",
        "create view Terms as select GetText(m.span) as term from Mention m;\noutput view Terms;",
    );
    let engine = Engine::compile_aql(&oracle).unwrap();
    let mut counts: HashMap<String, (i64, i64)> = HashMap::new();
    for d in docs {
        let result = engine.run_doc(d);
        let mut seen: HashSet<String> = HashSet::new();
        for (h, rows) in result.iter() {
            if h.name() != "Terms" {
                continue;
            }
            for t in rows {
                let term = match &t[0] {
                    Value::Str(s) => s.to_string(),
                    other => panic!("non-text term {other:?}"),
                };
                let e = counts.entry(term.clone()).or_default();
                e.0 += 1;
                if seen.insert(term) {
                    e.1 += 1;
                }
            }
        }
    }
    let mut rows: Vec<(String, i64, i64)> = counts
        .into_iter()
        .map(|(term, (n, docs))| (term, n, docs))
        .collect();
    rows.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    rows.truncate(k);
    rows
}

/// Naive T7 reference: dictionary tag -> (hits, documents), groups sorted
/// by key ascending (the `GroupAgg` finish order).
fn naive_t7(docs: &[Document]) -> Vec<(String, i64, i64)> {
    let oracle = pre_aggregation_aql(
        "t7",
        "create view DictDocFreq",
        "create view Tags as select t.dict as dict from Tagged t;\noutput view Tags;",
    );
    let engine = Engine::compile_aql(&oracle).unwrap();
    let mut counts: HashMap<String, (i64, i64)> = HashMap::new();
    for d in docs {
        let result = engine.run_doc(d);
        let mut seen: HashSet<String> = HashSet::new();
        for (h, rows) in result.iter() {
            if h.name() != "Tags" {
                continue;
            }
            for t in rows {
                let dict = match &t[0] {
                    Value::Str(s) => s.to_string(),
                    other => panic!("non-text dict tag {other:?}"),
                };
                let e = counts.entry(dict.clone()).or_default();
                e.0 += 1;
                if seen.insert(dict) {
                    e.1 += 1;
                }
            }
        }
    }
    let mut rows: Vec<(String, i64, i64)> = counts
        .into_iter()
        .map(|(dict, (n, docs))| (dict, n, docs))
        .collect();
    rows.sort_by(|a, b| a.0.cmp(&b.0));
    rows
}

/// Byte-exact rendering of the corpus tables. Row ORDER within a table is
/// part of the contract (top-k ranking, group-key sort), so each line
/// carries its row index; tables themselves sort by view name.
fn render_tables(tables: &[CorpusResult]) -> String {
    let mut lines: Vec<String> = Vec::new();
    for t in tables {
        for (i, row) in t.rows.iter().enumerate() {
            let mut line = format!("{}|{i:04}|", t.view);
            for v in row {
                line.push_str(&format!("{v};"));
            }
            lines.push(line);
        }
    }
    lines.sort();
    lines.join("\n")
}

/// The oracle's tables rendered in the same shape `render_tables`
/// produces — T6 rows carry the trailing score column (= the count).
fn render_oracle(t6: &[(String, i64, i64)], t7: &[(String, i64, i64)]) -> String {
    let mut lines: Vec<String> = Vec::new();
    for (i, (term, n, docs)) in t6.iter().enumerate() {
        lines.push(format!("t6.TopEntities|{i:04}|{term:?};{n};{docs};{n};"));
    }
    for (i, (dict, n, docs)) in t7.iter().enumerate() {
        lines.push(format!("t7.DictDocFreq|{i:04}|{dict:?};{n};{docs};"));
    }
    lines.sort();
    lines.join("\n")
}

#[test]
fn t6_t7_match_the_naive_oracle_across_modes_strategies_and_workers() {
    let docs = mixed_docs();
    assert!(docs.len() >= 200, "acceptance floor: {} docs", docs.len());

    let expected = render_oracle(&naive_t6(&docs, 10), &naive_t7(&docs));
    assert!(
        expected.contains("t6.TopEntities") && expected.contains("t7.DictDocFreq"),
        "the oracle must have found mentions in the generated corpus:\n{expected}"
    );

    for mode in [
        PartitionMode::None,
        PartitionMode::ExtractOnly,
        PartitionMode::SingleSubgraph,
        PartitionMode::MultiSubgraph,
    ] {
        for strategy in [ExecStrategy::Columnar, ExecStrategy::LegacyRows] {
            let mut cfg = if matches!(mode, PartitionMode::None) {
                EngineConfig::default()
            } else {
                EngineConfig::simulated(mode)
            };
            cfg.strategy = strategy;
            let engine = Engine::builder()
                .register_builtin("t6")
                .register_builtin("t7")
                .config(cfg)
                .build()
                .unwrap();
            for threads in [1usize, 4, 8] {
                let mut session = engine
                    .session()
                    .threads(threads)
                    .queue_depth(2 * threads)
                    .start();
                for d in &docs {
                    session.push(d.clone()).unwrap();
                }
                let report = session.finish();
                assert_eq!(report.docs, docs.len(), "mode {mode:?} lost documents");
                assert_eq!(
                    render_tables(&report.corpus),
                    expected,
                    "corpus tables diverged from the naive oracle: \
                     mode {mode:?}, strategy {strategy:?}, {threads} workers"
                );
            }
            if !matches!(mode, PartitionMode::None) {
                engine.shutdown();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// AggPartial / top_k property tests
// ---------------------------------------------------------------------------

/// The T6-shaped aggregate spec: one text key, Count, CountDocs.
fn agg_spec() -> (Vec<(String, AggCol)>, Schema) {
    let cols = vec![
        ("term".to_string(), AggCol::Key(0)),
        ("n".to_string(), AggCol::Count),
        ("docs".to_string(), AggCol::CountDocs),
    ];
    let schema = Schema::of(&[
        ("term", FieldType::Str),
        ("n", FieldType::Int),
        ("docs", FieldType::Int),
    ]);
    (cols, schema)
}

fn term_rows(terms: &[String]) -> Vec<Tuple> {
    terms
        .iter()
        .map(|t| vec![Value::Str(t.as_str().into())])
        .collect()
}

/// Documents of terms over a 3-letter alphabet with length 1–2 — small on
/// purpose, so cross-document collisions (shared groups, tied counts) are
/// the common case, not the rare one.
fn gen_term_docs(r: &mut Prng) -> Vec<Vec<String>> {
    let n = r.below(7);
    (0..n)
        .map(|_| {
            let m = r.below(10);
            (0..m)
                .map(|_| {
                    let len = r.range(1, 3);
                    r.string_over(b"abc", len)
                })
                .collect()
        })
        .collect()
}

#[test]
fn merge_is_associative_commutative_and_sharding_invariant() {
    let (cols, schema) = agg_spec();
    prop::check(seed() ^ 0x4A66, 128, gen_term_docs, |docs| {
        // reference: absorb everything, in order, into one partial
        let mut all = AggPartial::new(&cols, &schema);
        for d in docs {
            all.absorb_doc(&term_rows(d));
        }
        let want = all.finish().to_tuples();

        // document-permutation invariance
        let mut rev = AggPartial::new(&cols, &schema);
        for d in docs.iter().rev() {
            rev.absorb_doc(&term_rows(d));
        }
        if rev.finish().to_tuples() != want {
            return false;
        }

        // sharding invariance: round-robin over every worker count the
        // session suite uses, merged forward and backward (commutativity)
        for shards in [1usize, 2, 3, 4, 8] {
            let mut parts: Vec<AggPartial> =
                (0..shards).map(|_| AggPartial::new(&cols, &schema)).collect();
            for (i, d) in docs.iter().enumerate() {
                parts[i % shards].absorb_doc(&term_rows(d));
            }
            let mut fwd = AggPartial::new(&cols, &schema);
            for p in &parts {
                fwd.merge(p);
            }
            let mut bwd = AggPartial::new(&cols, &schema);
            for p in parts.iter().rev() {
                bwd.merge(p);
            }
            if fwd.finish().to_tuples() != want || bwd.finish().to_tuples() != want {
                return false;
            }
        }

        // associativity on a 3-way split: (a·b)·c == a·(b·c)
        let third = docs.len() / 3;
        let mut abc = [
            AggPartial::new(&cols, &schema),
            AggPartial::new(&cols, &schema),
            AggPartial::new(&cols, &schema),
        ];
        for (i, d) in docs.iter().enumerate() {
            let slot = if i < third {
                0
            } else if i < 2 * third {
                1
            } else {
                2
            };
            abc[slot].absorb_doc(&term_rows(d));
        }
        let [a, b, c] = abc;
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        left.finish().to_tuples() == want && right.finish().to_tuples() == want
    });
}

#[test]
fn top_k_is_permutation_invariant_with_a_total_tie_order() {
    let (cols, schema) = agg_spec();
    let mut out_schema = schema.clone();
    out_schema.fields.push(Field {
        name: "score".into(),
        ty: FieldType::Int,
    });
    let tokens = Tokenizer::standard().tokenize("");
    let ctx = EvalCtx {
        text: "",
        tokens: &tokens,
    };
    let as_int = |v: &Value| -> i64 {
        match v {
            Value::Int(n) => *n,
            other => panic!("non-integer score {other:?}"),
        }
    };
    let as_str = |v: &Value| -> String {
        match v {
            Value::Str(s) => s.to_string(),
            other => panic!("non-text term {other:?}"),
        }
    };
    prop::check(seed() ^ 0x70_B1, 128, gen_term_docs, |docs| {
        let mut p = AggPartial::new(&cols, &schema);
        for d in docs {
            p.absorb_doc(&term_rows(d));
        }
        let agg = p.finish();
        let tuples = agg.to_tuples();
        for k in [1usize, 2, 3, 10] {
            let fwd = top_k(&agg, k, &Expr::Col(1), &out_schema, &ctx).to_tuples();
            if fwd.len() != k.min(tuples.len()) {
                return false;
            }
            // reversed AND rotated input orders select identical rows in
            // an identical order — the tie-break is a total order, so
            // arrival order has nothing left to decide
            for split in [tuples.len(), tuples.len() / 2, 1] {
                let mut permuted: Vec<Tuple> = tuples[split.min(tuples.len())..].to_vec();
                permuted.extend_from_slice(&tuples[..split.min(tuples.len())]);
                permuted.reverse();
                let got = top_k(
                    &TupleBatch::from_rows(&schema, &permuted),
                    k,
                    &Expr::Col(1),
                    &out_schema,
                    &ctx,
                )
                .to_tuples();
                if got != fwd {
                    return false;
                }
            }
            // ranking invariant: scores non-increasing, ties strictly
            // ascending by term bytes (no duplicate groups can exist)
            for w in fwd.windows(2) {
                let (s0, s1) = (as_int(&w[0][3]), as_int(&w[1][3]));
                if s0 < s1 {
                    return false;
                }
                if s0 == s1 && as_str(&w[0][0]) >= as_str(&w[1][0]) {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn count_docs_is_a_document_frequency_not_a_row_count() {
    // direct unit-shaped check through the public API: three documents,
    // one term repeated within a document — Count sees 5 mentions,
    // CountDocs sees 2 documents
    let (cols, schema) = agg_spec();
    let mut p = AggPartial::new(&cols, &schema);
    p.absorb_doc(&term_rows(&["ibm".into(), "ibm".into(), "ibm".into(), "acme".into()]));
    p.absorb_doc(&term_rows(&[]));
    p.absorb_doc(&term_rows(&["ibm".into(), "ibm".into()]));
    let rows = p.finish().to_tuples();
    assert_eq!(rows.len(), 2);
    assert_eq!(
        rows[1],
        vec![Value::Str("ibm".into()), Value::Int(5), Value::Int(2)]
    );
}
