//! Integration: the full AOT bridge — python-lowered HLO artifacts loaded
//! and executed through PJRT, differentially tested against the pure-Rust
//! engine and against the software matchers.
//!
//! Requires the `pjrt` cargo feature (the whole file is compiled out
//! otherwise) and `artifacts/` (run `make artifacts` first). Tests are
//! skipped gracefully if the directory is missing so `cargo test` works in
//! a fresh checkout, but CI/Make always builds artifacts first.

#![cfg(feature = "pjrt")]

use std::sync::Arc;

use boost::accel::{AccelOptions, AccelService, AccelSubgraphRunner};
use boost::exec::{Executor, Profiler};
use boost::hwcompiler::{compile_subgraph, AccelConfig, STREAMS};
use boost::partition::{partition, PartitionMode};
use boost::runtime::{
    EngineSpec, NativePackageEngine, PackageEngine, PackedPackage, PjrtPackageEngine,
};
use boost::text::Document;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let d = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("dfa_m4_s64_b4096.hlo.txt").exists() {
        Some(d)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

const QUERY: &str = r#"
    create dictionary Orgs as ('IBM', 'IBM Research', 'Columbia University');
    create view Org as
      extract dictionary 'Orgs' on d.text as match from Document d;
    create view Person as
      extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name from Document d;
    create view PersonOrg as
      select p.name as person, o.match as org,
             CombineSpans(p.name, o.match) as ctx
      from Person p, Org o
      where FollowsTok(p.name, o.match, 0, 4)
      consolidate on ctx using 'ContainedWithin';
    output view PersonOrg;
"#;

fn config() -> AccelConfig {
    let g = boost::optimizer::optimize(&boost::aql::compile(QUERY).unwrap());
    let plan = partition(&g, PartitionMode::SingleSubgraph);
    compile_subgraph(&plan.subgraphs[0]).unwrap()
}

#[test]
fn pjrt_equals_native_engine_on_packages() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = config();
    let (tables, accepts) = cfg.pack_tables();
    let pjrt = PjrtPackageEngine::new(&dir).expect("pjrt client");
    let native = NativePackageEngine;

    let texts = [
        "Laura Chiticariu works at IBM Research in Almaden.",
        "Eva Sitaridi is at Columbia University; Peter Hofstee visits IBM.",
        "nothing relevant here at all",
        "",
    ];
    let block = 4096usize;
    let mut bytes = vec![0i32; STREAMS * block];
    for (s, t) in texts.iter().enumerate() {
        for (i, b) in t.bytes().enumerate() {
            bytes[s * block + i] = b as i32;
        }
    }
    let pkg = PackedPackage {
        bytes,
        block,
        tables: std::sync::Arc::new(tables),
        accepts: std::sync::Arc::new(accepts),
        machines: cfg.geometry.0,
        states: cfg.geometry.1,
    };
    let key = cfg.artifact_key(block);
    let a = pjrt.run(key, &pkg).expect("pjrt run");
    let b = native.run(key, &pkg).expect("native run");
    assert_eq!(a.hits, b.hits, "sparse hits must match exactly");
    assert_eq!(a.counts, b.counts);
    assert!(!a.hits.is_empty(), "expected some hits on this text");
    // executable cache: second run must reuse the compiled artifact
    let _ = pjrt.run(key, &pkg).unwrap();
    assert_eq!(pjrt.cached_executables(), 1);
}

#[test]
fn pjrt_backed_service_equals_pure_software() {
    let Some(dir) = artifacts_dir() else { return };
    let g = boost::optimizer::optimize(&boost::aql::compile(QUERY).unwrap());
    let plan = partition(&g, PartitionMode::SingleSubgraph);
    let configs: Vec<AccelConfig> = plan
        .subgraphs
        .iter()
        .map(|s| compile_subgraph(s).unwrap())
        .collect();
    let service = AccelService::start(
        configs,
        EngineSpec::Pjrt {
            artifacts_dir: dir,
        },
        AccelOptions::default(),
    );
    let accel_exec = Executor::new(
        Arc::new(plan.supergraph.clone()),
        Arc::new(Profiler::disabled()),
    )
    .with_subgraph_runner(Arc::new(AccelSubgraphRunner::new(service.clone(), &plan)));
    // pure-software reference on the ORIGINAL graph
    let sw_exec = Executor::new(Arc::new(g.clone()), Arc::new(Profiler::disabled()));

    let texts = [
        "Laura Chiticariu works at IBM Research in Almaden.",
        "Fred Reiss and Huaiyu Zhu are at IBM Research today.",
        "Eva Sitaridi is at Columbia University. Kubilay Atasu visits IBM.",
        "no entities in this sentence",
    ];
    for (i, t) in texts.iter().enumerate() {
        let doc = Document::new(i as u64, *t);
        let mut a: Vec<String> = accel_exec.run_doc(&doc)["PersonOrg"]
            .iter()
            .map(|t| format!("{t:?}"))
            .collect();
        let mut b: Vec<String> = sw_exec.run_doc(&doc)["PersonOrg"]
            .iter()
            .map(|t| format!("{t:?}"))
            .collect();
        a.sort();
        b.sort();
        assert_eq!(a, b, "text {t:?}");
    }
    let snap = service.metrics().snapshot();
    assert!(snap.packages > 0 && snap.docs >= 4);
    service.shutdown();
}

#[test]
fn all_artifact_variants_load_and_run() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtPackageEngine::new(&dir).expect("pjrt client");
    for &(machines, states) in boost::hwcompiler::GEOMETRIES {
        for &block in boost::hwcompiler::BLOCK_SIZES {
            let key = boost::hwcompiler::ArtifactKey {
                machines,
                states,
                block,
            };
            // trivial tables: everything loops on START, nothing accepts
            let mut tables = vec![0i32; machines * states * 256];
            for m in 0..machines {
                for s in 0..states {
                    for b in 0..256 {
                        tables[(m * states + s) * 256 + b] = 1;
                    }
                }
            }
            let pkg = PackedPackage {
                bytes: vec![7i32; STREAMS * block],
                block,
                tables: std::sync::Arc::new(tables),
                accepts: std::sync::Arc::new(vec![0i32; machines * states]),
                machines,
                states,
            };
            let out = pjrt
                .run(key, &pkg)
                .unwrap_or_else(|e| panic!("variant {key:?} failed: {e}"));
            assert!(out.hits.is_empty(), "variant {key:?} produced spurious hits");
        }
    }
}
