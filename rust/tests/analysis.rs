//! Adversarial corpus for the static plan verifier (ISSUE 8 satellite).
//!
//! Each program below is wrong in exactly one way and must be rejected
//! with its *specific* stable diagnostic code — not a panic, not a
//! different code, not a silent pass. The flip side is the
//! zero-false-positive suite at the bottom: every built-in query must
//! come through `check_query` without a single diagnostic in every
//! partition mode, and a strict (default) engine build over T1–T7 must
//! still execute a randomized corpus.

use boost::analysis::{check_query, Report};
use boost::coordinator::Engine;
use boost::corpus::CorpusSpec;
use boost::partition::PartitionMode;

/// Check one adversarial program under the default accelerated mode and
/// document size `repro check` uses.
fn check(src: &str) -> Report {
    check_query("adv", src, PartitionMode::ExtractOnly, 2048)
}

/// Assert the program is rejected with `code` (and nothing weaker than
/// an error).
fn assert_rejected(src: &str, code: &str) -> Report {
    let r = check(src);
    assert!(r.has_code(code), "expected {code}, got:\n{}", r.render());
    assert!(r.has_errors(), "{code} must be an error:\n{}", r.render());
    r
}

// ---------------------------------------------------------------- E0##

#[test]
fn lex_error_is_e001() {
    let r = assert_rejected("create view V as @@;", "E001");
    // lex errors carry an exact byte position
    assert!(r.diagnostics[0].loc.is_some(), "{}", r.render());
}

#[test]
fn parse_error_is_e002() {
    assert_rejected("create view V;", "E002");
}

#[test]
fn unknown_view_is_e010() {
    let r = assert_rejected("output view Nope;", "E010");
    let loc = r.diagnostics[0].loc.as_ref().expect("located");
    assert_eq!(loc.line, 1);
}

#[test]
fn unknown_dictionary_is_e011() {
    assert_rejected(
        "create view X as extract dictionary 'NoSuchDict' on d.text as m from Document d;
         output view X;",
        "E011",
    );
}

#[test]
fn unknown_function_is_e012() {
    assert_rejected(
        "create view A as extract regex /a/ on d.text as m from Document d;
         create view V as select Zap(a.m) as z from A a;
         output view V;",
        "E012",
    );
}

#[test]
fn unknown_alias_is_e013() {
    assert_rejected(
        "create view A as extract regex /a/ on d.text as m from Document d;
         create view V as select q.m from A a;
         output view V;",
        "E013",
    );
}

#[test]
fn unknown_column_is_e014() {
    assert_rejected(
        "create view A as extract regex /a/ on d.text as m from Document d;
         create view V as select a.zzz from A a;
         output view V;",
        "E014",
    );
}

#[test]
fn duplicate_view_is_e015() {
    assert_rejected(
        "create view A as extract regex /a/ on d.text as m from Document d;
         create view A as extract regex /b/ on d.text as m from Document d;
         output view A;",
        "E015",
    );
}

#[test]
fn bad_regex_is_e016() {
    assert_rejected(
        "create view A as extract regex /a{5,2}/ on d.text as m from Document d;
         output view A;",
        "E016",
    );
}

#[test]
fn extraction_over_a_view_is_e017() {
    assert_rejected(
        "create view A as extract regex /a/ on d.text as m from Document d;
         create view B as extract regex /b/ on a.m as m from A a;
         output view B;",
        "E017",
    );
}

// ------------------------------------------- E018–E022 (aggregation)

#[test]
fn group_by_unknown_column_is_e018() {
    // 'nope' is in the group-by list but not among the select items
    let r = assert_rejected(
        "create view A as extract regex /[A-Z][a-z]+/ on d.text as m from Document d;
         create view V as select GetText(a.m) as term, Count() as n from A a
           group by term, nope;
         output view V;",
        "E018",
    );
    // the diagnostic points at the offending name in the source
    assert!(r.diagnostics.iter().any(|d| d.loc.is_some()), "{}", r.render());
}

#[test]
fn group_by_span_column_is_e019() {
    // grouping on a raw span: spans are per-document offsets, not
    // corpus-mergeable keys — the fix (GetText) is named in the message
    assert_rejected(
        "create view A as extract regex /[A-Z][a-z]+/ on d.text as m from Document d;
         create view V as select a.m as m, Count() as n from A a group by m;
         output view V;",
        "E019",
    );
}

#[test]
fn top_zero_is_e020() {
    assert_rejected(
        "create view A as extract regex /[A-Z][a-z]+/ on d.text as m from Document d;
         create view V as select GetText(a.m) as term, Count() as n from A a
           group by term score n top 0;
         output view V;",
        "E020",
    );
}

#[test]
fn text_valued_score_is_e021() {
    // ranking needs a numeric score; 'term' is Text
    assert_rejected(
        "create view A as extract regex /[A-Z][a-z]+/ on d.text as m from Document d;
         create view V as select GetText(a.m) as term, Count() as n from A a
           group by term score term top 5;
         output view V;",
        "E021",
    );
}

#[test]
fn selecting_from_a_corpus_level_view_is_e022() {
    // an aggregated view only exists after Session::finish() merges the
    // per-worker partials — it cannot feed a per-document select
    assert_rejected(
        "create view A as extract regex /[A-Z][a-z]+/ on d.text as m from Document d;
         create view Agg as select GetText(a.m) as term, Count() as n from A a
           group by term;
         create view V as select g.term as term from Agg g;
         output view V;",
        "E022",
    );
}

#[test]
fn bare_key_without_aggregate_is_e022() {
    // group by with no Count()/CountDocs() in the select list
    assert_rejected(
        "create view A as extract regex /[A-Z][a-z]+/ on d.text as m from Document d;
         create view V as select GetText(a.m) as term from A a group by term;
         output view V;",
        "E022",
    );
}

#[test]
fn score_without_top_is_e022() {
    assert_rejected(
        "create view A as extract regex /[A-Z][a-z]+/ on d.text as m from Document d;
         create view V as select GetText(a.m) as term, Count() as n from A a
           group by term score n;
         output view V;",
        "E022",
    );
}

// ---------------------------------------------------------------- E1##

#[test]
fn ill_typed_comparison_is_e102() {
    // GetText yields Str; comparing it against an Int literal cannot type
    assert_rejected(
        "create view A as extract regex /a/ on d.text as m from Document d;
         create view V as select a.m as m from A a where GetText(a.m) > 3;
         output view V;",
        "E102",
    );
}

#[test]
fn non_boolean_predicate_is_e103() {
    // the predicate types fine (Int) but a Select wants Boolean
    assert_rejected(
        "create view A as extract regex /a/ on d.text as m from Document d;
         create view V as select a.m as m from A a where GetLength(a.m);
         output view V;",
        "E103",
    );
}

#[test]
fn consolidate_on_non_span_column_is_e107() {
    // 'n' is an Integer output column; consolidation is span-order based
    assert_rejected(
        "create view A as extract regex /a/ on d.text as m from Document d;
         create view V as select GetLength(a.m) as n from A a
           consolidate on n using 'ContainedWithin';
         output view V;",
        "E107",
    );
}

// ---------------------------------------------------------------- E3## / W3##

#[test]
fn too_many_extraction_machines_is_e301() {
    // extract-only partitioning runs every extraction as one parallel-
    // machine pass; 33 distinct regexes exceed the widest (32, _)
    // artifact geometry
    let mut src = String::new();
    for i in 1..=33 {
        src.push_str(&format!(
            "create view V{i} as extract regex /x{{{i}}}y/ on d.text as m from Document d;\n\
             output view V{i};\n"
        ));
    }
    let r = assert_rejected(&src, "E301");
    assert!(r.render().contains("33 extraction machines"), "{}", r.render());
}

#[test]
fn geometry_overflow_is_mode_dependent() {
    // the same 33-regex program is perfectly valid AQL — running it in
    // pure software must produce zero diagnostics; only offloading it
    // hits the geometry wall
    let mut src = String::new();
    for i in 1..=33 {
        src.push_str(&format!(
            "create view V{i} as extract regex /x{{{i}}}y/ on d.text as m from Document d;\n\
             output view V{i};\n"
        ));
    }
    let r = check_query("adv", &src, PartitionMode::None, 2048);
    assert!(r.is_clean(), "{}", r.render());
}

#[test]
fn unprofitable_offload_is_w310() {
    // at 64 KiB documents the chained nested-loop joins dwarf the three
    // extractions; offloading <25% of estimated cost draws the warning
    let src = "
        create view A as extract regex /alpha/ on d.text as m from Document d;
        create view B as extract regex /beta/ on d.text as m from Document d;
        create view E as extract regex /gamma/ on d.text as m from Document d;
        create view C as select a.m as m, b.m as n from A a, B b
          where Follows(a.m, b.m, 0, 50);
        create view D as select c.m as m from C c, E e
          where Follows(c.m, e.m, 0, 50);
        output view D;
    ";
    let r = check_query("adv", src, PartitionMode::ExtractOnly, 65536);
    assert!(r.has_code("W310"), "{}", r.render());
    // a lint, not a rejection
    assert!(!r.has_errors(), "{}", r.render());
}

// ------------------------------------------------- zero false positives

#[test]
fn builtins_are_clean_in_every_mode() {
    for q in boost::queries::all() {
        for mode in [
            PartitionMode::None,
            PartitionMode::ExtractOnly,
            PartitionMode::SingleSubgraph,
            PartitionMode::MultiSubgraph,
        ] {
            let r = check_query(q.name, &q.aql, mode, 2048);
            assert!(
                r.is_clean(),
                "builtin {} under {mode:?} is not clean:\n{}",
                q.name,
                r.render()
            );
        }
    }
}

#[test]
fn strict_build_runs_a_randomized_corpus() {
    // strict mode is the default; all seven builtins must build and then
    // survive the same randomized-document treatment the differential
    // suite applies (seed overridable via BOOST_DIFF_SEED)
    let seed = std::env::var("BOOST_DIFF_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1FF_2026u64);
    let mut b = Engine::builder();
    for q in boost::queries::all() {
        b = b.register_builtin(q.name);
    }
    let engine = b.build().expect("strict build of all builtins");
    assert!(engine.rejected_queries().is_empty());
    let mut tuples = 0usize;
    for d in CorpusSpec::news(30, 256).with_seed(seed).generate().docs {
        let result = engine.run_doc(&d);
        tuples += result.iter().map(|(_, rows)| rows.len()).sum::<usize>();
    }
    // the news corpus plants entities the builtins extract — a run that
    // finds nothing means the plan was mangled, not that analysis passed
    assert!(tuples > 0);
}
