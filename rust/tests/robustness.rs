//! Robustness: no input may panic the compiler chain — malformed AQL,
//! hostile regex patterns, adversarial documents. Errors must be returned,
//! never thrown.

use boost::coordinator::Engine;
use boost::text::Document;
use boost::util::{prop, Prng};

#[test]
fn aql_random_token_soup_never_panics() {
    let vocab = [
        "create", "view", "dictionary", "as", "extract", "regex", "on", "from", "select",
        "where", "and", "or", "not", "output", "consolidate", "using", "union", "all",
        "Document", "d", "x", ".", ",", ";", "(", ")", "'a'", "/a+/", "42", "=", "<",
        "GetLength", "Follows", "text", "match",
    ];
    let mut rng = Prng::new(0xF422);
    for case in 0..400 {
        let n = rng.range(1, 30);
        let src: String = (0..n)
            .map(|_| *rng.pick(&vocab))
            .collect::<Vec<_>>()
            .join(" ");
        // must not panic — Err is fine, Ok is fine
        let _ = std::panic::catch_unwind(|| boost::aql::compile(&src))
            .unwrap_or_else(|_| panic!("panicked on case {case}: {src}"));
    }
}

#[test]
fn aql_random_bytes_never_panic() {
    let mut rng = Prng::new(0xBEEF);
    for _ in 0..400 {
        let len = rng.below(80);
        let src: String = (0..len).map(|_| rng.printable() as char).collect();
        let _ = boost::aql::compile(&src); // Err is expected; panics are not
    }
}

#[test]
fn regex_random_patterns_never_panic() {
    let mut rng = Prng::new(0x9E9E);
    for _ in 0..600 {
        let len = rng.below(24);
        let pat: String = (0..len)
            .map(|_| *rng.pick(b"ab[]()*+?{}|\\dws-^$.,0159") as char)
            .collect();
        match boost::regex::compile(&pat, rng.chance(0.5)) {
            Ok(re) => {
                // compiled patterns must also scan arbitrary text safely
                let text: String = (0..rng.below(60)).map(|_| rng.printable() as char).collect();
                let _ = re.find_all(&text);
                let _ = re.find_all_via_ends(&text);
            }
            Err(_) => {}
        }
    }
}

#[test]
fn engine_handles_adversarial_documents() {
    let q = boost::queries::builtin("t1").unwrap();
    let engine = Engine::compile_aql(&q.aql).unwrap();
    let adversarial = [
        String::new(),
        " ".repeat(5000),
        "A".repeat(5000),
        "Aa ".repeat(2000),                     // dense person-regex prefixes
        "IBM ".repeat(1500),                    // dense dictionary hits
        "2014-01-01 ".repeat(400),              // dense date hits
        (0u8..=127).map(|b| b.max(1) as char).collect::<String>().repeat(40),
        "\n\n\n\t\t\t".repeat(500),
    ];
    for (i, text) in adversarial.iter().enumerate() {
        let doc = Document::new(i as u64, text.as_str());
        let _ = engine.run_doc(&doc); // must not panic, whatever the yield
    }
}

#[test]
fn prop_engine_output_spans_in_bounds() {
    let q = boost::queries::builtin("t2").unwrap();
    let engine = Engine::compile_aql(&q.aql).unwrap();
    prop::check(
        0x5EED,
        150,
        |r: &mut Prng| {
            let len = r.below(400);
            (0..len)
                .map(|_| *r.pick(b"Aa bB(4) 5-190.x@ \n") as char)
                .collect::<String>()
        },
        |text| {
            let doc = Document::new(0, text.as_str());
            let out = engine.run_doc(&doc);
            out.views().iter().flatten().all(|t| {
                t.iter().all(|v| match v {
                    boost::aog::Value::Span(s) => {
                        s.begin <= s.end && s.end as usize <= text.len()
                    }
                    _ => true,
                })
            })
        },
    );
}

#[test]
fn invalid_subgraph_id_is_rejected_without_killing_the_comm_thread() {
    // regression: the communication thread used to index its per-subgraph
    // pending tables with the submission's unvalidated subgraph_id — an
    // out-of-range id panicked the thread and hung every in-flight worker.
    // It must instead answer Err on that submission's own reply channel
    // and keep serving valid ids afterwards.
    use boost::accel::{AccelOptions, AccelService};
    use boost::hwcompiler::compile_subgraph;
    use boost::partition::{partition, PartitionMode};
    use boost::runtime::EngineSpec;
    use boost::text::TokenIndex;
    use std::sync::Arc;

    let q = boost::queries::builtin("t1").unwrap();
    let g = boost::optimizer::optimize(&boost::aql::compile(&q.aql).unwrap());
    let plan = partition(&g, PartitionMode::ExtractOnly);
    let n_subgraphs = plan.subgraphs.len();
    let configs = plan
        .subgraphs
        .iter()
        .map(|s| compile_subgraph(s).unwrap())
        .collect();
    let service = AccelService::start(
        configs,
        EngineSpec::Sim(boost::runtime::SimSpec::default()),
        AccelOptions::default(),
    );

    let doc = Document::new(0, "Laura Chiticariu works at IBM Research.");
    let rx = service.submit(
        n_subgraphs + 1,
        doc.clone(),
        Arc::new(TokenIndex::default()),
        vec![],
    );
    let res = rx.recv().expect("an invalid id must still get a reply");
    let err = res.expect_err("an out-of-range subgraph id must be an error");
    assert!(err.contains("invalid subgraph"), "{err}");

    // the communication thread must have survived: a valid submission
    // afterwards still completes
    let rx = service.submit(0, doc, Arc::new(TokenIndex::default()), vec![]);
    rx.recv()
        .expect("the comm thread must still be alive after the bad id")
        .expect("a valid submission must still succeed");
    service.shutdown();
}

#[test]
fn tokenizer_never_panics_on_any_bytes() {
    let mut rng = Prng::new(7);
    for _ in 0..300 {
        let len = rng.below(200);
        // arbitrary ASCII including control chars (but valid UTF-8)
        let s: String = (0..len).map(|_| (rng.below(127) as u8 + 1) as char).collect();
        let idx = boost::text::Tokenizer::standard().tokenize(&s);
        let _ = idx.token_count();
    }
}
