//! Fault-containment regression suite (ISSUE 9): poison-document
//! quarantine, deadline expiry, circuit-breaker trip → probe → re-admit
//! on a bricked device window, and the serving tier's `DocErr` taxonomy —
//! everything `repro chaos` exercises, pinned down as deterministic
//! tests.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use boost::coordinator::{Engine, EngineConfig, ResultSink};
use boost::corpus::CorpusSpec;
use boost::exec::{DocResult, ViewHandle};
use boost::partition::PartitionMode;
use boost::runtime::{ChaosPlan, DocError, EngineSpec, FaultPlan, SimSpec};
use boost::serve::protocol::{self, ERR_DEADLINE, ERR_DOC_PANIC};
use boost::serve::{Client, ServeConfig, Server};
use boost::text::Document;

fn catalog(config: EngineConfig) -> Arc<Engine> {
    Arc::new(
        Engine::builder()
            .register_builtin("t1")
            .register_builtin("t3")
            .config(config)
            .build()
            .expect("catalog builds"),
    )
}

fn full_table(engine: &Engine) -> Vec<ViewHandle> {
    engine
        .queries()
        .iter()
        .flat_map(|q| q.views().iter().cloned())
        .collect()
}

fn encode_views(table: &[ViewHandle], result: &DocResult) -> Vec<(u16, Vec<u8>)> {
    table
        .iter()
        .enumerate()
        .map(|(vi, h)| {
            let mut buf = Vec::new();
            protocol::encode_batch(result.view_batch(h), &mut buf);
            (vi as u16, buf)
        })
        .collect()
}

/// Captures both sides of the sink: per-doc encoded results and per-doc
/// structured errors, keyed by document id.
struct ChaosSink {
    table: Vec<ViewHandle>,
    results: Mutex<HashMap<u64, Vec<(u16, Vec<u8>)>>>,
    errors: Mutex<Vec<(u64, bool, String)>>,
}

impl ChaosSink {
    fn new(table: Vec<ViewHandle>) -> ChaosSink {
        ChaosSink {
            table,
            results: Mutex::new(HashMap::new()),
            errors: Mutex::new(Vec::new()),
        }
    }
}

impl ResultSink for ChaosSink {
    fn on_result(&self, doc: &Document, result: &DocResult) {
        let views = encode_views(&self.table, result);
        let prev = self.results.lock().unwrap().insert(doc.id, views);
        assert!(prev.is_none(), "doc {} answered twice", doc.id);
    }

    fn on_error(&self, doc: &Document, error: &DocError) {
        self.errors
            .lock()
            .unwrap()
            .push((doc.id, error.is_deadline(), error.to_string()));
    }
}

/// Injected panics mid-corpus: each poisoned document becomes one
/// structured `DocError::Panicked` and one quarantine entry; every other
/// document's results are byte-identical to a clean engine's.
#[test]
fn injected_panics_are_contained_and_quarantined() {
    let corpus = CorpusSpec::news(60, 384).with_seed(0xC4A0_0001).generate();
    let plan = Arc::new(ChaosPlan::new(7).panic_every(5));
    let planned: Vec<u64> = corpus
        .docs
        .iter()
        .filter(|d| plan.panics(d.id))
        .map(|d| d.id)
        .collect();
    assert!(!planned.is_empty(), "seed must poison at least one doc");

    let engine = catalog(EngineConfig::default());
    let sink = Arc::new(ChaosSink::new(full_table(&engine)));
    let mut session = engine
        .session()
        .threads(4)
        .sink(sink.clone())
        .chaos(plan.clone())
        .start();
    for doc in &corpus.docs {
        session.push(doc.clone()).expect("push");
    }
    let report = session.finish();

    assert_eq!(report.errors, planned.len(), "one error per poisoned doc");
    assert_eq!(report.expired, 0, "panics are not deadline expiries");
    assert_eq!(report.docs, corpus.docs.len() - planned.len());

    let errors = sink.errors.lock().unwrap();
    assert_eq!(errors.len(), planned.len());
    for (id, is_deadline, message) in errors.iter() {
        assert!(!is_deadline, "doc {id} must be Panicked, not deadline");
        assert!(plan.panics(*id), "doc {id} failed without a planned fault");
        assert!(
            message.contains("chaos"),
            "panic message should carry the injected payload: {message}"
        );
    }
    drop(errors);

    // quarantine: one entry per poison doc (cap permitting), total exact
    assert_eq!(engine.quarantine().total(), planned.len() as u64);
    assert!(!engine.quarantine().is_empty());

    // survivors byte-identical to a clean engine over the same catalog
    let clean = catalog(EngineConfig::default());
    let clean_table = full_table(&clean);
    let results = sink.results.lock().unwrap();
    assert_eq!(results.len(), corpus.docs.len() - planned.len());
    for doc in &corpus.docs {
        if plan.panics(doc.id) {
            assert!(!results.contains_key(&doc.id), "poisoned doc {} has a result", doc.id);
        } else {
            let want = encode_views(&clean_table, &clean.run_doc(doc));
            assert_eq!(results[&doc.id], want, "survivor doc {} diverged", doc.id);
        }
    }
}

/// A device dark for its first packages then recovered: the breakers must
/// trip after the threshold, go half-open after the cooldown, and re-admit
/// the device on the first healthy probe — with every document still
/// answered byte-identically via failover in the meantime.
#[test]
fn brick_window_trips_probes_and_readmits_breakers() {
    let corpus = CorpusSpec::news(40, 512).with_seed(0xC4A0_0002).generate();
    let mut cfg = EngineConfig::accelerated(
        PartitionMode::ExtractOnly,
        EngineSpec::Sim(SimSpec::default().with_fault(FaultPlan {
            brick_from: 1,
            brick_until: 3,
            ..FaultPlan::none()
        })),
    );
    cfg.accel.devices = 2;
    cfg.accel.breaker_threshold = 2;
    cfg.accel.breaker_cooldown = Duration::from_millis(10);
    let engine = catalog(cfg);
    let clean = catalog(EngineConfig::default());
    let clean_table = full_table(&clean);

    // repeated passes: early ones trip the breakers inside the brick
    // window, later ones hand the half-open probes packages past the
    // window. Bounded so a regression fails instead of spinning.
    let mut readmitted = false;
    for _pass in 0..40 {
        let sink = Arc::new(ChaosSink::new(full_table(&engine)));
        let mut session = engine.session().threads(2).sink(sink.clone()).start();
        for doc in &corpus.docs {
            session.push(doc.clone()).expect("push");
        }
        let report = session.finish();
        assert_eq!(report.errors, 0, "device faults must never surface as doc errors");
        assert_eq!(report.docs, corpus.docs.len());

        let results = sink.results.lock().unwrap();
        for doc in &corpus.docs {
            let want = encode_views(&clean_table, &clean.run_doc(doc));
            assert_eq!(results[&doc.id], want, "doc {} diverged under faults", doc.id);
        }
        drop(results);

        let pool = engine.accel_pool_snapshot().expect("pool snapshot");
        if pool.breaker_trips > 0 && pool.breaker_readmits > 0 {
            readmitted = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(15));
    }
    assert!(readmitted, "expected trip → half-open probe → re-admit within the pass budget");

    let pool = engine.accel_pool_snapshot().expect("pool snapshot");
    assert!(pool.breaker_trips >= 1, "breakers never tripped: {pool:?}");
    assert!(pool.breaker_probes >= 1, "no half-open probe went out: {pool:?}");
    assert!(pool.breaker_readmits >= 1, "no device was re-admitted: {pool:?}");
    let breakers = engine.accel_breaker_snapshots().expect("breaker snapshots");
    assert_eq!(breakers.len(), 2);
}

/// Deadline expiry in both directions: a zero budget sheds every document
/// (checked at dequeue — nothing hangs, nothing executes to completion),
/// a generous budget passes every document untouched.
#[test]
fn zero_budget_sheds_and_generous_budget_passes() {
    let corpus = CorpusSpec::news(24, 256).with_seed(0xC4A0_0003).generate();
    let engine = catalog(EngineConfig::default());

    // zero budget: every document expires before (or at) dequeue
    let sink = Arc::new(ChaosSink::new(full_table(&engine)));
    let mut session = engine
        .session()
        .threads(2)
        .sink(sink.clone())
        .deadline(Duration::ZERO)
        .start();
    for doc in &corpus.docs {
        session.push(doc.clone()).expect("push");
    }
    let report = session.finish();
    assert_eq!(report.docs, 0, "no document beats a zero budget");
    assert_eq!(report.errors, corpus.docs.len());
    assert_eq!(report.expired, corpus.docs.len(), "every error is a deadline expiry");
    let errors = sink.errors.lock().unwrap();
    assert!(errors.iter().all(|(_, is_deadline, _)| *is_deadline));
    drop(errors);

    // generous budget: deadline plumbing must not perturb a healthy run
    let sink = Arc::new(ChaosSink::new(full_table(&engine)));
    let mut session = engine
        .session()
        .threads(2)
        .sink(sink.clone())
        .deadline(Duration::from_secs(60))
        .start();
    for doc in &corpus.docs {
        session.push(doc.clone()).expect("push");
    }
    let report = session.finish();
    assert_eq!(report.docs, corpus.docs.len());
    assert_eq!(report.errors, 0);
    assert_eq!(report.expired, 0);
}

/// A slow simulated device vs a small budget: accelerator-routed work
/// expires inside the accel path (post-stage shed / typed submit error)
/// and surfaces as the same `DocError::DeadlineExceeded` — never a hang,
/// never a partial result.
#[test]
fn slow_sim_device_expires_small_budgets() {
    let corpus = CorpusSpec::news(16, 512).with_seed(0xC4A0_0004).generate();
    // a single device is never adaptively routed to software, so every
    // offloaded subgraph must cross the slow simulator
    let cfg = EngineConfig::accelerated(
        PartitionMode::ExtractOnly,
        EngineSpec::Sim(SimSpec::default().with_latency(Duration::from_millis(30))),
    );
    let engine = catalog(cfg);

    let sink = Arc::new(ChaosSink::new(full_table(&engine)));
    let mut session = engine
        .session()
        .threads(2)
        .sink(sink.clone())
        .deadline(Duration::from_millis(2))
        .start();
    for doc in &corpus.docs {
        session.push(doc.clone()).expect("push");
    }
    let report = session.finish();
    assert_eq!(
        report.docs + report.errors,
        corpus.docs.len(),
        "every document answered exactly once"
    );
    assert!(report.errors > 0, "a 2ms budget cannot survive a 30ms device");
    assert_eq!(report.expired, report.errors, "all errors are deadline expiries");
    let errors = sink.errors.lock().unwrap();
    assert!(errors.iter().all(|(_, is_deadline, _)| *is_deadline));
}

/// The serving tier's view of containment: poisoned documents come back
/// as `DocErr(doc-panic)` frames, a per-document zero budget comes back
/// as `DocErr(deadline)`, the connection keeps streaming results for
/// everything else, and `Done` counts every answered document.
#[test]
fn serve_doc_err_frames_carry_the_taxonomy() {
    let corpus = CorpusSpec::news(30, 256).with_seed(0xC4A0_0005).generate();
    let plan = Arc::new(ChaosPlan::new(11).panic_every(6));
    let planned: Vec<u64> = corpus
        .docs
        .iter()
        .filter(|d| plan.panics(d.id))
        .map(|d| d.id)
        .collect();
    assert!(!planned.is_empty(), "seed must poison at least one doc");

    let engine = catalog(EngineConfig::default());
    let server = Server::start(
        engine.clone(),
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            chaos: Some(plan.clone()),
            ..ServeConfig::default()
        },
    )
    .expect("server starts");

    // one extra poison-free doc pinned to a zero budget: it must expire
    // even though the connection itself has no default deadline
    let deadline_doc = corpus
        .docs
        .iter()
        .find(|d| !plan.panics(d.id))
        .expect("a clean doc exists");
    let mut client = Client::connect(server.local_addr(), &[], &[]).expect("connect");
    for doc in &corpus.docs {
        if doc.id == deadline_doc.id {
            client
                .send_with_budget(doc.id, &doc.text, 0)
                .expect("send with budget");
        } else {
            client.send(doc.id, &doc.text).expect("send");
        }
    }
    let report = client.finish().expect("finish");

    assert_eq!(
        report.done,
        corpus.docs.len() as u64,
        "Done counts successes plus per-doc errors"
    );
    assert_eq!(
        report.results.len() + report.doc_errors.len(),
        corpus.docs.len()
    );
    let mut saw_deadline = false;
    for e in &report.doc_errors {
        if e.doc_id == deadline_doc.id {
            assert_eq!(e.code, ERR_DEADLINE, "zero-budget doc: {e:?}");
            saw_deadline = true;
        } else {
            assert_eq!(e.code, ERR_DOC_PANIC, "poisoned doc: {e:?}");
            assert!(plan.panics(e.doc_id), "doc {} failed without a fault", e.doc_id);
        }
    }
    assert!(saw_deadline, "the zero-budget doc must come back as DocErr(deadline)");
    assert_eq!(report.doc_errors.len(), planned.len() + 1);

    // server-side gauges agree with the frames on the wire
    assert_eq!(server.stats().doc_errors, (planned.len() + 1) as u64);
    assert_eq!(server.stats().deadline_expired, 1);
    assert!(engine.quarantine().total() >= planned.len() as u64);
}
