//! Golden-view snapshot tests: every bundled query (T1–T7) over the
//! small hand-written corpus in `tests/golden/corpus.txt`, rendered in a
//! stable line format and compared against committed `tests/golden/
//! <query>.golden` snapshots. A view-shape regression (different spans,
//! different tuple content, a view appearing/disappearing) fails with a
//! readable first-divergence diff and writes the full actual rendering to
//! `<query>.actual` so CI can upload it.
//!
//! Blessing workflow (see TESTING.md): when a snapshot file is missing, or
//! `UPDATE_GOLDEN=1` is set, the test writes the snapshot and passes —
//! commit the generated `.golden` files to pin the behaviour.

use std::path::PathBuf;

use boost::coordinator::Engine;
use boost::text::Document;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn corpus() -> Vec<Document> {
    let text = std::fs::read_to_string(golden_dir().join("corpus.txt"))
        .expect("tests/golden/corpus.txt is committed");
    text.lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
        .enumerate()
        .map(|(i, l)| Document::new(i as u64, l))
        .collect()
}

/// Stable rendering: one line per tuple, `doc <id> <view>: <cells>`, with
/// spans as `[begin,end)="text"` so a diff reads without tooling.
fn render(engine: &Engine, docs: &[Document]) -> String {
    let mut out = String::new();
    for doc in docs {
        let result = engine.run_doc(doc);
        for (h, rows) in result.iter() {
            for t in rows {
                out.push_str(&format!("doc {:2} {}:", doc.id, h.name()));
                for v in t {
                    match v {
                        boost::aog::Value::Span(s) => out.push_str(&format!(
                            " [{},{})={:?}",
                            s.begin,
                            s.end,
                            s.text(&doc.text)
                        )),
                        other => out.push_str(&format!(" {other}")),
                    }
                }
                out.push('\n');
            }
        }
    }
    out
}

/// First line where two renderings diverge, with context — the "readable
/// diff" the snapshot failure prints.
fn first_divergence(want: &str, got: &str) -> String {
    let (mut wl, mut gl) = (want.lines(), got.lines());
    let mut line_no = 1;
    loop {
        match (wl.next(), gl.next()) {
            (Some(w), Some(g)) if w == g => line_no += 1,
            (w, g) => {
                return format!(
                    "first divergence at line {line_no}:\n  golden: {}\n  actual: {}",
                    w.unwrap_or("<end of golden>"),
                    g.unwrap_or("<end of actual>")
                )
            }
        }
    }
}

fn check_golden(query: &str) {
    let q = boost::queries::builtin(query).unwrap();
    let engine = Engine::compile_aql(&q.aql).unwrap();
    let docs = corpus();
    let got = render(&engine, &docs);

    // snapshots must themselves be deterministic before they can pin
    // anything
    assert_eq!(got, render(&engine, &docs), "{query}: rendering not deterministic");

    let path = golden_dir().join(format!("{query}.golden"));
    let bless = std::env::var("UPDATE_GOLDEN").is_ok_and(|v| v == "1");
    if bless || !path.exists() {
        // REQUIRE_GOLDEN=1 (set in CI once snapshots are committed) turns
        // a missing snapshot into a hard failure instead of a silent
        // bless — otherwise a deleted .golden would make the suite
        // vacuous forever
        assert!(
            bless || !std::env::var("REQUIRE_GOLDEN").is_ok_and(|v| v == "1"),
            "{query}: golden snapshot {} is missing and REQUIRE_GOLDEN=1 forbids \
             blessing — restore the committed snapshot or re-bless locally",
            path.display()
        );
        std::fs::write(&path, &got).expect("write golden snapshot");
        eprintln!(
            "{query}: blessed {} ({} lines) — commit it to pin the views",
            path.display(),
            got.lines().count()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden snapshot");
    if want != got {
        let actual = golden_dir().join(format!("{query}.actual"));
        std::fs::write(&actual, &got).expect("write actual snapshot");
        panic!(
            "{query}: view snapshot regressed ({} golden lines, {} actual — full \
             actual written to {}).\n{}\nIf the change is intentional, re-bless \
             with UPDATE_GOLDEN=1 and commit the new snapshot.",
            want.lines().count(),
            got.lines().count(),
            actual.display(),
            first_divergence(&want, &got),
        );
    }
}

#[test]
fn golden_t1() {
    check_golden("t1");
}

#[test]
fn golden_t2() {
    check_golden("t2");
}

#[test]
fn golden_t3() {
    check_golden("t3");
}

#[test]
fn golden_t4() {
    check_golden("t4");
}

#[test]
fn golden_t5() {
    check_golden("t5");
}

#[test]
fn golden_t6() {
    // per-document run_doc treats each document as a corpus of one, so
    // the aggregate views (term, n, docs, score) are still deterministic
    // per-doc rows — pinnable exactly like the extraction queries
    check_golden("t6");
}

#[test]
fn golden_t7() {
    check_golden("t7");
}

#[test]
fn golden_corpus_yields_annotations_for_every_query() {
    // the snapshot must never be vacuous: each bundled query extracts
    // something from the committed corpus
    let docs = corpus();
    assert!(docs.len() >= 10, "committed corpus shrank to {}", docs.len());
    for q in boost::queries::all() {
        let engine = Engine::compile_aql(&q.aql).unwrap();
        let total: usize = docs.iter().map(|d| engine.run_doc(d).total_tuples()).sum();
        assert!(total > 0, "{} extracted nothing from the golden corpus", q.name);
    }
}

#[test]
fn golden_views_identical_under_the_simulated_accelerator() {
    // the snapshot pins the SW path; the simulated HW path must render the
    // exact same bytes (ties the golden suite to the differential one)
    use boost::coordinator::EngineConfig;
    use boost::partition::PartitionMode;
    let docs = corpus();
    let q = boost::queries::builtin("t1").unwrap();
    let sw = Engine::compile_aql(&q.aql).unwrap();
    let hw = Engine::with_config(
        &q.aql,
        EngineConfig::simulated(PartitionMode::SingleSubgraph),
    )
    .unwrap();
    assert_eq!(render(&sw, &docs), render(&hw, &docs));
    hw.shutdown();
}
