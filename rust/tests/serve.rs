//! Serving-tier integration tests (ISSUE 6 satellite): loopback
//! correctness under concurrency, slow-client backpressure, mid-stream
//! disconnects, malformed frames, and admission control — all against a
//! real TCP server on an ephemeral loopback port.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use boost::coordinator::{Engine, EngineConfig};
use boost::corpus::CorpusSpec;
use boost::exec::ViewHandle;
use boost::partition::PartitionMode;
use boost::serve::protocol::{self, Frame};
use boost::serve::{run_load, Client, ClientError, ServeConfig, Server};
use boost::text::Document;

fn catalog(config: EngineConfig) -> Arc<Engine> {
    Arc::new(
        Engine::builder()
            .register_builtin("t1")
            .register_builtin("t3")
            .config(config)
            .build()
            .expect("catalog builds"),
    )
}

fn start(engine: Arc<Engine>, max_connections: usize, queue_depth: usize) -> Server {
    Server::start(
        engine,
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            admin_addr: Some("127.0.0.1:0".into()),
            max_connections,
            queue_depth,
            threads_per_connection: 2,
            ..ServeConfig::default()
        },
    )
    .expect("server starts")
}

/// The server's view table for an empty Hello: every query's views, in
/// catalog order — the order the selftest and these tests must mirror
/// when building the run_doc reference.
fn full_table(engine: &Engine) -> Vec<ViewHandle> {
    engine
        .queries()
        .iter()
        .flat_map(|q| q.views().iter().cloned())
        .collect()
}

fn reference_views(engine: &Engine, table: &[ViewHandle], doc: &Document) -> Vec<(u16, Vec<u8>)> {
    let result = engine.run_doc(doc);
    table
        .iter()
        .enumerate()
        .map(|(vi, h)| {
            let mut buf = Vec::new();
            protocol::encode_batch(result.view_batch(h), &mut buf);
            (vi as u16, buf)
        })
        .collect()
}

fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

/// 8 concurrent clients over a randomized corpus: every per-view result
/// byte-identical to synchronous `run_doc` on the same engine.
#[test]
fn loopback_concurrent_clients_byte_identical() {
    let engine = catalog(EngineConfig::default());
    let corpus = CorpusSpec::news(48, 384).with_seed(0x5E7E_0001).generate();
    let table = full_table(&engine);
    let server = start(engine.clone(), 16, 32);

    let report = run_load(server.local_addr(), &corpus.docs, 8, &[]).expect("load run");
    assert_eq!(report.docs, corpus.docs.len());
    assert_eq!(report.results.len(), corpus.docs.len());

    let mut seen = vec![false; corpus.docs.len()];
    for rf in &report.results {
        let doc = &corpus.docs[rf.doc_id as usize];
        assert!(!std::mem::replace(&mut seen[rf.doc_id as usize], true));
        assert_eq!(
            rf.views,
            reference_views(&engine, &table, doc),
            "doc {} not byte-identical to run_doc",
            rf.doc_id
        );
    }
    assert!(seen.iter().all(|&s| s), "every document answered");
    assert_eq!(server.stats().docs, corpus.docs.len() as u64);
}

/// Same equivalence over the simulated-accelerator engine: the serving
/// tier composes with the accelerated path, not just pure software.
#[test]
fn loopback_simulated_engine_byte_identical() {
    let engine = catalog(EngineConfig::simulated(PartitionMode::ExtractOnly));
    let corpus = CorpusSpec::tweets(24, 256).with_seed(0x5E7E_0002).generate();
    let table = full_table(&engine);
    let server = start(engine.clone(), 16, 32);

    let report = run_load(server.local_addr(), &corpus.docs, 4, &[]).expect("load run");
    assert_eq!(report.results.len(), corpus.docs.len());
    for rf in &report.results {
        let doc = &corpus.docs[rf.doc_id as usize];
        assert_eq!(rf.views, reference_views(&engine, &table, doc));
    }
    drop(server); // joins connection handlers before the engine drops
}

/// A client that sends documents but never reads results: the writer
/// blocks on the socket, the depth-1 result queue fills, the sink blocks
/// (accounted as `blocked_ns`), and only THIS connection's pipeline
/// stalls — the reader thread still serves other clients.
#[test]
fn slow_client_backpressure_is_accounted_and_isolated() {
    let engine = catalog(EngineConfig::default());
    let server = start(engine, 16, 1);
    let addr = server.local_addr();

    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = BufWriter::new(stream.try_clone().expect("clone"));
    protocol::write_frame(
        &mut writer,
        &Frame::Hello {
            queries: vec![],
            views: vec![],
            budget_ms: None,
        },
    )
    .expect("hello");
    writer.flush().expect("flush");
    match protocol::read_frame(&mut reader).expect("welcome") {
        Some(Frame::Welcome { .. }) => {}
        other => panic!("expected Welcome, got {other:?}"),
    }

    // sender thread: pump documents WITHOUT reading results, until the
    // main thread has observed backpressure (or a generous cap)
    let observed = Arc::new(AtomicBool::new(false));
    let sender = {
        let observed = observed.clone();
        let text = "Alice Smith met Bob Jones at IBM Research in New York. ".repeat(8);
        std::thread::spawn(move || {
            for i in 0..20_000u64 {
                if observed.load(Ordering::Relaxed) {
                    break;
                }
                protocol::write_frame(
                    &mut writer,
                    &Frame::Doc {
                        id: i,
                        budget_ms: None,
                        bytes: text.as_bytes().to_vec(),
                    },
                )
                .expect("doc frame");
                writer.flush().expect("doc flush");
            }
            protocol::write_frame(&mut writer, &Frame::Finish).expect("finish");
            writer.flush().expect("finish flush");
        })
    };

    // a live connection's queue gauges show the blocked producer
    let saw_block = wait_until(Duration::from_secs(30), || {
        server
            .connections()
            .iter()
            .any(|c| c.queue.blocked_ns > 0 && c.queue.stalls > 0)
    });
    observed.store(true, Ordering::Relaxed);

    // while the slow client is stalled, a well-behaved client on the SAME
    // server completes promptly — the stall is per-connection
    let mut neighbour = Client::connect(addr, &[], &[]).expect("neighbour connect");
    neighbour.send(0, "Carol visited Paris.").expect("send");
    let neighbour_report = neighbour.finish().expect("neighbour finish");
    assert_eq!(neighbour_report.results.len(), 1);

    // unblock: drain the slow client's results to Done
    let mut done = false;
    while let Some(frame) = protocol::read_frame(&mut reader).expect("drain") {
        if let Frame::Done { .. } = frame {
            done = true;
            break;
        }
    }
    assert!(done, "slow client drained to Done");
    sender.join().expect("sender joins");
    assert!(saw_block, "expected blocked_ns > 0 on the slow connection");

    // after teardown the evidence survives in the aggregate
    assert!(wait_until(Duration::from_secs(5), || {
        server.stats().result_blocked_ns > 0
    }));
}

/// A client that vanishes mid-stream tears down one session; the server
/// accounts the disconnect and keeps serving new connections.
#[test]
fn mid_stream_disconnect_is_survived() {
    let engine = catalog(EngineConfig::default());
    let server = start(engine, 16, 8);
    let addr = server.local_addr();

    {
        let mut rogue = Client::connect(addr, &[], &[]).expect("rogue connect");
        rogue.send(7, "Dave left without saying goodbye").expect("send");
        // dropped without finish: socket shut down mid-stream
    }
    assert!(
        wait_until(Duration::from_secs(5), || server.stats().disconnects >= 1),
        "server accounts the disconnect"
    );

    let mut after = Client::connect(addr, &[], &[]).expect("post-disconnect connect");
    after.send(8, "Erin met Frank at MIT.").expect("send");
    let report = after.finish().expect("finish");
    assert_eq!(report.results.len(), 1);
    assert_eq!(report.results[0].doc_id, 8);
}

/// Unknown frame types and truncated frames produce a clean protocol
/// error (an `Error` frame where the socket still works, a counted
/// teardown where it doesn't) — never a panic, and never a wedged server.
#[test]
fn malformed_and_truncated_frames_fail_cleanly() {
    let engine = catalog(EngineConfig::default());
    let server = start(engine, 16, 8);
    let addr = server.local_addr();

    // unknown frame type after a valid handshake → Error frame back
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = BufWriter::new(stream.try_clone().expect("clone"));
    protocol::write_frame(
        &mut writer,
        &Frame::Hello {
            queries: vec![],
            views: vec![],
            budget_ms: None,
        },
    )
    .expect("hello");
    writer.flush().expect("flush");
    assert!(matches!(
        protocol::read_frame(&mut reader).expect("welcome"),
        Some(Frame::Welcome { .. })
    ));
    writer.write_all(&[5, 0, 0, 0, 0x7F, 1, 2, 3, 4]).expect("garbage frame");
    writer.flush().expect("flush");
    let mut got_error = false;
    while let Ok(Some(frame)) = protocol::read_frame(&mut reader) {
        if let Frame::Error { code, .. } = frame {
            assert_eq!(code, protocol::ERR_PROTOCOL);
            got_error = true;
            break;
        }
    }
    assert!(got_error, "expected an Error frame for the unknown type");
    drop((reader, writer, stream));

    // truncated frame: a length prefix promising more than arrives
    let mut stream = TcpStream::connect(addr).expect("connect 2");
    stream.write_all(&[100, 0, 0, 0, 0x01, 0x01]).expect("partial frame");
    drop(stream);

    assert!(
        wait_until(Duration::from_secs(5), || {
            server.stats().protocol_errors >= 2
        }),
        "both violations counted"
    );

    // the server still serves
    let mut after = Client::connect(addr, &[], &[]).expect("post-error connect");
    after.send(1, "Grace phoned Heidi.").expect("send");
    assert_eq!(after.finish().expect("finish").results.len(), 1);
}

/// Past the connection cap the server answers `Busy` and closes; once a
/// slot frees, new connections are admitted again.
#[test]
fn admission_control_rejects_past_cap_with_busy() {
    let engine = catalog(EngineConfig::default());
    let server = start(engine, 1, 8);
    let addr = server.local_addr();

    let first = Client::connect(addr, &[], &[]).expect("first connect");
    match Client::connect(addr, &[], &[]) {
        Err(ClientError::Busy { cap, .. }) => assert_eq!(cap, 1),
        other => panic!("expected Busy, got {other:?}"),
    }
    assert!(server.stats().rejected >= 1);

    drop(first); // frees the slot (mid-stream disconnect path)
    assert!(
        wait_until(Duration::from_secs(5), || server.stats().active == 0),
        "slot freed"
    );
    let mut third = Client::connect(addr, &[], &[]).expect("post-release connect");
    third.send(1, "Ivan texted Judy.").expect("send");
    assert_eq!(third.finish().expect("finish").results.len(), 1);
}

/// The Hello's namespaces really scope the connection: a subscription to
/// a view outside the selected queries is rejected with a clean error.
#[test]
fn hello_namespaces_scope_the_view_table() {
    let engine = catalog(EngineConfig::default());
    let server = start(engine.clone(), 16, 8);
    let addr = server.local_addr();

    // t1-only connection sees exactly t1's views
    let t1 = Client::connect(addr, &["t1".to_string()], &[]).expect("t1 connect");
    let t1_names: Vec<String> = engine
        .query("t1")
        .expect("t1 registered")
        .views()
        .iter()
        .map(|h| h.name().to_string())
        .collect();
    assert_eq!(t1.view_table(), &t1_names[..]);
    drop(t1);

    // a t3 view is not visible from a t1-only connection
    match Client::connect(addr, &["t1".to_string()], &["t3.Phones".to_string()]) {
        Err(ClientError::Rejected { code, .. }) => {
            assert_eq!(code, protocol::ERR_UNKNOWN_VIEW)
        }
        Ok(_) => panic!("expected the cross-namespace subscription to be rejected"),
        Err(other) => panic!("expected Rejected, got {other}"),
    }

    // unknown query namespace → clean error too
    match Client::connect(addr, &["nope".to_string()], &[]) {
        Err(ClientError::Rejected { code, .. }) => {
            assert_eq!(code, protocol::ERR_UNKNOWN_QUERY)
        }
        other => panic!("expected Rejected, got {other:?}"),
    }
}

/// A lenient catalog quarantines an entry the static analyzer rejects;
/// a Hello naming it gets a structured `ERR_QUERY_REJECTED` Error frame
/// carrying the diagnostic code — not "unknown query" — and healthy
/// queries on the same server are unaffected.
#[test]
fn hello_naming_a_rejected_query_gets_a_structured_error() {
    let engine = Arc::new(
        Engine::builder()
            .register_builtin("t1")
            .register("broken", "output view Nope;")
            .lenient()
            .build()
            .expect("lenient catalog builds"),
    );
    let server = start(engine, 4, 8);
    let addr = server.local_addr();

    match Client::connect(addr, &["broken".to_string()], &[]) {
        Err(ClientError::Rejected { code, message }) => {
            assert_eq!(code, protocol::ERR_QUERY_REJECTED);
            assert!(message.contains("E010"), "{message}");
            assert!(message.contains("broken"), "{message}");
        }
        other => panic!("expected ERR_QUERY_REJECTED, got {other:?}"),
    }

    let mut healthy = Client::connect(addr, &["t1".to_string()], &[]).expect("t1 connect");
    healthy.send(0, "Alice met Bob at IBM.").expect("send");
    assert_eq!(healthy.finish().expect("finish").results.len(), 1);
}

/// `GET /metrics` on the admin port: HTTP/1.0 200, JSON, with serve,
/// arena, and block-pool sections; other paths 404.
#[test]
fn admin_metrics_endpoint_serves_json() {
    use std::io::Read;

    let engine = catalog(EngineConfig::default());
    let server = start(engine, 16, 8);
    let admin = server.admin_addr().expect("admin configured");

    // generate a little traffic first
    let corpus = CorpusSpec::news(4, 256).with_seed(0x5E7E_0003).generate();
    let _ = run_load(server.local_addr(), &corpus.docs, 2, &[]).expect("load");

    let get = |path: &str| -> String {
        let mut s = TcpStream::connect(admin).expect("admin connect");
        write!(s, "GET {path} HTTP/1.0\r\nHost: x\r\n\r\n").expect("request");
        let mut body = String::new();
        s.read_to_string(&mut body).expect("response");
        body
    };
    let resp = get("/metrics");
    assert!(resp.starts_with("HTTP/1.0 200"), "got: {resp}");
    assert!(resp.contains("\"serve\""));
    assert!(resp.contains("\"arena\""));
    assert!(resp.contains("\"blocks\""));
    assert!(resp.contains("\"accepted\""));
    // a software-only engine has no accelerator service attached: the
    // pool sections must be present but null, not missing
    assert!(resp.contains("\"accel_devices\":null"), "got: {resp}");
    assert!(resp.contains("\"accel_pool\":null"), "got: {resp}");
    let resp = get("/nope");
    assert!(resp.starts_with("HTTP/1.0 404"), "got: {resp}");
}

/// With a multi-device accelerated engine behind the server, `/metrics`
/// carries one row per device (its package counters + submission queue)
/// and the pool-level routing counters.
#[test]
fn admin_metrics_reports_per_device_accel_sections() {
    use std::io::Read;

    let mut config = EngineConfig::simulated(PartitionMode::ExtractOnly);
    config.accel.devices = 2;
    let engine = catalog(config);
    let server = start(engine, 16, 8);
    let admin = server.admin_addr().expect("admin configured");

    // traffic through the accelerated path so the per-device counters move
    let corpus = CorpusSpec::news(8, 256).with_seed(0x5E7E_0004).generate();
    let _ = run_load(server.local_addr(), &corpus.docs, 2, &[]).expect("load");

    let mut s = TcpStream::connect(admin).expect("admin connect");
    write!(s, "GET /metrics HTTP/1.0\r\nHost: x\r\n\r\n").expect("request");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("response");
    assert!(resp.starts_with("HTTP/1.0 200"), "got: {resp}");
    assert!(
        resp.contains("\"accel_devices\":[{\"device\":0,"),
        "per-device rows missing: {resp}"
    );
    assert!(resp.contains("\"device\":1,"), "second device missing: {resp}");
    assert!(
        resp.contains("\"accel_pool\":{\"retries\":"),
        "pool counters missing: {resp}"
    );
    assert!(resp.contains("\"sw_routed\":"), "routing counter missing: {resp}");
}
