//! End-to-end integration: every built-in query × every offload scenario ×
//! both package engines must produce identical annotations to the pure
//! software path, on realistic corpora — plus failure-injection and
//! concurrency tests of the full engine.

use boost::coordinator::{Engine, EngineConfig};
use boost::corpus::CorpusSpec;
use boost::partition::PartitionMode;
use boost::runtime::EngineSpec;
use boost::text::Document;

fn doc_rows(engine: &Engine, doc: &Document) -> Vec<String> {
    let mut rows: Vec<String> = engine
        .run_doc(doc)
        .iter()
        .flat_map(|(h, rows)| rows.iter().map(move |t| format!("{}:{t:?}", h.name())))
        .collect();
    rows.sort();
    rows
}

#[test]
fn all_queries_all_modes_equal_software_native() {
    let corpus = CorpusSpec::news(10, 1024).generate();
    for q in boost::queries::all() {
        let sw = Engine::compile_aql(&q.aql).unwrap();
        for mode in [
            PartitionMode::ExtractOnly,
            PartitionMode::SingleSubgraph,
            PartitionMode::MultiSubgraph,
        ] {
            let hw = Engine::with_config(
                &q.aql,
                EngineConfig::accelerated(mode, EngineSpec::Native),
            )
            .unwrap();
            for d in &corpus.docs {
                assert_eq!(
                    doc_rows(&sw, d),
                    doc_rows(&hw, d),
                    "query {} mode {:?} doc {}",
                    q.name,
                    mode,
                    d.id
                );
            }
            hw.shutdown();
        }
    }
}

#[test]
fn t1_pjrt_equals_software_on_corpus() {
    if !std::path::Path::new("artifacts/dfa_m8_s256_b16384.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let q = boost::queries::builtin("t1").unwrap();
    let corpus = CorpusSpec::news(12, 2048).generate();
    let sw = Engine::compile_aql(&q.aql).unwrap();
    let hw = Engine::with_config(
        &q.aql,
        EngineConfig::accelerated(
            PartitionMode::MultiSubgraph,
            EngineSpec::Pjrt {
                artifacts_dir: "artifacts".into(),
            },
        ),
    )
    .unwrap();
    let a = sw.run_corpus(&corpus, 1);
    let b = hw.run_corpus(&corpus, 4);
    assert_eq!(a.tuples, b.tuples);
    let snap = hw.accel_snapshot().unwrap();
    assert!(snap.packages > 0);
    hw.shutdown();
}

#[test]
fn tweets_and_logs_corpora_run_clean() {
    for (q, corpus) in [
        ("t3", CorpusSpec::tweets(40, 256).generate()),
        ("t2", CorpusSpec::logs(40, 512).generate()),
    ] {
        let q = boost::queries::builtin(q).unwrap();
        let sw = Engine::compile_aql(&q.aql).unwrap();
        let hw = Engine::with_config(
            &q.aql,
            EngineConfig::accelerated(PartitionMode::ExtractOnly, EngineSpec::Native),
        )
        .unwrap();
        let a = sw.run_corpus(&corpus, 2);
        let b = hw.run_corpus(&corpus, 2);
        assert_eq!(a.tuples, b.tuples, "{}", q.name);
        hw.shutdown();
    }
}

#[test]
fn engine_failure_missing_artifacts_surfaces_as_panic_not_hang() {
    // PJRT engine pointed at a bogus directory: the communication thread
    // fails the submissions; the worker observes a panic (not a deadlock).
    let q = boost::queries::builtin("t1").unwrap();
    let hw = Engine::with_config(
        &q.aql,
        EngineConfig::accelerated(
            PartitionMode::ExtractOnly,
            EngineSpec::Pjrt {
                artifacts_dir: "/nonexistent/path".into(),
            },
        ),
    )
    .unwrap();
    let corpus = CorpusSpec::news(2, 256).generate();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        hw.run_doc(&corpus.docs[0]);
    }));
    assert!(res.is_err(), "expected an accelerator error panic");
    hw.shutdown();
}

#[test]
fn unoptimized_engine_matches_optimized() {
    let q = boost::queries::builtin("t4").unwrap();
    let corpus = CorpusSpec::news(8, 1024).generate();
    let opt = Engine::compile_aql(&q.aql).unwrap();
    let mut cfg = EngineConfig::software();
    cfg.optimize = false;
    let naive = Engine::with_config(&q.aql, cfg).unwrap();
    for d in &corpus.docs {
        assert_eq!(doc_rows(&opt, d), doc_rows(&naive, d), "doc {}", d.id);
    }
}

#[test]
fn concurrent_mixed_corpus_stress() {
    // 8 oversubscribed workers × accelerated engine × mixed doc sizes:
    // exercises combining, package splits, and the wake-up protocol.
    let q = boost::queries::builtin("t1").unwrap();
    let hw = Engine::with_config(
        &q.aql,
        EngineConfig::accelerated(PartitionMode::SingleSubgraph, EngineSpec::Native),
    )
    .unwrap();
    let sw = Engine::compile_aql(&q.aql).unwrap();
    let mut docs = Vec::new();
    for (i, size) in [128usize, 2048, 256, 4096, 512]
        .iter()
        .cycle()
        .take(60)
        .enumerate()
    {
        let d = CorpusSpec::news(1, *size)
            .with_seed(i as u64 + 1)
            .generate()
            .docs
            .remove(0);
        // document ids must be unique per run (the accelerator runner
        // caches per (doc id, subgraph))
        docs.push(Document::new(i as u64, d.text.to_string()));
    }
    let corpus = boost::corpus::Corpus { docs };
    let a = hw.run_corpus(&corpus, 8);
    let b = sw.run_corpus(&corpus, 2);
    assert_eq!(a.tuples, b.tuples);
    let snap = hw.accel_snapshot().unwrap();
    assert_eq!(snap.docs as usize, corpus.len());
    hw.shutdown();
}

#[test]
fn aql_from_file_flow() {
    // mirrors the CLI --aql path: write a query file, compile, run
    let dir = std::env::temp_dir().join("boost_e2e_aql");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("q.aql");
    std::fs::write(
        &path,
        "create view Caps as extract regex /[A-Z][a-z]+/ on d.text as w from Document d;\n\
         output view Caps;\n",
    )
    .unwrap();
    let aql = std::fs::read_to_string(&path).unwrap();
    let engine = Engine::compile_aql(&aql).unwrap();
    let out = engine.run_doc(&Document::new(0, "Alice met Bob"));
    assert_eq!(out["Caps"].len(), 2); // Alice, Bob
}

#[test]
fn minus_and_block_operators() {
    // minus: capitalized words that are NOT org names; block: clusters of
    // number mentions.
    let aql = r#"
        create dictionary Orgs as ('IBM', 'Globex');
        create view Caps as
          extract regex /[A-Z][a-z]*/ on d.text as w from Document d;
        create view OrgM as
          extract dictionary 'Orgs' on d.text as w from Document d;
        create view NonOrgCaps as
          (select c.w as w from Caps c) minus (select o.w as w from OrgM o);
        create view Num as
          extract regex /\d+/ on d.text as n from Document d;
        create view NumCluster as
          block n.n with gap 4 min 2 from Num n;
        output view NonOrgCaps;
        output view NumCluster;
    "#;
    let engine = Engine::compile_aql(aql).unwrap();
    let text = "Alice at IBM saw 10 11 12 and then 99 alone; Globex and Bob.";
    let out = engine.run_doc(&Document::new(0, text));
    let caps: Vec<&str> = out["NonOrgCaps"]
        .iter()
        .map(|t| t[0].as_span().text(text))
        .collect();
    assert!(caps.contains(&"Alice") && caps.contains(&"Bob"));
    assert!(!caps.contains(&"IBM") && !caps.contains(&"Globex"));
    // 10 11 12 cluster (gaps of 1 char); 99 is alone (min 2)
    let clusters = &out["NumCluster"];
    assert_eq!(clusters.len(), 1, "{clusters:?}");
    assert_eq!(clusters[0][0].as_span().text(text), "10 11 12");

    // accelerated path must agree (both ops are hw-supported)
    let hw = Engine::with_config(
        aql,
        EngineConfig::accelerated(PartitionMode::MultiSubgraph, EngineSpec::Native),
    )
    .unwrap();
    assert_eq!(
        doc_rows(&engine, &Document::new(0, text)),
        doc_rows(&hw, &Document::new(0, text))
    );
    hw.shutdown();
}

#[test]
fn dictionary_from_file() {
    let dir = std::env::temp_dir().join("boost_dict_file");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("orgs.dict");
    std::fs::write(&path, "# comment line\nIBM\nIBM Research\n\n  Globex  \n").unwrap();
    let aql = format!(
        "create dictionary Orgs from file '{}';\n\
         create view O as extract dictionary 'Orgs' on d.text as m from Document d;\n\
         output view O;",
        path.display()
    );
    let engine = Engine::compile_aql(&aql).unwrap();
    let text = "Globex bought IBM Research.";
    let out = engine.run_doc(&Document::new(0, text));
    let hits: Vec<&str> = out["O"]
        .iter()
        .map(|t| t[0].as_span().text(text))
        .collect();
    assert!(hits.contains(&"Globex"));
    assert!(hits.contains(&"IBM Research"));
    // missing file is a clean error
    assert!(Engine::compile_aql(
        "create dictionary D from file '/no/such/file'; \
         create view V as extract dictionary 'D' on d.text as m from Document d; \
         output view V;"
    )
    .is_err());
}
