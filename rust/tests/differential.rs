//! The differential test harness for the hardware path (ISSUE 2 tentpole).
//!
//! Randomized corpora are pushed through three routes —
//!
//!   A. the pure-software executor (`Engine::run_doc` on an unpartitioned
//!      engine),
//!   B. the full streaming pipeline: `Session` + `AccelService` over the
//!      deterministic simulator (packing → communication thread →
//!      simulated scan → hit-stream decoding → span reconstruction →
//!      relational post-stage),
//!   C. synchronous `run_doc` on the simulated-accelerator engine —
//!
//! and every route must produce byte-identical views, document by
//! document. On top of that: a property-based `pack_group` → simulated
//! scan → span-reconstruction round-trip across every compiled block
//! size, fault-injection runs (duplicated/reordered hit records, failing
//! devices) driving the robustness path, and backpressure under a slow
//! simulated device.
//!
//! The corpus seed is fixed (reproducible CI) but overridable through the
//! `BOOST_DIFF_SEED` environment variable for fuzzing sessions.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use boost::accel::{pack_group, AccelOptions, AccelService, AccelSubgraphRunner};
use boost::coordinator::{CollectSink, Engine, EngineConfig};
use boost::corpus::CorpusSpec;
use boost::exec::{DocResult, Executor, Profiler};
use boost::hwcompiler::{compile_subgraph, AccelConfig, MatcherRef, BLOCK_SIZES};
use boost::partition::{partition, PartitionMode, PartitionPlan, SoftwareSubgraphRunner};
use boost::runtime::{
    EngineSpec, FaultPlan, PackageEngine, PackedPackage, SimPackageEngine, SimSpec,
};
use boost::text::{Document, TokenIndex};
use boost::util::{prop, Prng};

/// Fixed default seed; override with BOOST_DIFF_SEED=<u64> to fuzz.
fn seed() -> u64 {
    std::env::var("BOOST_DIFF_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1FF_2026)
}

/// Byte-exact rendering of every view tuple of one document. Lines are
/// sorted so the comparison is insensitive to tuple order within a view
/// (the content itself — spans, offsets, texts — must match byte for
/// byte).
fn render(doc: &Document, result: &DocResult) -> String {
    let mut lines: Vec<String> = Vec::new();
    for (h, rows) in result.iter() {
        for t in rows {
            let mut line = format!("{}|{}|", doc.id, h.name());
            for v in t {
                match v {
                    boost::aog::Value::Span(s) => {
                        line.push_str(&format!("[{},{})={:?};", s.begin, s.end, s.text(&doc.text)))
                    }
                    other => line.push_str(&format!("{other};")),
                }
            }
            lines.push(line);
        }
    }
    lines.sort();
    lines.join("\n")
}

/// Compile T1's subgraph configs for service-level tests (the same path
/// `Engine::with_config` takes internally).
fn t1_service_parts(mode: PartitionMode) -> (Vec<AccelConfig>, PartitionPlan) {
    let q = boost::queries::builtin("t1").unwrap();
    let g = boost::optimizer::optimize(&boost::aql::compile(&q.aql).unwrap());
    let plan = partition(&g, mode);
    let configs = plan
        .subgraphs
        .iter()
        .map(|s| compile_subgraph(s).unwrap())
        .collect();
    (configs, plan)
}

#[test]
fn three_routes_byte_identical_on_randomized_corpus() {
    let q = boost::queries::builtin("t1").unwrap();
    let sw = Engine::compile_aql(&q.aql).unwrap();
    let hw = Engine::with_config(
        &q.aql,
        EngineConfig::simulated(PartitionMode::SingleSubgraph),
    )
    .unwrap();

    // ≥ 200 randomized documents across all three corpus flavours, plus
    // handcrafted edge documents (empty, whitespace, dense entities)
    let mut rng = Prng::new(seed());
    let mut texts: Vec<String> = Vec::new();
    for d in CorpusSpec::news(120, 256).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for d in CorpusSpec::tweets(60, 128).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for d in CorpusSpec::logs(30, 320).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for e in [
        "",
        " ",
        "IBM",
        "Laura Chiticariu works at IBM Research in Almaden.",
        "IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM IBM",
    ] {
        texts.push(e.to_string());
    }
    let docs: Vec<Document> = texts
        .into_iter()
        .enumerate()
        .map(|(i, t)| Document::new(i as u64, t))
        .collect();
    assert!(docs.len() >= 200, "acceptance floor: {} docs", docs.len());

    // route A: pure-software executor
    let route_a: Vec<String> = docs.iter().map(|d| render(d, &sw.run_doc(d))).collect();

    // route B: Session + AccelService over the simulator
    let sink = Arc::new(CollectSink::default());
    let mut session = hw
        .session()
        .threads(4)
        .queue_depth(4)
        .sink(sink.clone())
        .start();
    for d in &docs {
        session.push(d.clone()).unwrap();
    }
    let report = session.finish();
    assert_eq!(report.docs, docs.len());
    let by_id: HashMap<u64, DocResult> = sink
        .take()
        .into_iter()
        .map(|(d, r)| (d.id, r))
        .collect();

    // route C: synchronous run_doc on a FRESH simulated engine — the
    // engine route B ran would serve every document from the
    // AccelSubgraphRunner's per-(doc, text, subgraph) cache, which would
    // make this route a replay of route B instead of an uncombined
    // re-execution of the whole pipeline
    let hw_sync = Engine::with_config(
        &q.aql,
        EngineConfig::simulated(PartitionMode::SingleSubgraph),
    )
    .unwrap();
    for (i, d) in docs.iter().enumerate() {
        let b = render(d, &by_id[&d.id]);
        assert_eq!(
            route_a[i], b,
            "route A (software) vs B (session over sim) diverged on doc {}: {:?}",
            d.id, d.text
        );
        let c = render(d, &hw_sync.run_doc(d));
        assert_eq!(
            route_a[i], c,
            "route A (software) vs C (sim run_doc) diverged on doc {}: {:?}",
            d.id, d.text
        );
    }
    let sim_sync = hw_sync.sim_snapshot().unwrap();
    assert!(
        sim_sync.packages >= docs.len() as u64,
        "route C must have re-scanned every document (uncombined), got {} packages",
        sim_sync.packages
    );
    hw_sync.shutdown();

    let sim = hw.sim_snapshot().expect("simulated engine exposes sim stats");
    assert!(sim.packages > 0, "the simulator must have scanned packages");
    assert_eq!(sim.faults, 0);
    let accel = hw.accel_snapshot().unwrap();
    assert!(accel.docs >= docs.len() as u64, "every doc crossed the HW path");
    assert!(accel.cycles > 0, "cycle accounting must flow into metrics");
    hw.shutdown();
}

#[test]
fn every_query_and_mode_agrees_with_software_under_the_simulator() {
    let corpus = CorpusSpec::news(8, 512).generate();
    for q in boost::queries::all() {
        let sw = Engine::compile_aql(&q.aql).unwrap();
        for mode in [
            PartitionMode::ExtractOnly,
            PartitionMode::SingleSubgraph,
            PartitionMode::MultiSubgraph,
        ] {
            let hw = Engine::with_config(&q.aql, EngineConfig::simulated(mode)).unwrap();
            for d in &corpus.docs {
                assert_eq!(
                    render(d, &sw.run_doc(d)),
                    render(d, &hw.run_doc(d)),
                    "query {} mode {:?} doc {}",
                    q.name,
                    mode,
                    d.id
                );
            }
            hw.shutdown();
        }
    }
}

#[test]
fn packing_roundtrip_recovers_reference_spans_across_block_sizes() {
    // pack_group → simulated scan → span reconstruction must recover
    // exactly the spans of a direct reference scan, for every compiled
    // block size, including empty documents and NUL-adjacent placements
    // (exact block fits leave no room for the separator byte).
    const Q: &str = "create dictionary D as ('abc', 'cab', 'b');\n\
                     create view R as extract regex /ab+/ on d.text as m from Document d;\n\
                     create view W as extract dictionary 'D' on d.text as m from Document d;\n\
                     output view R; output view W;";
    let g = boost::optimizer::optimize(&boost::aql::compile(Q).unwrap());
    let plan = partition(&g, PartitionMode::ExtractOnly);
    let cfg = compile_subgraph(&plan.subgraphs[0]).unwrap();
    let (tables, accepts) = cfg.pack_tables();
    let (tables, accepts) = (Arc::new(tables), Arc::new(accepts));

    for &block in BLOCK_SIZES {
        let sim = SimPackageEngine::new(SimSpec::default());
        prop::check(
            seed() ^ block as u64,
            40,
            |r: &mut Prng| prop::packing_corpus(r, 10, block, b"abc "),
            |texts| {
                let docs: Vec<Document> = texts
                    .iter()
                    .enumerate()
                    .map(|(i, t)| Document::new(i as u64, t.as_str()))
                    .collect();
                let refs: Vec<&Document> = docs.iter().collect();
                let (pkgs, oversized) = pack_group(&refs, block);
                if !oversized.is_empty() {
                    return false; // nothing in the generator exceeds block
                }
                // decode: per-document, per-machine (local_end, state)
                let mut per_doc: Vec<Vec<Vec<(usize, u32)>>> =
                    vec![vec![Vec::new(); cfg.machines.len()]; docs.len()];
                for wp in &pkgs {
                    let pkg = PackedPackage {
                        bytes: wp.bytes.clone(),
                        block,
                        tables: tables.clone(),
                        accepts: accepts.clone(),
                        machines: cfg.geometry.0,
                        states: cfg.geometry.1,
                    };
                    let out = match sim.run(cfg.artifact_key(block), &pkg) {
                        Ok(o) => o,
                        Err(_) => return false,
                    };
                    for (m, stream, pos, state) in out.hits {
                        if m >= cfg.machines.len() {
                            return false; // padding machines never hit
                        }
                        match wp.slot_at(stream, pos) {
                            Some(si) => {
                                let slot = wp.slots[si];
                                per_doc[slot.doc_index][m].push((pos + 1 - slot.offset, state));
                            }
                            // a hit on a separator or padding byte would be
                            // a NUL-isolation bug
                            None => return false,
                        }
                    }
                }
                // reconstruct and compare against direct reference scans
                for (di, d) in docs.iter().enumerate() {
                    for (mi, machine) in cfg.machines.iter().enumerate() {
                        match &machine.matcher {
                            MatcherRef::Regex(re) => {
                                let ends: Vec<usize> =
                                    per_doc[di][mi].iter().map(|&(e, _)| e).collect();
                                if re.from_hw_ends(&d.text, &ends) != re.find_all(&d.text) {
                                    return false;
                                }
                            }
                            MatcherRef::Dict(ac) => {
                                let mut hw = ac.from_hw_states(d.text.as_bytes(), &per_doc[di][mi]);
                                let mut sw = ac.find_token_matches(d.text.as_bytes());
                                hw.sort_by_key(|m| (m.span.begin, m.span.end, m.entry));
                                sw.sort_by_key(|m| (m.span.begin, m.span.end, m.entry));
                                if hw != sw {
                                    return false;
                                }
                            }
                        }
                    }
                }
                true
            },
        );
    }
}

#[test]
fn duplicated_and_reordered_hit_records_are_normalized_by_the_post_stage() {
    // transport-layer corruption the post-stage must absorb: every hit
    // record duplicated AND the stream shuffled — views still byte-
    // identical to software
    let q = boost::queries::builtin("t1").unwrap();
    let sw = Engine::compile_aql(&q.aql).unwrap();
    let spec = SimSpec::default().with_fault(FaultPlan {
        duplicate_hits: true,
        reorder_hits: true,
        ..FaultPlan::none()
    });
    let hw = Engine::with_config(
        &q.aql,
        EngineConfig::accelerated(PartitionMode::SingleSubgraph, EngineSpec::Sim(spec)),
    )
    .unwrap();
    let corpus = CorpusSpec::news(20, 512).generate();
    for d in &corpus.docs {
        assert_eq!(
            render(d, &sw.run_doc(d)),
            render(d, &hw.run_doc(d)),
            "corrupted hit stream leaked into views on doc {}",
            d.id
        );
    }
    let sim = hw.sim_snapshot().unwrap();
    assert!(sim.faults > 0, "fault injection must actually have fired");
    hw.shutdown();
}

#[test]
fn injected_package_failures_fail_submissions_cleanly() {
    // a bricked device (every package errors) must fail the waiting
    // worker's submission with an error — never hang it
    let (configs, _plan) = t1_service_parts(PartitionMode::ExtractOnly);
    let spec = SimSpec::default().with_fault(FaultPlan {
        fail_every: 1,
        ..FaultPlan::none()
    });
    let service = AccelService::start(
        configs,
        EngineSpec::Sim(spec.clone()),
        AccelOptions::default(),
    );
    let doc = Document::new(0, "Laura Chiticariu works at IBM Research.");
    let rx = service.submit(0, doc, Arc::new(TokenIndex::default()), vec![]);
    let res = rx.recv().expect("a reply must arrive even on device failure");
    let err = res.expect_err("the injected fault must surface as an error");
    assert!(err.to_string().contains("injected device fault"), "{err}");
    assert!(spec.snapshot().faults >= 1);
    service.shutdown();
}

#[test]
fn bricked_device_in_a_pool_fails_over_and_stays_byte_identical() {
    // 3-device pool where device 1 is bricked (every package errors):
    // the dispatcher must retry its packages on the healthy siblings
    // (or re-scan them on the host), and the views of a randomized
    // corpus must stay byte-identical to the pure-software route —
    // workers never see the dead device.
    let (configs, plan) = t1_service_parts(PartitionMode::ExtractOnly);
    let healthy_a = SimSpec::default();
    let bricked = SimSpec::default().with_fault(FaultPlan {
        fail_every: 1,
        ..FaultPlan::none()
    });
    let healthy_b = SimSpec::default();
    let service = AccelService::start_pool(
        configs,
        vec![
            EngineSpec::Sim(healthy_a.clone()),
            EngineSpec::Sim(bricked.clone()),
            EngineSpec::Sim(healthy_b.clone()),
        ],
        AccelOptions::default(),
    );
    assert_eq!(service.devices(), 3);

    let accel_exec = Executor::new(
        Arc::new(plan.supergraph.clone()),
        Arc::new(Profiler::disabled()),
    )
    .with_subgraph_runner(Arc::new(AccelSubgraphRunner::new(service.clone(), &plan)));
    let sw_exec = Executor::new(
        Arc::new(plan.supergraph.clone()),
        Arc::new(Profiler::disabled()),
    )
    .with_subgraph_runner(Arc::new(SoftwareSubgraphRunner::new(&plan)));

    // randomized corpus across all three flavours, plus edge documents
    let mut rng = Prng::new(seed() ^ 0xFA11_0BE5);
    let mut texts: Vec<String> = Vec::new();
    for d in CorpusSpec::news(20, 512).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for d in CorpusSpec::tweets(20, 200).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    for d in CorpusSpec::logs(20, 384).with_seed(rng.next_u64()).generate().docs {
        texts.push(d.text.to_string());
    }
    texts.push(String::new());
    texts.push("IBM ".repeat(200));

    for (i, text) in texts.iter().enumerate() {
        let doc = Document::new(i as u64, text.as_str());
        assert_eq!(
            render(&doc, &accel_exec.run_doc(&doc)),
            render(&doc, &sw_exec.run_doc(&doc)),
            "doc {i} diverged through the bricked-device pool"
        );
    }

    // the bricked device must actually have been exercised and failed...
    assert!(
        bricked.snapshot().faults > 0,
        "round-robin dispatch must have routed packages to the bricked device"
    );
    // ...and its packages must have been recovered, not errored out
    let pool = service.pool_snapshot();
    assert!(
        pool.retries > 0,
        "the dead device's documents must have been re-queued on siblings"
    );
    assert!(
        pool.failovers + pool.sw_fallbacks > 0,
        "retried documents must have completed on a sibling or the host"
    );
    // the healthy siblings did real scans
    assert!(healthy_a.snapshot().packages + healthy_b.snapshot().packages > 0);
    service.shutdown();
}

#[test]
fn bricked_simulator_surfaces_as_panic_not_hang() {
    // engine-level counterpart: run_doc over a failing device panics the
    // worker (the documented contract) instead of deadlocking
    let q = boost::queries::builtin("t1").unwrap();
    let spec = SimSpec::default().with_fault(FaultPlan {
        fail_every: 1,
        ..FaultPlan::none()
    });
    let hw = Engine::with_config(
        &q.aql,
        EngineConfig::accelerated(PartitionMode::ExtractOnly, EngineSpec::Sim(spec)),
    )
    .unwrap();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        hw.run_doc(&Document::new(0, "Alice met Bob at IBM."));
    }));
    assert!(res.is_err(), "expected an accelerator error panic");
    hw.shutdown();
}

#[test]
fn slow_simulator_backpressure_bounds_the_submission_queue() {
    // a slow device (40 ms per package) against a depth-2 submission
    // queue: producers must block (stall count + nonzero blocked time)
    // and the queue must never exceed its bound
    let (configs, _plan) = t1_service_parts(PartitionMode::ExtractOnly);
    let spec = SimSpec::default().with_latency(Duration::from_millis(40));
    let service = AccelService::start(
        configs,
        EngineSpec::Sim(spec.clone()),
        AccelOptions {
            queue_depth: 2,
            ..AccelOptions::default()
        },
    );
    let text = "Laura Chiticariu works at IBM Research in Almaden.";
    let mut waiters = vec![service.submit(
        0,
        Document::new(0, text),
        Arc::new(TokenIndex::default()),
        vec![],
    )];
    // let the communication thread drain the first submission and enter
    // the 40 ms simulated scan
    std::thread::sleep(Duration::from_millis(10));
    for i in 1..=5u64 {
        // queue depth 2: the third of these pushes must block until the
        // device finishes its scan
        waiters.push(service.submit(
            i,
            Document::new(i, text),
            Arc::new(TokenIndex::default()),
            vec![],
        ));
    }
    for rx in waiters {
        rx.recv().expect("reply").expect("scan must succeed");
    }
    let q = service.queue_snapshot();
    assert_eq!(q.pushed, 6);
    assert!(
        q.stalls > 0,
        "queue depth 2 against a 40 ms/package device must stall the producer"
    );
    assert!(
        q.blocked_ns > 0,
        "stalled pushes must accumulate nonzero blocked time"
    );
    assert!(
        q.high_water <= 3,
        "bounded queue exceeded its depth: high water {}",
        q.high_water
    );
    assert!(spec.snapshot().packages > 0);
    service.shutdown();
}

#[test]
fn truncated_packages_are_rejected_not_scanned() {
    // hand-build a package with a truncated byte lane and a mismatched
    // geometry: the simulator must reject both with a readable error
    let (configs, _plan) = t1_service_parts(PartitionMode::ExtractOnly);
    let cfg = &configs[0];
    let (tables, accepts) = cfg.pack_tables();
    let block = 4096;
    let sim = SimPackageEngine::new(SimSpec::default());
    let pkg = PackedPackage {
        bytes: vec![0i32; 100], // truncated: should be STREAMS * block
        block,
        tables: Arc::new(tables),
        accepts: Arc::new(accepts),
        machines: cfg.geometry.0,
        states: cfg.geometry.1,
    };
    let err = sim
        .run(cfg.artifact_key(block), &pkg)
        .expect_err("truncated package must be rejected")
        .to_string();
    assert!(err.contains("truncated package"), "{err}");
    assert_eq!(sim.stats().snapshot().packages, 0);
}
