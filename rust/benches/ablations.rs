//! Ablations of the design choices DESIGN.md calls out:
//!
//! A1. work-package combining (the paper's >1000 B rule) vs one package
//!     per document — the mechanism behind Fig 6's small-document penalty;
//! A2. package block size (4096 vs 16384 bytes per stream), measured;
//! A3. number of parallel hardware streams (the paper fixes 4);
//! A4. multi-subgraph accounting: optimistic (paper's Fig 7 assumption,
//!     one pass) vs pessimistic (one accelerator pass per subgraph).

use std::sync::Arc;

use boost::accel::{AccelOptions, AccelService};
use boost::bench::{mbps, speedup, Table};
use boost::coordinator::Engine;
use boost::corpus::CorpusSpec;
use boost::hwcompiler::compile_subgraph;
use boost::partition::{partition, PartitionMode};
use boost::perfmodel::FpgaModel;
use boost::runtime::EngineSpec;
use boost::text::TokenIndex;

fn main() {
    a1_combining();
    a2_block_size();
    a3_streams();
    a4_multi_subgraph_accounting();
}

fn a1_combining() {
    let m = FpgaModel::paper();
    let mut t = Table::new(
        "A1 — package combining vs per-document packages (modeled, MB/s)",
        &["doc B", "combined", "uncombined", "gain"],
    );
    for &size in &[128usize, 256, 512, 1024, 2048, 4096] {
        let c = m.throughput(size, 16384);
        let u = m.throughput_uncombined(size);
        t.row(&[
            size.to_string(),
            mbps(c),
            mbps(u),
            speedup(c / u),
        ]);
    }
    t.print();
    println!("  the >1000 B combining rule matters most for small documents");
}

fn a2_block_size() {
    let q = boost::queries::builtin("t1").unwrap();
    let g = boost::optimizer::optimize(&boost::aql::compile(&q.aql).unwrap());
    let plan = partition(&g, PartitionMode::ExtractOnly);
    let cfg = compile_subgraph(&plan.subgraphs[0]).unwrap();
    let corpus = CorpusSpec::news(256, 1024).generate();

    let mut t = Table::new(
        "A2 — package block size (native engine, 256 docs x 1 KiB)",
        &["block", "wall ms", "pkgs", "docs/pkg", "measured MB/s"],
    );
    for &block in boost::hwcompiler::BLOCK_SIZES {
        let service = AccelService::start(
            vec![cfg.clone()],
            EngineSpec::Native,
            AccelOptions {
                block,
                ..Default::default()
            },
        );
        let t0 = std::time::Instant::now();
        let next = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= corpus.docs.len() {
                        break;
                    }
                    let rx = service.submit(
                        0,
                        corpus.docs[i].clone(),
                        Arc::new(TokenIndex::default()),
                        vec![],
                    );
                    rx.recv().unwrap().unwrap();
                });
            }
        });
        let wall = t0.elapsed();
        let snap = service.metrics().snapshot();
        service.shutdown();
        t.row(&[
            block.to_string(),
            format!("{:.1}", wall.as_secs_f64() * 1e3),
            snap.packages.to_string(),
            format!("{:.1}", snap.docs_per_package()),
            mbps(corpus.total_bytes() as f64 / wall.as_secs_f64()),
        ]);
    }
    t.print();
}

fn a3_streams() {
    let mut t = Table::new(
        "A3 — parallel hardware streams (modeled peak-constrained, 2 KiB docs)",
        &["streams", "raw GB/s", "tp MB/s"],
    );
    for &streams in &[1usize, 2, 4, 8] {
        let m = FpgaModel {
            bw_raw: 0.25e9 * streams as f64, // 250 MHz x 1 B/cycle/stream
            peak: (0.125e9 * streams as f64).min(2.5e9), // interface: raw/2
            ..FpgaModel::paper()
        };
        t.row(&[
            streams.to_string(),
            format!("{:.2}", m.bw_raw / 1e9),
            mbps(m.throughput(2048, 16384)),
        ]);
    }
    t.print();
    println!("  the paper's 4 streams saturate the measured 500 MB/s interface ceiling");
}

fn a4_multi_subgraph_accounting() {
    let model = FpgaModel::paper();
    let q = boost::queries::builtin("t5").unwrap();
    let engine = Engine::compile_aql(&q.aql).expect("compile");
    let corpus = CorpusSpec::news(200, 2048).generate();
    let r = engine.run_corpus(&corpus, 1);
    let tp_sw = r.throughput();
    let profile = engine.profile();
    let plan = partition(engine.graph(), PartitionMode::MultiSubgraph);
    let offloaded: Vec<usize> = plan
        .subgraphs
        .iter()
        .flat_map(|s| s.orig_nodes.iter().copied())
        .collect();
    let frac = profile.fraction_of_nodes(&offloaded);
    let subgraphs = plan.subgraphs.len();

    let mut t = Table::new(
        "A4 — T5 multi-subgraph: optimistic vs pessimistic pass accounting",
        &["accounting", "passes", "x2048B"],
    );
    t.row(&[
        "optimistic (paper Fig 7)".into(),
        "1".into(),
        speedup(model.estimate(tp_sw, frac, 2048, 16384, 1) / tp_sw),
    ]);
    t.row(&[
        "pessimistic (1/subgraph)".into(),
        subgraphs.to_string(),
        speedup(model.estimate(tp_sw, frac, 2048, 16384, subgraphs) / tp_sw),
    ]);
    t.print();
    println!("  the paper notes its multi-subgraph estimate ignores the extra communication");
}
