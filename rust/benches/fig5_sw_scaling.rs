//! E2 / paper Fig 5: software throughput vs number of worker threads for
//! 256-byte documents.
//!
//! TESTBED NOTE: the paper's POWER7 exposes 64 logical threads; this
//! machine has ONE core, so measured scaling is necessarily flat — the
//! paper's *shape* (near-linear to 8 threads, roll-off, the SMT-scheduler
//! jump between 32 and 40) is reproduced from a calibrated scaling model
//! documented below, and the measured column shows the real (1-core)
//! behaviour for honesty.

use boost::bench::{mbps, Table};
use boost::coordinator::Engine;
use boost::corpus::CorpusSpec;

/// POWER7 scaling multipliers read off the paper's Fig 5 relative to one
/// thread: near-linear to 8 (per-core), a roll-off while SMT threads pile
/// onto the first chip, and the jump at 40 when the OS scheduler spills to
/// the second processor (paper §4.1's explanation).
const POWER7_SCALE: &[(usize, f64)] = &[
    (1, 1.0),
    (2, 1.95),
    (4, 3.8),
    (8, 7.2),
    (16, 8.4),
    (32, 9.8),
    (40, 13.0),
    (48, 13.8),
    (56, 14.2),
    (64, 14.5),
];

fn main() {
    let threads_list: Vec<usize> = POWER7_SCALE.iter().map(|&(t, _)| t).collect();
    let corpus = CorpusSpec::tweets(1500, 256).generate();

    let queries = boost::queries::all();
    let mut table = Table::new(
        "Fig 5 — SW throughput (MB/s) vs threads, 256 B docs (measured on 1 core + POWER7-shape model)",
        &[
            "threads", "t1", "t2", "t3", "t4", "t5", "t1*model", "t5*model",
        ],
    );

    // single-thread baselines for the modeled curves
    let mut base = std::collections::HashMap::new();
    for q in &queries {
        let engine = Engine::compile_aql(&q.aql).expect("compile");
        let r = engine.run_corpus(&corpus, 1);
        base.insert(q.name, r.throughput());
    }

    for &t in &threads_list {
        let mut cells = vec![t.to_string()];
        for q in &queries {
            let engine = Engine::compile_aql(&q.aql).expect("compile");
            let r = engine.run_corpus(&corpus, t);
            cells.push(mbps(r.throughput()));
        }
        let scale = POWER7_SCALE
            .iter()
            .find(|&&(x, _)| x == t)
            .map(|&(_, s)| s)
            .unwrap_or(1.0);
        cells.push(mbps(base["t1"] * scale));
        cells.push(mbps(base["t5"] * scale));
        table.row(&cells);
    }
    table.print();
    println!("\nclaims: near-linear to 8 threads; roll-off after; jump between 32 and 40");
    println!("        (modeled columns; measured columns are flat on this 1-core testbed)");
    println!("        T5 throughput > T1-T4 (extraction-light query is faster in software)");
}
