//! Layer-level microbenchmarks — the profiling substrate for the §Perf
//! pass in EXPERIMENTS.md: regex engine scan rate, dictionary scan rate,
//! package engine latency (native vs PJRT), packing rate, consolidation.

use boost::bench::{mbps, time_median, Table};
use boost::corpus::CorpusSpec;
use boost::dict::{CaseMode, Dictionary};
use boost::hwcompiler::{compile_subgraph, STREAMS};
use boost::partition::{partition, PartitionMode};
use boost::runtime::{EngineSpec, PackageEngine, PackedPackage};
use boost::text::span::{consolidate, ConsolidatePolicy};
use boost::text::Span;

fn big_text(bytes: usize) -> String {
    let c = CorpusSpec::news(1, bytes).generate();
    c.docs[0].text.to_string()
}

fn main() {
    regex_scan();
    dict_scan();
    package_engines();
    packing_rate();
    consolidate_rate();
}

fn regex_scan() {
    let text = big_text(1 << 20);
    let mut t = Table::new(
        "regex engine — software scan rate over 1 MiB of news text",
        &["pattern", "matches", "MB/s"],
    );
    for pat in [
        r"[A-Z][a-z]+ [A-Z][a-z]+",
        r"\d{3}-\d{4}",
        r"[a-z0-9_]+@[a-z0-9]+\.[a-z]{2,4}",
        r"\$\d+(\.\d+)? million",
        r"[A-Z][A-Z0-9]{1,4}",
        r"http:\/\/[a-z0-9\.\/\-]+",
    ] {
        let re = boost::regex::compile(pat, false).unwrap();
        let mut found = 0usize;
        let d = time_median(
            || {
                found = re.find_all(&text).len();
            },
            1,
            5,
        );
        t.row(&[
            format!("/{pat}/"),
            found.to_string(),
            mbps(text.len() as f64 / d.as_secs_f64()),
        ]);
    }
    t.print();
}

fn dict_scan() {
    let text = big_text(1 << 20);
    let mut t = Table::new(
        "dictionary engine — Aho-Corasick scan rate over 1 MiB",
        &["dictionary", "entries", "matches", "MB/s"],
    );
    for (name, pool, case) in [
        ("orgs", boost::corpus::pools::ORGS, CaseMode::Insensitive),
        ("locations", boost::corpus::pools::LOCATIONS, CaseMode::Insensitive),
        ("sentiment", boost::corpus::pools::SENTIMENT, CaseMode::Exact),
    ] {
        let d = Dictionary::new(name, pool.iter().map(|s| s.to_string()).collect(), case);
        let ac = d.compile();
        let mut found = 0usize;
        let dur = time_median(
            || {
                found = ac.find_token_matches(text.as_bytes()).len();
            },
            1,
            5,
        );
        t.row(&[
            name.to_string(),
            d.entries.len().to_string(),
            found.to_string(),
            mbps(text.len() as f64 / dur.as_secs_f64()),
        ]);
    }
    t.print();
}

fn package_engines() {
    let q = boost::queries::builtin("t1").unwrap();
    let g = boost::optimizer::optimize(&boost::aql::compile(&q.aql).unwrap());
    let plan = partition(&g, PartitionMode::ExtractOnly);
    let cfg = compile_subgraph(&plan.subgraphs[0]).unwrap();
    let (tables, accepts) = cfg.pack_tables();

    let mut t = Table::new(
        "package engine — per-package latency (T1 extraction config, full block)",
        &["engine", "block", "latency ms", "MB/s"],
    );
    for &block in boost::hwcompiler::BLOCK_SIZES {
        let corpus = CorpusSpec::news(STREAMS, block).generate();
        let mut bytes = vec![0i32; STREAMS * block];
        for (s, doc) in corpus.docs.iter().enumerate() {
            for (i, b) in doc.text.bytes().enumerate() {
                bytes[s * block + i] = b as i32;
            }
        }
        let pkg = PackedPackage {
            bytes,
            block,
            tables: std::sync::Arc::new(tables.clone()),
            accepts: std::sync::Arc::new(accepts.clone()),
            machines: cfg.geometry.0,
            states: cfg.geometry.1,
        };
        let key = cfg.artifact_key(block);
        let specs: Vec<EngineSpec> = if std::path::Path::new("artifacts").exists() {
            vec![
                EngineSpec::Native,
                EngineSpec::Pjrt {
                    artifacts_dir: "artifacts".into(),
                },
            ]
        } else {
            vec![EngineSpec::Native]
        };
        for spec in specs {
            let engine = spec.build().unwrap();
            // warm the executable cache before timing
            engine.run(key, &pkg).unwrap();
            let d = time_median(
                || {
                    engine.run(key, &pkg).unwrap();
                },
                1,
                5,
            );
            t.row(&[
                spec.name().to_string(),
                block.to_string(),
                format!("{:.2}", d.as_secs_f64() * 1e3),
                mbps((STREAMS * block) as f64 / d.as_secs_f64()),
            ]);
        }
    }
    t.print();
}

fn packing_rate() {
    let corpus = CorpusSpec::tweets(4096, 256).generate();
    let refs: Vec<&boost::text::Document> = corpus.docs.iter().collect();
    let mut pkgs = 0usize;
    let d = time_median(
        || {
            let (p, _) = boost::accel::pack_group(&refs, 16384);
            pkgs = p.len();
        },
        1,
        5,
    );
    let mut t = Table::new("work-package packing", &["docs", "pkgs", "docs/s"]);
    t.row(&[
        corpus.len().to_string(),
        pkgs.to_string(),
        format!("{:.0}", corpus.len() as f64 / d.as_secs_f64()),
    ]);
    t.print();
}

fn consolidate_rate() {
    use boost::util::Prng;
    let mut rng = Prng::new(5);
    let spans: Vec<Span> = (0..10_000)
        .map(|_| {
            let b = rng.below(100_000) as u32;
            Span::new(b, b + rng.range(1, 60) as u32)
        })
        .collect();
    let mut t = Table::new("consolidation — 10k spans", &["policy", "kept", "ms"]);
    for policy in [
        ConsolidatePolicy::ContainedWithin,
        ConsolidatePolicy::ExactMatch,
        ConsolidatePolicy::LeftToRight,
    ] {
        let mut kept = 0usize;
        let d = time_median(
            || {
                kept = consolidate(&spans, policy).len();
            },
            1,
            5,
        );
        t.row(&[
            policy.name().to_string(),
            kept.to_string(),
            format!("{:.2}", d.as_secs_f64() * 1e3),
        ]);
    }
    t.print();
}
