//! E4+E5 / paper Fig 7 and the headline claim: estimated overall system
//! throughput via Eq. (1) for the three offload scenarios, at 256 B and
//! 2048 B documents.
//!
//! `tp_SW` is this testbed's measured software throughput; `rt_SW` comes
//! from the measured per-operator profile and the partition plan (exactly
//! the paper's §5 method); `tp_HW` comes from the calibrated FPGA model.
//! The reported numbers are *speedups over software*, the quantity the
//! paper's bars convey. Paper: T1 ×10 at 256 B, ×16 at 2 kB (multi-
//! subgraph); T5 gains little from extract-only but ~×3 from multiple
//! subgraphs.

use boost::bench::{speedup, Table};
use boost::coordinator::Engine;
use boost::corpus::CorpusSpec;
use boost::partition::{partition, PartitionMode};
use boost::perfmodel::FpgaModel;

fn main() {
    let model = FpgaModel::paper();
    let block = 16384usize;

    let mut table = Table::new(
        "Fig 7 — Eq.1 estimated speedup over software (per query, per scenario)",
        &[
            "query", "sw MB/s", "scenario", "offload%", "x256B", "x2048B",
        ],
    );

    for q in boost::queries::all() {
        // software baseline + profile on the optimized graph
        let engine = Engine::compile_aql(&q.aql).expect("compile");
        let corpus = CorpusSpec::news(250, 2048).generate();
        let report = engine.run_corpus(&corpus, 1);
        let tp_sw = report.throughput();
        let profile = engine.profile();

        let g = engine.graph().clone();
        for mode in [
            PartitionMode::ExtractOnly,
            PartitionMode::SingleSubgraph,
            PartitionMode::MultiSubgraph,
        ] {
            let plan = partition(&g, mode);
            let offloaded: Vec<usize> = plan
                .subgraphs
                .iter()
                .flat_map(|s| s.orig_nodes.iter().copied())
                .collect();
            let frac = profile.fraction_of_nodes(&offloaded);
            let est = |size: usize| -> f64 {
                model.estimate(tp_sw, frac, size, block, 1) / tp_sw
            };
            table.row(&[
                q.name.to_string(),
                format!("{:.1}", tp_sw / 1e6),
                mode.name().to_string(),
                format!("{:.1}", frac * 100.0),
                speedup(est(256)),
                speedup(est(2048)),
            ]);
        }
    }
    table.print();
    println!("\npaper claims: T1 up to 4.8x (extract-only), ~10x at 256 B and ~16x at 2 kB");
    println!("              (multi-subgraph); T5 limited until multi-subgraph (~3x)");
}
