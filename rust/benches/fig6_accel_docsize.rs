//! E3 / paper Fig 6: accelerator throughput vs document size (four
//! parallel streams, T1's extraction operators configured).
//!
//! Two series: the *modeled* FPGA throughput (the paper's constants —
//! peak 500 MB/s, per-document interface overhead) regenerates the
//! figure's shape; the *measured* series drives real work packages
//! through the PJRT-executed Pallas kernel and reports wall-clock
//! throughput of this testbed's "device" (a CPU interpreting the DFA
//! kernel — absolute numbers differ, the doc-size sensitivity shape is
//! the claim).

use std::sync::Arc;

use boost::accel::{AccelOptions, AccelService};
use boost::bench::{mbps, Table};
use boost::corpus::CorpusSpec;
use boost::hwcompiler::compile_subgraph;
use boost::partition::{partition, PartitionMode};
use boost::perfmodel::FpgaModel;
use boost::runtime::EngineSpec;
use boost::text::TokenIndex;

fn main() {
    let q = boost::queries::builtin("t1").unwrap();
    let g = boost::optimizer::optimize(&boost::aql::compile(&q.aql).unwrap());
    let plan = partition(&g, PartitionMode::ExtractOnly);
    let cfg = compile_subgraph(&plan.subgraphs[0]).unwrap();
    let model = FpgaModel::paper();

    let engine = if std::path::Path::new("artifacts/dfa_m8_s256_b16384.hlo.txt").exists() {
        EngineSpec::Pjrt {
            artifacts_dir: "artifacts".into(),
        }
    } else {
        eprintln!("artifacts/ missing; falling back to the native engine");
        EngineSpec::Native
    };

    let mut table = Table::new(
        "Fig 6 — accelerator throughput vs document size (T1 extraction, 4 streams, 16 KiB packages)",
        &[
            "doc B", "modeled MB/s", "peak/modeled", "measured MB/s", "pkgs", "docs/pkg",
        ],
    );

    for &size in &[128usize, 256, 512, 1024, 2048, 4096, 8192] {
        // enough docs for several packages
        let n_docs = (64 * 16384 / size).clamp(64, 2048);
        let corpus = CorpusSpec::news(n_docs, size).generate();

        let service = AccelService::start(
            vec![cfg.clone()],
            engine.clone(),
            AccelOptions::default(),
        );
        let t0 = std::time::Instant::now();
        // drive from 4 worker threads (document-per-thread submissions)
        let next = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= corpus.docs.len() {
                        break;
                    }
                    let doc = &corpus.docs[i];
                    let rx = service.submit(
                        0,
                        doc.clone(),
                        Arc::new(TokenIndex::default()),
                        vec![],
                    );
                    rx.recv().unwrap().unwrap();
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let snap = service.metrics().snapshot();
        service.shutdown();

        let modeled = model.throughput(size, 16384);
        table.row(&[
            size.to_string(),
            mbps(modeled),
            format!("{:.1}", model.peak / modeled),
            mbps(corpus.total_bytes() as f64 / wall),
            snap.packages.to_string(),
            format!("{:.1}", snap.docs_per_package()),
        ]);
    }
    table.print();
    println!("\nclaims: peak (500 MB/s) at >=2 kB docs; /5 at 256 B; /10 at 128 B");
}
