//! E1 / paper Fig 4: relative time spent in each operator family for the
//! five queries. The paper's claims: T1–T4 are dominated by extraction
//! operators (regex + dictionaries, up to 82 %); T5 spends >80 % in
//! relational operators.

use boost::bench::Table;
use boost::coordinator::Engine;
use boost::corpus::CorpusSpec;

/// Extraction-fraction bands read off the paper's Fig 4 (±10 % — it is a
/// stacked bar chart). Only the *shape* (which side dominates) feeds the
/// downstream estimates.
const PAPER_EXTRACTION_HINT: &[(&str, &str)] = &[
    ("t1", "~0.82"),
    ("t2", "~0.75"),
    ("t3", "~0.70"),
    ("t4", "~0.65"),
    ("t5", "<0.20"),
];

fn main() {
    let corpus = CorpusSpec::news(300, 2048).generate();
    let mut table = Table::new(
        "Fig 4 — relative operator time (300 news docs x 2048 B, 1 worker)",
        &[
            "query", "Regex%", "Dict%", "Join%", "Consol%", "Proj%", "other%",
            "extraction%", "paper",
        ],
    );
    for q in boost::queries::all() {
        let engine = Engine::compile_aql(&q.aql).expect("compile");
        engine.run_corpus(&corpus, 1);
        let p = engine.profile();
        let pick = |name: &str| -> f64 {
            p.by_operator()
                .get(name)
                .map(|o| o.fraction * 100.0)
                .unwrap_or(0.0)
        };
        let known = pick("RegularExpression")
            + pick("Dictionary")
            + pick("Join")
            + pick("Consolidate")
            + pick("Project");
        let paper = PAPER_EXTRACTION_HINT
            .iter()
            .find(|(n, _)| *n == q.name)
            .map(|(_, v)| *v)
            .unwrap_or("-");
        table.row(&[
            q.name.to_string(),
            format!("{:.1}", pick("RegularExpression")),
            format!("{:.1}", pick("Dictionary")),
            format!("{:.1}", pick("Join")),
            format!("{:.1}", pick("Consolidate")),
            format!("{:.1}", pick("Project")),
            format!("{:.1}", (100.0 - known).max(0.0)),
            format!("{:.1}", p.fraction_extraction() * 100.0),
            paper.to_string(),
        ]);
    }
    table.print();
    println!(
        "\nclaim check: T1-T4 extraction-dominated, T5 relational-dominated (>80% relational)"
    );
}
