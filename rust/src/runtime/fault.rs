//! Fault-containment primitives shared by the session, accelerator and
//! serving layers: structured per-document errors, the poison-document
//! [`Quarantine`] registry, per-device [`CircuitBreaker`]s, and the
//! liveness [`Watchdog`] that `GET /healthz` reports.
//!
//! The design rule across all four: **one bad document, one flapping
//! device or one stalled thread must never take the process with it.**
//! Workers convert panics into [`DocError`]s instead of dying, devices
//! that error repeatedly are circuit-broken out of dispatch instead of
//! feeding retry storms, and every long-lived thread publishes a
//! heartbeat so a wedged pipeline is *visible* (503) instead of silent.
//!
//! See ARCHITECTURE.md § "Fault containment" for the end-to-end picture
//! and [`crate::runtime::chaos`] for the seeded harness that drives all
//! of it at once.

use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// structured per-document errors
// ---------------------------------------------------------------------------

/// Why a document failed instead of producing a
/// [`DocResult`](crate::exec::DocResult). Delivered through
/// [`ResultSink::on_error`](crate::coordinator::ResultSink::on_error) and,
/// over the wire, as a `DocErr` frame with the matching
/// [`error code`](crate::serve::protocol::ERROR_TAXONOMY).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DocError {
    /// The document's deadline budget expired — either while it was still
    /// queued (checked at dequeue) or during execution (checked after the
    /// accelerator post-stage and after the software run).
    DeadlineExceeded {
        /// The budget the request carried.
        budget: Duration,
        /// How long the document had actually been in the pipeline when
        /// the expiry was detected.
        waited: Duration,
    },
    /// Execution panicked on this document; the panic was contained in
    /// the worker, the document was quarantined, and the worker kept
    /// going.
    Panicked {
        /// Rendered panic payload.
        message: String,
    },
}

impl DocError {
    /// Classify a payload caught by `catch_unwind` around per-document
    /// execution: a [`DeadlinePanic`] marker (raised by the accelerator
    /// path when a submission expired) becomes `DeadlineExceeded`;
    /// anything else is a genuine poison-document panic.
    pub fn from_panic(payload: Box<dyn Any + Send>) -> DocError {
        match payload.downcast::<DeadlinePanic>() {
            Ok(d) => DocError::DeadlineExceeded {
                budget: d.budget,
                waited: d.waited,
            },
            Err(other) => DocError::Panicked {
                message: panic_message(&other),
            },
        }
    }

    /// True for the deadline variant.
    pub fn is_deadline(&self) -> bool {
        matches!(self, DocError::DeadlineExceeded { .. })
    }
}

impl std::fmt::Display for DocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DocError::DeadlineExceeded { budget, waited } => write!(
                f,
                "deadline exceeded: budget {:.1} ms, waited {:.1} ms",
                budget.as_secs_f64() * 1e3,
                waited.as_secs_f64() * 1e3
            ),
            DocError::Panicked { message } => write!(f, "execution panicked: {message}"),
        }
    }
}

/// Typed panic payload the accelerator fetch path raises (via
/// `std::panic::panic_any`) when a submission came back
/// deadline-expired, so the session worker's `catch_unwind` can classify
/// the failure as [`DocError::DeadlineExceeded`] rather than a poison
/// document.
#[derive(Debug, Clone, Copy)]
pub struct DeadlinePanic {
    /// The budget the request carried.
    pub budget: Duration,
    /// Time spent before the expiry was detected.
    pub waited: Duration,
}

/// Render a `catch_unwind` payload: `&str` and `String` panics (the
/// overwhelmingly common cases) come through verbatim, anything else gets
/// a stable placeholder.
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// per-document deadline propagation (worker thread → accel submission)
// ---------------------------------------------------------------------------

thread_local! {
    static DOC_DEADLINE: std::cell::Cell<Option<Instant>> = const { std::cell::Cell::new(None) };
    static DOC_BUDGET: std::cell::Cell<Option<Duration>> = const { std::cell::Cell::new(None) };
}

/// Install the current document's absolute deadline on this worker thread
/// (the accelerator runner picks it up when building a `Submission`,
/// without widening the `SubgraphRunner` trait). Returns a guard that
/// clears it on drop, so a panicking run cannot leak the deadline onto
/// the next document.
pub fn set_doc_deadline(deadline: Option<Instant>, budget: Option<Duration>) -> DeadlineGuard {
    DOC_DEADLINE.with(|c| c.set(deadline));
    DOC_BUDGET.with(|c| c.set(budget));
    DeadlineGuard
}

/// The absolute deadline of the document currently executing on this
/// thread, if any.
pub fn doc_deadline() -> Option<Instant> {
    DOC_DEADLINE.with(|c| c.get())
}

/// The budget (relative form of [`doc_deadline`]) of the current
/// document, for error reporting.
pub fn doc_budget() -> Option<Duration> {
    DOC_BUDGET.with(|c| c.get())
}

/// Clears the thread-local deadline on drop — see [`set_doc_deadline`].
pub struct DeadlineGuard;

impl Drop for DeadlineGuard {
    fn drop(&mut self) {
        DOC_DEADLINE.with(|c| c.set(None));
        DOC_BUDGET.with(|c| c.set(None));
    }
}

// ---------------------------------------------------------------------------
// poison-document quarantine
// ---------------------------------------------------------------------------

/// One quarantined document: enough to reproduce and debug the poison
/// without holding the document body alive.
#[derive(Debug, Clone)]
pub struct QuarantineEntry {
    /// Id of the document that killed its worker attempt.
    pub doc_id: u64,
    /// Where execution died (e.g. `session worker` or `subgraph #2`).
    pub context: String,
    /// Rendered panic payload.
    pub payload: String,
}

/// Bounded registry of poison documents. Recording is lock-light (one
/// short mutex hold), the ring keeps only the most recent `cap` entries,
/// and `total` counts every quarantine ever recorded so `/metrics` sees
/// the true rate even after eviction.
#[derive(Debug)]
pub struct Quarantine {
    cap: usize,
    total: AtomicU64,
    entries: Mutex<VecDeque<QuarantineEntry>>,
}

/// Default retained-entry cap for [`Quarantine::new`] callers.
pub const DEFAULT_QUARANTINE_CAP: usize = 64;

impl Default for Quarantine {
    fn default() -> Self {
        Quarantine::new(DEFAULT_QUARANTINE_CAP)
    }
}

impl Quarantine {
    /// A registry retaining at most `cap` entries (older entries evict).
    pub fn new(cap: usize) -> Quarantine {
        Quarantine {
            cap: cap.max(1),
            total: AtomicU64::new(0),
            entries: Mutex::new(VecDeque::new()),
        }
    }

    /// Record a poison document.
    pub fn record(&self, doc_id: u64, context: &str, payload: String) {
        self.total.fetch_add(1, Ordering::Relaxed);
        let mut q = self.entries.lock().unwrap();
        if q.len() == self.cap {
            q.pop_front();
        }
        q.push_back(QuarantineEntry {
            doc_id,
            context: context.to_string(),
            payload,
        });
    }

    /// Every quarantine ever recorded (monotonic, survives eviction).
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Currently retained entries (most recent last).
    pub fn entries(&self) -> Vec<QuarantineEntry> {
        self.entries.lock().unwrap().iter().cloned().collect()
    }

    /// Number of retained entries.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when nothing is quarantined.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// per-device circuit breaker
// ---------------------------------------------------------------------------

/// Breaker states, the classic three-state machine:
///
/// ```text
///            K consecutive errors
///   Closed ──────────────────────▶ Open
///      ▲                            │ cooldown elapsed
///      │ probe succeeds             ▼
///      └──────────────────────── HalfOpen ──▶ (probe fails) back to Open
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: dispatch freely.
    Closed,
    /// Tripped: no dispatch until the cooldown elapses.
    Open,
    /// Cooldown elapsed: exactly one probe package is in flight.
    HalfOpen,
}

impl BreakerState {
    /// Stable lowercase name (used in `/healthz` JSON).
    pub fn name(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

const STATE_CLOSED: u8 = 0;
const STATE_OPEN: u8 = 1;
const STATE_HALF_OPEN: u8 = 2;

/// Per-device circuit breaker. All methods are lock-free and callable
/// from any thread (the dispatching session workers and the device's
/// communication thread share one instance).
#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    cooldown: Duration,
    epoch: Instant,
    state: AtomicU8,
    consecutive: AtomicU32,
    /// Milliseconds since `epoch` at which the breaker last opened.
    opened_at_ms: AtomicU64,
    trips: AtomicU64,
    probes: AtomicU64,
    readmits: AtomicU64,
}

/// Default consecutive-error threshold before a device trips Open.
pub const DEFAULT_BREAKER_THRESHOLD: u32 = 3;
/// Default Open→HalfOpen cooldown.
pub const DEFAULT_BREAKER_COOLDOWN: Duration = Duration::from_millis(50);

impl Default for CircuitBreaker {
    fn default() -> Self {
        CircuitBreaker::new(DEFAULT_BREAKER_THRESHOLD, DEFAULT_BREAKER_COOLDOWN)
    }
}

impl CircuitBreaker {
    /// A closed breaker tripping after `threshold` consecutive errors and
    /// probing again `cooldown` after opening.
    pub fn new(threshold: u32, cooldown: Duration) -> CircuitBreaker {
        CircuitBreaker {
            threshold: threshold.max(1),
            cooldown,
            epoch: Instant::now(),
            state: AtomicU8::new(STATE_CLOSED),
            consecutive: AtomicU32::new(0),
            opened_at_ms: AtomicU64::new(0),
            trips: AtomicU64::new(0),
            probes: AtomicU64::new(0),
            readmits: AtomicU64::new(0),
        }
    }

    /// May work be dispatched to this device right now?
    ///
    /// * `Closed` — yes.
    /// * `Open` — no, unless the cooldown elapsed, in which case exactly
    ///   one caller wins the transition to `HalfOpen` and its package
    ///   becomes the probe.
    /// * `HalfOpen` — no (one probe at a time).
    pub fn admit(&self) -> bool {
        match self.state.load(Ordering::Acquire) {
            STATE_CLOSED => true,
            STATE_HALF_OPEN => false,
            _ => {
                let opened = self.opened_at_ms.load(Ordering::Acquire);
                let now_ms = self.epoch.elapsed().as_millis() as u64;
                if now_ms.saturating_sub(opened) < self.cooldown.as_millis() as u64 {
                    return false;
                }
                // cooldown elapsed: one caller wins the probe slot
                if self
                    .state
                    .compare_exchange(
                        STATE_OPEN,
                        STATE_HALF_OPEN,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    self.probes.fetch_add(1, Ordering::Relaxed);
                    true
                } else {
                    false
                }
            }
        }
    }

    /// The device answered a package successfully: reset the error run;
    /// a successful half-open probe re-admits the device (→ `Closed`).
    pub fn record_success(&self) {
        self.consecutive.store(0, Ordering::Relaxed);
        if self
            .state
            .compare_exchange(
                STATE_HALF_OPEN,
                STATE_CLOSED,
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_ok()
        {
            self.readmits.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The device errored on a package: extend the error run; trip to
    /// `Open` at the threshold, and re-open immediately on a failed
    /// half-open probe.
    pub fn record_error(&self) {
        let run = self.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        let state = self.state.load(Ordering::Acquire);
        let should_open = state == STATE_HALF_OPEN || (state == STATE_CLOSED && run >= self.threshold);
        if should_open {
            self.opened_at_ms
                .store(self.epoch.elapsed().as_millis() as u64, Ordering::Release);
            if self.state.swap(STATE_OPEN, Ordering::AcqRel) != STATE_OPEN {
                self.trips.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Non-mutating preview of [`CircuitBreaker::admit`]: would a
    /// dispatch be admitted right now? Unlike `admit` this never claims
    /// the half-open probe slot, so routers can test "is any device
    /// available at all?" without burning probes on devices they won't
    /// pick.
    pub fn would_admit(&self) -> bool {
        match self.state.load(Ordering::Acquire) {
            STATE_CLOSED => true,
            STATE_HALF_OPEN => false,
            _ => {
                let opened = self.opened_at_ms.load(Ordering::Acquire);
                let now_ms = self.epoch.elapsed().as_millis() as u64;
                now_ms.saturating_sub(opened) >= self.cooldown.as_millis() as u64
            }
        }
    }

    /// Current state.
    pub fn state(&self) -> BreakerState {
        match self.state.load(Ordering::Acquire) {
            STATE_HALF_OPEN => BreakerState::HalfOpen,
            STATE_OPEN => BreakerState::Open,
            _ => BreakerState::Closed,
        }
    }

    /// Point-in-time counters.
    pub fn snapshot(&self) -> BreakerSnapshot {
        BreakerSnapshot {
            state: self.state(),
            consecutive_errors: self.consecutive.load(Ordering::Relaxed),
            trips: self.trips.load(Ordering::Relaxed),
            probes: self.probes.load(Ordering::Relaxed),
            readmits: self.readmits.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of one [`CircuitBreaker`]'s counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerSnapshot {
    /// Current state.
    pub state: BreakerState,
    /// Length of the current consecutive-error run.
    pub consecutive_errors: u32,
    /// Closed→Open (or HalfOpen→Open) transitions.
    pub trips: u64,
    /// Open→HalfOpen probe dispatches.
    pub probes: u64,
    /// HalfOpen→Closed re-admissions.
    pub readmits: u64,
}

// ---------------------------------------------------------------------------
// liveness watchdog
// ---------------------------------------------------------------------------

/// One long-lived thread's liveness record. Threads call
/// [`Heartbeat::beat`] at the top of every work loop iteration,
/// [`Heartbeat::idle`] right before blocking on an empty queue (an idle
/// thread is healthy no matter how long it blocks), and
/// [`Heartbeat::retire`] on clean exit.
#[derive(Debug)]
pub struct Heartbeat {
    name: String,
    /// Milliseconds since the owning watchdog's epoch at the last beat.
    last_ms: AtomicU64,
    beats: AtomicU64,
    idle: AtomicBool,
    retired: AtomicBool,
    epoch: Instant,
}

impl Heartbeat {
    /// Mark the thread alive and busy.
    pub fn beat(&self) {
        self.last_ms
            .store(self.epoch.elapsed().as_millis() as u64, Ordering::Release);
        self.beats.fetch_add(1, Ordering::Relaxed);
        self.idle.store(false, Ordering::Release);
    }

    /// Mark the thread idle (about to block waiting for work). Idle
    /// threads never count as stalled.
    pub fn idle(&self) {
        self.last_ms
            .store(self.epoch.elapsed().as_millis() as u64, Ordering::Release);
        self.idle.store(true, Ordering::Release);
    }

    /// Mark the thread cleanly exited (it stops being watched).
    pub fn retire(&self) {
        self.retired.store(true, Ordering::Release);
    }
}

/// Watches a registry of [`Heartbeat`]s and reports which threads look
/// stalled: busy (not idle, not retired) with no beat for longer than
/// `stall_after`. `GET /healthz` renders the report (503 when any thread
/// stalls).
#[derive(Debug)]
pub struct Watchdog {
    epoch: Instant,
    stall_after: Duration,
    threads: Mutex<Vec<Arc<Heartbeat>>>,
}

/// Default busy-with-no-beat window before a thread is flagged stalled.
pub const DEFAULT_STALL_AFTER: Duration = Duration::from_secs(10);

impl Default for Watchdog {
    fn default() -> Self {
        Watchdog::new(DEFAULT_STALL_AFTER)
    }
}

impl Watchdog {
    /// A watchdog flagging busy threads silent for `stall_after`.
    pub fn new(stall_after: Duration) -> Watchdog {
        Watchdog {
            epoch: Instant::now(),
            stall_after,
            threads: Mutex::new(Vec::new()),
        }
    }

    /// Register a thread; it starts idle (healthy) until its first beat.
    pub fn register(&self, name: impl Into<String>) -> Arc<Heartbeat> {
        let hb = Arc::new(Heartbeat {
            name: name.into(),
            last_ms: AtomicU64::new(self.epoch.elapsed().as_millis() as u64),
            beats: AtomicU64::new(0),
            idle: AtomicBool::new(true),
            retired: AtomicBool::new(false),
            epoch: self.epoch,
        });
        self.threads.lock().unwrap().push(hb.clone());
        hb
    }

    /// Per-thread liveness right now. Retired threads are dropped from
    /// the registry as a side effect (a finished session's workers don't
    /// linger in `/healthz`).
    pub fn report(&self) -> HealthReport {
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        let stall_ms = self.stall_after.as_millis() as u64;
        let mut threads = self.threads.lock().unwrap();
        threads.retain(|hb| !hb.retired.load(Ordering::Acquire));
        let rows: Vec<ThreadHealth> = threads
            .iter()
            .map(|hb| {
                let idle = hb.idle.load(Ordering::Acquire);
                let age_ms = now_ms.saturating_sub(hb.last_ms.load(Ordering::Acquire));
                ThreadHealth {
                    name: hb.name.clone(),
                    beats: hb.beats.load(Ordering::Relaxed),
                    idle,
                    age_ms,
                    stalled: !idle && age_ms > stall_ms,
                }
            })
            .collect();
        let healthy = rows.iter().all(|t| !t.stalled);
        HealthReport {
            healthy,
            threads: rows,
        }
    }
}

/// One thread's row in a [`HealthReport`].
#[derive(Debug, Clone)]
pub struct ThreadHealth {
    /// Thread name (e.g. `session-worker-0`, `accel-comm-1`).
    pub name: String,
    /// Total beats.
    pub beats: u64,
    /// Currently blocked waiting for work (healthy by definition).
    pub idle: bool,
    /// Milliseconds since the last beat (or idle transition).
    pub age_ms: u64,
    /// Busy and silent past the stall window.
    pub stalled: bool,
}

/// The watchdog's verdict: healthy (200) unless any busy thread stalled
/// (503), with the per-thread detail.
#[derive(Debug, Clone)]
pub struct HealthReport {
    /// No thread is stalled.
    pub healthy: bool,
    /// Per-thread rows.
    pub threads: Vec<ThreadHealth>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quarantine_is_bounded_and_counts_total() {
        let q = Quarantine::new(2);
        q.record(1, "worker", "a".into());
        q.record(2, "worker", "b".into());
        q.record(3, "worker", "c".into());
        assert_eq!(q.total(), 3);
        let e = q.entries();
        assert_eq!(e.len(), 2);
        assert_eq!(e[0].doc_id, 2, "oldest entry evicted");
        assert_eq!(e[1].doc_id, 3);
    }

    #[test]
    fn breaker_trips_probes_and_readmits() {
        let b = CircuitBreaker::new(3, Duration::from_millis(10));
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_error();
        b.record_error();
        assert!(b.admit(), "below threshold stays closed");
        b.record_error();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admit(), "open rejects before cooldown");
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.admit(), "cooldown elapsed: probe admitted");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.admit(), "one probe at a time");
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        let s = b.snapshot();
        assert_eq!(s.trips, 1);
        assert_eq!(s.probes, 1);
        assert_eq!(s.readmits, 1);
    }

    #[test]
    fn failed_probe_reopens() {
        let b = CircuitBreaker::new(1, Duration::from_millis(5));
        b.record_error();
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(8));
        assert!(b.admit());
        b.record_error();
        assert_eq!(b.state(), BreakerState::Open, "failed probe re-opens");
        assert_eq!(b.snapshot().trips, 2);
    }

    #[test]
    fn success_resets_consecutive_run() {
        let b = CircuitBreaker::new(3, Duration::from_millis(5));
        b.record_error();
        b.record_error();
        b.record_success();
        b.record_error();
        b.record_error();
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn watchdog_flags_busy_silence_not_idle() {
        let w = Watchdog::new(Duration::from_millis(20));
        let busy = w.register("busy");
        let idle = w.register("idle");
        busy.beat();
        idle.idle();
        std::thread::sleep(Duration::from_millis(35));
        let r = w.report();
        assert!(!r.healthy);
        let busy_row = r.threads.iter().find(|t| t.name == "busy").unwrap();
        let idle_row = r.threads.iter().find(|t| t.name == "idle").unwrap();
        assert!(busy_row.stalled);
        assert!(!idle_row.stalled, "idle threads are healthy");
    }

    #[test]
    fn retired_threads_leave_the_report() {
        let w = Watchdog::new(Duration::from_millis(1));
        let hb = w.register("worker");
        hb.beat();
        hb.retire();
        std::thread::sleep(Duration::from_millis(5));
        let r = w.report();
        assert!(r.healthy);
        assert!(r.threads.is_empty());
    }

    #[test]
    fn doc_error_classifies_deadline_panics() {
        let e = DocError::from_panic(Box::new(DeadlinePanic {
            budget: Duration::from_millis(5),
            waited: Duration::from_millis(9),
        }));
        assert!(e.is_deadline());
        let e = DocError::from_panic(Box::new("boom".to_string()));
        assert_eq!(
            e,
            DocError::Panicked {
                message: "boom".into()
            }
        );
    }

    #[test]
    fn deadline_guard_clears_thread_local() {
        {
            let _g = set_doc_deadline(
                Some(Instant::now()),
                Some(Duration::from_millis(1)),
            );
            assert!(doc_deadline().is_some());
            assert!(doc_budget().is_some());
        }
        assert!(doc_deadline().is_none());
        assert!(doc_budget().is_none());
    }
}
