//! Seeded chaos plans: deterministic per-document fault injection.
//!
//! A [`ChaosPlan`] decides — from the seed and the document id alone —
//! whether a document gets an injected panic, an injected delay, or runs
//! clean. Because the decision is a pure hash of `(seed, doc_id)`, the
//! faulted set is identical across runs and *independent of thread
//! scheduling*: a chaos test can assert that every unaffected document
//! produced byte-identical views, not just that "most things worked".
//!
//! The plan is consulted by session workers *inside* their containment
//! boundary (`catch_unwind` + deadline guard), so an injected panic
//! exercises exactly the same quarantine path a real poison document
//! would. The `repro chaos` CLI and `tests/chaos.rs` both drive their
//! runs through this type; the serve layer accepts one via
//! `ServeConfig` so the loopback server can be subjected to the same
//! schedule.

use std::time::Duration;

/// What the chaos plan wants done to one document.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosAction {
    /// Run the document normally.
    None,
    /// Panic inside the worker's containment boundary — the document
    /// must surface as a quarantined `DocError::Panicked`, never as a
    /// crashed worker.
    Panic,
    /// Sleep for the given duration before executing — under a deadline
    /// this forces a `DocError::DeadlineExceeded` without any panic.
    Delay(Duration),
}

/// A seeded, deterministic fault-injection schedule.
///
/// `panic_mod` / `delay_mod` are selection moduli: a document is picked
/// when its per-plan hash is divisible by the modulus, so roughly one in
/// `m` documents is affected. `0` disables that fault class entirely.
/// Panic selection wins over delay selection when both match.
#[derive(Debug, Clone)]
pub struct ChaosPlan {
    /// Seed mixed into every per-document decision.
    pub seed: u64,
    /// Inject a panic on ~1/`panic_mod` documents (0 = never).
    pub panic_mod: u64,
    /// Inject a delay on ~1/`delay_mod` documents (0 = never).
    pub delay_mod: u64,
    /// How long an injected delay sleeps.
    pub delay: Duration,
}

impl ChaosPlan {
    /// A plan with the given seed and no faults enabled.
    pub fn new(seed: u64) -> ChaosPlan {
        ChaosPlan {
            seed,
            panic_mod: 0,
            delay_mod: 0,
            delay: Duration::ZERO,
        }
    }

    /// Enable injected panics on roughly one in `m` documents.
    pub fn panic_every(mut self, m: u64) -> ChaosPlan {
        self.panic_mod = m;
        self
    }

    /// Enable injected delays of `delay` on roughly one in `m` documents.
    pub fn delay_every(mut self, m: u64, delay: Duration) -> ChaosPlan {
        self.delay_mod = m;
        self.delay = delay;
        self
    }

    /// The action for one document — a pure function of `(seed, doc_id)`.
    pub fn doc_action(&self, doc_id: u64) -> ChaosAction {
        let h = mix(self.seed ^ doc_id.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        if self.panic_mod != 0 && h % self.panic_mod == 0 {
            return ChaosAction::Panic;
        }
        // decorrelate the delay draw from the panic draw so the two
        // fault sets are independent samples, not nested ones
        let h2 = mix(h ^ 0xd1b5_4a32_d192_ed03);
        if self.delay_mod != 0 && h2 % self.delay_mod == 0 {
            return ChaosAction::Delay(self.delay);
        }
        ChaosAction::None
    }

    /// True when `doc_action` would inject a panic for this document.
    pub fn panics(&self, doc_id: u64) -> bool {
        matches!(self.doc_action(doc_id), ChaosAction::Panic)
    }

    /// True when `doc_action` would inject a delay for this document.
    pub fn delays(&self, doc_id: u64) -> bool {
        matches!(self.doc_action(doc_id), ChaosAction::Delay(_))
    }

    /// True when the plan can affect any document at all.
    pub fn is_active(&self) -> bool {
        self.panic_mod != 0 || self.delay_mod != 0
    }
}

/// 64-bit finalizer (murmur3-style): avalanche so consecutive doc ids
/// land on unrelated residues.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    x ^= x >> 33;
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_plan_never_faults() {
        let plan = ChaosPlan::new(42);
        assert!(!plan.is_active());
        for id in 0..1000 {
            assert_eq!(plan.doc_action(id), ChaosAction::None);
        }
    }

    #[test]
    fn selection_is_deterministic() {
        let a = ChaosPlan::new(7).panic_every(5);
        let b = ChaosPlan::new(7).panic_every(5);
        for id in 0..1000 {
            assert_eq!(a.doc_action(id), b.doc_action(id));
        }
    }

    #[test]
    fn panic_rate_is_roughly_one_in_m() {
        let plan = ChaosPlan::new(42).panic_every(10);
        let hits = (0..10_000u64).filter(|&id| plan.panics(id)).count();
        // 1/10 of 10k = 1000 expected; allow generous slack, the point
        // is "not zero, not everything"
        assert!((500..2000).contains(&hits), "panic hits: {hits}");
    }

    #[test]
    fn different_seeds_pick_different_docs() {
        let a = ChaosPlan::new(1).panic_every(4);
        let b = ChaosPlan::new(2).panic_every(4);
        let same = (0..4096u64)
            .filter(|&id| a.panics(id) == b.panics(id))
            .count();
        assert!(same < 4096, "seeds produced identical fault sets");
    }

    #[test]
    fn panic_wins_over_delay() {
        let plan = ChaosPlan::new(9)
            .panic_every(1)
            .delay_every(1, Duration::from_millis(1));
        for id in 0..100 {
            assert_eq!(plan.doc_action(id), ChaosAction::Panic);
        }
    }

    #[test]
    fn delay_carries_configured_duration() {
        let d = Duration::from_millis(17);
        let plan = ChaosPlan::new(3).delay_every(1, d);
        assert_eq!(plan.doc_action(0), ChaosAction::Delay(d));
        assert!(plan.delays(1));
    }
}
