//! Bounded MPMC work queue — the one scheduler primitive shared by the
//! software and hardware paths.
//!
//! A [`Session`](crate::coordinator::Session) feeds its worker pool through
//! one of these, and [`AccelService`](crate::accel::AccelService) receives
//! package submissions through another, so producers on either path get
//! the same behaviour: a full queue *blocks the producer* (backpressure)
//! instead of buffering unboundedly, and every queue exports the same
//! [`QueueStats`] gauges (depth, high-water, stall count).
//!
//! Built on [`std::sync::mpsc::sync_channel`]; the receiver half is
//! mutex-wrapped so a pool of consumers can share it (workers queue on the
//! mutex while one blocks in `recv`, which is equivalent to all of them
//! blocking on the channel). Single-consumer loops that combine work —
//! the accelerator's communication thread batching submissions into
//! packages — use [`QueueRx::drain_into`] to sweep everything pending
//! under one lock acquisition instead of locking per item.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};

use crate::metrics::{QueueSnapshot, QueueStats};

/// Producer half. Cloneable; all clones feed the same queue.
pub struct QueueTx<T> {
    tx: SyncSender<T>,
    stats: Arc<QueueStats>,
}

impl<T> Clone for QueueTx<T> {
    fn clone(&self) -> Self {
        QueueTx {
            tx: self.tx.clone(),
            stats: self.stats.clone(),
        }
    }
}

/// Consumer half. Shareable across a worker pool via `Arc`.
pub struct QueueRx<T> {
    rx: Mutex<Receiver<T>>,
    stats: Arc<QueueStats>,
}

/// Create a bounded queue holding at most `depth` items (≥ 1).
pub fn bounded<T>(depth: usize) -> (QueueTx<T>, QueueRx<T>) {
    let (tx, rx) = sync_channel(depth.max(1));
    let stats = Arc::new(QueueStats::default());
    (
        QueueTx {
            tx,
            stats: stats.clone(),
        },
        QueueRx {
            rx: Mutex::new(rx),
            stats,
        },
    )
}

impl<T> QueueTx<T> {
    /// Push one item, blocking while the queue is full (backpressure).
    /// Returns the item back when every consumer is gone.
    pub fn push(&self, item: T) -> Result<(), T> {
        match self.tx.try_send(item) {
            Ok(()) => {
                self.stats.on_push();
                Ok(())
            }
            Err(TrySendError::Full(item)) => {
                self.stats.on_stall();
                let t0 = std::time::Instant::now();
                let pushed = self.tx.send(item);
                self.stats.on_blocked(t0.elapsed().as_nanos() as u64);
                match pushed {
                    Ok(()) => {
                        self.stats.on_push();
                        Ok(())
                    }
                    Err(e) => Err(e.0),
                }
            }
            Err(TrySendError::Disconnected(item)) => Err(item),
        }
    }

    /// Push one item only if the queue has room **right now** — never
    /// blocks. Returns the item back when the queue is full or every
    /// consumer is gone. The accelerator pool's failover path uses this
    /// to forward a failed package to a sibling device: a communication
    /// thread must never block on another communication thread's queue
    /// (two full queues forwarding at each other would deadlock).
    pub fn try_push(&self, item: T) -> Result<(), T> {
        match self.tx.try_send(item) {
            Ok(()) => {
                self.stats.on_push();
                Ok(())
            }
            Err(TrySendError::Full(item)) | Err(TrySendError::Disconnected(item)) => Err(item),
        }
    }

    /// The queue's gauges (shared with the consumer half).
    pub fn stats(&self) -> &Arc<QueueStats> {
        &self.stats
    }

    /// Snapshot the gauges.
    pub fn snapshot(&self) -> QueueSnapshot {
        self.stats.snapshot()
    }
}

impl<T> QueueRx<T> {
    /// Pop one item, blocking while the queue is empty. Returns `None`
    /// once every producer is gone and the queue has drained — the
    /// consumer's termination signal.
    pub fn pop(&self) -> Option<T> {
        let item = self.rx.lock().unwrap().recv().ok();
        if item.is_some() {
            self.stats.on_pop();
        }
        item
    }

    /// Pop without blocking; `None` when the queue is currently empty or
    /// closed.
    pub fn try_pop(&self) -> Option<T> {
        match self.rx.lock().unwrap().try_recv() {
            Ok(item) => {
                self.stats.on_pop();
                Some(item)
            }
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Drain everything currently queued into `buf` without blocking,
    /// taking the receiver lock **once** for the whole sweep — the
    /// communication thread's handoff primitive (one lock per combining
    /// round instead of one per submission). Returns how many items were
    /// appended; `buf`'s existing contents are kept.
    pub fn drain_into(&self, buf: &mut Vec<T>) -> usize {
        let rx = self.rx.lock().unwrap();
        let mut n = 0;
        while let Ok(item) = rx.try_recv() {
            self.stats.on_pop();
            buf.push(item);
            n += 1;
        }
        n
    }

    /// The queue's gauges (shared with the producer half).
    pub fn stats(&self) -> &Arc<QueueStats> {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn fifo_within_single_producer_consumer() {
        let (tx, rx) = bounded::<u32>(4);
        for i in 0..4 {
            tx.push(i).unwrap();
        }
        for i in 0..4 {
            assert_eq!(rx.pop(), Some(i));
        }
        assert_eq!(rx.try_pop(), None);
    }

    #[test]
    fn pop_returns_none_after_producers_drop() {
        let (tx, rx) = bounded::<u32>(2);
        tx.push(7).unwrap();
        drop(tx);
        assert_eq!(rx.pop(), Some(7));
        assert_eq!(rx.pop(), None);
    }

    #[test]
    fn push_fails_after_consumer_drops() {
        let (tx, rx) = bounded::<u32>(2);
        drop(rx);
        assert_eq!(tx.push(1), Err(1));
    }

    #[test]
    fn full_queue_blocks_and_counts_stalls() {
        let (tx, rx) = bounded::<u32>(1);
        tx.push(0).unwrap();
        let unblocked = Arc::new(AtomicUsize::new(0));
        let flag = unblocked.clone();
        let t = std::thread::spawn(move || {
            tx.push(1).unwrap(); // must block until the pop below
            flag.store(1, Ordering::SeqCst);
            tx.snapshot()
        });
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(unblocked.load(Ordering::SeqCst), 0, "push must block on a full queue");
        assert_eq!(rx.pop(), Some(0));
        let snap = t.join().unwrap();
        assert_eq!(unblocked.load(Ordering::SeqCst), 1);
        assert!(snap.stalls >= 1, "the blocked push must be counted as a stall");
        assert!(
            snap.blocked_ns > 0,
            "a push that waited ~50 ms must report nonzero blocked time"
        );
        assert_eq!(snap.pushed, 2);
    }

    #[test]
    fn try_push_never_blocks_on_a_full_queue() {
        let (tx, rx) = bounded::<u32>(1);
        assert_eq!(tx.try_push(1), Ok(()));
        assert_eq!(tx.try_push(2), Err(2), "full queue must bounce, not block");
        let snap = tx.snapshot();
        assert_eq!(snap.pushed, 1, "a bounced try_push is not counted as pushed");
        assert_eq!(snap.stalls, 0, "try_push never stalls");
        assert_eq!(rx.pop(), Some(1));
        assert_eq!(tx.try_push(3), Ok(()));
        drop(rx);
        // drain the channel is impossible now, but disconnect still bounces
        assert_eq!(tx.try_push(4), Err(4));
    }

    #[test]
    fn drain_into_takes_everything_pending_in_order() {
        let (tx, rx) = bounded::<u32>(8);
        for i in 0..5 {
            tx.push(i).unwrap();
        }
        let mut buf = vec![99];
        assert_eq!(rx.drain_into(&mut buf), 5);
        assert_eq!(buf, vec![99, 0, 1, 2, 3, 4]);
        // empty queue: no-op, not an error
        assert_eq!(rx.drain_into(&mut buf), 0);
        assert_eq!(buf.len(), 6);
        // stats saw the pops: depth back to zero
        assert_eq!(rx.stats().snapshot().depth, 0);
        drop(tx);
        assert_eq!(rx.drain_into(&mut buf), 0, "closed queue drains nothing");
    }

    #[test]
    fn shared_consumers_drain_everything_once() {
        let (tx, rx) = bounded::<usize>(8);
        let rx = Arc::new(rx);
        let seen = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let rx = rx.clone();
            let seen = seen.clone();
            handles.push(std::thread::spawn(move || {
                while rx.pop().is_some() {
                    seen.fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        for i in 0..100 {
            tx.push(i).unwrap();
        }
        drop(tx);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(seen.load(Ordering::Relaxed), 100);
    }
}
