//! PJRT runtime: load AOT-compiled HLO artifacts and execute work packages
//! — plus the bounded work [`queue`] shared by the SW and HW schedulers
//! (session ingress and accelerator submissions ride the same primitive;
//! the communication thread drains it in combining rounds via
//! [`queue::QueueRx::drain_into`]).
//!
//! This is the only place the `xla` crate is touched, and only when the
//! `pjrt` cargo feature is enabled. Artifacts are the HLO-text files
//! produced by `python/compile/aot.py` (`make artifacts`); one
//! `PjRtLoadedExecutable` is compiled and cached per [`ArtifactKey`]
//! variant. Python never runs here — the binary is self-contained once
//! `artifacts/` exists.
//!
//! Three [`PackageEngine`] implementations exist:
//! * `PjrtPackageEngine` (feature `pjrt`) — the real path:
//!   `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//!   `execute`;
//! * [`SimPackageEngine`](sim::SimPackageEngine) — the deterministic
//!   accelerator simulator (package validation, cycle accounting,
//!   configurable latency, fault injection). The **default** backend when
//!   `pjrt` is off, and the engine the differential test harness drives;
//! * [`NativePackageEngine`] — a minimal pure-Rust table scan with
//!   identical semantics, kept as an independent reference implementation
//!   the simulator is differentially tested against.

pub mod chaos;
pub mod fault;
pub mod queue;
pub mod sim;

pub use chaos::{ChaosAction, ChaosPlan};
pub use fault::{
    BreakerSnapshot, BreakerState, CircuitBreaker, DocError, HealthReport, Quarantine,
    QuarantineEntry, Watchdog,
};
pub use sim::{FaultPlan, SimPackageEngine, SimSnapshot, SimSpec, SimStats};

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context};

use crate::hwcompiler::{ArtifactKey, STREAMS};

/// A work package's dense inputs, already padded to an artifact geometry.
pub struct PackedPackage {
    /// `STREAMS × block` byte values (0 = separator/padding).
    pub bytes: Vec<i32>,
    /// Bytes per stream.
    pub block: usize,
    /// `M × S × 256` transition tables (shared across packages — up to a
    /// few MiB, so cloning per package would dominate small payloads).
    pub tables: std::sync::Arc<Vec<i32>>,
    /// `M × S` accept flags.
    pub accepts: std::sync::Arc<Vec<i32>>,
    /// Padded machine count (`M`).
    pub machines: usize,
    /// Padded per-machine state count (`S`).
    pub states: usize,
}

/// A package execution result.
pub struct PackageHits {
    /// Sparse hits: `(machine, stream, position, state)`, position is the
    /// 0-based index of the byte that produced the accepting state.
    pub hits: Vec<(usize, usize, usize, u32)>,
    /// Per-(machine, stream) hit counts (from the L2 reduction).
    pub counts: Vec<i32>,
    /// Device cycles spent scanning this package (one byte per stream per
    /// cycle → `block` cycles; machines run in parallel). Every engine
    /// reports this full-block figure — the scan is fixed-size whatever
    /// the payload — and [`crate::perfmodel::FpgaModel::package_time_cycles`]
    /// turns it into modeled seconds for the metrics.
    pub cycles: u64,
}

/// Executes packed packages.
///
/// Deliberately NOT `Send`/`Sync`: the `xla` crate's PJRT client is
/// `Rc`-based, and the architecture confines the accelerator to the single
/// communication thread anyway (the paper's model — one thread drives the
/// device). Construct engines via [`EngineSpec`] on the thread that uses
/// them.
pub trait PackageEngine {
    /// Run one package.
    fn run(&self, key: ArtifactKey, pkg: &PackedPackage) -> Result<PackageHits>;
    /// Engine name for logs/reports.
    fn name(&self) -> &'static str;
}

/// A buildable engine description (Send), materialized on the communication
/// thread.
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// Deterministic accelerator simulator (validation, latency, fault
    /// injection, cycle stats). The default backend when `pjrt` is off.
    Sim(SimSpec),
    /// Pure-Rust table scan (no artifacts required) — the minimal
    /// reference implementation the simulator is tested against.
    Native,
    /// PJRT CPU client over `artifacts/`.
    Pjrt { artifacts_dir: PathBuf },
}

impl Default for EngineSpec {
    fn default() -> Self {
        EngineSpec::sim()
    }
}

impl EngineSpec {
    /// A fresh default-configured simulator spec (no latency, no faults).
    pub fn sim() -> EngineSpec {
        EngineSpec::Sim(SimSpec::default())
    }

    /// Materialize the engine (call on the thread that will use it).
    pub fn build(&self) -> Result<Box<dyn PackageEngine>> {
        Ok(match self {
            EngineSpec::Sim(spec) => Box::new(SimPackageEngine::new(spec.clone())),
            EngineSpec::Native => Box::new(NativePackageEngine),
            #[cfg(feature = "pjrt")]
            EngineSpec::Pjrt { artifacts_dir } => {
                Box::new(PjrtPackageEngine::new(artifacts_dir.clone())?)
            }
            #[cfg(not(feature = "pjrt"))]
            EngineSpec::Pjrt { .. } => {
                return Err(anyhow::anyhow!(
                    "this build has no PJRT support (rebuild with `--features pjrt`)"
                ))
            }
        })
    }

    /// Derive the spec for pool device `d` from this one. Simulators get
    /// a private [`SimStats`] instance (per-device gauges) and a
    /// decorrelated seed; native/PJRT specs clone as-is. Device 0 of a
    /// pool always uses the caller's spec verbatim — its shared `SimStats`
    /// `Arc` is what `Engine::sim_snapshot` reads — so `fork` is only
    /// called for devices `1..N`.
    pub fn fork(&self, d: u64) -> EngineSpec {
        match self {
            EngineSpec::Sim(spec) => EngineSpec::Sim(SimSpec {
                latency: spec.latency,
                fault: spec.fault,
                seed: spec.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(d),
                stats: std::sync::Arc::new(SimStats::default()),
            }),
            other => other.clone(),
        }
    }

    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            EngineSpec::Sim(_) => "sim",
            EngineSpec::Native => "native",
            EngineSpec::Pjrt { .. } => "pjrt",
        }
    }

    /// The simulator's shared counters, when this spec is a simulator.
    pub fn sim_stats(&self) -> Option<&std::sync::Arc<SimStats>> {
        match self {
            EngineSpec::Sim(spec) => Some(&spec.stats),
            _ => None,
        }
    }
}

/// The real PJRT-backed engine.
#[cfg(feature = "pjrt")]
pub struct PjrtPackageEngine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<ArtifactKey, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

#[cfg(feature = "pjrt")]
impl PjrtPackageEngine {
    /// Create a CPU PJRT client reading artifacts from `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client init failed: {e:?}"))?;
        Ok(PjrtPackageEngine {
            client,
            artifacts_dir: dir.into(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Platform string (for the CLI banner).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load(&self, key: ArtifactKey) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let path = self.artifacts_dir.join(key.file_name());
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
            anyhow!(
                "failed to load artifact {} (run `make artifacts`?): {e:?}",
                path.display()
            )
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("PJRT compile of {} failed: {e:?}", key.file_name()))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(feature = "pjrt")]
impl PackageEngine for PjrtPackageEngine {
    fn run(&self, key: ArtifactKey, pkg: &PackedPackage) -> Result<PackageHits> {
        debug_assert_eq!(pkg.machines, key.machines);
        debug_assert_eq!(pkg.states, key.states);
        debug_assert_eq!(pkg.block, key.block);
        let exe = self.load(key)?;
        let bytes = xla::Literal::vec1(&pkg.bytes)
            .reshape(&[STREAMS as i64, pkg.block as i64])
            .context("reshape bytes")?;
        let tables = xla::Literal::vec1(&pkg.tables)
            .reshape(&[pkg.machines as i64, pkg.states as i64, 256])
            .context("reshape tables")?;
        let accepts = xla::Literal::vec1(&pkg.accepts)
            .reshape(&[pkg.machines as i64, pkg.states as i64])
            .context("reshape accepts")?;
        let result = exe
            .execute::<xla::Literal>(&[bytes, tables, accepts])
            .map_err(|e| anyhow!("PJRT execute failed: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("device→host transfer failed: {e:?}"))?;
        // aot.py lowers with return_tuple=True: (hits, counts)
        let (hits_lit, counts_lit) = result
            .to_tuple2()
            .map_err(|e| anyhow!("expected 2-tuple output: {e:?}"))?;
        let hits_dense: Vec<i32> = hits_lit
            .to_vec()
            .map_err(|e| anyhow!("hits to_vec: {e:?}"))?;
        let counts: Vec<i32> = counts_lit
            .to_vec()
            .map_err(|e| anyhow!("counts to_vec: {e:?}"))?;
        Ok(PackageHits {
            hits: sparsify(&hits_dense, &counts, pkg.machines, pkg.block),
            counts,
            cycles: pkg.block as u64,
        })
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Convert the dense `[M, STREAMS, block]` hit tensor to sparse events,
/// using the counts to skip empty (machine, stream) rows without scanning
/// them. Shared by the PJRT path (device output is dense) and the
/// simulator (which reproduces the kernel's dense encoding exactly).
pub(crate) fn sparsify(
    hits: &[i32],
    counts: &[i32],
    machines: usize,
    block: usize,
) -> Vec<(usize, usize, usize, u32)> {
    let mut out = Vec::new();
    for m in 0..machines {
        for s in 0..STREAMS {
            if counts[m * STREAMS + s] == 0 {
                continue;
            }
            let base = (m * STREAMS + s) * block;
            for (i, &v) in hits[base..base + block].iter().enumerate() {
                if v > 0 {
                    out.push((m, s, i, v as u32));
                }
            }
        }
    }
    out
}

/// Pure-Rust engine with identical semantics: steps the packed tables
/// directly. Differential oracle for the PJRT path and fallback when no
/// artifacts are present.
pub struct NativePackageEngine;

impl PackageEngine for NativePackageEngine {
    fn run(&self, key: ArtifactKey, pkg: &PackedPackage) -> Result<PackageHits> {
        debug_assert_eq!(pkg.block, key.block);
        let (m_n, s_n, block) = (pkg.machines, pkg.states, pkg.block);
        let mut hits = Vec::new();
        let mut counts = vec![0i32; m_n * STREAMS];
        for m in 0..m_n {
            let table = &pkg.tables[m * s_n * 256..(m + 1) * s_n * 256];
            let accept = &pkg.accepts[m * s_n..(m + 1) * s_n];
            for s in 0..STREAMS {
                let row = &pkg.bytes[s * block..(s + 1) * block];
                let mut state = 1i32; // START
                for (i, &b) in row.iter().enumerate() {
                    state = table[(state as usize) * 256 + b as usize];
                    if accept[state as usize] > 0 {
                        hits.push((m, s, i, state as u32));
                        counts[m * STREAMS + s] += 1;
                    }
                }
            }
        }
        Ok(PackageHits {
            hits,
            counts,
            cycles: pkg.block as u64,
        })
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwcompiler::{compile_subgraph, ArtifactKey};
    use crate::partition::{partition, PartitionMode};

    fn packed_for(aql: &str, texts: &[&str], block: usize) -> (ArtifactKey, PackedPackage) {
        let g = crate::optimizer::optimize(&crate::aql::compile(aql).unwrap());
        let plan = partition(&g, PartitionMode::ExtractOnly);
        let cfg = compile_subgraph(&plan.subgraphs[0]).unwrap();
        let (tables, accepts) = cfg.pack_tables();
        let mut bytes = vec![0i32; STREAMS * block];
        for (s, t) in texts.iter().enumerate().take(STREAMS) {
            for (i, b) in t.bytes().enumerate() {
                bytes[s * block + i] = b as i32;
            }
        }
        let key = cfg.artifact_key(block);
        (
            key,
            PackedPackage {
                bytes,
                block,
                tables: std::sync::Arc::new(tables),
                accepts: std::sync::Arc::new(accepts),
                machines: cfg.geometry.0,
                states: cfg.geometry.1,
            },
        )
    }

    const Q: &str = "create view V as extract regex /ab+/ on d.text as m from Document d; \
                     output view V;";

    #[test]
    fn native_engine_finds_ends() {
        let (key, pkg) = packed_for(Q, &["xxabbby", "", "ab", "ba"], 4096);
        let out = NativePackageEngine.run(key, &pkg).unwrap();
        // stream 0: 'ab','abb','abbb' end-hits at byte idx 3,4,5; stream 2 at 1
        let s0: Vec<usize> = out
            .hits
            .iter()
            .filter(|(m, s, _, _)| *m == 0 && *s == 0)
            .map(|(_, _, i, _)| *i)
            .collect();
        assert_eq!(s0, vec![3, 4, 5]);
        let s2: Vec<usize> = out
            .hits
            .iter()
            .filter(|(_, s, _, _)| *s == 2)
            .map(|(_, _, i, _)| *i)
            .collect();
        assert_eq!(s2, vec![1]);
        assert_eq!(out.counts[0], 3);
        assert_eq!(out.counts[2], 1);
        assert_eq!(out.counts[3], 0);
    }

    #[test]
    fn native_engine_separator_isolation() {
        // two docs packed in one stream with a NUL between them
        let g = crate::optimizer::optimize(&crate::aql::compile(Q).unwrap());
        let plan = partition(&g, PartitionMode::ExtractOnly);
        let cfg = compile_subgraph(&plan.subgraphs[0]).unwrap();
        let (tables, accepts) = cfg.pack_tables();
        let block = 4096;
        let mut bytes = vec![0i32; STREAMS * block];
        let payload = b"ab\0ab";
        for (i, &b) in payload.iter().enumerate() {
            bytes[i] = b as i32;
        }
        let pkg = PackedPackage {
            bytes,
            block,
            tables: std::sync::Arc::new(tables),
            accepts: std::sync::Arc::new(accepts),
            machines: cfg.geometry.0,
            states: cfg.geometry.1,
        };
        let out = NativePackageEngine
            .run(cfg.artifact_key(block), &pkg)
            .unwrap();
        let ends: Vec<usize> = out.hits.iter().map(|(_, _, i, _)| *i).collect();
        assert_eq!(ends, vec![1, 4]); // one hit per doc, none across the NUL
    }

    // PJRT round-trip tests live in rust/tests/pjrt_roundtrip.rs (they need
    // the artifacts directory built by `make artifacts`).

    #[test]
    fn sparsify_respects_counts() {
        let machines = 1;
        let block = 4;
        let hits = vec![0, 2, 0, 3, /* stream1 */ 9, 9, 9, 9, 0, 0, 0, 0, 0, 0, 0, 0];
        // counts claim stream 1 is empty — sparsify must skip it entirely
        let counts = vec![2, 0, 0, 0];
        let out = sparsify(&hits, &counts, machines, block);
        assert_eq!(out, vec![(0, 0, 1, 2), (0, 0, 3, 3)]);
    }
}
