//! Deterministic accelerator simulator — the default [`PackageEngine`]
//! when the `pjrt` feature is off, and the correctness oracle the
//! differential test harness (`rust/tests/differential.rs`) drives.
//!
//! [`SimPackageEngine`] interprets the hwcompiler's compiled artifacts
//! (padded DFA transition tables, Aho–Corasick automata, every
//! [`BLOCK_SIZES`](crate::hwcompiler::BLOCK_SIZES) variant) directly over
//! [`PackedPackage`] byte streams and emits exactly the hit-stream
//! encoding the Pallas kernel produces: a dense `[M, STREAMS, block]`
//! accepting-state tensor plus per-`(machine, stream)` counts, sparsified
//! the same way the PJRT path sparsifies device output. Unlike
//! [`NativePackageEngine`](super::NativePackageEngine) (the minimal
//! independent reference scan), the simulator also models the *device*:
//!
//! * **validation** — malformed packages (truncated byte lanes, tables
//!   that don't match the artifact geometry, out-of-range bytes, corrupt
//!   transitions) are rejected with an error instead of a panic, the way
//!   real hardware raises a status-register fault;
//! * **timing** — a configurable per-package latency (a slow device for
//!   backpressure tests) and a cycle counter (one byte per stream per
//!   cycle, machines in parallel — the paper's 250 MHz × 4 streams), which
//!   [`crate::perfmodel::FpgaModel::package_time_cycles`] converts to
//!   modeled seconds;
//! * **fault injection** — deterministic duplication/reordering of hit
//!   records and every-Nth package failures, driving the robustness path
//!   (the post-stage must normalize the hit stream; the service must fail
//!   submissions cleanly rather than hang workers).
//!
//! Everything is deterministic: faults derive from a seeded [`Prng`], and
//! the scan itself is a pure function of the package.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::hwcompiler::{ArtifactKey, STREAMS};
use crate::util::Prng;

use super::{sparsify, PackageEngine, PackageHits, PackedPackage};

/// Which faults the simulator injects. Deterministic given the spec seed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Fail every Nth package with an error (0 disables). `1` fails every
    /// package — the "device bricked" scenario.
    pub fail_every: usize,
    /// Emit every hit record twice (a transport-layer duplication bug the
    /// post-stage must dedup).
    pub duplicate_hits: bool,
    /// Shuffle the hit-record stream (records may arrive out of
    /// `(machine, stream, position)` order; the post-stage must sort).
    pub reorder_hits: bool,
    /// Brick window start: packages numbered `brick_from..brick_until`
    /// (1-based per-engine sequence) all fail. Models a device going dark
    /// for a while and then recovering — the schedule the circuit-breaker
    /// trip→probe→re-admit path is tested against. Window is active only
    /// when `brick_until > brick_from`.
    pub brick_from: u64,
    /// Brick window end (exclusive). See [`FaultPlan::brick_from`].
    pub brick_until: u64,
}

impl FaultPlan {
    /// No faults — the clean device.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when no fault is configured.
    pub fn is_none(&self) -> bool {
        *self == FaultPlan::default()
    }
}

/// Simulator counters. Shared via `Arc` so tests keep a handle while the
/// engine itself lives on the communication thread.
#[derive(Debug, Default)]
pub struct SimStats {
    /// Packages scanned successfully.
    pub packages: AtomicU64,
    /// Simulated device cycles (block bytes per stream, streams and
    /// machines in parallel → `block` cycles per package).
    pub cycles: AtomicU64,
    /// Clean (pre-fault-injection) hit records produced.
    pub hits: AtomicU64,
    /// Faults injected (failed packages + packages with mutated hits).
    pub faults: AtomicU64,
}

impl SimStats {
    /// Point-in-time copy.
    pub fn snapshot(&self) -> SimSnapshot {
        SimSnapshot {
            packages: self.packages.load(Ordering::Relaxed),
            cycles: self.cycles.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            faults: self.faults.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of [`SimStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimSnapshot {
    /// Packages scanned.
    pub packages: u64,
    /// Device cycles modeled.
    pub cycles: u64,
    /// Hit records emitted.
    pub hits: u64,
    /// Faults injected (dup/reorder/fail events).
    pub faults: u64,
}

/// Buildable simulator description (`Send + Clone`), carried by
/// [`EngineSpec::Sim`](super::EngineSpec::Sim) and materialized on the
/// communication thread. Cloning shares the stats handle, so the spec a
/// caller keeps observes the engine the service runs.
#[derive(Debug, Clone)]
pub struct SimSpec {
    /// Artificial per-package device latency (zero by default). A few
    /// milliseconds here turns the simulator into the "slow accelerator"
    /// that exercises submission-queue backpressure.
    pub latency: Duration,
    /// Fault-injection plan.
    pub fault: FaultPlan,
    /// Seed for the fault-injection PRNG (reorders are deterministic).
    pub seed: u64,
    /// Shared counters.
    pub stats: Arc<SimStats>,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            latency: Duration::ZERO,
            fault: FaultPlan::none(),
            seed: 0x51D_ECA2,
            stats: Arc::new(SimStats::default()),
        }
    }
}

impl SimSpec {
    /// Set the per-package latency.
    pub fn with_latency(mut self, latency: Duration) -> SimSpec {
        self.latency = latency;
        self
    }

    /// Set the fault plan.
    pub fn with_fault(mut self, fault: FaultPlan) -> SimSpec {
        self.fault = fault;
        self
    }

    /// Set the fault-injection seed.
    pub fn with_seed(mut self, seed: u64) -> SimSpec {
        self.seed = seed;
        self
    }

    /// Snapshot the shared counters.
    pub fn snapshot(&self) -> SimSnapshot {
        self.stats.snapshot()
    }
}

/// The simulator engine. Confined to the communication thread like every
/// [`PackageEngine`]; observable from outside through the shared
/// [`SimStats`].
pub struct SimPackageEngine {
    spec: SimSpec,
    prng: RefCell<Prng>,
    seen: Cell<u64>,
}

impl SimPackageEngine {
    /// Build from a spec (shares the spec's stats handle).
    pub fn new(spec: SimSpec) -> SimPackageEngine {
        let prng = RefCell::new(Prng::new(spec.seed));
        SimPackageEngine {
            spec,
            prng,
            seen: Cell::new(0),
        }
    }

    /// The engine's stats handle.
    pub fn stats(&self) -> &Arc<SimStats> {
        &self.spec.stats
    }

    /// Reject packages whose tensors don't match the artifact geometry —
    /// the simulator's stand-in for the device's status-register fault on
    /// a truncated or corrupt DMA transfer.
    fn validate(&self, key: ArtifactKey, pkg: &PackedPackage) -> Result<()> {
        if pkg.machines != key.machines || pkg.states != key.states || pkg.block != key.block {
            bail!(
                "package geometry (m={}, s={}, b={}) does not match artifact {}",
                pkg.machines,
                pkg.states,
                pkg.block,
                key.file_name()
            );
        }
        let (want_bytes, want_tables, want_accepts) = key.tensor_sizes();
        if pkg.bytes.len() != want_bytes {
            bail!(
                "truncated package: {} byte-lane values, artifact {} expects {}",
                pkg.bytes.len(),
                key.file_name(),
                want_bytes
            );
        }
        if pkg.tables.len() != want_tables {
            bail!(
                "truncated tables: {} entries, artifact {} expects {}",
                pkg.tables.len(),
                key.file_name(),
                want_tables
            );
        }
        if pkg.accepts.len() != want_accepts {
            bail!(
                "truncated accepts: {} entries, artifact {} expects {}",
                pkg.accepts.len(),
                key.file_name(),
                want_accepts
            );
        }
        if let Some(&b) = pkg.bytes.iter().find(|&&b| !(0..256).contains(&b)) {
            bail!("byte lane value {b} outside 0..256 (corrupt package)");
        }
        Ok(())
    }
}

impl PackageEngine for SimPackageEngine {
    fn run(&self, key: ArtifactKey, pkg: &PackedPackage) -> Result<PackageHits> {
        self.validate(key, pkg)?;

        if !self.spec.latency.is_zero() {
            std::thread::sleep(self.spec.latency);
        }

        let n = self.seen.get() + 1;
        self.seen.set(n);
        let fault = self.spec.fault;
        if fault.fail_every > 0 && n % fault.fail_every as u64 == 0 {
            self.spec.stats.faults.fetch_add(1, Ordering::Relaxed);
            bail!("injected device fault on package #{n}");
        }
        if fault.brick_until > fault.brick_from && n >= fault.brick_from && n < fault.brick_until
        {
            self.spec.stats.faults.fetch_add(1, Ordering::Relaxed);
            bail!("injected brick fault on package #{n} (device dark until package {})",
                fault.brick_until);
        }

        // The kernel's two-phase output: a dense [M, STREAMS, block] tensor
        // holding the accepting state id at each position (0 elsewhere),
        // plus the L2-reduced per-(machine, stream) counts.
        let (m_n, s_n, block) = (pkg.machines, pkg.states, pkg.block);
        let mut dense = vec![0i32; m_n * STREAMS * block];
        let mut counts = vec![0i32; m_n * STREAMS];
        for m in 0..m_n {
            let table = &pkg.tables[m * s_n * 256..(m + 1) * s_n * 256];
            let accept = &pkg.accepts[m * s_n..(m + 1) * s_n];
            for s in 0..STREAMS {
                let row = &pkg.bytes[s * block..(s + 1) * block];
                let out = &mut dense[(m * STREAMS + s) * block..(m * STREAMS + s + 1) * block];
                let mut state = 1usize; // START
                for (i, &b) in row.iter().enumerate() {
                    let next = table[state * 256 + b as usize];
                    if !(0..s_n as i32).contains(&next) {
                        bail!(
                            "corrupt transition: machine {m} state {state} byte {b} -> {next} \
                             (artifact has {s_n} states)"
                        );
                    }
                    state = next as usize;
                    if accept[state] > 0 {
                        out[i] = state as i32;
                        counts[m * STREAMS + s] += 1;
                    }
                }
            }
        }
        let mut hits = sparsify(&dense, &counts, m_n, block);

        self.spec.stats.packages.fetch_add(1, Ordering::Relaxed);
        // One byte per stream per cycle, streams and machines in parallel:
        // a package costs `block` cycles regardless of payload.
        self.spec.stats.cycles.fetch_add(block as u64, Ordering::Relaxed);
        self.spec
            .stats
            .hits
            .fetch_add(hits.len() as u64, Ordering::Relaxed);

        if fault.duplicate_hits || fault.reorder_hits {
            self.spec.stats.faults.fetch_add(1, Ordering::Relaxed);
            if fault.duplicate_hits {
                let copy = hits.clone();
                hits.extend(copy);
            }
            if fault.reorder_hits {
                self.prng.borrow_mut().shuffle(&mut hits);
            }
        }

        Ok(PackageHits {
            hits,
            counts,
            cycles: block as u64,
        })
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwcompiler::compile_subgraph;
    use crate::partition::{partition, PartitionMode};
    use crate::runtime::NativePackageEngine;

    const Q: &str = "create view V as extract regex /ab+/ on d.text as m from Document d; \
                     output view V;";

    fn packed(texts: &[&str], block: usize) -> (ArtifactKey, PackedPackage) {
        let g = crate::optimizer::optimize(&crate::aql::compile(Q).unwrap());
        let plan = partition(&g, PartitionMode::ExtractOnly);
        let cfg = compile_subgraph(&plan.subgraphs[0]).unwrap();
        let (tables, accepts) = cfg.pack_tables();
        let mut bytes = vec![0i32; STREAMS * block];
        for (s, t) in texts.iter().enumerate().take(STREAMS) {
            for (i, b) in t.bytes().enumerate() {
                bytes[s * block + i] = b as i32;
            }
        }
        (
            cfg.artifact_key(block),
            PackedPackage {
                bytes,
                block,
                tables: Arc::new(tables),
                accepts: Arc::new(accepts),
                machines: cfg.geometry.0,
                states: cfg.geometry.1,
            },
        )
    }

    #[test]
    fn sim_equals_native_engine() {
        let (key, pkg) = packed(&["xxabbby", "", "ab", "ba\0abb"], 4096);
        let sim = SimPackageEngine::new(SimSpec::default());
        let a = sim.run(key, &pkg).unwrap();
        let b = NativePackageEngine.run(key, &pkg).unwrap();
        assert_eq!(a.hits, b.hits, "sim and native must agree exactly");
        assert_eq!(a.counts, b.counts);
        assert!(!a.hits.is_empty());
        let snap = sim.stats().snapshot();
        assert_eq!(snap.packages, 1);
        assert_eq!(snap.cycles, 4096);
        assert_eq!(snap.hits, a.hits.len() as u64);
        assert_eq!(snap.faults, 0);
    }

    #[test]
    fn truncated_package_is_rejected() {
        let (key, mut pkg) = packed(&["ab"], 4096);
        pkg.bytes.truncate(100);
        let sim = SimPackageEngine::new(SimSpec::default());
        let err = sim.run(key, &pkg).unwrap_err().to_string();
        assert!(err.contains("truncated package"), "{err}");
        assert_eq!(sim.stats().snapshot().packages, 0);
    }

    #[test]
    fn geometry_mismatch_is_rejected() {
        let (mut key, pkg) = packed(&["ab"], 4096);
        key.block = 16384;
        let sim = SimPackageEngine::new(SimSpec::default());
        let err = sim.run(key, &pkg).unwrap_err().to_string();
        assert!(err.contains("does not match artifact"), "{err}");
    }

    #[test]
    fn out_of_range_byte_is_rejected() {
        let (key, mut pkg) = packed(&["ab"], 4096);
        pkg.bytes[7] = 300;
        let sim = SimPackageEngine::new(SimSpec::default());
        let err = sim.run(key, &pkg).unwrap_err().to_string();
        assert!(err.contains("outside 0..256"), "{err}");
    }

    #[test]
    fn truncated_tables_are_rejected() {
        let (key, mut pkg) = packed(&["ab"], 4096);
        let mut t = (*pkg.tables).clone();
        t.truncate(t.len() - 256);
        pkg.tables = Arc::new(t);
        let sim = SimPackageEngine::new(SimSpec::default());
        let err = sim.run(key, &pkg).unwrap_err().to_string();
        assert!(err.contains("truncated tables"), "{err}");
    }

    #[test]
    fn fail_every_nth_package() {
        let (key, pkg) = packed(&["ab"], 4096);
        let sim = SimPackageEngine::new(SimSpec::default().with_fault(FaultPlan {
            fail_every: 2,
            ..FaultPlan::none()
        }));
        assert!(sim.run(key, &pkg).is_ok());
        assert!(sim.run(key, &pkg).is_err());
        assert!(sim.run(key, &pkg).is_ok());
        assert!(sim.run(key, &pkg).is_err());
        let snap = sim.stats().snapshot();
        assert_eq!(snap.packages, 2);
        assert_eq!(snap.faults, 2);
    }

    #[test]
    fn brick_window_fails_then_recovers() {
        let (key, pkg) = packed(&["ab"], 4096);
        let sim = SimPackageEngine::new(SimSpec::default().with_fault(FaultPlan {
            brick_from: 2,
            brick_until: 4,
            ..FaultPlan::none()
        }));
        assert!(sim.run(key, &pkg).is_ok(), "before the window");
        assert!(sim.run(key, &pkg).is_err(), "package 2 bricked");
        assert!(sim.run(key, &pkg).is_err(), "package 3 bricked");
        assert!(sim.run(key, &pkg).is_ok(), "device recovered");
        assert_eq!(sim.stats().snapshot().faults, 2);
    }

    #[test]
    fn duplicate_and_reorder_faults_mutate_only_the_stream() {
        let (key, pkg) = packed(&["xxabbby", "ab", "abab", ""], 4096);
        let clean = SimPackageEngine::new(SimSpec::default())
            .run(key, &pkg)
            .unwrap();
        let faulty = SimPackageEngine::new(SimSpec::default().with_fault(FaultPlan {
            duplicate_hits: true,
            reorder_hits: true,
            ..FaultPlan::none()
        }))
        .run(key, &pkg)
        .unwrap();
        assert_eq!(faulty.hits.len(), 2 * clean.hits.len());
        // sorted + deduped, the faulty stream recovers the clean one — the
        // exact normalization the accel post-stage applies
        let mut norm = faulty.hits.clone();
        norm.sort_unstable();
        norm.dedup();
        assert_eq!(norm, clean.hits);
    }

    #[test]
    fn deterministic_across_runs() {
        let (key, pkg) = packed(&["xxabbby", "ab", "abab", "bbb"], 4096);
        let spec = SimSpec::default().with_fault(FaultPlan {
            reorder_hits: true,
            ..FaultPlan::none()
        });
        let a = SimPackageEngine::new(spec.clone()).run(key, &pkg).unwrap();
        let b = SimPackageEngine::new(SimSpec {
            stats: Arc::new(SimStats::default()),
            ..spec
        })
        .run(key, &pkg)
        .unwrap();
        assert_eq!(a.hits, b.hits, "same seed, same shuffle");
    }

    #[test]
    fn latency_is_applied() {
        let (key, pkg) = packed(&["ab"], 4096);
        let sim =
            SimPackageEngine::new(SimSpec::default().with_latency(Duration::from_millis(5)));
        let t0 = std::time::Instant::now();
        sim.run(key, &pkg).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }
}
