//! The engine facade: register one or many AQL programs in a **query
//! catalog**, compile them into a single shared supergraph, optionally
//! partition it for the accelerator, and stream documents through it with
//! the paper's document-per-thread worker model.
//!
//! The paper's deployment model is *not* one accelerator per query:
//! SystemT's extended compilation flow folds the extraction operators of
//! **all** deployed queries into a single FPGA image, shared by every
//! query's document stream (§III–IV). [`CatalogBuilder`]
//! (`Engine::builder().register("t1", aql)….build()`) reproduces that
//! shape: each registered program is compiled under its own namespace,
//! the graphs are merged over one shared `DocScan`, identical extraction
//! leaves are interned (one machine per distinct pattern, catalog-wide),
//! and the optimizer + partitioner run **once** over the merged graph so
//! hardware/software placement is decided globally. One
//! [`AccelService`], one partition plan, one artifact set — and every
//! document pushed through a [`Session`] is evaluated against *all*
//! registered queries in a single pass.
//!
//! Results are addressed through namespaced handles:
//! [`Engine::query`]`("t1")?` → [`QueryHandle`] →
//! [`QueryHandle::view`]`("Entities")?` → [`ViewHandle`].
//! [`Engine::compile_aql`] remains the one-entry convenience wrapper and
//! resolves unqualified view names exactly as before.
//!
//! The primary run surface is the push-based [`Session`] pipeline
//! ([`Engine::session`]); [`Engine::run_corpus`] and [`Engine::run_doc`]
//! are thin conveniences over the same machinery.

pub mod session;

pub use session::{CallbackSink, CollectSink, CountingSink, ResultSink, Session, SessionBuilder};

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::accel::{AccelOptions, AccelService, AccelSubgraphRunner};
use crate::aog::{Graph, Tuple};
use crate::corpus::Corpus;
use crate::exec::{CorpusResult, DocResult, ExecStrategy, Executor, Profile, Profiler, ViewHandle};
use crate::hwcompiler::{compile_subgraph, AccelConfig, ArtifactKey, BLOCK_SIZES};
use crate::metrics::{AccelDeviceSnapshot, AccelSnapshot, PoolSnapshot, QueueSnapshot};
use crate::partition::{partition, PartitionMode, PartitionPlan, SoftwareSubgraphRunner};
use crate::runtime::fault::{
    BreakerSnapshot, HealthReport, Quarantine, Watchdog, DEFAULT_QUARANTINE_CAP,
    DEFAULT_STALL_AFTER,
};
use crate::runtime::EngineSpec;
use crate::text::Document;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Offload scenario.
    pub mode: PartitionMode,
    /// Which accelerator backend executes packages (ignored for
    /// [`PartitionMode::None`]).
    pub engine: EngineSpec,
    /// Communication-interface options.
    pub accel: AccelOptions,
    /// Collect per-operator profiles (Fig 4). Cheap; on by default.
    pub profile: bool,
    /// Run the optimizer (on by default; off exposes the naive plans).
    pub optimize: bool,
    /// Which software-executor pipeline to run: columnar `TupleBatch`
    /// execution (default) or the seed's row-at-a-time `Vec<Tuple>`
    /// baseline ([`ExecStrategy::LegacyRows`] — differential tests and
    /// old-vs-new benches only).
    pub strategy: ExecStrategy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: PartitionMode::None,
            // the deterministic simulator is the default backend: every
            // build (no XLA toolchain required) exercises the full HW path
            engine: EngineSpec::default(),
            accel: AccelOptions::default(),
            profile: true,
            optimize: true,
            strategy: ExecStrategy::Columnar,
        }
    }
}

impl EngineConfig {
    /// Software-only configuration.
    pub fn software() -> EngineConfig {
        EngineConfig::default()
    }

    /// Software-only configuration on the legacy row-at-a-time pipeline —
    /// the pre-columnar baseline `repro bench` measures "old" against.
    pub fn legacy_rows() -> EngineConfig {
        EngineConfig {
            strategy: ExecStrategy::LegacyRows,
            ..Default::default()
        }
    }

    /// Accelerated configuration with the given mode and backend.
    pub fn accelerated(mode: PartitionMode, engine: EngineSpec) -> EngineConfig {
        EngineConfig {
            mode,
            engine,
            ..Default::default()
        }
    }

    /// Accelerated configuration over the deterministic simulator — the
    /// default hardware path when `pjrt` is off.
    pub fn simulated(mode: PartitionMode) -> EngineConfig {
        EngineConfig::accelerated(mode, EngineSpec::default())
    }
}

/// A resolved reference to one registered query of an [`Engine`]: its
/// name, its namespace prefix, and typed [`ViewHandle`]s for each of its
/// output views. Obtained from [`Engine::query`]; cheap to clone.
#[derive(Debug, Clone)]
pub struct QueryHandle {
    name: Arc<str>,
    /// Namespace prefix of this query's view names (`"t1."`, or `""` for
    /// engines compiled through [`Engine::compile_aql`]).
    prefix: Arc<str>,
    /// Global handles of this query's output views, in output order.
    views: Arc<[ViewHandle]>,
}

impl QueryHandle {
    /// The query's registered name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// This query's output views (global handles; their
    /// [`ViewHandle::name`]s carry the namespace prefix).
    pub fn views(&self) -> &[ViewHandle] {
        &self.views
    }

    /// Resolve one of this query's output views by its **unqualified**
    /// name, as written in the query's `output view` statement.
    pub fn view(&self, name: &str) -> Result<ViewHandle> {
        self.views
            .iter()
            .find(|h| {
                h.name()
                    .strip_prefix(&*self.prefix)
                    .is_some_and(|n| n == name)
            })
            .cloned()
            .ok_or_else(|| {
                anyhow!(
                    "query '{}' has no output view named '{name}' (outputs: {})",
                    self.name,
                    self.view_names().join(", ")
                )
            })
    }

    /// Unqualified names of this query's output views, in output order.
    pub fn view_names(&self) -> Vec<&str> {
        self.views
            .iter()
            .map(|h| h.name().strip_prefix(&*self.prefix).unwrap_or(h.name()))
            .collect()
    }

    /// Iterate this query's `(handle, tuples)` pairs in a document result
    /// produced by the same engine.
    pub fn iter<'a>(
        &'a self,
        result: &'a DocResult,
    ) -> impl Iterator<Item = (&'a ViewHandle, &'a Vec<Tuple>)> {
        self.views.iter().map(move |h| (h, result.view(h)))
    }

    /// Total tuples this query produced for one document. Counts from
    /// whichever result layout exists ([`DocResult::view_len`]) — no row
    /// materialization.
    pub fn total_tuples(&self, result: &DocResult) -> usize {
        self.views.iter().map(|h| result.view_len(h)).sum()
    }
}

/// Internal: which slice of the merged graph's output list belongs to
/// which registered query.
struct QuerySpec {
    name: String,
    prefix: String,
    outputs: std::ops::Range<usize>,
}

/// How a catalog entry's AQL is obtained at build time.
enum EntrySource {
    Aql(String),
    Builtin,
}

/// Builds an [`Engine`] from a **catalog** of named AQL programs — the
/// paper's many-queries-one-image deployment. Create via
/// [`Engine::builder`], add entries with [`CatalogBuilder::register`] /
/// [`CatalogBuilder::register_builtin`], then [`CatalogBuilder::build`].
///
/// ```no_run
/// use boost::coordinator::Engine;
/// # fn main() -> anyhow::Result<()> {
/// let engine = Engine::builder()
///     .register_builtin("t1")
///     .register("caps", "create view Caps as extract regex /[A-Z]+/ \
///                        on d.text as w from Document d; output view Caps;")
///     .build()?;
/// let entities = engine.query("t1")?.view("EntitiesClean")?;
/// let caps = engine.query("caps")?.view("Caps")?;
/// # let _ = (entities, caps); Ok(())
/// # }
/// ```
pub struct CatalogBuilder {
    entries: Vec<(String, EntrySource)>,
    config: EngineConfig,
    lenient: bool,
}

impl CatalogBuilder {
    /// Empty catalog with the default (software) configuration.
    pub fn new() -> CatalogBuilder {
        CatalogBuilder {
            entries: Vec::new(),
            config: EngineConfig::default(),
            lenient: false,
        }
    }

    /// Register a query under `name`. Names must be unique within the
    /// catalog and become the namespace of the query's views
    /// (`<name>.<View>` in the merged graph).
    pub fn register(mut self, name: impl Into<String>, aql: impl Into<String>) -> CatalogBuilder {
        self.entries.push((name.into(), EntrySource::Aql(aql.into())));
        self
    }

    /// Register one of the built-in evaluation queries
    /// ([`crate::queries::builtin`]: `t1`‥`t7`) under its own name.
    /// Unknown names error at [`CatalogBuilder::build`].
    pub fn register_builtin(mut self, name: impl Into<String>) -> CatalogBuilder {
        self.entries.push((name.into(), EntrySource::Builtin));
        self
    }

    /// Replace the default [`EngineConfig`] (offload mode, backend,
    /// communication-interface options).
    pub fn config(mut self, config: EngineConfig) -> CatalogBuilder {
        self.config = config;
        self
    }

    /// Quarantine entries whose AQL fails static analysis instead of
    /// failing the whole build. Strict (the default) errors on the first
    /// rejected entry; lenient excludes rejected entries from the merged
    /// graph and surfaces them through [`Engine::rejected_queries`] — the
    /// serve tier answers a Hello naming one with a structured Error
    /// frame. Registration mistakes (bad or duplicate names, unknown
    /// builtins) stay fatal either way, as do analysis failures of the
    /// *merged* graph.
    pub fn lenient(mut self) -> CatalogBuilder {
        self.lenient = true;
        self
    }

    /// Parse every registered program, run the static analyzer over each
    /// entry (strict by default, see [`CatalogBuilder::lenient`]), merge
    /// the survivors into one shared supergraph (common `DocScan`,
    /// interned extraction leaves), and run the optimizer, partitioner
    /// and hardware compiler **once** over the merged graph — with the
    /// analyzer re-verifying the plan after every rewrite.
    pub fn build(self) -> Result<Engine> {
        if self.entries.is_empty() {
            return Err(anyhow!("catalog is empty — register at least one query"));
        }
        let mut merged = Graph::new();
        let mut specs: Vec<QuerySpec> = Vec::new();
        let mut rejected: Vec<RejectedQuery> = Vec::new();
        for (name, source) in &self.entries {
            if name.is_empty()
                || !name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                return Err(anyhow!(
                    "bad query name '{name}': use ASCII letters, digits, '_' or '-' \
                     (the name becomes the view namespace '<name>.<View>')"
                ));
            }
            if specs.iter().any(|s| s.name == *name)
                || rejected.iter().any(|r| r.name == *name)
            {
                return Err(anyhow!("duplicate query name '{name}' in catalog"));
            }
            let aql = match source {
                EntrySource::Aql(src) => src.clone(),
                EntrySource::Builtin => {
                    crate::queries::builtin(name)
                        .ok_or_else(|| {
                            anyhow!("unknown built-in query '{name}' (try `repro queries`)")
                        })?
                        .aql
                }
            };
            let mut report = crate::analysis::Report::new();
            let g = match crate::aql::compile_ns(&aql, name) {
                Ok(g) => {
                    // per-entry graph invariants (passes 1–2); compile
                    // output is expected clean — this guards the compiler
                    report.merge(crate::analysis::check_graph(&g));
                    if report.has_errors() {
                        None
                    } else {
                        Some(g)
                    }
                }
                Err(e) => {
                    report.push(crate::analysis::diagnostic_from_compile(name, &aql, &e));
                    None
                }
            };
            let Some(g) = g else {
                if self.lenient {
                    rejected.push(RejectedQuery {
                        name: name.clone(),
                        report,
                    });
                    continue;
                }
                return Err(anyhow!("query '{name}' rejected:\n{}", report.render()));
            };
            let start = merged.outputs.len();
            merged.merge_from(&g);
            specs.push(QuerySpec {
                name: name.clone(),
                prefix: format!("{name}."),
                outputs: start..merged.outputs.len(),
            });
        }
        if specs.is_empty() {
            return Err(anyhow!(
                "all {} catalog entries were rejected:\n{}",
                rejected.len(),
                rejected
                    .iter()
                    .map(|r| r.report.render())
                    .collect::<Vec<_>>()
                    .join("\n")
            ));
        }
        // the merge itself is a rebuild: verify it (fatal in both modes)
        let merged_report = crate::analysis::check_graph(&merged);
        if merged_report.has_errors() {
            return Err(anyhow!(
                "merged catalog graph failed verification:\n{}",
                merged_report.render()
            ));
        }
        let mut engine = Engine::from_parts(merged, specs, self.config)?;
        engine.rejected = rejected;
        Ok(engine)
    }
}

impl Default for CatalogBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// A catalog entry quarantined by a lenient build: its registered name
/// and the diagnostics that rejected it (see [`CatalogBuilder::lenient`]).
#[derive(Debug, Clone)]
pub struct RejectedQuery {
    /// The name the entry was registered under.
    pub name: String,
    /// The static-analysis diagnostics that rejected it.
    pub report: crate::analysis::Report,
}

/// A compiled, ready-to-run engine.
pub struct Engine {
    graph: Arc<Graph>,
    plan: Option<PartitionPlan>,
    executor: Arc<Executor>,
    profiler: Arc<Profiler>,
    service: Option<Arc<AccelService>>,
    config: EngineConfig,
    queries: Vec<QueryHandle>,
    /// Deduplicated artifact variants the hardware compiler selected for
    /// this engine (empty for software-only engines).
    artifacts: Vec<ArtifactKey>,
    /// Entries a lenient build quarantined (always empty under strict).
    rejected: Vec<RejectedQuery>,
    /// Non-fatal diagnostics (W###) the build-time analyzer produced.
    analysis: crate::analysis::Report,
    /// Poison-document registry: panics contained by session workers land
    /// here (bounded; see [`Engine::quarantine`]).
    quarantine: Arc<Quarantine>,
    /// Liveness registry for this engine's worker threads (see
    /// [`Engine::health`]).
    watchdog: Arc<Watchdog>,
}

impl Engine {
    /// Start a multi-query [`CatalogBuilder`] — one engine, many AQL
    /// programs, one shared accelerator image.
    ///
    /// ```
    /// use boost::prelude::*;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let engine = Engine::builder()
    ///     .register(
    ///         "caps",
    ///         "create view Caps as extract regex /[A-Z][a-z]+/ on d.text \
    ///          as w from Document d; output view Caps;",
    ///     )
    ///     .register_builtin("t1")
    ///     .build()?;
    /// let caps = engine.query("caps")?.view("Caps")?;
    /// let result = engine.run_doc(&Document::new(0, "Alice met Bob at IBM"));
    /// assert_eq!(result[&caps].len(), 2); // Alice, Bob
    /// # Ok(())
    /// # }
    /// ```
    pub fn builder() -> CatalogBuilder {
        CatalogBuilder::new()
    }

    /// Compile a single AQL program with the default (software)
    /// configuration — the one-entry convenience wrapper over the catalog
    /// path. View names stay unqualified.
    pub fn compile_aql(aql: &str) -> Result<Engine> {
        Engine::with_config(aql, EngineConfig::default())
    }

    /// Compile a single AQL program with an explicit configuration.
    pub fn with_config(aql: &str, config: EngineConfig) -> Result<Engine> {
        let g = crate::aql::compile(aql).map_err(|e| anyhow!("{e}"))?;
        let specs = vec![QuerySpec {
            name: "default".into(),
            prefix: String::new(),
            outputs: 0..g.outputs.len(),
        }];
        Engine::from_parts(g, specs, config)
    }

    /// Shared construction path: optimize the (merged) graph — verifying
    /// every rewrite stage — partition it (verifying the split), compile
    /// the hardware subgraphs, start the one [`AccelService`], and
    /// resolve the per-query handle table.
    fn from_parts(g: Graph, specs: Vec<QuerySpec>, config: EngineConfig) -> Result<Engine> {
        let mut analysis = crate::analysis::Report::new();
        // created before the accelerator service so its communication
        // threads can register heartbeats alongside the session workers
        let quarantine = Arc::new(Quarantine::new(DEFAULT_QUARANTINE_CAP));
        let watchdog = Arc::new(Watchdog::new(DEFAULT_STALL_AFTER));
        let g = if config.optimize {
            let stages: [(&str, fn(&Graph) -> Result<Graph, crate::optimizer::RewriteError>); 3] = [
                ("dedup", crate::optimizer::try_dedup_extractions),
                ("pushdown", crate::optimizer::try_push_predicates),
                ("prune", crate::optimizer::try_prune_dead),
            ];
            let mut cur = g;
            for (stage, run) in stages {
                let next = run(&cur).map_err(|e| {
                    anyhow!("{}", crate::analysis::diagnostic_from_rewrite(&e).render())
                })?;
                let verdict = crate::analysis::verify_rewrite(stage, &cur, &next);
                if verdict.has_errors() {
                    return Err(anyhow!(
                        "optimizer pass '{stage}' broke the plan:\n{}",
                        verdict.render()
                    ));
                }
                cur = next;
            }
            cur
        } else {
            g
        };

        let (exec_graph, plan, service, artifacts): (
            Graph,
            Option<PartitionPlan>,
            Option<Arc<AccelService>>,
            Vec<ArtifactKey>,
        ) = if config.mode == PartitionMode::None {
            (g.clone(), None, None, Vec::new())
        } else {
            let plan = partition(&g, config.mode);
            let plan_report = crate::analysis::check_plan(&g, &plan);
            if plan_report.has_errors() {
                return Err(anyhow!(
                    "partition verification failed:\n{}",
                    plan_report.render()
                ));
            }
            // feasibility/profitability lint at the cost model's standard
            // document size; warnings land in Engine::analysis_report
            analysis.merge(crate::analysis::lint_hardware(&g, &plan, 2048));
            let configs: Vec<AccelConfig> = plan
                .subgraphs
                .iter()
                .map(compile_subgraph)
                .collect::<Result<_, _>>()
                .map_err(|e| anyhow!("hardware compile failed: {e}"))?;
            let mut artifacts: Vec<ArtifactKey> = configs
                .iter()
                .flat_map(|c| BLOCK_SIZES.iter().map(move |&b| c.artifact_key(b)))
                .collect();
            artifacts.sort_by_key(|k| (k.machines, k.states, k.block));
            artifacts.dedup();
            let mut accel_opts = config.accel.clone();
            accel_opts.watchdog = Some(watchdog.clone());
            let service = AccelService::start(configs, config.engine.clone(), accel_opts);
            (plan.supergraph.clone(), Some(plan), Some(service), artifacts)
        };

        let profiler = Arc::new(if config.profile {
            Profiler::for_graph(&exec_graph)
        } else {
            Profiler::disabled()
        });
        let exec_graph = Arc::new(exec_graph);
        let mut executor =
            Executor::new(exec_graph.clone(), profiler.clone()).with_strategy(config.strategy);
        if let (Some(plan), Some(service)) = (&plan, &service) {
            executor = executor.with_subgraph_runner(Arc::new(AccelSubgraphRunner::new(
                service.clone(),
                plan,
            )));
        }
        let queries = Engine::resolve_queries(&executor, &specs);
        Ok(Engine {
            graph: Arc::new(g),
            plan,
            executor: Arc::new(executor),
            profiler,
            service,
            config,
            queries,
            artifacts,
            rejected: Vec::new(),
            analysis,
            quarantine,
            watchdog,
        })
    }

    /// Turn the output ranges recorded at registration time into handle
    /// tables over the executor's view catalog. The optimizer and the
    /// partitioner both preserve output count and order, so the ranges
    /// survive every rewrite — asserted here.
    fn resolve_queries(executor: &Executor, specs: &[QuerySpec]) -> Vec<QueryHandle> {
        let handles = executor.catalog().handles();
        specs
            .iter()
            .map(|s| {
                assert!(
                    s.outputs.end <= handles.len(),
                    "query '{}' outputs {:?} exceed the {} compiled views \
                     (optimizer/partitioner dropped an output?)",
                    s.name,
                    s.outputs,
                    handles.len()
                );
                QueryHandle {
                    name: s.name.as_str().into(),
                    prefix: s.prefix.as_str().into(),
                    views: handles[s.outputs.clone()].to_vec().into(),
                }
            })
            .collect()
    }

    /// Compile with a partition plan but run subgraphs in *software*
    /// (reference runner) — used by tests and ablations.
    pub fn with_software_subgraphs(aql: &str, mode: PartitionMode) -> Result<Engine> {
        let g = crate::optimizer::optimize(&crate::aql::compile(aql).map_err(|e| anyhow!("{e}"))?);
        let plan = partition(&g, mode);
        let profiler = Arc::new(Profiler::for_graph(&plan.supergraph));
        let runner = Arc::new(SoftwareSubgraphRunner::new(&plan));
        let executor = Executor::new(Arc::new(plan.supergraph.clone()), profiler.clone())
            .with_subgraph_runner(runner);
        let specs = vec![QuerySpec {
            name: "default".into(),
            prefix: String::new(),
            outputs: 0..plan.supergraph.outputs.len(),
        }];
        let queries = Engine::resolve_queries(&executor, &specs);
        Ok(Engine {
            graph: Arc::new(g),
            plan: Some(plan),
            executor: Arc::new(executor),
            profiler,
            service: None,
            config: EngineConfig {
                mode,
                ..Default::default()
            },
            queries,
            artifacts: Vec::new(),
            rejected: Vec::new(),
            analysis: crate::analysis::Report::new(),
            quarantine: Arc::new(Quarantine::new(DEFAULT_QUARANTINE_CAP)),
            watchdog: Arc::new(Watchdog::new(DEFAULT_STALL_AFTER)),
        })
    }

    /// The (optimized) logical graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The partition plan, if any.
    pub fn plan(&self) -> Option<&PartitionPlan> {
        self.plan.as_ref()
    }

    /// The engine configuration.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Resolve a typed handle for output view `name` — the compile-time
    /// replacement for stringly-typed result lookups.
    ///
    /// Accepts the view's full (possibly qualified) name as it appears in
    /// the merged graph (`"t1.Entities"`), or — when exactly one
    /// registered query outputs a view of that unqualified name — the
    /// bare name (`"Entities"`). An unqualified name shared by several
    /// queries is an error naming the qualified candidates; disambiguate
    /// through [`Engine::query`].
    pub fn view(&self, name: &str) -> Result<ViewHandle> {
        if let Some(h) = self.executor.catalog().resolve(name) {
            return Ok(h.clone());
        }
        let mut candidates: Vec<ViewHandle> = self
            .queries
            .iter()
            .filter_map(|q| q.view(name).ok())
            .collect();
        match candidates.len() {
            1 => Ok(candidates.pop().expect("len checked")),
            0 => Err(anyhow!(
                "no output view named '{name}' (outputs: {})",
                self.views()
                    .iter()
                    .map(|h| h.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )),
            _ => Err(anyhow!(
                "view name '{name}' is ambiguous across the catalog — use one of: {}",
                candidates
                    .iter()
                    .map(|h| h.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )),
        }
    }

    /// Resolve a registered query by name.
    pub fn query(&self, name: &str) -> Result<QueryHandle> {
        self.queries
            .iter()
            .find(|q| &*q.name == name)
            .cloned()
            .ok_or_else(|| {
                anyhow!(
                    "no registered query named '{name}' (catalog: {})",
                    self.queries
                        .iter()
                        .map(|q| q.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// All registered queries, in registration order. Engines compiled
    /// through [`Engine::compile_aql`] have a single entry named
    /// `default` with an empty namespace.
    pub fn queries(&self) -> &[QueryHandle] {
        &self.queries
    }

    /// Catalog entries a [`CatalogBuilder::lenient`] build quarantined,
    /// with the diagnostics that rejected them. Always empty for strict
    /// (default) builds — those fail instead.
    pub fn rejected_queries(&self) -> &[RejectedQuery] {
        &self.rejected
    }

    /// Look up one quarantined entry by registered name.
    pub fn rejected_query(&self, name: &str) -> Option<&RejectedQuery> {
        self.rejected.iter().find(|r| r.name == name)
    }

    /// Non-fatal diagnostics (warnings such as `W310`/`W311`) the
    /// build-time static analyzer produced for this engine's plan.
    pub fn analysis_report(&self) -> &crate::analysis::Report {
        &self.analysis
    }

    /// The deduplicated artifact variants this engine's hardware compiler
    /// selected — the catalog's **shared image menu**. Empty for
    /// software-only engines.
    pub fn artifact_keys(&self) -> &[ArtifactKey] {
        &self.artifacts
    }

    /// All output views of this engine, in output order.
    pub fn views(&self) -> &[ViewHandle] {
        self.executor.catalog().handles()
    }

    /// Evaluate one document synchronously on the calling thread.
    pub fn run_doc(&self, doc: &Document) -> DocResult {
        self.executor.run_doc(doc)
    }

    /// Open a streaming [`Session`] builder: configure worker threads,
    /// bounded queue depth, a [`ResultSink`] and per-view subscriptions,
    /// then `start()` and `push` documents with backpressure.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use boost::prelude::*;
    ///
    /// # fn main() -> anyhow::Result<()> {
    /// let engine = Engine::compile_aql(
    ///     "create view Word as extract regex /[a-z]+/ on d.text as w \
    ///      from Document d; output view Word;",
    /// )?;
    /// let sink = Arc::new(CollectSink::default());
    /// let mut session = engine
    ///     .session()
    ///     .threads(2)
    ///     .queue_depth(4) // ≤ 4 queued + 2 in workers, then push blocks
    ///     .sink(sink.clone())
    ///     .start();
    /// session.push(Document::new(0, "one two"))?;
    /// session.push(Document::new(1, "three"))?;
    /// let report = session.finish();
    /// assert_eq!(report.docs, 2);
    /// assert_eq!(report.tuples, 3);
    /// assert_eq!(sink.len(), 2);
    /// # Ok(())
    /// # }
    /// ```
    pub fn session(&self) -> SessionBuilder {
        SessionBuilder::new(self.executor.clone(), self.service.clone())
            .quarantine(self.quarantine.clone())
            .watchdog(self.watchdog.clone())
    }

    /// The engine's poison-document quarantine: every panic contained by
    /// a session worker is recorded here (bounded ring; total count keeps
    /// climbing past the cap). Shared by every session of this engine.
    pub fn quarantine(&self) -> &Arc<Quarantine> {
        &self.quarantine
    }

    /// Liveness report over this engine's registered worker threads:
    /// healthy when no *busy* thread has gone silent past the stall
    /// window (idle workers blocked on an empty queue are healthy). The
    /// serving tier's `GET /healthz` is a thin view over this.
    pub fn health(&self) -> HealthReport {
        self.watchdog.report()
    }

    /// The engine's watchdog registry — the serving tier registers its
    /// own comm/writer threads here so `/healthz` covers them too.
    pub fn watchdog(&self) -> &Arc<Watchdog> {
        &self.watchdog
    }

    /// Snapshot the per-operator profile (over everything run so far).
    pub fn profile(&self) -> Profile {
        self.profiler.snapshot(self.executor.graph())
    }

    /// Reset profile counters.
    pub fn reset_profile(&self) {
        self.profiler.reset();
    }

    /// Accelerator metrics, when a service is attached.
    pub fn accel_snapshot(&self) -> Option<AccelSnapshot> {
        self.service.as_ref().map(|s| s.metrics().snapshot())
    }

    /// Gauges of the accelerator's bounded submission queue, when a
    /// service is attached. On a multi-device pool this is the merged
    /// view across every device (see
    /// [`AccelService::queue_snapshot`]); per-device rows come from
    /// [`Engine::accel_device_snapshots`].
    pub fn accel_queue_snapshot(&self) -> Option<QueueSnapshot> {
        self.service.as_ref().map(|s| s.queue_snapshot())
    }

    /// Per-device accelerator gauges (package counters plus submission
    /// queue, in device order), when a service is attached.
    pub fn accel_device_snapshots(&self) -> Option<Vec<AccelDeviceSnapshot>> {
        self.service.as_ref().map(|s| s.device_snapshots())
    }

    /// Pool-level routing counters (sibling retries, completed
    /// failovers, host fallbacks, software-routed calls), when a
    /// service is attached.
    pub fn accel_pool_snapshot(&self) -> Option<PoolSnapshot> {
        self.service.as_ref().map(|s| s.pool_snapshot())
    }

    /// Per-device circuit-breaker snapshots (state + trip/probe/re-admit
    /// counters, in device order), when a service is attached.
    pub fn accel_breaker_snapshots(&self) -> Option<Vec<BreakerSnapshot>> {
        self.service.as_ref().map(|s| s.breaker_snapshots())
    }

    /// The simulator's counters (packages, cycles, injected faults), when
    /// this engine runs over [`EngineSpec::Sim`].
    pub fn sim_snapshot(&self) -> Option<crate::runtime::SimSnapshot> {
        self.config.engine.sim_stats().map(|s| s.snapshot())
    }

    /// Per-shard gauges of the return-to-origin buffer arena
    /// (checkouts, fresh allocations, local and cross-thread returns).
    /// The arena is **process-wide** — these counters cover every engine
    /// and session in the process, not just this one.
    pub fn arena_shards(&self) -> Vec<crate::metrics::ArenaShardSnapshot> {
        crate::exec::batch::shard_stats()
    }

    /// Process-wide arena totals (all shards summed) — after warm-up,
    /// `fresh` stays flat on both execution routes; `returns_cross`
    /// counts the buffers that crossed threads and were routed home.
    pub fn arena_snapshot(&self) -> crate::metrics::ArenaSnapshot {
        crate::exec::batch::global_arena_stats()
    }

    /// Drive a fully-materialized corpus with `threads` workers — a thin
    /// wrapper over [`Engine::session`] (document-per-thread over the
    /// bounded queue, the paper's execution model). Streaming producers
    /// should use the session directly.
    pub fn run_corpus(&self, corpus: &Corpus, threads: usize) -> RunReport {
        let threads = threads.max(1);
        let mut session = self
            .session()
            .threads(threads)
            .queue_depth(2 * threads)
            .start();
        for doc in &corpus.docs {
            // Document text is Arc'd, so this clone is a refcount bump
            session
                .push(doc.clone())
                .expect("session worker pool died mid-corpus");
        }
        session.finish()
    }

    /// Shut down the accelerator service (also happens on drop).
    pub fn shutdown(&self) {
        if let Some(s) = &self.service {
            s.shutdown();
        }
    }
}

/// Result of a corpus run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Documents processed.
    pub docs: usize,
    /// Payload bytes processed.
    pub bytes: usize,
    /// Total output tuples across views.
    pub tuples: usize,
    /// Documents answered with a structured error (quarantined panic or
    /// deadline expiry) instead of a result. Not counted in `docs`.
    pub errors: usize,
    /// The subset of `errors` that were deadline expiries.
    pub expired: usize,
    /// Wall-clock duration of the run.
    pub wall: Duration,
    /// Worker threads used.
    pub threads: usize,
    /// Accelerator counters, when a service was attached.
    pub accel: Option<AccelSnapshot>,
    /// Finished corpus-level aggregate tables (`group by` / `top k`
    /// views), one entry per aggregate output view, built by merging
    /// every worker's [`crate::exec::AggPartial`]s at drain time. Empty
    /// when the catalog has no aggregate views. Deterministic for any
    /// worker count and document arrival order.
    pub corpus: Vec<CorpusResult>,
}

impl RunReport {
    /// Measured wall-clock throughput in bytes/s.
    pub fn throughput(&self) -> f64 {
        self.bytes as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Documents per second.
    pub fn docs_per_sec(&self) -> f64 {
        self.docs as f64 / self.wall.as_secs_f64().max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusSpec;

    fn t1_aql() -> String {
        crate::queries::builtin("t1").unwrap().aql
    }

    #[test]
    fn software_engine_runs_corpus() {
        let engine = Engine::compile_aql(&t1_aql()).unwrap();
        let corpus = CorpusSpec::news(12, 1024).generate();
        let report = engine.run_corpus(&corpus, 4);
        assert_eq!(report.docs, 12);
        assert!(report.tuples > 0);
        assert!(report.throughput() > 0.0);
        let profile = engine.profile();
        assert!(profile.total_ns() > 0);
        assert!(profile.fraction_extraction() > 0.0);
    }

    #[test]
    fn accelerated_engine_matches_software() {
        let corpus = CorpusSpec::news(8, 512).generate();
        let sw = Engine::compile_aql(&t1_aql()).unwrap();
        let hw = Engine::with_config(
            &t1_aql(),
            EngineConfig::accelerated(PartitionMode::SingleSubgraph, EngineSpec::Native),
        )
        .unwrap();
        for d in &corpus.docs {
            let mut a: Vec<String> = sw
                .run_doc(d)
                .iter()
                .flat_map(|(h, rows)| {
                    rows.iter().map(move |t| format!("{}:{t:?}", h.name()))
                })
                .collect();
            let mut b: Vec<String> = hw
                .run_doc(d)
                .iter()
                .flat_map(|(h, rows)| {
                    rows.iter().map(move |t| format!("{}:{t:?}", h.name()))
                })
                .collect();
            a.sort();
            b.sort();
            assert_eq!(a, b);
        }
        assert!(hw.accel_snapshot().unwrap().packages > 0);
        hw.shutdown();
    }

    #[test]
    fn simulated_engine_matches_software_and_reports_sim_stats() {
        let corpus = CorpusSpec::news(6, 512).generate();
        let sw = Engine::compile_aql(&t1_aql()).unwrap();
        let hw = Engine::with_config(
            &t1_aql(),
            EngineConfig::simulated(PartitionMode::SingleSubgraph),
        )
        .unwrap();
        for d in &corpus.docs {
            assert_eq!(
                sw.run_doc(d).total_tuples(),
                hw.run_doc(d).total_tuples(),
                "doc {}",
                d.id
            );
        }
        let sim = hw.sim_snapshot().expect("simulated engine has sim stats");
        assert!(sim.packages > 0, "the simulator must have scanned packages");
        assert!(sim.cycles > 0);
        assert_eq!(sim.faults, 0);
        let accel = hw.accel_snapshot().unwrap();
        assert_eq!(accel.packages, sim.packages);
        assert_eq!(accel.cycles, sim.cycles);
        // a software-only engine never runs the simulator
        assert_eq!(sw.sim_snapshot().map(|s| s.packages), Some(0));
        hw.shutdown();
    }

    #[test]
    fn multithreaded_run_is_deterministic_in_counts() {
        let engine = Engine::compile_aql(&t1_aql()).unwrap();
        let corpus = CorpusSpec::news(20, 512).generate();
        let r1 = engine.run_corpus(&corpus, 1);
        let r8 = engine.run_corpus(&corpus, 8);
        assert_eq!(r1.tuples, r8.tuples);
    }

    #[test]
    fn run_report_corpus_aggregates_are_thread_invariant() {
        let aql = "create view E as extract regex /[A-Z][a-z]+/ on d.text as m \
                   from Document d; \
                   create view Top as select GetText(e.m) as term, Count() as n, \
                   CountDocs() as docs from E e group by term score n top 5; \
                   output view Top;";
        let engine = Engine::compile_aql(aql).unwrap();
        let corpus = CorpusSpec::news(20, 512).generate();
        let r1 = engine.run_corpus(&corpus, 1);
        let r8 = engine.run_corpus(&corpus, 8);
        assert_eq!(r1.corpus.len(), 1);
        assert_eq!(r1.corpus[0].view, "Top");
        assert!(!r1.corpus[0].rows.is_empty());
        assert!(r1.corpus[0].rows.len() <= 5);
        // byte-identical finished table regardless of worker count
        assert_eq!(
            format!("{:?}", r1.corpus[0].rows),
            format!("{:?}", r8.corpus[0].rows)
        );
        // a catalog without aggregate views reports an empty corpus table
        let plain = Engine::compile_aql(&t1_aql()).unwrap();
        assert!(plain.run_corpus(&corpus, 2).corpus.is_empty());
    }

    #[test]
    fn software_subgraph_engine_equivalent() {
        let corpus = CorpusSpec::news(6, 512).generate();
        let plain = Engine::compile_aql(&t1_aql()).unwrap();
        let swsg =
            Engine::with_software_subgraphs(&t1_aql(), PartitionMode::MultiSubgraph).unwrap();
        let a = plain.run_corpus(&corpus, 2);
        let b = swsg.run_corpus(&corpus, 2);
        assert_eq!(a.tuples, b.tuples);
    }

    #[test]
    fn bad_aql_is_an_error() {
        assert!(Engine::compile_aql("create banana;").is_err());
    }

    #[test]
    fn lenient_build_quarantines_bad_entries() {
        let engine = Engine::builder()
            .register_builtin("t1")
            .register("broken", "output view Nope;")
            .lenient()
            .build()
            .unwrap();
        // the good entry runs…
        assert_eq!(engine.queries().len(), 1);
        assert!(engine.query("t1").is_ok());
        // …the bad one is quarantined with its diagnostic, not silently gone
        assert!(engine.query("broken").is_err());
        let r = engine.rejected_query("broken").expect("quarantined");
        assert!(r.report.has_code("E010"), "{}", r.report.render());
        assert_eq!(engine.rejected_queries().len(), 1);
        let d = Document::new(0, "Alice met Bob at IBM");
        assert!(engine.run_doc(&d).total_tuples() > 0);
    }

    #[test]
    fn lenient_build_with_no_survivors_is_an_error() {
        assert!(Engine::builder()
            .register("only", "output view Nope;")
            .lenient()
            .build()
            .is_err());
    }

    #[test]
    fn strict_build_renders_coded_diagnostics() {
        let err = Engine::builder()
            .register("q", "output view Nope;")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("E010"), "{err}");
        assert!(err.contains("Nope"), "{err}");
    }

    #[test]
    fn strict_accelerated_build_is_warning_free_for_builtins() {
        let engine = Engine::builder()
            .register_builtin("t1")
            .register_builtin("t2")
            .register_builtin("t3")
            .register_builtin("t4")
            .register_builtin("t5")
            .config(EngineConfig::simulated(PartitionMode::ExtractOnly))
            .build()
            .unwrap();
        assert!(
            engine.analysis_report().is_clean(),
            "{}",
            engine.analysis_report().render()
        );
        engine.shutdown();
    }

    #[test]
    fn session_smoke_collect_and_subscribe() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        let engine = Engine::compile_aql(&t1_aql()).unwrap();
        let person_org = engine.view("PersonOrg").unwrap();
        assert!(engine.view("NoSuchView").is_err());

        let collected = Arc::new(CollectSink::default());
        let seen = Arc::new(AtomicUsize::new(0));
        let seen2 = seen.clone();
        let mut session = engine
            .session()
            .threads(2)
            .queue_depth(4)
            .sink(collected.clone())
            .subscribe(&person_org, move |_doc, _rows| {
                seen2.fetch_add(1, Ordering::Relaxed);
            })
            .start();
        let corpus = CorpusSpec::news(10, 512).generate();
        let pushed = session.push_batch(corpus.docs.iter().cloned()).unwrap();
        assert_eq!(pushed, 10);
        let report = session.finish();
        assert_eq!(report.docs, 10);
        assert_eq!(collected.len(), 10);
        // the subscription fires once per document, match or not
        assert_eq!(seen.load(Ordering::Relaxed), 10);
        // collected results agree with synchronous evaluation
        for (doc, result) in collected.take() {
            assert_eq!(
                result.total_tuples(),
                engine.run_doc(&doc).total_tuples(),
                "doc {}",
                doc.id
            );
        }
    }

    #[test]
    fn catalog_builds_namespaced_handles() {
        let engine = Engine::builder()
            .register_builtin("t1")
            .register_builtin("t2")
            .build()
            .unwrap();
        assert_eq!(engine.queries().len(), 2);
        assert!(engine.query("t9").is_err());

        let t1 = engine.query("t1").unwrap();
        let h = t1.view("PersonOrg").unwrap();
        assert_eq!(h.name(), "t1.PersonOrg");
        assert!(t1.view("Contacts").is_err(), "Contacts belongs to t2");
        assert_eq!(t1.view_names(), vec!["PersonOrg", "EntitiesClean"]);

        // Engine::view accepts qualified names, and unqualified names when
        // they are unambiguous across the catalog
        assert_eq!(engine.view("t2.Contacts").unwrap().name(), "t2.Contacts");
        assert_eq!(engine.view("Contacts").unwrap().name(), "t2.Contacts");
        assert!(engine.view("Nope").is_err());
    }

    #[test]
    fn catalog_namespaces_isolate_same_named_views() {
        let engine = Engine::builder()
            .register(
                "qa",
                "create view V as extract regex /a+/ on d.text as m from Document d; \
                 output view V;",
            )
            .register(
                "qb",
                "create view V as extract regex /b+/ on d.text as m from Document d; \
                 output view V;",
            )
            .build()
            .unwrap();
        // both queries define V: unqualified resolution is ambiguous…
        let err = engine.view("V").unwrap_err().to_string();
        assert!(err.contains("ambiguous"), "{err}");
        // …but each query's own handle sees only its V
        let va = engine.query("qa").unwrap().view("V").unwrap();
        let vb = engine.query("qb").unwrap().view("V").unwrap();
        let r = engine.run_doc(&Document::new(0, "aaa b"));
        assert_eq!(r[&va].len(), 1);
        assert_eq!(r[&vb].len(), 1);
        let qa = engine.query("qa").unwrap();
        assert_eq!(qa.total_tuples(&r), 1);
        assert_eq!(qa.iter(&r).count(), 1);
    }

    #[test]
    fn catalog_rejects_bad_registrations() {
        assert!(Engine::builder().build().is_err(), "empty catalog");
        assert!(Engine::builder()
            .register_builtin("t1")
            .register_builtin("t1")
            .build()
            .is_err());
        assert!(Engine::builder().register_builtin("t99").build().is_err());
        assert!(Engine::builder()
            .register("bad.name", "output view X;")
            .build()
            .is_err());
        assert!(Engine::builder()
            .register("q", "create banana;")
            .build()
            .is_err());
    }

    #[test]
    fn catalog_interns_shared_extraction_leaves() {
        // t1 and t2 share the Url regex; t3/t4/t5 share t1's OrgDict
        let merged = Engine::builder()
            .register_builtin("t1")
            .register_builtin("t2")
            .build()
            .unwrap();
        let singles: usize = ["t1", "t2"]
            .iter()
            .map(|q| {
                Engine::compile_aql(&crate::queries::builtin(q).unwrap().aql)
                    .unwrap()
                    .graph()
                    .extraction_leaves()
            })
            .sum();
        let merged_leaves = merged.graph().extraction_leaves();
        assert!(
            merged_leaves < singles,
            "no interning: merged {merged_leaves} vs per-query sum {singles}"
        );
    }

    #[test]
    fn catalog_merged_results_match_single_query_engines() {
        let engine = Engine::builder()
            .register_builtin("t1")
            .register_builtin("t2")
            .register_builtin("t3")
            .register_builtin("t4")
            .register_builtin("t5")
            .config(EngineConfig::simulated(PartitionMode::ExtractOnly))
            .build()
            .unwrap();
        // extract-only folds every deduplicated leaf into ONE image: one
        // plan, one subgraph, one artifact set
        let plan = engine.plan().expect("accelerated engine has a plan");
        assert_eq!(plan.subgraphs.len(), 1);
        assert!(!engine.artifact_keys().is_empty());

        let d = Document::new(
            0,
            "Laura Chiticariu works at IBM Research in Zurich. \
             Call (408) 555-9876 or visit http://example.org/x on 2014-06-30.",
        );
        let merged_result = engine.run_doc(&d);
        for q in ["t1", "t2", "t3", "t4", "t5"] {
            let single =
                Engine::compile_aql(&crate::queries::builtin(q).unwrap().aql).unwrap();
            let qh = engine.query(q).unwrap();
            assert_eq!(
                qh.total_tuples(&merged_result),
                single.run_doc(&d).total_tuples(),
                "query {q} diverged between merged catalog and single engine"
            );
        }
        assert!(engine.sim_snapshot().unwrap().packages > 0);
        engine.shutdown();
    }

    #[test]
    fn session_subscribe_query_fires_per_document() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        let engine = Engine::builder()
            .register_builtin("t1")
            .register_builtin("t5")
            .build()
            .unwrap();
        let t1 = engine.query("t1").unwrap();
        let docs_seen = Arc::new(AtomicUsize::new(0));
        let tuples_seen = Arc::new(AtomicUsize::new(0));
        let (d2, t2) = (docs_seen.clone(), tuples_seen.clone());
        let mut session = engine
            .session()
            .threads(2)
            .queue_depth(4)
            .subscribe_query(&t1, move |_doc, qh, result| {
                d2.fetch_add(1, Ordering::Relaxed);
                t2.fetch_add(qh.total_tuples(result), Ordering::Relaxed);
            })
            .start();
        let corpus = CorpusSpec::news(8, 512).generate();
        session.push_batch(corpus.docs.iter().cloned()).unwrap();
        session.finish();
        assert_eq!(docs_seen.load(Ordering::Relaxed), 8);
        let expect: usize = corpus
            .docs
            .iter()
            .map(|d| t1.total_tuples(&engine.run_doc(d)))
            .sum();
        assert_eq!(tuples_seen.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn compile_aql_engine_has_default_query() {
        let engine = Engine::compile_aql(&t1_aql()).unwrap();
        assert_eq!(engine.queries().len(), 1);
        let q = engine.query("default").unwrap();
        // empty namespace: unqualified handles, identical to Engine::view
        assert_eq!(q.view("PersonOrg").unwrap().name(), "PersonOrg");
        assert!(engine.artifact_keys().is_empty(), "software engine");
    }

    #[test]
    fn legacy_rows_engine_matches_columnar() {
        let corpus = CorpusSpec::news(6, 512).generate();
        let col = Engine::compile_aql(&t1_aql()).unwrap();
        let leg = Engine::with_config(&t1_aql(), EngineConfig::legacy_rows()).unwrap();
        for d in &corpus.docs {
            assert_eq!(
                col.run_doc(d).views(),
                leg.run_doc(d).views(),
                "doc {}",
                d.id
            );
        }
    }

    #[test]
    fn report_math() {
        let r = RunReport {
            docs: 10,
            bytes: 1_000_000,
            tuples: 5,
            errors: 0,
            expired: 0,
            wall: Duration::from_millis(100),
            threads: 2,
            accel: None,
            corpus: Vec::new(),
        };
        assert!((r.throughput() - 1.0e7).abs() < 1.0);
        assert!((r.docs_per_sec() - 100.0).abs() < 1e-6);
    }
}
