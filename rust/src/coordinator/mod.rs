//! The engine facade: compile an AQL query, optionally partition it for
//! the accelerator, and drive corpora through it with the paper's
//! document-per-thread worker model.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::accel::{AccelOptions, AccelService, AccelSubgraphRunner};
use crate::aog::Graph;
use crate::corpus::Corpus;
use crate::exec::{DocOutput, Executor, Profile, Profiler};
use crate::hwcompiler::{compile_subgraph, AccelConfig};
use crate::metrics::AccelSnapshot;
use crate::partition::{partition, PartitionMode, PartitionPlan, SoftwareSubgraphRunner};
use crate::runtime::EngineSpec;
use crate::text::Document;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Offload scenario.
    pub mode: PartitionMode,
    /// Which accelerator backend executes packages (ignored for
    /// [`PartitionMode::None`]).
    pub engine: EngineSpec,
    /// Communication-interface options.
    pub accel: AccelOptions,
    /// Collect per-operator profiles (Fig 4). Cheap; on by default.
    pub profile: bool,
    /// Run the optimizer (on by default; off exposes the naive plans).
    pub optimize: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: PartitionMode::None,
            engine: EngineSpec::Native,
            accel: AccelOptions::default(),
            profile: true,
            optimize: true,
        }
    }
}

impl EngineConfig {
    /// Software-only configuration.
    pub fn software() -> EngineConfig {
        EngineConfig::default()
    }

    /// Accelerated configuration with the given mode and backend.
    pub fn accelerated(mode: PartitionMode, engine: EngineSpec) -> EngineConfig {
        EngineConfig {
            mode,
            engine,
            ..Default::default()
        }
    }
}

/// A compiled, ready-to-run engine.
pub struct Engine {
    graph: Arc<Graph>,
    plan: Option<PartitionPlan>,
    executor: Arc<Executor>,
    profiler: Arc<Profiler>,
    service: Option<Arc<AccelService>>,
    config: EngineConfig,
}

impl Engine {
    /// Compile AQL with the default (software) configuration.
    pub fn compile_aql(aql: &str) -> Result<Engine> {
        Engine::with_config(aql, EngineConfig::default())
    }

    /// Compile AQL with an explicit configuration.
    pub fn with_config(aql: &str, config: EngineConfig) -> Result<Engine> {
        let g = crate::aql::compile(aql).map_err(|e| anyhow!("{e}"))?;
        let g = if config.optimize {
            crate::optimizer::optimize(&g)
        } else {
            g
        };

        let (exec_graph, plan, service): (Graph, Option<PartitionPlan>, Option<Arc<AccelService>>) =
            if config.mode == PartitionMode::None {
                (g.clone(), None, None)
            } else {
                let plan = partition(&g, config.mode);
                let configs: Vec<AccelConfig> = plan
                    .subgraphs
                    .iter()
                    .map(compile_subgraph)
                    .collect::<Result<_, _>>()
                    .map_err(|e| anyhow!("hardware compile failed: {e}"))?;
                let service =
                    AccelService::start(configs, config.engine.clone(), config.accel.clone());
                (plan.supergraph.clone(), Some(plan), Some(service))
            };

        let profiler = Arc::new(if config.profile {
            Profiler::for_graph(&exec_graph)
        } else {
            Profiler::disabled()
        });
        let exec_graph = Arc::new(exec_graph);
        let mut executor = Executor::new(exec_graph.clone(), profiler.clone());
        if let (Some(plan), Some(service)) = (&plan, &service) {
            let _ = plan;
            executor = executor
                .with_subgraph_runner(Arc::new(AccelSubgraphRunner::new(service.clone())));
        }
        Ok(Engine {
            graph: Arc::new(g),
            plan,
            executor: Arc::new(executor),
            profiler,
            service,
            config,
        })
    }

    /// Compile with a partition plan but run subgraphs in *software*
    /// (reference runner) — used by tests and ablations.
    pub fn with_software_subgraphs(aql: &str, mode: PartitionMode) -> Result<Engine> {
        let g = crate::optimizer::optimize(&crate::aql::compile(aql).map_err(|e| anyhow!("{e}"))?);
        let plan = partition(&g, mode);
        let profiler = Arc::new(Profiler::for_graph(&plan.supergraph));
        let runner = Arc::new(SoftwareSubgraphRunner::new(&plan));
        let executor = Arc::new(
            Executor::new(Arc::new(plan.supergraph.clone()), profiler.clone())
                .with_subgraph_runner(runner),
        );
        Ok(Engine {
            graph: Arc::new(g),
            plan: Some(plan),
            executor,
            profiler,
            service: None,
            config: EngineConfig {
                mode,
                ..Default::default()
            },
        })
    }

    /// The (optimized) logical graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The partition plan, if any.
    pub fn plan(&self) -> Option<&PartitionPlan> {
        self.plan.as_ref()
    }

    /// The engine configuration.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Evaluate one document.
    pub fn run_doc(&self, doc: &Document) -> DocOutput {
        self.executor.run_doc(doc)
    }

    /// Snapshot the per-operator profile (over everything run so far).
    pub fn profile(&self) -> Profile {
        self.profiler.snapshot(self.executor.graph())
    }

    /// Reset profile counters.
    pub fn reset_profile(&self) {
        self.profiler.reset();
    }

    /// Accelerator metrics, when a service is attached.
    pub fn accel_snapshot(&self) -> Option<AccelSnapshot> {
        self.service.as_ref().map(|s| s.metrics().snapshot())
    }

    /// Drive a corpus with `threads` workers (document-per-thread, shared
    /// work index — the paper's execution model).
    pub fn run_corpus(&self, corpus: &Corpus, threads: usize) -> RunReport {
        let threads = threads.max(1);
        let next = AtomicUsize::new(0);
        let tuples = AtomicUsize::new(0);
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| {
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= corpus.docs.len() {
                            break;
                        }
                        let out = self.executor.run_doc(&corpus.docs[i]);
                        tuples.fetch_add(out.total_tuples(), Ordering::Relaxed);
                    }
                });
            }
        });
        let wall = t0.elapsed();
        RunReport {
            docs: corpus.docs.len(),
            bytes: corpus.total_bytes(),
            tuples: tuples.into_inner(),
            wall,
            threads,
            accel: self.accel_snapshot(),
        }
    }

    /// Shut down the accelerator service (also happens on drop).
    pub fn shutdown(&self) {
        if let Some(s) = &self.service {
            s.shutdown();
        }
    }
}

/// Result of a corpus run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub docs: usize,
    pub bytes: usize,
    pub tuples: usize,
    pub wall: Duration,
    pub threads: usize,
    pub accel: Option<AccelSnapshot>,
}

impl RunReport {
    /// Measured wall-clock throughput in bytes/s.
    pub fn throughput(&self) -> f64 {
        self.bytes as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Documents per second.
    pub fn docs_per_sec(&self) -> f64 {
        self.docs as f64 / self.wall.as_secs_f64().max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusSpec;

    fn t1_aql() -> String {
        crate::queries::builtin("t1").unwrap().aql
    }

    #[test]
    fn software_engine_runs_corpus() {
        let engine = Engine::compile_aql(&t1_aql()).unwrap();
        let corpus = CorpusSpec::news(12, 1024).generate();
        let report = engine.run_corpus(&corpus, 4);
        assert_eq!(report.docs, 12);
        assert!(report.tuples > 0);
        assert!(report.throughput() > 0.0);
        let profile = engine.profile();
        assert!(profile.total_ns() > 0);
        assert!(profile.fraction_extraction() > 0.0);
    }

    #[test]
    fn accelerated_engine_matches_software() {
        let corpus = CorpusSpec::news(8, 512).generate();
        let sw = Engine::compile_aql(&t1_aql()).unwrap();
        let hw = Engine::with_config(
            &t1_aql(),
            EngineConfig::accelerated(PartitionMode::SingleSubgraph, EngineSpec::Native),
        )
        .unwrap();
        for d in &corpus.docs {
            let mut a: Vec<String> = sw
                .run_doc(d)
                .views
                .iter()
                .flat_map(|(v, rows)| rows.iter().map(move |t| format!("{v}:{t:?}")))
                .collect();
            let mut b: Vec<String> = hw
                .run_doc(d)
                .views
                .iter()
                .flat_map(|(v, rows)| rows.iter().map(move |t| format!("{v}:{t:?}")))
                .collect();
            a.sort();
            b.sort();
            assert_eq!(a, b);
        }
        assert!(hw.accel_snapshot().unwrap().packages > 0);
        hw.shutdown();
    }

    #[test]
    fn multithreaded_run_is_deterministic_in_counts() {
        let engine = Engine::compile_aql(&t1_aql()).unwrap();
        let corpus = CorpusSpec::news(20, 512).generate();
        let r1 = engine.run_corpus(&corpus, 1);
        let r8 = engine.run_corpus(&corpus, 8);
        assert_eq!(r1.tuples, r8.tuples);
    }

    #[test]
    fn software_subgraph_engine_equivalent() {
        let corpus = CorpusSpec::news(6, 512).generate();
        let plain = Engine::compile_aql(&t1_aql()).unwrap();
        let swsg =
            Engine::with_software_subgraphs(&t1_aql(), PartitionMode::MultiSubgraph).unwrap();
        let a = plain.run_corpus(&corpus, 2);
        let b = swsg.run_corpus(&corpus, 2);
        assert_eq!(a.tuples, b.tuples);
    }

    #[test]
    fn bad_aql_is_an_error() {
        assert!(Engine::compile_aql("create banana;").is_err());
    }

    #[test]
    fn report_math() {
        let r = RunReport {
            docs: 10,
            bytes: 1_000_000,
            tuples: 5,
            wall: Duration::from_millis(100),
            threads: 2,
            accel: None,
        };
        assert!((r.throughput() - 1.0e7).abs() < 1.0);
        assert!((r.docs_per_sec() - 100.0).abs() < 1e-6);
    }
}
