//! The engine facade: compile an AQL query, optionally partition it for
//! the accelerator, and stream documents through it with the paper's
//! document-per-thread worker model.
//!
//! The primary run surface is the push-based [`Session`] pipeline
//! ([`Engine::session`]); [`Engine::run_corpus`] and [`Engine::run_doc`]
//! are thin conveniences over the same machinery.

pub mod session;

pub use session::{CallbackSink, CollectSink, CountingSink, ResultSink, Session, SessionBuilder};

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::accel::{AccelOptions, AccelService, AccelSubgraphRunner};
use crate::aog::Graph;
use crate::corpus::Corpus;
use crate::exec::{DocResult, Executor, Profile, Profiler, ViewHandle};
use crate::hwcompiler::{compile_subgraph, AccelConfig};
use crate::metrics::{AccelSnapshot, QueueSnapshot};
use crate::partition::{partition, PartitionMode, PartitionPlan, SoftwareSubgraphRunner};
use crate::runtime::EngineSpec;
use crate::text::Document;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Offload scenario.
    pub mode: PartitionMode,
    /// Which accelerator backend executes packages (ignored for
    /// [`PartitionMode::None`]).
    pub engine: EngineSpec,
    /// Communication-interface options.
    pub accel: AccelOptions,
    /// Collect per-operator profiles (Fig 4). Cheap; on by default.
    pub profile: bool,
    /// Run the optimizer (on by default; off exposes the naive plans).
    pub optimize: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: PartitionMode::None,
            // the deterministic simulator is the default backend: every
            // build (no XLA toolchain required) exercises the full HW path
            engine: EngineSpec::default(),
            accel: AccelOptions::default(),
            profile: true,
            optimize: true,
        }
    }
}

impl EngineConfig {
    /// Software-only configuration.
    pub fn software() -> EngineConfig {
        EngineConfig::default()
    }

    /// Accelerated configuration with the given mode and backend.
    pub fn accelerated(mode: PartitionMode, engine: EngineSpec) -> EngineConfig {
        EngineConfig {
            mode,
            engine,
            ..Default::default()
        }
    }

    /// Accelerated configuration over the deterministic simulator — the
    /// default hardware path when `pjrt` is off.
    pub fn simulated(mode: PartitionMode) -> EngineConfig {
        EngineConfig::accelerated(mode, EngineSpec::default())
    }
}

/// A compiled, ready-to-run engine.
pub struct Engine {
    graph: Arc<Graph>,
    plan: Option<PartitionPlan>,
    executor: Arc<Executor>,
    profiler: Arc<Profiler>,
    service: Option<Arc<AccelService>>,
    config: EngineConfig,
}

impl Engine {
    /// Compile AQL with the default (software) configuration.
    pub fn compile_aql(aql: &str) -> Result<Engine> {
        Engine::with_config(aql, EngineConfig::default())
    }

    /// Compile AQL with an explicit configuration.
    pub fn with_config(aql: &str, config: EngineConfig) -> Result<Engine> {
        let g = crate::aql::compile(aql).map_err(|e| anyhow!("{e}"))?;
        let g = if config.optimize {
            crate::optimizer::optimize(&g)
        } else {
            g
        };

        let (exec_graph, plan, service): (Graph, Option<PartitionPlan>, Option<Arc<AccelService>>) =
            if config.mode == PartitionMode::None {
                (g.clone(), None, None)
            } else {
                let plan = partition(&g, config.mode);
                let configs: Vec<AccelConfig> = plan
                    .subgraphs
                    .iter()
                    .map(compile_subgraph)
                    .collect::<Result<_, _>>()
                    .map_err(|e| anyhow!("hardware compile failed: {e}"))?;
                let service =
                    AccelService::start(configs, config.engine.clone(), config.accel.clone());
                (plan.supergraph.clone(), Some(plan), Some(service))
            };

        let profiler = Arc::new(if config.profile {
            Profiler::for_graph(&exec_graph)
        } else {
            Profiler::disabled()
        });
        let exec_graph = Arc::new(exec_graph);
        let mut executor = Executor::new(exec_graph.clone(), profiler.clone());
        if let (Some(plan), Some(service)) = (&plan, &service) {
            executor = executor.with_subgraph_runner(Arc::new(AccelSubgraphRunner::new(
                service.clone(),
                plan,
            )));
        }
        Ok(Engine {
            graph: Arc::new(g),
            plan,
            executor: Arc::new(executor),
            profiler,
            service,
            config,
        })
    }

    /// Compile with a partition plan but run subgraphs in *software*
    /// (reference runner) — used by tests and ablations.
    pub fn with_software_subgraphs(aql: &str, mode: PartitionMode) -> Result<Engine> {
        let g = crate::optimizer::optimize(&crate::aql::compile(aql).map_err(|e| anyhow!("{e}"))?);
        let plan = partition(&g, mode);
        let profiler = Arc::new(Profiler::for_graph(&plan.supergraph));
        let runner = Arc::new(SoftwareSubgraphRunner::new(&plan));
        let executor = Arc::new(
            Executor::new(Arc::new(plan.supergraph.clone()), profiler.clone())
                .with_subgraph_runner(runner),
        );
        Ok(Engine {
            graph: Arc::new(g),
            plan: Some(plan),
            executor,
            profiler,
            service: None,
            config: EngineConfig {
                mode,
                ..Default::default()
            },
        })
    }

    /// The (optimized) logical graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The partition plan, if any.
    pub fn plan(&self) -> Option<&PartitionPlan> {
        self.plan.as_ref()
    }

    /// The engine configuration.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Resolve a typed handle for output view `name` — the compile-time
    /// replacement for stringly-typed result lookups.
    pub fn view(&self, name: &str) -> Result<ViewHandle> {
        self.executor.catalog().resolve(name).cloned().ok_or_else(|| {
            anyhow!(
                "no output view named '{name}' (outputs: {})",
                self.views()
                    .iter()
                    .map(|h| h.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
    }

    /// All output views of this engine, in output order.
    pub fn views(&self) -> &[ViewHandle] {
        self.executor.catalog().handles()
    }

    /// Evaluate one document synchronously on the calling thread.
    pub fn run_doc(&self, doc: &Document) -> DocResult {
        self.executor.run_doc(doc)
    }

    /// Open a streaming [`Session`] builder: configure worker threads,
    /// bounded queue depth, a [`ResultSink`] and per-view subscriptions,
    /// then `start()` and `push` documents with backpressure.
    pub fn session(&self) -> SessionBuilder {
        SessionBuilder::new(self.executor.clone(), self.service.clone())
    }

    /// Snapshot the per-operator profile (over everything run so far).
    pub fn profile(&self) -> Profile {
        self.profiler.snapshot(self.executor.graph())
    }

    /// Reset profile counters.
    pub fn reset_profile(&self) {
        self.profiler.reset();
    }

    /// Accelerator metrics, when a service is attached.
    pub fn accel_snapshot(&self) -> Option<AccelSnapshot> {
        self.service.as_ref().map(|s| s.metrics().snapshot())
    }

    /// Gauges of the accelerator's bounded submission queue, when a
    /// service is attached.
    pub fn accel_queue_snapshot(&self) -> Option<QueueSnapshot> {
        self.service.as_ref().map(|s| s.queue_snapshot())
    }

    /// The simulator's counters (packages, cycles, injected faults), when
    /// this engine runs over [`EngineSpec::Sim`].
    pub fn sim_snapshot(&self) -> Option<crate::runtime::SimSnapshot> {
        self.config.engine.sim_stats().map(|s| s.snapshot())
    }

    /// Drive a fully-materialized corpus with `threads` workers — a thin
    /// wrapper over [`Engine::session`] (document-per-thread over the
    /// bounded queue, the paper's execution model). Streaming producers
    /// should use the session directly.
    pub fn run_corpus(&self, corpus: &Corpus, threads: usize) -> RunReport {
        let threads = threads.max(1);
        let mut session = self
            .session()
            .threads(threads)
            .queue_depth(2 * threads)
            .start();
        for doc in &corpus.docs {
            // Document text is Arc'd, so this clone is a refcount bump
            session
                .push(doc.clone())
                .expect("session worker pool died mid-corpus");
        }
        session.finish()
    }

    /// Shut down the accelerator service (also happens on drop).
    pub fn shutdown(&self) {
        if let Some(s) = &self.service {
            s.shutdown();
        }
    }
}

/// Result of a corpus run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub docs: usize,
    pub bytes: usize,
    pub tuples: usize,
    pub wall: Duration,
    pub threads: usize,
    pub accel: Option<AccelSnapshot>,
}

impl RunReport {
    /// Measured wall-clock throughput in bytes/s.
    pub fn throughput(&self) -> f64 {
        self.bytes as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Documents per second.
    pub fn docs_per_sec(&self) -> f64 {
        self.docs as f64 / self.wall.as_secs_f64().max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::CorpusSpec;

    fn t1_aql() -> String {
        crate::queries::builtin("t1").unwrap().aql
    }

    #[test]
    fn software_engine_runs_corpus() {
        let engine = Engine::compile_aql(&t1_aql()).unwrap();
        let corpus = CorpusSpec::news(12, 1024).generate();
        let report = engine.run_corpus(&corpus, 4);
        assert_eq!(report.docs, 12);
        assert!(report.tuples > 0);
        assert!(report.throughput() > 0.0);
        let profile = engine.profile();
        assert!(profile.total_ns() > 0);
        assert!(profile.fraction_extraction() > 0.0);
    }

    #[test]
    fn accelerated_engine_matches_software() {
        let corpus = CorpusSpec::news(8, 512).generate();
        let sw = Engine::compile_aql(&t1_aql()).unwrap();
        let hw = Engine::with_config(
            &t1_aql(),
            EngineConfig::accelerated(PartitionMode::SingleSubgraph, EngineSpec::Native),
        )
        .unwrap();
        for d in &corpus.docs {
            let mut a: Vec<String> = sw
                .run_doc(d)
                .iter()
                .flat_map(|(h, rows)| {
                    rows.iter().map(move |t| format!("{}:{t:?}", h.name()))
                })
                .collect();
            let mut b: Vec<String> = hw
                .run_doc(d)
                .iter()
                .flat_map(|(h, rows)| {
                    rows.iter().map(move |t| format!("{}:{t:?}", h.name()))
                })
                .collect();
            a.sort();
            b.sort();
            assert_eq!(a, b);
        }
        assert!(hw.accel_snapshot().unwrap().packages > 0);
        hw.shutdown();
    }

    #[test]
    fn simulated_engine_matches_software_and_reports_sim_stats() {
        let corpus = CorpusSpec::news(6, 512).generate();
        let sw = Engine::compile_aql(&t1_aql()).unwrap();
        let hw = Engine::with_config(
            &t1_aql(),
            EngineConfig::simulated(PartitionMode::SingleSubgraph),
        )
        .unwrap();
        for d in &corpus.docs {
            assert_eq!(
                sw.run_doc(d).total_tuples(),
                hw.run_doc(d).total_tuples(),
                "doc {}",
                d.id
            );
        }
        let sim = hw.sim_snapshot().expect("simulated engine has sim stats");
        assert!(sim.packages > 0, "the simulator must have scanned packages");
        assert!(sim.cycles > 0);
        assert_eq!(sim.faults, 0);
        let accel = hw.accel_snapshot().unwrap();
        assert_eq!(accel.packages, sim.packages);
        assert_eq!(accel.cycles, sim.cycles);
        // a software-only engine never runs the simulator
        assert_eq!(sw.sim_snapshot().map(|s| s.packages), Some(0));
        hw.shutdown();
    }

    #[test]
    fn multithreaded_run_is_deterministic_in_counts() {
        let engine = Engine::compile_aql(&t1_aql()).unwrap();
        let corpus = CorpusSpec::news(20, 512).generate();
        let r1 = engine.run_corpus(&corpus, 1);
        let r8 = engine.run_corpus(&corpus, 8);
        assert_eq!(r1.tuples, r8.tuples);
    }

    #[test]
    fn software_subgraph_engine_equivalent() {
        let corpus = CorpusSpec::news(6, 512).generate();
        let plain = Engine::compile_aql(&t1_aql()).unwrap();
        let swsg =
            Engine::with_software_subgraphs(&t1_aql(), PartitionMode::MultiSubgraph).unwrap();
        let a = plain.run_corpus(&corpus, 2);
        let b = swsg.run_corpus(&corpus, 2);
        assert_eq!(a.tuples, b.tuples);
    }

    #[test]
    fn bad_aql_is_an_error() {
        assert!(Engine::compile_aql("create banana;").is_err());
    }

    #[test]
    fn session_smoke_collect_and_subscribe() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        let engine = Engine::compile_aql(&t1_aql()).unwrap();
        let person_org = engine.view("PersonOrg").unwrap();
        assert!(engine.view("NoSuchView").is_err());

        let collected = Arc::new(CollectSink::default());
        let seen = Arc::new(AtomicUsize::new(0));
        let seen2 = seen.clone();
        let mut session = engine
            .session()
            .threads(2)
            .queue_depth(4)
            .sink(collected.clone())
            .subscribe(&person_org, move |_doc, _rows| {
                seen2.fetch_add(1, Ordering::Relaxed);
            })
            .start();
        let corpus = CorpusSpec::news(10, 512).generate();
        let pushed = session.push_batch(corpus.docs.iter().cloned()).unwrap();
        assert_eq!(pushed, 10);
        let report = session.finish();
        assert_eq!(report.docs, 10);
        assert_eq!(collected.len(), 10);
        // the subscription fires once per document, match or not
        assert_eq!(seen.load(Ordering::Relaxed), 10);
        // collected results agree with synchronous evaluation
        for (doc, result) in collected.take() {
            assert_eq!(
                result.total_tuples(),
                engine.run_doc(&doc).total_tuples(),
                "doc {}",
                doc.id
            );
        }
    }

    #[test]
    fn report_math() {
        let r = RunReport {
            docs: 10,
            bytes: 1_000_000,
            tuples: 5,
            wall: Duration::from_millis(100),
            threads: 2,
            accel: None,
        };
        assert!((r.throughput() - 1.0e7).abs() < 1.0);
        assert!((r.docs_per_sec() - 100.0).abs() < 1e-6);
    }
}
