//! The streaming `Session` API: a push-based document pipeline with
//! bounded buffering and pluggable result delivery.
//!
//! [`Engine::run_corpus`] needs the whole corpus in RAM and only returns
//! aggregate counts; a [`Session`] instead accepts documents one at a time
//! (`push`), runs a worker pool over a bounded queue, and delivers every
//! per-document [`DocResult`] to a [`ResultSink`] as it completes. When
//! producers outrun the workers the queue fills and `push` blocks — the
//! producer gets *backpressure* instead of unbounded memory growth, which
//! is what lets the engine sit behind a firehose.
//!
//! In-flight bound: with queue depth `Q` and `T` worker threads, at most
//! `Q + T` documents exist inside the pipeline at any instant (`Q` queued
//! plus one in each worker's hands). `run_corpus`, the CLI, the benches
//! and the examples are all thin layers over this type, so the software
//! and accelerated paths share one scheduler.
//!
//! ```text
//! push(doc) ─▶ [bounded queue, ≤ Q] ─▶ worker 0..T ─▶ ResultSink
//!      ▲                                   │
//!      └────────── blocks when full ◀──────┘   finish() → RunReport
//! ```
//!
//! [`Engine::run_corpus`]: super::Engine::run_corpus

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::accel::AccelService;
use crate::exec::{CorpusAgg, DocResult, Executor, ViewHandle};
use crate::metrics::QueueSnapshot;
use crate::runtime::chaos::{ChaosAction, ChaosPlan};
use crate::runtime::fault::{self, DocError, Quarantine, Watchdog};
use crate::runtime::queue::{self, QueueTx};
use crate::text::Document;

use super::{QueryHandle, RunReport};

/// Receives per-document results from a [`Session`]'s worker threads.
///
/// Exactly one of `on_result` / `on_error` is called per pushed document,
/// from whichever worker finished it (so implementations must be
/// thread-safe); with one worker thread, calls arrive in push order.
/// `on_finish` is called exactly once, after the last per-document call,
/// from the thread that calls [`Session::finish`].
pub trait ResultSink: Send + Sync {
    /// One document completed.
    fn on_result(&self, doc: &Document, result: &DocResult);

    /// One document failed with a contained, structured error (deadline
    /// expiry or a quarantined panic). Default: ignore — sinks that only
    /// care about successes keep working unchanged.
    fn on_error(&self, doc: &Document, error: &DocError) {
        let _ = (doc, error);
    }

    /// The session drained and is shutting down.
    fn on_finish(&self, report: &RunReport) {
        let _ = report;
    }
}

/// Drops results; the session's aggregate counters (docs, bytes, tuples)
/// are maintained regardless. The default sink — what `run_corpus` and the
/// benches use.
#[derive(Debug, Default)]
pub struct CountingSink;

impl ResultSink for CountingSink {
    fn on_result(&self, _doc: &Document, _result: &DocResult) {}
}

/// Clones every `(document, result)` pair into memory. For tests and
/// small batches — a firehose should prefer [`CallbackSink`] or a custom
/// sink that retires results incrementally.
#[derive(Debug, Default)]
pub struct CollectSink {
    results: Mutex<Vec<(Document, DocResult)>>,
}

impl CollectSink {
    /// Take everything collected so far.
    pub fn take(&self) -> Vec<(Document, DocResult)> {
        std::mem::take(&mut *self.results.lock().unwrap())
    }

    /// Number of results currently held.
    pub fn len(&self) -> usize {
        self.results.lock().unwrap().len()
    }

    /// True when nothing has been collected (or everything was taken).
    pub fn is_empty(&self) -> bool {
        self.results.lock().unwrap().is_empty()
    }
}

impl ResultSink for CollectSink {
    fn on_result(&self, doc: &Document, result: &DocResult) {
        self.results
            .lock()
            .unwrap()
            .push((doc.clone(), result.clone()));
    }
}

/// Adapts a closure into a [`ResultSink`].
pub struct CallbackSink<F: Fn(&Document, &DocResult) + Send + Sync> {
    f: F,
}

impl<F: Fn(&Document, &DocResult) + Send + Sync> CallbackSink<F> {
    /// Wrap `f`; it runs on worker threads, once per document.
    pub fn new(f: F) -> CallbackSink<F> {
        CallbackSink { f }
    }
}

impl<F: Fn(&Document, &DocResult) + Send + Sync> ResultSink for CallbackSink<F> {
    fn on_result(&self, doc: &Document, result: &DocResult) {
        (self.f)(doc, result)
    }
}

type ViewCallback = Box<dyn Fn(&Document, &[crate::aog::Tuple]) + Send + Sync>;
type QueryCallback = Box<dyn Fn(&Document, &QueryHandle, &DocResult) + Send + Sync>;

/// Configures and starts a [`Session`]. Created by
/// [`Engine::session`](super::Engine::session).
pub struct SessionBuilder {
    executor: Arc<Executor>,
    service: Option<Arc<AccelService>>,
    threads: usize,
    queue_depth: Option<usize>,
    sink: Arc<dyn ResultSink>,
    subscriptions: Vec<(ViewHandle, ViewCallback)>,
    query_subscriptions: Vec<(QueryHandle, QueryCallback)>,
    deadline: Option<Duration>,
    quarantine: Option<Arc<Quarantine>>,
    watchdog: Option<Arc<Watchdog>>,
    chaos: Option<Arc<ChaosPlan>>,
}

impl SessionBuilder {
    pub(super) fn new(
        executor: Arc<Executor>,
        service: Option<Arc<AccelService>>,
    ) -> SessionBuilder {
        SessionBuilder {
            executor,
            service,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(8),
            queue_depth: None,
            sink: Arc::new(CountingSink),
            subscriptions: Vec::new(),
            query_subscriptions: Vec::new(),
            deadline: None,
            quarantine: None,
            watchdog: None,
            chaos: None,
        }
    }

    /// Worker-thread count (default: available parallelism).
    pub fn threads(mut self, n: usize) -> SessionBuilder {
        self.threads = n.max(1);
        self
    }

    /// Bounded ingress-queue depth (default: `2 × threads`). With depth
    /// `Q` and `T` threads, at most `Q + T` documents are in flight;
    /// `push` blocks beyond that.
    pub fn queue_depth(mut self, q: usize) -> SessionBuilder {
        self.queue_depth = Some(q.max(1));
        self
    }

    /// Replace the default [`CountingSink`].
    pub fn sink(mut self, sink: Arc<dyn ResultSink>) -> SessionBuilder {
        self.sink = sink;
        self
    }

    /// Default per-document deadline budget, measured from `push`. An
    /// expired document (checked at dequeue and again after execution) is
    /// answered with [`DocError::DeadlineExceeded`] through
    /// [`ResultSink::on_error`] instead of a result — it never hangs the
    /// pipeline. [`Session::push_with_deadline`] overrides per document.
    pub fn deadline(mut self, budget: Duration) -> SessionBuilder {
        self.deadline = Some(budget);
        self
    }

    /// Attach a poison-document quarantine registry: worker panics are
    /// contained (`catch_unwind`), recorded here, and surfaced as
    /// [`DocError::Panicked`]. [`Engine::session`](super::Engine::session)
    /// attaches the engine's own registry automatically.
    pub fn quarantine(mut self, quarantine: Arc<Quarantine>) -> SessionBuilder {
        self.quarantine = Some(quarantine);
        self
    }

    /// Register the session's workers with a liveness watchdog (each
    /// worker publishes idle/busy heartbeats for `GET /healthz`).
    /// [`Engine::session`](super::Engine::session) attaches the engine's
    /// watchdog automatically.
    pub fn watchdog(mut self, watchdog: Arc<Watchdog>) -> SessionBuilder {
        self.watchdog = Some(watchdog);
        self
    }

    /// Install a seeded chaos plan: workers consult it per document and
    /// inject panics/delays (inside the containment boundary) — the test
    /// harness behind `repro chaos`.
    pub fn chaos(mut self, plan: Arc<ChaosPlan>) -> SessionBuilder {
        self.chaos = Some(plan);
        self
    }

    /// Subscribe to one view: `f` runs on a worker thread once per
    /// document with that document's tuples for the view (possibly
    /// empty), before the sink sees the full result.
    ///
    /// Execution is columnar internally; the row-shaped `&[Tuple]` handed
    /// to `f` is materialized lazily from the document's
    /// [`TupleBatch`](crate::exec::TupleBatch)es on first subscription
    /// delivery (sessions without subscriptions never build rows —
    /// counting sinks stay fully columnar).
    ///
    /// Panics immediately (not per-document in a worker) if `view` was
    /// resolved from a different engine.
    pub fn subscribe<F>(mut self, view: &ViewHandle, f: F) -> SessionBuilder
    where
        F: Fn(&Document, &[crate::aog::Tuple]) + Send + Sync + 'static,
    {
        let own = self.executor.catalog().handles().get(view.index());
        assert!(
            own.is_some_and(|o| o.name() == view.name() && o.schema() == view.schema()),
            "view handle '{}' does not belong to this engine",
            view.name()
        );
        self.subscriptions.push((view.clone(), Box::new(f)));
        self
    }

    /// Subscribe to one registered query of a multi-query catalog: `f`
    /// runs on a worker thread once per document with the full
    /// [`DocResult`] and the query's handle — iterate the query's views
    /// with [`QueryHandle::iter`]. Fires before the sink sees the result.
    ///
    /// Panics immediately if `query`'s views were resolved from a
    /// different engine.
    pub fn subscribe_query<F>(mut self, query: &QueryHandle, f: F) -> SessionBuilder
    where
        F: Fn(&Document, &QueryHandle, &DocResult) + Send + Sync + 'static,
    {
        let catalog = self.executor.catalog();
        assert!(
            query.views().iter().all(|v| {
                catalog
                    .handles()
                    .get(v.index())
                    .is_some_and(|o| o.name() == v.name() && o.schema() == v.schema())
            }),
            "query handle '{}' does not belong to this engine",
            query.name()
        );
        self.query_subscriptions.push((query.clone(), Box::new(f)));
        self
    }

    /// Spawn the worker pool and start accepting documents.
    pub fn start(self) -> Session {
        let threads = self.threads;
        let depth = self.queue_depth.unwrap_or(2 * threads).max(1);
        let (tx, rx) = queue::bounded::<Job>(depth);
        let rx = Arc::new(rx);
        let shared = Arc::new(Shared::default());
        let subscriptions = Arc::new(self.subscriptions);
        let query_subscriptions = Arc::new(self.query_subscriptions);
        let mut workers = Vec::with_capacity(threads);
        for w in 0..threads {
            let rx = rx.clone();
            let shared = shared.clone();
            let sink = self.sink.clone();
            let executor = self.executor.clone();
            let subscriptions = subscriptions.clone();
            let query_subscriptions = query_subscriptions.clone();
            let quarantine = self.quarantine.clone();
            let chaos = self.chaos.clone();
            let heartbeat = self
                .watchdog
                .as_ref()
                .map(|wd| wd.register(format!("session-worker-{w}")));
            let handle = std::thread::Builder::new()
                .name(format!("session-worker-{w}"))
                .spawn(move || {
                    // home this worker on the arena shard for its index:
                    // stable across sessions, so a restarted pipeline's
                    // worker `w` inherits the buffers its predecessor
                    // returned (and buffers this worker ships through the
                    // accelerator come home to the same shard)
                    crate::exec::batch::pin_thread(crate::exec::batch::ArenaId::for_worker(w));
                    // corpus-level aggregate state accumulated by THIS
                    // worker only; merged with the other workers' partials
                    // at drain time (merge is commutative + associative,
                    // so the worker count and arrival order don't matter)
                    let mut corpus = CorpusAgg::default();
                    loop {
                        if let Some(hb) = &heartbeat {
                            hb.idle(); // blocking on an empty queue is healthy
                        }
                        let Some(job) = rx.pop() else { break };
                        if let Some(hb) = &heartbeat {
                            hb.beat();
                        }
                        run_job(
                            job,
                            &executor,
                            &shared,
                            &*sink,
                            &subscriptions,
                            &query_subscriptions,
                            quarantine.as_deref(),
                            chaos.as_deref(),
                            &mut corpus,
                        );
                    }
                    if !corpus.is_empty() {
                        shared.partials.lock().unwrap().push(corpus);
                    }
                    if let Some(hb) = &heartbeat {
                        hb.retire();
                    }
                })
                .expect("spawn session worker");
            workers.push(handle);
        }
        Session {
            tx: Some(tx),
            workers,
            shared,
            executor: self.executor,
            sink: self.sink,
            service: self.service,
            threads,
            queue_depth: depth,
            started: Instant::now(),
            pushed: 0,
            default_budget: self.deadline,
        }
    }
}

/// One queued document plus its deadline bookkeeping.
struct Job {
    doc: Document,
    enqueued: Instant,
    budget: Option<Duration>,
}

/// The per-document body of a session worker: deadline check at dequeue,
/// chaos injection, contained (`catch_unwind`) execution, deadline
/// re-check after execution, and exactly one sink delivery.
#[allow(clippy::too_many_arguments)]
fn run_job(
    job: Job,
    executor: &Arc<Executor>,
    shared: &Shared,
    sink: &dyn ResultSink,
    subscriptions: &[(ViewHandle, ViewCallback)],
    query_subscriptions: &[(QueryHandle, QueryCallback)],
    quarantine: Option<&Quarantine>,
    chaos: Option<&ChaosPlan>,
    corpus: &mut CorpusAgg,
) {
    let Job {
        doc,
        enqueued,
        budget,
    } = job;
    let deliver_error = |err: DocError| {
        shared.errors.fetch_add(1, Ordering::Relaxed);
        if err.is_deadline() {
            shared.expired.fetch_add(1, Ordering::Relaxed);
        }
        shared.bytes.fetch_add(doc.len() as u64, Ordering::Relaxed);
        sink.on_error(&doc, &err);
        shared.in_flight.fetch_sub(1, Ordering::Relaxed);
    };
    // deadline check at dequeue: work that expired while queued is
    // answered immediately, without burning a worker on it
    if let Some(b) = budget {
        let waited = enqueued.elapsed();
        if waited > b {
            deliver_error(DocError::DeadlineExceeded { budget: b, waited });
            return;
        }
    }
    let deadline = budget.map(|b| enqueued + b);
    let outcome = {
        // the guard clears the thread-local even if run_doc panics
        let _g = fault::set_doc_deadline(deadline, budget);
        catch_unwind(AssertUnwindSafe(|| {
            if let Some(plan) = chaos {
                match plan.doc_action(doc.id) {
                    ChaosAction::Panic => {
                        panic!("chaos: injected panic on doc {}", doc.id)
                    }
                    ChaosAction::Delay(d) => std::thread::sleep(d),
                    ChaosAction::None => {}
                }
            }
            executor.run_doc_agg(&doc)
        }))
    };
    match outcome {
        Ok((result, delta)) => {
            // post-stage check: the result exists, but an expired budget
            // is still answered as an expiry so clients see one taxonomy
            if let Some(b) = budget {
                let waited = enqueued.elapsed();
                if waited > b {
                    deliver_error(DocError::DeadlineExceeded { budget: b, waited });
                    return;
                }
            }
            // only successful documents contribute to corpus aggregates —
            // an expired or panicked doc is an error, not a data point
            corpus.merge(&delta);
            shared.docs.fetch_add(1, Ordering::Relaxed);
            shared.bytes.fetch_add(doc.len() as u64, Ordering::Relaxed);
            shared
                .tuples
                .fetch_add(result.total_tuples() as u64, Ordering::Relaxed);
            for (view, f) in subscriptions.iter() {
                f(&doc, result.view(view));
            }
            for (query, f) in query_subscriptions.iter() {
                f(&doc, query, &result);
            }
            sink.on_result(&doc, &result);
            shared.in_flight.fetch_sub(1, Ordering::Relaxed);
        }
        Err(payload) => {
            let err = DocError::from_panic(payload);
            if let (DocError::Panicked { message }, Some(q)) = (&err, quarantine) {
                q.record(doc.id, "session worker", message.clone());
            }
            deliver_error(err);
        }
    }
}

/// Counters shared between the session handle and its workers.
#[derive(Debug, Default)]
struct Shared {
    docs: AtomicU64,
    bytes: AtomicU64,
    tuples: AtomicU64,
    /// Documents answered with a structured [`DocError`].
    errors: AtomicU64,
    /// The subset of `errors` that were deadline expiries.
    expired: AtomicU64,
    /// Documents inside the pipeline (queued or being processed).
    in_flight: AtomicI64,
    max_in_flight: AtomicI64,
    /// One [`CorpusAgg`] per worker that absorbed at least one document
    /// with aggregate views; merged (order-invariant) at drain time.
    partials: Mutex<Vec<CorpusAgg>>,
}

/// A running push-based pipeline. Feed it with [`Session::push`] /
/// [`Session::push_batch`]; close it with [`Session::finish`] to join the
/// workers and collect the [`RunReport`].
pub struct Session {
    tx: Option<QueueTx<Job>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    executor: Arc<Executor>,
    sink: Arc<dyn ResultSink>,
    service: Option<Arc<AccelService>>,
    threads: usize,
    queue_depth: usize,
    started: Instant,
    pushed: u64,
    default_budget: Option<Duration>,
}

impl Session {
    /// Push one document, blocking while the pipeline is full
    /// (backpressure). Uses the builder's default deadline, if any.
    /// Fails only if the worker pool died (a worker panicked on a
    /// poisoned document).
    pub fn push(&mut self, doc: Document) -> Result<()> {
        let budget = self.default_budget;
        self.push_job(doc, budget)
    }

    /// Push one document with an explicit deadline budget, overriding the
    /// builder default. The budget is measured from this call; if it
    /// expires before the document finishes (queueing counts), the sink
    /// receives [`DocError::DeadlineExceeded`] instead of a result.
    pub fn push_with_deadline(&mut self, doc: Document, budget: Duration) -> Result<()> {
        self.push_job(doc, Some(budget))
    }

    fn push_job(&mut self, doc: Document, budget: Option<Duration>) -> Result<()> {
        let tx = self
            .tx
            .as_ref()
            .expect("push after finish — the session is closed");
        tx.push(Job {
            doc,
            enqueued: Instant::now(),
            budget,
        })
        .map_err(|_| anyhow!("session worker pool shut down (worker panic?)"))?;
        self.pushed += 1;
        // counted after the queue accepts it: a blocked push is NOT in
        // flight, so the Q + T bound is exact
        let now = self.shared.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.shared.max_in_flight.fetch_max(now, Ordering::Relaxed);
        Ok(())
    }

    /// Push a batch; returns how many documents were accepted.
    pub fn push_batch(&mut self, docs: impl IntoIterator<Item = Document>) -> Result<usize> {
        let mut n = 0;
        for doc in docs {
            self.push(doc)?;
            n += 1;
        }
        Ok(n)
    }

    /// Documents pushed so far.
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Documents fully processed so far.
    pub fn completed(&self) -> u64 {
        self.shared.docs.load(Ordering::Relaxed)
    }

    /// Documents currently inside the pipeline (queued + in workers).
    pub fn in_flight(&self) -> usize {
        self.shared.in_flight.load(Ordering::Relaxed).max(0) as usize
    }

    /// High-water mark of [`Session::in_flight`] — bounded by
    /// `queue_depth + threads` by construction.
    pub fn max_in_flight(&self) -> usize {
        self.shared.max_in_flight.load(Ordering::Relaxed).max(0) as usize
    }

    /// Worker-thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Configured ingress-queue depth.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Gauges of the ingress queue (depth, high-water, producer stalls).
    pub fn queue_snapshot(&self) -> QueueSnapshot {
        self.tx
            .as_ref()
            .map(|tx| tx.snapshot())
            .unwrap_or_default()
    }

    /// Close the intake, drain the pipeline, join the workers, notify the
    /// sink, and return the aggregate report.
    pub fn finish(mut self) -> RunReport {
        let (report, worker_panic) = self.drain_and_report();
        if worker_panic {
            panic!(
                "session finished with panicked worker(s): {} of {} docs completed",
                report.docs, self.pushed
            );
        }
        report
    }

    /// Shared shutdown path for `finish` and `Drop`: close the queue, join
    /// the workers, build the report, and fire `on_finish` exactly once.
    fn drain_and_report(&mut self) -> (RunReport, bool) {
        self.tx = None; // close the queue: workers drain and exit
        let mut worker_panic = false;
        for h in self.workers.drain(..) {
            worker_panic |= h.join().is_err();
        }
        // merge every worker's corpus partial; the merge is commutative
        // and associative, so the fold order (= worker exit order) is
        // irrelevant and the finished tables are byte-identical for any
        // thread count
        let merged = {
            let mut partials = self.shared.partials.lock().unwrap();
            let mut acc = CorpusAgg::default();
            for p in partials.drain(..) {
                acc.merge(&p);
            }
            acc
        };
        let report = RunReport {
            docs: self.shared.docs.load(Ordering::Relaxed) as usize,
            bytes: self.shared.bytes.load(Ordering::Relaxed) as usize,
            tuples: self.shared.tuples.load(Ordering::Relaxed) as usize,
            errors: self.shared.errors.load(Ordering::Relaxed) as usize,
            expired: self.shared.expired.load(Ordering::Relaxed) as usize,
            wall: self.started.elapsed(),
            threads: self.threads,
            accel: self.service.as_ref().map(|s| s.metrics().snapshot()),
            corpus: self.executor.corpus_results(&merged),
        };
        self.sink.on_finish(&report);
        (report, worker_panic)
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // abandoning a session without finish(): still drain the queue,
        // join the workers (no detached thread outlives the engine) and
        // deliver the sink's exactly-once on_finish. A session that went
        // through finish() has no workers left and skips all of this.
        if !self.workers.is_empty() {
            let _ = self.drain_and_report();
        }
    }
}
