//! The built-in evaluation queries T1–T7.
//!
//! The paper evaluates five proprietary customer queries; it reports only
//! their per-operator time profiles (Fig 4): T1–T4 are dominated by
//! extraction operators (up to 82 % regex+dictionary), T5 spends more than
//! 80 % in relational operators. These queries are engineered to land in
//! the same profile bands on the synthetic news corpus — EXPERIMENTS.md E1
//! verifies the achieved distributions.
//!
//! T6 and T7 extend the suite with **corpus-level** analytics in the
//! TextBenDS style (aggregation/top-k keyword benchmarking): T6 ranks the
//! most frequent entity mentions across the whole corpus (`group by` +
//! `score` + `top k`), T7 computes per-dictionary document frequencies
//! (`CountDocs()`). Their per-document output is the corpus-of-one
//! aggregate; the cross-document tables come back through
//! [`crate::coordinator::RunReport::corpus`].
//!
//! Dictionaries are generated from [`crate::corpus::pools`], the same
//! pools the corpus generator plants, so selectivities are realistic.

use crate::corpus::pools;

/// A named built-in query.
#[derive(Debug, Clone)]
pub struct Query {
    /// Short id (`t1`..`t7`).
    pub name: &'static str,
    /// Human-readable title.
    pub title: &'static str,
    /// What the paper's profile says this query should look like.
    pub profile_hint: &'static str,
    /// The program source.
    pub aql: String,
}

fn dict_entries(pool: &[&str]) -> String {
    pool.iter()
        .map(|e| format!("'{e}'"))
        .collect::<Vec<_>>()
        .join(", ")
}

fn t1() -> Query {
    let aql = format!(
        r#"
-- T1: named-entity extraction (persons, organizations, locations, dates)
create dictionary OrgDict as ({orgs});
create dictionary LocDict as ({locs});
create dictionary MonthDict as ({months});

create view Person as
  extract regex /[A-Z][a-z]+ ([A-Z]\. )?[A-Z][a-z]+([\-'][A-Z][a-z]+)?/
  on d.text as name from Document d;
create view Acronym as
  extract regex /[A-Z][A-Z0-9]{{1,4}}/ on d.text as sym from Document d;
create view Org as
  extract dictionary 'OrgDict' on d.text as match from Document d;
create view Loc as
  extract dictionary 'LocDict' on d.text as match from Document d;
create view Month as
  extract dictionary 'MonthDict' on d.text as m from Document d;
create view DateM as
  extract regex /\d{{4}}-\d{{2}}-\d{{2}}|\d{{1,2}}\/\d{{1,2}}\/\d{{2,4}}/
  on d.text as m from Document d;
create view Url as
  extract regex /http:\/\/[a-z0-9\.\/\-]+/ on d.text as u from Document d;

create view PersonOrg as
  select p.name as person, o.match as org, CombineSpans(p.name, o.match) as ctx
  from Person p, Org o
  where FollowsTok(p.name, o.match, 0, 6)
  consolidate on ctx using 'ContainedWithin';

create view AcroClean as
  select a.sym as sym from Acronym a
  where GetText(a.sym) != 'AAAAA';

create view Entities as
  (select p.name as span from Person p)
  union all
  (select o.match as span from Org o)
  union all
  (select l.match as span from Loc l)
  union all
  (select a.sym as span from AcroClean a)
  union all
  (select m.m as span from Month m)
  union all
  (select u.u as span from Url u)
  union all
  (select dd.m as span from DateM dd);

create view EntitiesClean as
  select e.span as span from Entities e
  consolidate on span using 'ContainedWithin';

output view PersonOrg;
output view EntitiesClean;
"#,
        orgs = dict_entries(pools::ORGS),
        locs = dict_entries(pools::LOCATIONS),
        months = dict_entries(pools::MONTHS),
    );
    Query {
        name: "t1",
        title: "Named entities",
        profile_hint: "extraction-dominated (regex + dictionaries)",
        aql,
    }
}

fn t2() -> Query {
    let aql = r#"
-- T2: contact information (phones, emails, URLs) near person mentions
create view Person as
  extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name from Document d;
create view Phone as
  extract regex /(\(\d{3}\) )?\d{3}-\d{4}/ on d.text as num from Document d;
create view Email as
  extract regex /[a-z0-9_]+@[a-z0-9]+\.[a-z]{2,4}/ on d.text as addr from Document d;
create view Url as
  extract regex /http:\/\/[a-z0-9\.\/\-]+/ on d.text as url from Document d;

create view PersonPhone as
  select p.name as person, ph.num as phone, CombineSpans(p.name, ph.num) as ctx
  from Person p, Phone ph
  where FollowsTok(p.name, ph.num, 0, 8)
  consolidate on ctx using 'ContainedWithin';

create view Contacts as
  (select ph.num as span from Phone ph)
  union all
  (select e.addr as span from Email e)
  union all
  (select u.url as span from Url u);

create view PersonPhoneText as
  select GetText(pp.person) as who, GetText(pp.phone) as num
  from PersonPhone pp;

output view PersonPhone;
output view PersonPhoneText;
output view Contacts;
"#
    .to_string();
    Query {
        name: "t2",
        title: "Contact information",
        profile_hint: "extraction-dominated (regex-heavy)",
        aql,
    }
}

fn t3() -> Query {
    let aql = format!(
        r#"
-- T3: brand sentiment (dictionary-heavy)
create dictionary OrgDict as ({orgs});
create dictionary SentimentDict as ({sent});
create dictionary TopicDict as ({nouns});

create view Brand as
  extract dictionary 'OrgDict' on d.text as match from Document d;
create view Sentiment as
  extract dictionary 'SentimentDict' on d.text as word from Document d;
create view Topic as
  extract dictionary 'TopicDict' on d.text as topic from Document d;

create view BrandSentiment as
  select b.match as brand, s.word as sentiment,
         CombineSpans(b.match, s.word) as ctx
  from Brand b, Sentiment s
  where Follows(b.match, s.word, 0, 60)
  consolidate on ctx using 'ContainedWithin';

create view BrandTopic as
  select b.match as brand, t.topic as topic
  from Brand b, Topic t
  where FollowsTok(b.match, t.topic, 0, 10);

output view BrandSentiment;
output view BrandTopic;
"#,
        orgs = dict_entries(pools::ORGS),
        sent = dict_entries(pools::SENTIMENT),
        nouns = dict_entries(pools::NOUNS),
    );
    Query {
        name: "t3",
        title: "Brand sentiment",
        profile_hint: "extraction-dominated (dictionary-heavy)",
        aql,
    }
}

fn t4() -> Query {
    let aql = format!(
        r#"
-- T4: financial events (amounts, tickers, dates near organizations)
create dictionary OrgDict as ({orgs});

create view Money as
  extract regex /\$\d+(\.\d+)? million/ on d.text as amount from Document d;
create view Ticker as
  extract regex /\([A-Z]{{2,5}}\)/ on d.text as sym from Document d;
create view DateM as
  extract regex /\d{{4}}-\d{{2}}-\d{{2}}/ on d.text as m from Document d;
create view Org as
  extract dictionary 'OrgDict' on d.text as match from Document d;

create view Deal as
  select o.match as org, m.amount as amount, CombineSpans(o.match, m.amount) as ctx
  from Org o, Money m
  where FollowsTok(o.match, m.amount, 0, 6)
  consolidate on ctx using 'ContainedWithin';

create view DatedDeal as
  select dl.org as org, dl.amount as amount, dt.m as on_date
  from Deal dl, DateM dt
  where Follows(dl.amount, dt.m, 0, 40);

output view Deal;
output view DatedDeal;
"#,
        orgs = dict_entries(pools::ORGS),
    );
    Query {
        name: "t4",
        title: "Financial events",
        profile_hint: "extraction-dominated (mixed regex + dictionary)",
        aql,
    }
}

fn t5() -> Query {
    let aql = format!(
        r#"
-- T5: co-occurrence analytics — deliberately join-heavy: one cheap,
-- high-yield extractor feeding multi-way span joins (the paper's T5
-- spends >80% of its time in relational operators)
create dictionary OrgDict as ({orgs});

create view Cap as
  extract regex /[A-Z][a-z]+/ on d.text as w from Document d;
create view Org as
  extract dictionary 'OrgDict' on d.text as match from Document d;

create view CapPair as
  select a.w as w1, b.w as w2, CombineSpans(a.w, b.w) as pair
  from Cap a, Cap b
  where Follows(a.w, b.w, 0, 10);

create view PairNearOrg as
  select p.pair as pair, o.match as org, CombineSpans(p.pair, o.match) as ctx
  from CapPair p, Org o
  where Follows(p.pair, o.match, 0, 50)
  consolidate on ctx using 'ContainedWithin';

-- overlap analysis: deliberately NOT a band-joinable predicate, so it
-- exercises the general nested-loop join exactly like the paper's
-- relational-heavy analytics
create view Conflicts as
  select x.ctx as a, y.ctx as b
  from PairNearOrg x, PairNearOrg y
  where Overlaps(x.ctx, y.ctx) and GetText(x.org) = GetText(y.org)
        and GetBegin(x.ctx) < GetBegin(y.ctx);

create view Cooc as
  select x.ctx as ctx, GetLength(x.ctx) as len
  from PairNearOrg x
  where GetLength(x.ctx) > 12;

output view Cooc;
output view Conflicts;
"#,
        orgs = dict_entries(pools::ORGS),
    );
    Query {
        name: "t5",
        title: "Co-occurrence analytics",
        profile_hint: "relational-dominated (>80% joins/selects)",
        aql,
    }
}

fn t6() -> Query {
    let aql = format!(
        r#"
-- T6: top-k entity mentions across the corpus (TextBenDS-style top-k
-- keyword query): every capitalized word plus every organization hit,
-- grouped by surface form, ranked by total mention count
create dictionary OrgDict as ({orgs});

create view Cap as
  extract regex /[A-Z][a-z]+/ on d.text as w from Document d;
create view Org as
  extract dictionary 'OrgDict' on d.text as match from Document d;

create view Mention as
  (select c.w as span from Cap c)
  union all
  (select o.match as span from Org o);

create view TopEntities as
  select GetText(m.span) as term, Count() as n, CountDocs() as docs
  from Mention m
  group by term
  score n
  top 10;

output view TopEntities;
"#,
        orgs = dict_entries(pools::ORGS),
    );
    Query {
        name: "t6",
        title: "Top-k entities",
        profile_hint: "corpus-level aggregation (group by + top k)",
        aql,
    }
}

fn t7() -> Query {
    let aql = format!(
        r#"
-- T7: per-dictionary document frequency — how many mentions and how many
-- distinct documents each deployed dictionary fires in (TextBenDS-style
-- aggregation query over the whole corpus)
create dictionary OrgDict as ({orgs});
create dictionary LocDict as ({locs});
create dictionary SentimentDict as ({sent});

create view OrgHit as
  extract dictionary 'OrgDict' on d.text as match from Document d;
create view LocHit as
  extract dictionary 'LocDict' on d.text as match from Document d;
create view SentHit as
  extract dictionary 'SentimentDict' on d.text as match from Document d;

create view Tagged as
  (select 'org' as dict from OrgHit h)
  union all
  (select 'loc' as dict from LocHit h)
  union all
  (select 'sentiment' as dict from SentHit h);

create view DictDocFreq as
  select t.dict as dict, Count() as n, CountDocs() as docs
  from Tagged t
  group by dict;

output view DictDocFreq;
"#,
        orgs = dict_entries(pools::ORGS),
        locs = dict_entries(pools::LOCATIONS),
        sent = dict_entries(pools::SENTIMENT),
    );
    Query {
        name: "t7",
        title: "Dictionary document frequency",
        profile_hint: "corpus-level aggregation (group by + CountDocs)",
        aql,
    }
}

/// All built-in queries in paper order (T6/T7 are this repo's
/// corpus-level extensions).
pub fn all() -> Vec<Query> {
    vec![t1(), t2(), t3(), t4(), t5(), t6(), t7()]
}

/// Look up a built-in query by name (`t1`..`t7`).
pub fn builtin(name: &str) -> Option<Query> {
    all().into_iter().find(|q| q.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::{partition, PartitionMode};

    #[test]
    fn all_queries_compile_and_optimize() {
        for q in all() {
            let g = crate::aql::compile(&q.aql)
                .unwrap_or_else(|e| panic!("{} failed to compile: {e}", q.name));
            let opt = crate::optimizer::optimize(&g);
            assert!(!opt.outputs.is_empty(), "{}", q.name);
        }
    }

    #[test]
    fn all_queries_partition_and_hw_compile() {
        for q in all() {
            let g = crate::optimizer::optimize(&crate::aql::compile(&q.aql).unwrap());
            for mode in [
                PartitionMode::ExtractOnly,
                PartitionMode::SingleSubgraph,
                PartitionMode::MultiSubgraph,
            ] {
                let plan = partition(&g, mode);
                assert!(
                    !plan.subgraphs.is_empty(),
                    "{} produced no subgraphs under {mode:?}",
                    q.name
                );
                for s in &plan.subgraphs {
                    crate::hwcompiler::compile_subgraph(s).unwrap_or_else(|e| {
                        panic!("{} subgraph hw-compile failed ({mode:?}): {e}", q.name)
                    });
                }
            }
        }
    }

    #[test]
    fn queries_produce_annotations_on_news() {
        use crate::exec::{Executor, Profiler};
        use std::sync::Arc;
        let corpus = crate::corpus::CorpusSpec::news(6, 2048).generate();
        for q in all() {
            let g = crate::optimizer::optimize(&crate::aql::compile(&q.aql).unwrap());
            let prof = Arc::new(Profiler::for_graph(&g));
            let ex = Executor::new(Arc::new(g), prof);
            let total: usize = corpus
                .docs
                .iter()
                .map(|d| ex.run_doc(d).total_tuples())
                .sum();
            assert!(total > 0, "{} produced no annotations", q.name);
        }
    }

    #[test]
    fn t5_is_relational_dominated() {
        use crate::exec::{Executor, Profiler};
        use std::sync::Arc;
        let corpus = crate::corpus::CorpusSpec::news(8, 2048).generate();
        let q = builtin("t5").unwrap();
        let g = crate::optimizer::optimize(&crate::aql::compile(&q.aql).unwrap());
        let prof = Arc::new(Profiler::for_graph(&g));
        let ex = Executor::new(Arc::new(g), prof.clone());
        for d in &corpus.docs {
            ex.run_doc(d);
        }
        let p = prof.snapshot(ex.graph());
        let extraction = p.fraction_extraction();
        assert!(
            extraction < 0.5,
            "t5 extraction fraction {extraction} — should be relational-dominated"
        );
    }

    #[test]
    fn t1_is_extraction_dominated() {
        use crate::exec::{Executor, Profiler};
        use std::sync::Arc;
        let corpus = crate::corpus::CorpusSpec::news(8, 2048).generate();
        let q = builtin("t1").unwrap();
        let g = crate::optimizer::optimize(&crate::aql::compile(&q.aql).unwrap());
        let prof = Arc::new(Profiler::for_graph(&g));
        let ex = Executor::new(Arc::new(g), prof.clone());
        for d in &corpus.docs {
            ex.run_doc(d);
        }
        let p = prof.snapshot(ex.graph());
        assert!(
            p.fraction_extraction() > 0.5,
            "t1 extraction fraction {} — should be extraction-dominated",
            p.fraction_extraction()
        );
    }

    #[test]
    fn builtin_lookup() {
        assert!(builtin("t3").is_some());
        assert!(builtin("t6").is_some());
        assert!(builtin("t7").is_some());
        assert!(builtin("t9").is_none());
        assert_eq!(all().len(), 7);
    }

    #[test]
    fn t6_t7_produce_corpus_tables() {
        use crate::aog::Value;
        use crate::coordinator::Engine;
        let engine = Engine::builder()
            .register_builtin("t6")
            .register_builtin("t7")
            .build()
            .unwrap();
        let corpus = crate::corpus::CorpusSpec::news(12, 1024).generate();
        let report = engine.run_corpus(&corpus, 4);
        assert_eq!(report.corpus.len(), 2);
        let t6 = report
            .corpus
            .iter()
            .find(|c| c.view == "t6.TopEntities")
            .expect("t6 table");
        assert!(!t6.rows.is_empty() && t6.rows.len() <= 10, "{t6:?}");
        // ranked by score (= n) descending
        let scores: Vec<i64> = t6
            .rows
            .iter()
            .map(|r| match &r[3] {
                Value::Int(n) => *n,
                other => panic!("score must be Int, got {other:?}"),
            })
            .collect();
        assert!(
            scores.windows(2).all(|w| w[0] >= w[1]),
            "top-k not sorted by score: {scores:?}"
        );
        let t7 = report
            .corpus
            .iter()
            .find(|c| c.view == "t7.DictDocFreq")
            .expect("t7 table");
        // news docs plant all three pools; docs ≤ corpus size
        assert_eq!(t7.rows.len(), 3, "{t7:?}");
        for row in &t7.rows {
            match (&row[1], &row[2]) {
                (Value::Int(n), Value::Int(docs)) => {
                    assert!(*n >= *docs && *docs >= 1 && *docs <= 12, "{row:?}");
                }
                other => panic!("bad count types: {other:?}"),
            }
        }
    }
}
