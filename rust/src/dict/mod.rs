//! Token-based dictionary matching — SystemT's second extraction primitive
//! (paper ref [21]: "Token-based dictionary pattern matching for text
//! analytics").
//!
//! A dictionary is a set of multi-token phrases. Matching finds every
//! occurrence of every entry that lies on token boundaries. The engine is a
//! from-scratch Aho–Corasick automaton, DFA-ized into the same dense
//! `states × 256` transition-table layout as the regex DFAs — so the
//! accelerator runs dictionaries and regexes with the *same* kernel, just
//! different tables (exactly the paper's configurable-operator-module
//! approach).

pub mod ac;

pub use ac::AhoCorasick;

use crate::text::tokenizer::is_word_byte;
use crate::text::Span;

/// Case handling for a dictionary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CaseMode {
    /// Entries match exactly.
    Exact,
    /// ASCII case-insensitive.
    Insensitive,
}

/// A named dictionary: entries plus matching configuration.
#[derive(Debug, Clone)]
pub struct Dictionary {
    /// Dictionary name (as referenced from AQL).
    pub name: String,
    /// The entries, normalized.
    pub entries: Vec<String>,
    /// Case-folding policy.
    pub case: CaseMode,
}

/// One dictionary match: the covered span and the entry index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DictMatch {
    /// Matched byte range.
    pub span: Span,
    /// Index of the matched entry.
    pub entry: u32,
}

impl Dictionary {
    /// Create a dictionary; entries are trimmed, empties dropped,
    /// duplicates (post case-fold) removed.
    pub fn new(name: impl Into<String>, entries: Vec<String>, case: CaseMode) -> Self {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for e in entries {
            let t = e.trim().to_string();
            if t.is_empty() {
                continue;
            }
            let key = match case {
                CaseMode::Exact => t.clone(),
                CaseMode::Insensitive => t.to_ascii_lowercase(),
            };
            if seen.insert(key) {
                out.push(t);
            }
        }
        Dictionary {
            name: name.into(),
            entries: out,
            case,
        }
    }

    /// Compile to an Aho–Corasick matcher.
    pub fn compile(&self) -> AhoCorasick {
        AhoCorasick::build(&self.entries, self.case)
    }
}

/// Check that `[begin, end)` lies on word boundaries of `text`: the bytes
/// just outside the span must not be word bytes (or the span must touch the
/// document edge). This is the "whole token" condition of token-based
/// matching, applied identically by the software operator and the
/// accelerator post-stage.
#[inline]
pub fn on_word_boundaries(text: &[u8], begin: usize, end: usize) -> bool {
    let left_ok = begin == 0 || !is_word_byte(text[begin - 1]) || !is_word_byte(text[begin]);
    let right_ok = end == text.len() || !is_word_byte(text[end]) || !is_word_byte(text[end - 1]);
    left_ok && right_ok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_trim() {
        let d = Dictionary::new(
            "d",
            vec![
                " IBM ".into(),
                "ibm".into(),
                "".into(),
                "Research".into(),
                "IBM".into(),
            ],
            CaseMode::Insensitive,
        );
        assert_eq!(d.entries, vec!["IBM".to_string(), "Research".to_string()]);
    }

    #[test]
    fn exact_keeps_both_cases() {
        let d = Dictionary::new(
            "d",
            vec!["IBM".into(), "ibm".into()],
            CaseMode::Exact,
        );
        assert_eq!(d.entries.len(), 2);
    }

    #[test]
    fn word_boundary_checks() {
        let t = b"the IBM lab";
        assert!(on_word_boundaries(t, 4, 7)); // "IBM"
        assert!(!on_word_boundaries(t, 4, 6)); // "IB|M"
        assert!(!on_word_boundaries(t, 5, 7)); // "I|BM"
        assert!(on_word_boundaries(t, 0, 3)); // doc start
        assert!(on_word_boundaries(t, 8, 11)); // doc end
    }

    #[test]
    fn punctuation_spans_are_boundary_free() {
        let t = b"a-b";
        // "-" at [1,2): neighbours are word bytes but the span itself is
        // punctuation, so it still counts as whole-token.
        assert!(on_word_boundaries(t, 1, 2));
    }
}
