//! From-scratch Aho–Corasick, DFA-ized to the shared dense-table layout.
//!
//! Construction is the textbook goto/fail/output build followed by fail-link
//! resolution into a dense next-state table. State 0 is unused (dead, to
//! match the regex DFA convention), state 1 is the root; byte 0 (NUL, the
//! work-package separator) resets to the root from every state.

use super::{CaseMode, DictMatch};
use crate::text::Span;

/// Aho–Corasick automaton over bytes.
#[derive(Debug, Clone)]
pub struct AhoCorasick {
    /// Dense `num_states × 256` next-state table (state 0 dead, 1 root).
    pub table: Vec<u32>,
    /// Number of states including the dead state.
    pub num_states: u32,
    /// Per-state matched entries: `(entry_id, byte_len)` pairs. Indexed by
    /// state. The accelerator returns accepting *state ids*, which the
    /// post-stage maps through this to recover spans — the kernel itself
    /// never needs entry identities.
    pub outputs: Vec<Vec<(u32, u32)>>,
    /// Case mode (input bytes are folded during scan when insensitive).
    pub case: CaseMode,
}

const ROOT: u32 = 1;

impl AhoCorasick {
    /// Build from entries. Entries are folded to lowercase when
    /// `case == Insensitive`; the scanner folds input bytes to match.
    pub fn build(entries: &[String], case: CaseMode) -> AhoCorasick {
        // Trie construction. next[state][byte] = state, 0 = absent.
        let mut next: Vec<[u32; 256]> = vec![[0u32; 256], [0u32; 256]]; // dead + root
        let mut outputs: Vec<Vec<(u32, u32)>> = vec![Vec::new(), Vec::new()];

        for (id, entry) in entries.iter().enumerate() {
            let folded: Vec<u8> = entry
                .bytes()
                .map(|b| match case {
                    CaseMode::Exact => b,
                    CaseMode::Insensitive => b.to_ascii_lowercase(),
                })
                .collect();
            let mut cur = ROOT;
            for &b in &folded {
                let slot = next[cur as usize][b as usize];
                cur = if slot == 0 {
                    let id = next.len() as u32;
                    next.push([0u32; 256]);
                    outputs.push(Vec::new());
                    next[cur as usize][b as usize] = id;
                    id
                } else {
                    slot
                };
            }
            outputs[cur as usize].push((id as u32, folded.len() as u32));
        }

        // BFS fail links; resolve into dense table.
        let n = next.len();
        let mut fail = vec![ROOT; n];
        let mut queue = std::collections::VecDeque::new();
        for b in 0..256usize {
            let t = next[ROOT as usize][b];
            if t != 0 {
                fail[t as usize] = ROOT;
                queue.push_back(t);
            }
        }
        while let Some(s) = queue.pop_front() {
            for b in 0..256usize {
                let t = next[s as usize][b];
                if t == 0 {
                    continue;
                }
                // fail(t) = goto(fail(s), b) chased through fail links
                let mut f = fail[s as usize];
                loop {
                    let g = next[f as usize][b];
                    if g != 0 && g != t {
                        fail[t as usize] = g;
                        break;
                    }
                    if f == ROOT {
                        if g == 0 || g == t {
                            fail[t as usize] = ROOT;
                        }
                        break;
                    }
                    f = fail[f as usize];
                }
                // inherit outputs along the fail chain
                let inherited = outputs[fail[t as usize] as usize].clone();
                outputs[t as usize].extend(inherited);
                queue.push_back(t);
            }
        }

        // Dense DFA: delta(s, b) = goto(s,b) if present else delta(fail(s), b).
        // Process in BFS order so parents are resolved first.
        let mut table = vec![0u32; n * 256];
        // root row
        for b in 0..256usize {
            let t = next[ROOT as usize][b];
            table[ROOT as usize * 256 + b] = if t != 0 { t } else { ROOT };
        }
        // re-BFS for the rest
        let mut queue = std::collections::VecDeque::new();
        let mut visited = vec![false; n];
        visited[ROOT as usize] = true;
        for b in 0..256usize {
            let t = next[ROOT as usize][b];
            if t != 0 && !visited[t as usize] {
                visited[t as usize] = true;
                queue.push_back(t);
            }
        }
        while let Some(s) = queue.pop_front() {
            for b in 0..256usize {
                let t = next[s as usize][b];
                let resolved = if t != 0 {
                    t
                } else {
                    table[fail[s as usize] as usize * 256 + b]
                };
                table[s as usize * 256 + b] = resolved;
                if t != 0 && !visited[t as usize] {
                    visited[t as usize] = true;
                    queue.push_back(t);
                }
            }
        }
        // NUL resets everywhere (package separator), dead row stays dead→root.
        for s in 0..n {
            table[s * 256] = ROOT;
        }

        AhoCorasick {
            table,
            num_states: n as u32,
            outputs,
            case,
        }
    }

    /// Step the DFA.
    #[inline]
    pub fn step(&self, state: u32, byte: u8) -> u32 {
        let b = match self.case {
            CaseMode::Exact => byte,
            CaseMode::Insensitive => byte.to_ascii_lowercase(),
        };
        self.table[state as usize * 256 + b as usize]
    }

    /// True if the state has at least one output.
    #[inline]
    pub fn is_accept(&self, state: u32) -> bool {
        !self.outputs[state as usize].is_empty()
    }

    /// Scan `text`, returning every entry occurrence (before token-boundary
    /// filtering). Multiple entries ending at one position all fire.
    pub fn find_all(&self, text: &[u8]) -> Vec<DictMatch> {
        let mut out = Vec::new();
        let mut state = ROOT;
        for (i, &b) in text.iter().enumerate() {
            state = self.step(state, b);
            for &(entry, len) in &self.outputs[state as usize] {
                let end = i + 1;
                let begin = end - len as usize;
                out.push(DictMatch {
                    span: Span::new(begin as u32, end as u32),
                    entry,
                });
            }
        }
        out
    }

    /// Matches whose spans lie on word boundaries — the token-based
    /// semantics exposed to queries.
    pub fn find_token_matches(&self, text: &[u8]) -> Vec<DictMatch> {
        self.find_all(text)
            .into_iter()
            .filter(|m| {
                super::on_word_boundaries(text, m.span.begin as usize, m.span.end as usize)
            })
            .collect()
    }

    /// Reconstruct matches from accelerator-reported `(position, state)`
    /// pairs (position = exclusive end offset of the byte that produced
    /// `state`). Must agree with [`AhoCorasick::find_token_matches`].
    pub fn from_hw_states(&self, text: &[u8], hits: &[(usize, u32)]) -> Vec<DictMatch> {
        let mut out = Vec::new();
        for &(end, state) in hits {
            for &(entry, len) in &self.outputs[state as usize] {
                if (len as usize) <= end {
                    let begin = end - len as usize;
                    if super::on_word_boundaries(text, begin, end) {
                        out.push(DictMatch {
                            span: Span::new(begin as u32, end as u32),
                            entry,
                        });
                    }
                }
            }
        }
        out
    }

    /// Table footprint in bytes (accelerator budget accounting).
    pub fn table_bytes(&self) -> usize {
        self.table.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dict(entries: &[&str], case: CaseMode) -> AhoCorasick {
        AhoCorasick::build(
            &entries.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            case,
        )
    }

    fn matches(ac: &AhoCorasick, text: &str) -> Vec<(u32, u32, u32)> {
        ac.find_token_matches(text.as_bytes())
            .iter()
            .map(|m| (m.span.begin, m.span.end, m.entry))
            .collect()
    }

    #[test]
    fn single_entry() {
        let ac = dict(&["ibm"], CaseMode::Exact);
        assert_eq!(matches(&ac, "the ibm lab"), vec![(4, 7, 0)]);
    }

    #[test]
    fn multiple_entries_and_occurrences() {
        let ac = dict(&["he", "she", "his", "hers"], CaseMode::Exact);
        // raw AC on "ushers": she, he, hers — token filtering kills all
        // (inside the word "ushers")
        assert_eq!(ac.find_all(b"ushers").len(), 3);
        assert!(matches(&ac, "ushers").is_empty());
        assert_eq!(matches(&ac, "he and she"), vec![(0, 2, 0), (7, 10, 1)]);
    }

    #[test]
    fn overlapping_outputs_at_same_end() {
        let ac = dict(&["search", "research"], CaseMode::Exact);
        let got = matches(&ac, "research");
        // "research" lies on boundaries; "search" inside it does not.
        assert_eq!(got, vec![(0, 8, 1)]);
    }

    #[test]
    fn case_insensitive() {
        let ac = dict(&["IBM Research"], CaseMode::Insensitive);
        assert_eq!(matches(&ac, "ibm research rocks"), vec![(0, 12, 0)]);
        assert_eq!(matches(&ac, "IBM RESEARCH"), vec![(0, 12, 0)]);
    }

    #[test]
    fn multi_token_phrase() {
        let ac = dict(&["New York", "York"], CaseMode::Exact);
        let got = matches(&ac, "in New York City");
        assert_eq!(got, vec![(3, 11, 0), (7, 11, 1)]);
    }

    #[test]
    fn empty_dictionary() {
        let ac = dict(&[], CaseMode::Exact);
        assert!(matches(&ac, "anything").is_empty());
    }

    #[test]
    fn nul_separator_resets() {
        let ac = dict(&["ab"], CaseMode::Exact);
        assert!(ac.find_all(b"a\0b").is_empty());
        assert_eq!(ac.find_all(b"ab\0ab").len(), 2);
    }

    #[test]
    fn hw_state_reconstruction_agrees() {
        let ac = dict(&["he", "she", "hers", "his"], CaseMode::Exact);
        for text in ["he and she said hers", "ushers", "h e r s", ""] {
            // simulate the accelerator: record (end, state) at accepting steps
            let mut hits = Vec::new();
            let mut state = ROOT;
            for (i, &b) in text.as_bytes().iter().enumerate() {
                state = ac.step(state, b);
                if ac.is_accept(state) {
                    hits.push((i + 1, state));
                }
            }
            let mut hw = ac.from_hw_states(text.as_bytes(), &hits);
            let mut sw = ac.find_token_matches(text.as_bytes());
            hw.sort_by_key(|m| (m.span.begin, m.span.end, m.entry));
            sw.sort_by_key(|m| (m.span.begin, m.span.end, m.entry));
            assert_eq!(hw, sw, "text {text:?}");
        }
    }

    /// Differential test against the third-party aho-corasick crate
    /// (oracle). Gated like the regex oracle tests — the offline build has
    /// no external dev-dependencies (see Cargo.toml `oracle-tests`).
    #[cfg(feature = "oracle-tests")]
    #[test]
    fn oracle_differential() {
        use crate::util::Prng;
        let entries = ["ab", "abc", "bca", "c", "cab"];
        let mine = dict(&entries, CaseMode::Exact);
        let oracle = aho_corasick::AhoCorasick::new(entries).unwrap();
        let mut rng = Prng::new(5);
        for _ in 0..300 {
            let len = rng.below(50).max(1);
            let t = rng.string_over(b"abc ", len);
            let mut got: Vec<(usize, usize, usize)> = mine
                .find_all(t.as_bytes())
                .iter()
                .map(|m| (m.span.begin as usize, m.span.end as usize, m.entry as usize))
                .collect();
            // oracle: overlapping all-matches
            let mut want: Vec<(usize, usize, usize)> = oracle
                .find_overlapping_iter(&t)
                .map(|m| (m.start(), m.end(), m.pattern().as_usize()))
                .collect();
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "text {t:?}");
        }
    }

    #[test]
    fn table_layout_conventions() {
        let ac = dict(&["xy"], CaseMode::Exact);
        // state 0 dead row exists, state 1 root; NUL resets everywhere
        assert!(ac.num_states >= 3);
        for s in 0..ac.num_states {
            assert_eq!(ac.table[s as usize * 256], ROOT);
        }
        // root loops to itself on unrelated bytes
        assert_eq!(ac.table[ROOT as usize * 256 + b'q' as usize], ROOT);
    }
}
