//! From-scratch Aho–Corasick, DFA-ized to the shared dense-table layout.
//!
//! Construction is the textbook goto/fail/output build followed by fail-link
//! resolution into a dense next-state table. State 0 is unused (dead, to
//! match the regex DFA convention), state 1 is the root; byte 0 (NUL, the
//! work-package separator) resets to the root from every state.

use super::{CaseMode, DictMatch};
use crate::text::Span;

/// Aho–Corasick automaton over bytes.
#[derive(Debug, Clone)]
pub struct AhoCorasick {
    /// Dense `num_states × 256` next-state table (state 0 dead, 1 root).
    pub table: Vec<u32>,
    /// Number of states including the dead state.
    pub num_states: u32,
    /// Per-state matched entries: `(entry_id, byte_len)` pairs. Indexed by
    /// state. The accelerator returns accepting *state ids*, which the
    /// post-stage maps through this to recover spans — the kernel itself
    /// never needs entry identities.
    pub outputs: Vec<Vec<(u32, u32)>>,
    /// Case mode (input bytes are folded during scan when insensitive).
    pub case: CaseMode,
}

const ROOT: u32 = 1;

impl AhoCorasick {
    /// Build from entries. Entries are folded to lowercase when
    /// `case == Insensitive`; the scanner folds input bytes to match.
    pub fn build(entries: &[String], case: CaseMode) -> AhoCorasick {
        // Trie construction. next[state][byte] = state, 0 = absent.
        let mut next: Vec<[u32; 256]> = vec![[0u32; 256], [0u32; 256]]; // dead + root
        let mut outputs: Vec<Vec<(u32, u32)>> = vec![Vec::new(), Vec::new()];

        for (id, entry) in entries.iter().enumerate() {
            let folded: Vec<u8> = entry
                .bytes()
                .map(|b| match case {
                    CaseMode::Exact => b,
                    CaseMode::Insensitive => b.to_ascii_lowercase(),
                })
                .collect();
            let mut cur = ROOT;
            for &b in &folded {
                let slot = next[cur as usize][b as usize];
                cur = if slot == 0 {
                    let id = next.len() as u32;
                    next.push([0u32; 256]);
                    outputs.push(Vec::new());
                    next[cur as usize][b as usize] = id;
                    id
                } else {
                    slot
                };
            }
            outputs[cur as usize].push((id as u32, folded.len() as u32));
        }

        // Single-pass BFS: fail links AND the dense table in one sweep,
        // O(states × 256) total. The invariant is the classic one — when
        // state `s` is popped, `fail(s)` lies at a strictly smaller depth,
        // so its table row is already final and `fail(t) = delta(fail(s),
        // b)` is a single table read instead of a fail-chain walk (the
        // chain chase made construction superlinear, which started to hurt
        // once the catalog began interning merged-query dictionaries).
        let n = next.len();
        let mut fail = vec![ROOT; n];
        let mut table = vec![0u32; n * 256];
        let mut queue = std::collections::VecDeque::new();
        for b in 0..256usize {
            let t = next[ROOT as usize][b];
            table[ROOT as usize * 256 + b] = if t != 0 { t } else { ROOT };
            if t != 0 {
                fail[t as usize] = ROOT;
                queue.push_back(t);
            }
        }
        while let Some(s) = queue.pop_front() {
            let fail_s = fail[s as usize] as usize;
            for b in 0..256usize {
                let t = next[s as usize][b];
                if t != 0 {
                    table[s as usize * 256 + b] = t;
                    fail[t as usize] = table[fail_s * 256 + b];
                    // inherit outputs along the (already final) fail chain
                    let inherited = outputs[fail[t as usize] as usize].clone();
                    outputs[t as usize].extend(inherited);
                    queue.push_back(t);
                } else {
                    table[s as usize * 256 + b] = table[fail_s * 256 + b];
                }
            }
        }
        // NUL resets everywhere (package separator), dead row stays dead→root.
        for s in 0..n {
            table[s * 256] = ROOT;
        }

        AhoCorasick {
            table,
            num_states: n as u32,
            outputs,
            case,
        }
    }

    /// Step the DFA.
    #[inline]
    pub fn step(&self, state: u32, byte: u8) -> u32 {
        let b = match self.case {
            CaseMode::Exact => byte,
            CaseMode::Insensitive => byte.to_ascii_lowercase(),
        };
        self.table[state as usize * 256 + b as usize]
    }

    /// True if the state has at least one output.
    #[inline]
    pub fn is_accept(&self, state: u32) -> bool {
        !self.outputs[state as usize].is_empty()
    }

    /// The scan core: every entry occurrence, in scan order, handed to
    /// `emit` (before token-boundary filtering). Multiple entries ending
    /// at one position all fire.
    fn scan_all(&self, text: &[u8], mut emit: impl FnMut(DictMatch)) {
        let mut state = ROOT;
        for (i, &b) in text.iter().enumerate() {
            state = self.step(state, b);
            for &(entry, len) in &self.outputs[state as usize] {
                let end = i + 1;
                let begin = end - len as usize;
                emit(DictMatch {
                    span: Span::new(begin as u32, end as u32),
                    entry,
                });
            }
        }
    }

    /// Scan `text`, returning every entry occurrence (before token-boundary
    /// filtering).
    pub fn find_all(&self, text: &[u8]) -> Vec<DictMatch> {
        let mut out = Vec::new();
        self.scan_all(text, |m| out.push(m));
        out
    }

    /// Matches whose spans lie on word boundaries — the token-based
    /// semantics exposed to queries.
    pub fn find_token_matches(&self, text: &[u8]) -> Vec<DictMatch> {
        let mut out = Vec::new();
        self.scan_all(text, |m| {
            if super::on_word_boundaries(text, m.span.begin as usize, m.span.end as usize) {
                out.push(m);
            }
        });
        out
    }

    /// [`AhoCorasick::find_token_matches`] appending spans to `out` — the
    /// columnar extraction path writes matches straight into an
    /// arena-backed span column, with no intermediate `DictMatch` vector.
    pub fn find_token_spans_into(&self, text: &[u8], out: &mut Vec<Span>) {
        self.scan_all(text, |m| {
            if super::on_word_boundaries(text, m.span.begin as usize, m.span.end as usize) {
                out.push(m.span);
            }
        });
    }

    /// The reconstruction core shared by both emit shapes.
    fn hw_states_each(
        &self,
        text: &[u8],
        hits: &[(usize, u32)],
        mut emit: impl FnMut(DictMatch),
    ) {
        for &(end, state) in hits {
            for &(entry, len) in &self.outputs[state as usize] {
                if (len as usize) <= end {
                    let begin = end - len as usize;
                    if super::on_word_boundaries(text, begin, end) {
                        emit(DictMatch {
                            span: Span::new(begin as u32, end as u32),
                            entry,
                        });
                    }
                }
            }
        }
    }

    /// Reconstruct matches from accelerator-reported `(position, state)`
    /// pairs (position = exclusive end offset of the byte that produced
    /// `state`). Must agree with [`AhoCorasick::find_token_matches`].
    pub fn from_hw_states(&self, text: &[u8], hits: &[(usize, u32)]) -> Vec<DictMatch> {
        let mut out = Vec::new();
        self.hw_states_each(text, hits, |m| out.push(m));
        out
    }

    /// [`AhoCorasick::from_hw_states`] appending spans to `out` — the
    /// accelerator post-stage reconstructs straight into an arena-backed
    /// span column.
    pub fn from_hw_states_spans_into(
        &self,
        text: &[u8],
        hits: &[(usize, u32)],
        out: &mut Vec<Span>,
    ) {
        self.hw_states_each(text, hits, |m| out.push(m.span));
    }

    /// Table footprint in bytes (accelerator budget accounting).
    pub fn table_bytes(&self) -> usize {
        self.table.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dict(entries: &[&str], case: CaseMode) -> AhoCorasick {
        AhoCorasick::build(
            &entries.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            case,
        )
    }

    fn matches(ac: &AhoCorasick, text: &str) -> Vec<(u32, u32, u32)> {
        ac.find_token_matches(text.as_bytes())
            .iter()
            .map(|m| (m.span.begin, m.span.end, m.entry))
            .collect()
    }

    #[test]
    fn single_entry() {
        let ac = dict(&["ibm"], CaseMode::Exact);
        assert_eq!(matches(&ac, "the ibm lab"), vec![(4, 7, 0)]);
    }

    #[test]
    fn multiple_entries_and_occurrences() {
        let ac = dict(&["he", "she", "his", "hers"], CaseMode::Exact);
        // raw AC on "ushers": she, he, hers — token filtering kills all
        // (inside the word "ushers")
        assert_eq!(ac.find_all(b"ushers").len(), 3);
        assert!(matches(&ac, "ushers").is_empty());
        assert_eq!(matches(&ac, "he and she"), vec![(0, 2, 0), (7, 10, 1)]);
    }

    #[test]
    fn overlapping_outputs_at_same_end() {
        let ac = dict(&["search", "research"], CaseMode::Exact);
        let got = matches(&ac, "research");
        // "research" lies on boundaries; "search" inside it does not.
        assert_eq!(got, vec![(0, 8, 1)]);
    }

    #[test]
    fn case_insensitive() {
        let ac = dict(&["IBM Research"], CaseMode::Insensitive);
        assert_eq!(matches(&ac, "ibm research rocks"), vec![(0, 12, 0)]);
        assert_eq!(matches(&ac, "IBM RESEARCH"), vec![(0, 12, 0)]);
    }

    #[test]
    fn multi_token_phrase() {
        let ac = dict(&["New York", "York"], CaseMode::Exact);
        let got = matches(&ac, "in New York City");
        assert_eq!(got, vec![(3, 11, 0), (7, 11, 1)]);
    }

    #[test]
    fn empty_dictionary() {
        let ac = dict(&[], CaseMode::Exact);
        assert!(matches(&ac, "anything").is_empty());
    }

    #[test]
    fn nul_separator_resets() {
        let ac = dict(&["ab"], CaseMode::Exact);
        assert!(ac.find_all(b"a\0b").is_empty());
        assert_eq!(ac.find_all(b"ab\0ab").len(), 2);
    }

    #[test]
    fn hw_state_reconstruction_agrees() {
        let ac = dict(&["he", "she", "hers", "his"], CaseMode::Exact);
        for text in ["he and she said hers", "ushers", "h e r s", ""] {
            // simulate the accelerator: record (end, state) at accepting steps
            let mut hits = Vec::new();
            let mut state = ROOT;
            for (i, &b) in text.as_bytes().iter().enumerate() {
                state = ac.step(state, b);
                if ac.is_accept(state) {
                    hits.push((i + 1, state));
                }
            }
            let mut hw = ac.from_hw_states(text.as_bytes(), &hits);
            let mut sw = ac.find_token_matches(text.as_bytes());
            hw.sort_by_key(|m| (m.span.begin, m.span.end, m.entry));
            sw.sort_by_key(|m| (m.span.begin, m.span.end, m.entry));
            assert_eq!(hw, sw, "text {text:?}");
        }
    }

    /// Differential test against the third-party aho-corasick crate
    /// (oracle). Gated like the regex oracle tests — the offline build has
    /// no external dev-dependencies (see Cargo.toml `oracle-tests`).
    #[cfg(feature = "oracle-tests")]
    #[test]
    fn oracle_differential() {
        use crate::util::Prng;
        let entries = ["ab", "abc", "bca", "c", "cab"];
        let mine = dict(&entries, CaseMode::Exact);
        let oracle = aho_corasick::AhoCorasick::new(entries).unwrap();
        let mut rng = Prng::new(5);
        for _ in 0..300 {
            let len = rng.below(50).max(1);
            let t = rng.string_over(b"abc ", len);
            let mut got: Vec<(usize, usize, usize)> = mine
                .find_all(t.as_bytes())
                .iter()
                .map(|m| (m.span.begin as usize, m.span.end as usize, m.entry as usize))
                .collect();
            // oracle: overlapping all-matches
            let mut want: Vec<(usize, usize, usize)> = oracle
                .find_overlapping_iter(&t)
                .map(|m| (m.start(), m.end(), m.pattern().as_usize()))
                .collect();
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "text {t:?}");
        }
    }

    #[test]
    fn spans_into_agrees_with_match_form() {
        let ac = dict(&["he", "she", "hers", "New York", "York"], CaseMode::Exact);
        for text in ["he and she said hers", "in New York City", "ushers", ""] {
            let mut spans = Vec::new();
            ac.find_token_spans_into(text.as_bytes(), &mut spans);
            let want: Vec<_> = ac
                .find_token_matches(text.as_bytes())
                .iter()
                .map(|m| m.span)
                .collect();
            assert_eq!(spans, want, "text {text:?}");
        }
    }

    /// The single-pass BFS build must produce exactly the textbook DFA:
    /// delta(s, b) = goto(s, b) if present, else delta(fail(s), b) with
    /// fail links resolved by chain-walking (the construction the rewrite
    /// replaced). Checked by stepping both on random-ish text.
    #[test]
    fn dense_table_matches_chain_walk_reference() {
        let entries = ["he", "she", "his", "hers", "a", "ab", "abab", "bab"];
        let ac = dict(&entries, CaseMode::Exact);
        // reference scan: walk the raw trie with explicit fail chasing
        let text = b"ushershishehehersabababbababa he she";
        let mut got = ac.find_all(text);
        // reference matcher: check every (start, entry) pair directly
        let mut want = Vec::new();
        for (id, e) in entries.iter().enumerate() {
            let eb = e.as_bytes();
            for start in 0..text.len().saturating_sub(eb.len() - 1) {
                if &text[start..start + eb.len()] == eb {
                    want.push(DictMatch {
                        span: Span::new(start as u32, (start + eb.len()) as u32),
                        entry: id as u32,
                    });
                }
            }
        }
        let key = |m: &DictMatch| (m.span.begin, m.span.end, m.entry);
        got.sort_by_key(key);
        want.sort_by_key(key);
        assert_eq!(got, want);
    }

    #[test]
    fn table_layout_conventions() {
        let ac = dict(&["xy"], CaseMode::Exact);
        // state 0 dead row exists, state 1 root; NUL resets everywhere
        assert!(ac.num_states >= 3);
        for s in 0..ac.num_states {
            assert_eq!(ac.table[s as usize * 256], ROOT);
        }
        // root loops to itself on unrelated bytes
        assert_eq!(ac.table[ROOT as usize * 256 + b'q' as usize], ROOT);
    }
}
