//! Operator-graph partitioning: supergraph + hardware subgraphs (Fig 1).
//!
//! The paper identifies offloadable regions as **maximal convex subgraphs**
//! (their ref [22]) over the set of hardware-supported operators: a subgraph
//! is *convex* when no path between two of its members passes through a
//! non-member, so it can execute atomically on the accelerator without a
//! software round-trip.
//!
//! Three offload scenarios are modeled, matching the paper's Fig 7 series:
//! * [`PartitionMode::ExtractOnly`] — only the extraction operators move
//!   (one accelerator pass, all patterns as parallel machines);
//! * [`PartitionMode::SingleSubgraph`] — one maximal convex subgraph
//!   containing the extraction operators plus as many supported relational
//!   operators as possible;
//! * [`PartitionMode::MultiSubgraph`] — every maximal convex subgraph of
//!   supported operators (additional subgraphs may consume software tuples
//!   through `ExtInput` slots).

pub mod convex;

pub use convex::{is_convex, maximal_convex_components};

use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::Arc;

use crate::aog::{Graph, NodeId, OpKind, Schema, Tuple};
use crate::exec::{ExecStrategy, Executor, Profiler, SubgraphRunner, TupleBatch};
use crate::text::{Document, TokenIndex};

/// Offload scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionMode {
    /// Nothing offloaded (pure software baseline).
    None,
    /// Extraction operators only (paper Fig 7 series 2).
    ExtractOnly,
    /// One maximal convex subgraph (series 3).
    SingleSubgraph,
    /// All maximal convex subgraphs (series 4).
    MultiSubgraph,
}

impl PartitionMode {
    /// Parse a CLI-style name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" | "sw" => Some(Self::None),
            "extract" | "extract-only" => Some(Self::ExtractOnly),
            "single" | "single-subgraph" => Some(Self::SingleSubgraph),
            "multi" | "multi-subgraph" => Some(Self::MultiSubgraph),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::ExtractOnly => "extract-only",
            Self::SingleSubgraph => "single-subgraph",
            Self::MultiSubgraph => "multi-subgraph",
        }
    }
}

/// One offloaded subgraph, in standalone executable form.
#[derive(Debug, Clone)]
pub struct SubgraphSpec {
    /// Subgraph id (index into [`PartitionPlan::subgraphs`]).
    pub id: usize,
    /// Standalone body: `DocScan` + `ExtInput` leaves + the member
    /// operators; outputs registered as `out0`, `out1`, ...
    pub body: Graph,
    /// Body node ids of the subgraph outputs, in `output_idx` order.
    pub outputs: Vec<NodeId>,
    /// Number of `ExtInput` slots (software tuple streams it consumes).
    pub ext_inputs: usize,
    /// Member node ids in the ORIGINAL graph (for profile attribution).
    pub orig_nodes: Vec<NodeId>,
}

/// The partition result.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    /// The offload scenario this plan was built for.
    pub mode: PartitionMode,
    /// The software supergraph (with `SubgraphExec` placeholders).
    pub supergraph: Graph,
    /// The offloaded subgraphs, by id.
    pub subgraphs: Vec<SubgraphSpec>,
}

/// Is this operator implementable by the streaming accelerator?
/// (paper §3: extraction operators, and relational operators whose
/// predicates avoid string materialization; blocking operators — sort,
/// limit — stay in software.)
pub fn hw_supported(kind: &OpKind) -> bool {
    match kind {
        OpKind::RegexExtract { regex, .. } => {
            // must fit the largest artifact state budget
            regex.search.num_states as usize <= crate::hwcompiler::MAX_HW_STATES
        }
        OpKind::DictExtract { matcher, .. } => {
            matcher.num_states as usize <= crate::hwcompiler::MAX_HW_STATES
        }
        OpKind::Select { pred } => pred.hw_supported(),
        OpKind::Project { cols } => cols.iter().all(|(_, e)| e.hw_supported()),
        OpKind::Join { pred } => pred.hw_supported(),
        OpKind::Union
        | OpKind::Consolidate { .. }
        | OpKind::Difference
        | OpKind::Block { .. } => true,
        // aggregation is stateful across the whole corpus (worker
        // partials merged at session drain) — like sort/limit it blocks
        // and stays in software
        OpKind::DocScan
        | OpKind::Sort { .. }
        | OpKind::Limit { .. }
        | OpKind::GroupAgg { .. }
        | OpKind::TopK { .. }
        | OpKind::SubgraphExec { .. }
        | OpKind::ExtInput { .. } => false,
    }
}

/// Partition `g` under `mode`.
pub fn partition(g: &Graph, mode: PartitionMode) -> PartitionPlan {
    let supported: Vec<bool> = g.nodes.iter().map(|n| hw_supported(&n.kind)).collect();
    let groups: Vec<Vec<NodeId>> = match mode {
        PartitionMode::None => Vec::new(),
        PartitionMode::ExtractOnly => {
            // all extraction ops as ONE accelerator pass (parallel machines)
            let ex: Vec<NodeId> = g
                .nodes
                .iter()
                .filter(|n| n.kind.is_extraction() && supported[n.id])
                .map(|n| n.id)
                .collect();
            if ex.is_empty() {
                Vec::new()
            } else {
                vec![ex]
            }
        }
        PartitionMode::SingleSubgraph => {
            let comps = maximal_convex_components(g, &supported);
            // pick the component covering the largest estimated cost; the
            // paper's choice is "all extraction operators plus as many
            // supported operators as possible", which is the extraction-
            // heavy component — cost fraction is the faithful proxy.
            let cost = crate::optimizer::estimate(g, 2048);
            comps
                .into_iter()
                .max_by(|a, b| {
                    cost.fraction_of(a)
                        .partial_cmp(&cost.fraction_of(b))
                        .unwrap()
                })
                .map(|c| vec![c])
                .unwrap_or_default()
        }
        PartitionMode::MultiSubgraph => maximal_convex_components(g, &supported),
    };

    build_plan(g, mode, groups)
}

/// Rewrite: extract each group into a [`SubgraphSpec`] and replace its
/// members in the supergraph with `SubgraphExec` placeholders.
fn build_plan(g: &Graph, mode: PartitionMode, groups: Vec<Vec<NodeId>>) -> PartitionPlan {
    let consumers = g.consumers();
    let mut member_of: Vec<Option<usize>> = vec![None; g.nodes.len()];
    for (gi, group) in groups.iter().enumerate() {
        for &n in group {
            member_of[n] = Some(gi);
        }
    }
    let output_names: HashMap<NodeId, bool> = g
        .outputs
        .iter()
        .map(|(_, n)| (*n, true))
        .collect();

    // Determine each group's external outputs (consumed outside, or query
    // outputs) and external tuple inputs (non-DocScan inputs from outside).
    let mut specs: Vec<SubgraphSpec> = Vec::new();
    // per group: (original ids of outputs, original ids feeding ext slots)
    let mut side: Vec<(Vec<NodeId>, Vec<NodeId>)> = Vec::new();
    for (gi, group) in groups.iter().enumerate() {
        let mut body = Graph::new();
        let mut local: HashMap<NodeId, NodeId> = HashMap::new();
        let mut ext_slots: Vec<NodeId> = Vec::new(); // original ids feeding slots
        let doc_local = body.add(OpKind::DocScan, vec![]).expect("docscan");
        // group is in topological order (components preserve node order)
        for &n in group {
            let node = &g.nodes[n];
            let mut inputs_local = Vec::new();
            for &i in &node.inputs {
                let li = if let Some(&l) = local.get(&i) {
                    l
                } else if matches!(g.nodes[i].kind, OpKind::DocScan) {
                    doc_local
                } else {
                    // external tuple stream → ExtInput slot
                    let slot = ext_slots.iter().position(|&e| e == i).unwrap_or_else(|| {
                        ext_slots.push(i);
                        ext_slots.len() - 1
                    });
                    let schema = g.nodes[i].schema.clone();
                    // reuse the ExtInput node if the slot already exists
                    match body.nodes.iter().find(|bn| {
                        matches!(&bn.kind, OpKind::ExtInput { slot: s, .. } if *s == slot)
                    }) {
                        Some(bn) => bn.id,
                        None => body
                            .add(OpKind::ExtInput { slot, schema }, vec![])
                            .expect("ext input"),
                    }
                };
                inputs_local.push(li);
            }
            let l = body
                .add(node.kind.clone(), inputs_local)
                .expect("subgraph body build");
            local.insert(n, l);
        }
        // outputs: members consumed outside the group or output views
        let mut outputs_orig: Vec<NodeId> = group
            .iter()
            .copied()
            .filter(|&n| {
                output_names.contains_key(&n)
                    || consumers[n]
                        .iter()
                        .any(|&c| member_of[c] != Some(gi))
            })
            .collect();
        outputs_orig.sort_unstable();
        let outputs: Vec<NodeId> = outputs_orig.iter().map(|n| local[n]).collect();
        for (k, &l) in outputs.iter().enumerate() {
            body.add_output(format!("out{k}"), l)
                .expect("subgraph outputs target body nodes");
        }
        specs.push(SubgraphSpec {
            id: gi,
            body,
            outputs,
            ext_inputs: ext_slots.len(),
            orig_nodes: group.clone(),
        });
        side.push((outputs_orig, ext_slots));
    }

    // Rebuild the supergraph: members are replaced by SubgraphExec nodes.
    //
    // A group executes atomically, so the SubgraphExec nodes of group `gi`
    // depend on ALL of the group's ext-input sources — which may sit
    // *after* some member in the original topological order. Emission is
    // therefore dependency-driven (Kahn over "units": one unit per SW node
    // and one per group). Group-level dependencies are acyclic because a
    // software path from one member to another would violate convexity.
    let mut sup = Graph::new();
    let mut remap: Vec<Option<NodeId>> = vec![None; g.nodes.len()];
    let mut doc_sup: Option<NodeId> = None;
    let mut group_emitted = vec![false; groups.len()];
    let total_units = g.nodes.len();
    let mut emitted_nodes = vec![false; total_units];

    let mut progress = true;
    while progress {
        progress = false;
        for node in &g.nodes {
            if emitted_nodes[node.id] {
                continue;
            }
            match member_of[node.id] {
                None => {
                    // SW node: ready when every input is remapped
                    if !node.inputs.iter().all(|&i| remap[i].is_some()) {
                        continue;
                    }
                    if matches!(node.kind, OpKind::DocScan) {
                        let id = sup.add(OpKind::DocScan, vec![]).expect("docscan");
                        doc_sup = Some(id);
                        remap[node.id] = Some(id);
                        emitted_nodes[node.id] = true;
                        progress = true;
                        continue;
                    }
                    let inputs: Vec<NodeId> =
                        node.inputs.iter().map(|&i| remap[i].unwrap()).collect();
                    let id = sup
                        .add(node.kind.clone(), inputs)
                        .expect("supergraph rebuild");
                    if let Some(v) = &node.view {
                        sup.name_view(id, v.clone());
                    }
                    remap[node.id] = Some(id);
                    emitted_nodes[node.id] = true;
                    progress = true;
                }
                Some(gi) => {
                    if group_emitted[gi] {
                        emitted_nodes[node.id] = true;
                        progress = true;
                        continue;
                    }
                    // group ready when all ext sources are remapped
                    let (outputs_orig, ext_slots) = &side[gi];
                    if !ext_slots.iter().all(|&s| remap[s].is_some()) {
                        continue;
                    }
                    let doc = doc_sup
                        .get_or_insert_with(|| {
                            sup.add(OpKind::DocScan, vec![]).expect("docscan")
                        })
                        .to_owned();
                    for (output_idx, &out_orig) in outputs_orig.iter().enumerate() {
                        let mut inputs = vec![doc];
                        for &src in ext_slots {
                            inputs.push(remap[src].unwrap());
                        }
                        let out_node = &g.nodes[out_orig];
                        let id = sup
                            .add(
                                OpKind::SubgraphExec {
                                    subgraph_id: gi,
                                    output_idx,
                                    schema: out_node.schema.clone(),
                                },
                                inputs,
                            )
                            .expect("subgraph exec node");
                        if let Some(v) = &out_node.view {
                            sup.name_view(id, v.clone());
                        }
                        remap[out_orig] = Some(id);
                    }
                    group_emitted[gi] = true;
                    emitted_nodes[node.id] = true;
                    progress = true;
                }
            }
        }
    }
    debug_assert!(
        emitted_nodes.iter().all(|&e| e),
        "partition produced a cyclic supergraph (convexity bug)"
    );
    for (name, target) in &g.outputs {
        sup.add_output(
            name.clone(),
            remap[*target].expect("output view must be an external output"),
        )
        .expect("remapped output targets a supergraph node");
    }

    PartitionPlan {
        mode,
        supergraph: sup,
        subgraphs: specs,
    }
}

/// Software reference implementation of [`SubgraphRunner`]: executes the
/// subgraph bodies in software. Used to validate partition correctness
/// (partitioned + this runner ≡ original graph) and as the fallback when no
/// accelerator is configured.
pub struct SoftwareSubgraphRunner {
    executors: Vec<Executor>,
}

impl SoftwareSubgraphRunner {
    /// Build from a plan (columnar bodies).
    pub fn new(plan: &PartitionPlan) -> SoftwareSubgraphRunner {
        SoftwareSubgraphRunner::with_strategy(plan, ExecStrategy::Columnar)
    }

    /// Build from a plan with an explicit body-executor strategy — the
    /// columnar differential suite runs a fully-legacy pipeline this way.
    pub fn with_strategy(
        plan: &PartitionPlan,
        strategy: ExecStrategy,
    ) -> SoftwareSubgraphRunner {
        let executors = plan
            .subgraphs
            .iter()
            .map(|s| {
                Executor::new(Arc::new(s.body.clone()), Arc::new(Profiler::disabled()))
                    .with_strategy(strategy)
            })
            .collect();
        SoftwareSubgraphRunner { executors }
    }

    /// Run one subgraph body with panic containment: a panic inside the
    /// body is re-raised enriched with the subgraph id and document id,
    /// so the session worker's quarantine records *where* the poison
    /// document blew up, not just that it did. Typed
    /// [`DeadlinePanic`](crate::runtime::fault::DeadlinePanic) payloads
    /// pass through untouched — stringifying them would turn a deadline
    /// expiry into a generic panic in the error taxonomy.
    fn contain<T>(&self, id: usize, doc: &Document, f: impl FnOnce() -> T) -> T {
        match catch_unwind(AssertUnwindSafe(f)) {
            Ok(v) => v,
            Err(payload) => {
                if payload.is::<crate::runtime::fault::DeadlinePanic>() {
                    resume_unwind(payload);
                }
                panic!(
                    "subgraph #{id} panicked on doc {}: {}",
                    doc.id,
                    crate::runtime::fault::panic_message(payload.as_ref())
                );
            }
        }
    }
}

impl SubgraphRunner for SoftwareSubgraphRunner {
    fn run(
        &self,
        id: usize,
        output_idx: usize,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&[Tuple]],
    ) -> Vec<Tuple> {
        let out = self.contain(id, doc, || {
            self.executors[id].run_doc_with(doc, tokens, ext, &HashMap::new())
        });
        // body outputs are registered positionally (`out0`, `out1`, …), so
        // output_idx indexes the typed result directly; a miswired graph
        // must fail loudly here, matching AccelSubgraphRunner
        assert!(
            output_idx < out.num_views(),
            "subgraph #{id} has {} outputs, output_idx {output_idx} is out of range",
            out.num_views()
        );
        out.views()[output_idx].clone()
    }

    fn run_batch(
        &self,
        id: usize,
        output_idx: usize,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&TupleBatch],
        _schema: &Schema,
    ) -> TupleBatch {
        let out = self.contain(id, doc, || {
            self.executors[id].run_doc_batched(doc, tokens, ext, &HashMap::new())
        });
        assert!(
            output_idx < out.num_views(),
            "subgraph #{id} has {} outputs, output_idx {output_idx} is out of range",
            out.num_views()
        );
        out.batches()[output_idx].clone()
    }
}

/// Convenience: make a schema available for tests.
pub fn subgraph_output_schema(spec: &SubgraphSpec, idx: usize) -> &Schema {
    &spec.body.nodes[spec.outputs[idx]].schema
}

#[cfg(test)]
mod tests {
    use super::*;

    const PERSON_ORG: &str = r#"
        create dictionary Orgs as ('IBM', 'IBM Research', 'Columbia University');
        create view Org as
          extract dictionary 'Orgs' on d.text as match from Document d;
        create view Person as
          extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name from Document d;
        create view PersonOrg as
          select p.name as person, o.match as org,
                 CombineSpans(p.name, o.match) as ctx
          from Person p, Org o
          where FollowsTok(p.name, o.match, 0, 4)
          consolidate on ctx using 'ContainedWithin';
        output view PersonOrg;
    "#;

    fn graph() -> Graph {
        crate::optimizer::optimize(&crate::aql::compile(PERSON_ORG).unwrap())
    }

    fn run_plan(plan: &PartitionPlan, text: &str) -> Vec<Vec<String>> {
        let runner = Arc::new(SoftwareSubgraphRunner::new(plan));
        let ex = Executor::new(
            Arc::new(plan.supergraph.clone()),
            Arc::new(Profiler::disabled()),
        )
        .with_subgraph_runner(runner);
        let out = ex.run_doc(&Document::new(0, text));
        let mut rows: Vec<Vec<String>> = out
            .views()
            .iter()
            .flat_map(|rows| rows.iter().map(|t| t.iter().map(|v| v.to_string()).collect()))
            .collect();
        rows.sort();
        rows
    }

    fn run_sw(g: &Graph, text: &str) -> Vec<Vec<String>> {
        let ex = Executor::new(Arc::new(g.clone()), Arc::new(Profiler::disabled()));
        let out = ex.run_doc(&Document::new(0, text));
        let mut rows: Vec<Vec<String>> = out
            .views()
            .iter()
            .flat_map(|rows| rows.iter().map(|t| t.iter().map(|v| v.to_string()).collect()))
            .collect();
        rows.sort();
        rows
    }

    const SAMPLES: &[&str] = &[
        "Laura Chiticariu works at IBM Research in Almaden.",
        "Fred Reiss and Huaiyu Zhu are at IBM Research today.",
        "nothing here",
        "",
        "Eva Sitaridi is at Columbia University. Peter Hofstee visits IBM.",
    ];

    #[test]
    fn extract_only_offloads_extraction() {
        let g = graph();
        let plan = partition(&g, PartitionMode::ExtractOnly);
        assert_eq!(plan.subgraphs.len(), 1);
        let sg = &plan.subgraphs[0];
        assert_eq!(sg.orig_nodes.len(), 2); // regex + dict
        assert_eq!(sg.ext_inputs, 0);
        assert_eq!(sg.outputs.len(), 2); // both consumed by the join
        // supergraph has no extraction operators left
        assert_eq!(plan.supergraph.op_counts().get("RegularExpression"), None);
        assert_eq!(plan.supergraph.op_counts().get("Dictionary"), None);
        assert_eq!(plan.supergraph.op_counts()["SubgraphExec"], 2);
    }

    #[test]
    fn extract_only_is_equivalent() {
        let g = graph();
        let plan = partition(&g, PartitionMode::ExtractOnly);
        for t in SAMPLES {
            assert_eq!(run_plan(&plan, t), run_sw(&g, t), "text {t:?}");
        }
    }

    #[test]
    fn single_subgraph_takes_relational_ops_too() {
        let g = graph();
        let plan = partition(&g, PartitionMode::SingleSubgraph);
        assert_eq!(plan.subgraphs.len(), 1);
        let sg = &plan.subgraphs[0];
        // regex, dict, join, select(merged into join by optimizer),
        // project, consolidate are all supported and convex here
        assert!(sg.orig_nodes.len() >= 4, "{:?}", sg.orig_nodes);
        // whole query offloaded → single output, no relational ops in sup
        assert_eq!(plan.supergraph.op_counts().get("Join"), None);
    }

    #[test]
    fn single_subgraph_is_equivalent() {
        let g = graph();
        let plan = partition(&g, PartitionMode::SingleSubgraph);
        for t in SAMPLES {
            assert_eq!(run_plan(&plan, t), run_sw(&g, t), "text {t:?}");
        }
    }

    #[test]
    fn multi_subgraph_is_equivalent() {
        let g = graph();
        let plan = partition(&g, PartitionMode::MultiSubgraph);
        for t in SAMPLES {
            assert_eq!(run_plan(&plan, t), run_sw(&g, t), "text {t:?}");
        }
    }

    #[test]
    fn none_mode_is_identity() {
        let g = graph();
        let plan = partition(&g, PartitionMode::None);
        assert!(plan.subgraphs.is_empty());
        for t in SAMPLES {
            assert_eq!(run_plan(&plan, t), run_sw(&g, t));
        }
    }

    #[test]
    fn sort_stays_in_software() {
        let g = crate::optimizer::optimize(
            &crate::aql::compile(
                "create view A as extract regex /[a-z]+/ on d.text as m from Document d;
                 create view V as select a.m as m from A a order by m limit 3;
                 output view V;",
            )
            .unwrap(),
        );
        let plan = partition(&g, PartitionMode::MultiSubgraph);
        assert!(plan.supergraph.op_counts()["Sort"] == 1);
        assert!(plan.supergraph.op_counts()["Limit"] == 1);
        for t in SAMPLES {
            assert_eq!(run_plan(&plan, t), run_sw(&g, t));
        }
    }

    #[test]
    fn aggregation_stays_in_software() {
        let g = crate::optimizer::optimize(
            &crate::aql::compile(
                "create view A as extract regex /[a-z]+/ on d.text as m from Document d;
                 create view V as select GetText(a.m) as term, Count() as n from A a
                 group by term score n top 3;
                 output view V;",
            )
            .unwrap(),
        );
        for mode in [PartitionMode::ExtractOnly, PartitionMode::MultiSubgraph] {
            let plan = partition(&g, mode);
            // the blocking aggregate operators never cross into a subgraph
            assert_eq!(plan.supergraph.op_counts()["GroupAgg"], 1, "{mode:?}");
            assert_eq!(plan.supergraph.op_counts()["TopK"], 1, "{mode:?}");
            for t in SAMPLES {
                assert_eq!(run_plan(&plan, t), run_sw(&g, t), "{mode:?} {t:?}");
            }
        }
    }

    #[test]
    fn unsupported_pred_splits_subgraph() {
        // GetText predicate is not hw-supported: the select must stay in
        // software while extraction still offloads.
        let g = crate::optimizer::optimize(
            &crate::aql::compile(
                "create view A as extract regex /[a-z]+/ on d.text as m from Document d;
                 create view V as select a.m as m from A a
                   where GetText(a.m) = 'hello';
                 output view V;",
            )
            .unwrap(),
        );
        let plan = partition(&g, PartitionMode::MultiSubgraph);
        assert_eq!(plan.supergraph.op_counts()["Select"], 1);
        for t in ["hello world hello", "abc"] {
            assert_eq!(run_plan(&plan, t), run_sw(&g, t));
        }
    }

    #[test]
    fn ext_input_flows_into_downstream_subgraph() {
        // sup1 → unsupported (GetText select) → sup2 (consolidate):
        // multi-subgraph mode must create a second subgraph consuming the
        // software stream via ExtInput.
        let g = crate::optimizer::optimize(
            &crate::aql::compile(
                "create view A as extract regex /[a-z]+/ on d.text as m from Document d;
                 create view F as select a.m as m from A a where GetText(a.m) != 'skip';
                 create view V as select f.m as m from F f consolidate on m using 'ContainedWithin';
                 output view V;",
            )
            .unwrap(),
        );
        let plan = partition(&g, PartitionMode::MultiSubgraph);
        assert!(
            plan.subgraphs.iter().any(|s| s.ext_inputs > 0),
            "expected a tuple-fed subgraph: {:?}",
            plan.subgraphs.iter().map(|s| s.ext_inputs).collect::<Vec<_>>()
        );
        for t in ["abc skip def", "skip", ""] {
            assert_eq!(run_plan(&plan, t), run_sw(&g, t), "text {t:?}");
        }
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in [
            PartitionMode::None,
            PartitionMode::ExtractOnly,
            PartitionMode::SingleSubgraph,
            PartitionMode::MultiSubgraph,
        ] {
            assert_eq!(PartitionMode::parse(m.name()), Some(m));
        }
        assert_eq!(PartitionMode::parse("bogus"), None);
    }
}
