//! Maximal convex components over the hardware-supported node set.
//!
//! A node set `S` in a DAG is *convex* iff no path `u → x → v` exists with
//! `u, v ∈ S` and `x ∉ S` (paper ref [22]: convex subgraphs are exactly the
//! sets that can execute atomically on the accelerator without processor
//! intervention). This module provides the convexity test and a greedy
//! grow-and-merge enumeration of maximal convex components.

use crate::aog::{Graph, NodeId, OpKind};

/// Exact convexity test for `set` (given as a boolean mask) via
/// reachability: `set` is convex iff no non-member lies on a path between
/// members, i.e. `descendants(set) ∩ ancestors(set) ⊆ set`.
pub fn is_convex(g: &Graph, member: &[bool]) -> bool {
    let n = g.nodes.len();
    // desc[x]: x reachable FROM some member (x strictly downstream)
    let mut desc = vec![false; n];
    for node in &g.nodes {
        let from_member_input = node
            .inputs
            .iter()
            .any(|&i| member[i] || desc[i]);
        if from_member_input && !member[node.id] {
            desc[node.id] = true;
        } else if from_member_input {
            // member fed by member — fine, but propagation continues
            // through it only if it is itself a member (paths through
            // members are allowed)
        }
        // propagate "reaches a member-descendant" through non-members
        if !member[node.id] {
            let any = node.inputs.iter().any(|&i| desc[i] || member[i]);
            if any {
                desc[node.id] = true;
            }
        }
    }
    // anc-from-desc: does any member consume (directly or transitively
    // through any nodes) a desc-marked non-member? Walk again forward: a
    // member with an input chain passing a desc non-member violates.
    let mut tainted = vec![false; n]; // node sees a desc non-member upstream
    for node in &g.nodes {
        let mut t = false;
        for &i in &node.inputs {
            if desc[i] && !member[i] {
                t = true;
            }
            if tainted[i] && !member[i] {
                // taint propagates through non-members; entering a member
                // is exactly the violation
                t = true;
            }
        }
        if member[node.id] && t {
            return false;
        }
        tainted[node.id] = t;
    }
    true
}

/// Greedy enumeration of maximal convex components of the supported nodes.
///
/// Pass 1 walks nodes in topological order, attaching each supported node
/// to the components of its supported producers when the union stays
/// convex. Pass 2 merges components pairwise to a fixpoint. The result is
/// a set of disjoint convex components none of which can absorb another —
/// maximality in the paper's sense.
pub fn maximal_convex_components(g: &Graph, supported: &[bool]) -> Vec<Vec<NodeId>> {
    let n = g.nodes.len();
    let mut comp_of: Vec<Option<usize>> = vec![None; n];
    let mut comps: Vec<Vec<NodeId>> = Vec::new();

    let mask_of = |comps: &Vec<Vec<NodeId>>, ids: &[usize], extra: Option<NodeId>| {
        let mut m = vec![false; n];
        for &ci in ids {
            for &x in &comps[ci] {
                m[x] = true;
            }
        }
        if let Some(e) = extra {
            m[e] = true;
        }
        m
    };

    for node in &g.nodes {
        if !supported[node.id] {
            continue;
        }
        // candidate components: those of supported producers
        let mut cand: Vec<usize> = node
            .inputs
            .iter()
            .filter_map(|&i| comp_of[i])
            .collect();
        cand.sort_unstable();
        cand.dedup();
        // try attaching to the union of producer components
        if !cand.is_empty() {
            let mask = mask_of(&comps, &cand, Some(node.id));
            if is_convex(g, &mask) {
                // merge cand components into the first
                let target = cand[0];
                for &ci in &cand[1..] {
                    let moved = std::mem::take(&mut comps[ci]);
                    for &x in &moved {
                        comp_of[x] = Some(target);
                    }
                    comps[target].extend(moved);
                }
                comps[target].push(node.id);
                comp_of[node.id] = Some(target);
                continue;
            }
            // try each producer component individually (largest first)
            let mut by_size = cand.clone();
            by_size.sort_by_key(|&ci| std::cmp::Reverse(comps[ci].len()));
            let mut placed = false;
            for &ci in &by_size {
                let mask = mask_of(&comps, &[ci], Some(node.id));
                if is_convex(g, &mask) {
                    comps[ci].push(node.id);
                    comp_of[node.id] = Some(ci);
                    placed = true;
                    break;
                }
            }
            if placed {
                continue;
            }
        }
        // singleton component
        comp_of[node.id] = Some(comps.len());
        comps.push(vec![node.id]);
    }

    // Pass 2: pairwise merge to fixpoint (maximality).
    let mut changed = true;
    while changed {
        changed = false;
        'outer: for a in 0..comps.len() {
            if comps[a].is_empty() {
                continue;
            }
            for b in (a + 1)..comps.len() {
                if comps[b].is_empty() {
                    continue;
                }
                let mut mask = vec![false; n];
                for &x in comps[a].iter().chain(&comps[b]) {
                    mask[x] = true;
                }
                if is_convex(g, &mask) {
                    let moved = std::mem::take(&mut comps[b]);
                    comps[a].extend(moved);
                    comps[a].sort_unstable();
                    changed = true;
                    continue 'outer;
                }
            }
        }
    }

    let mut out: Vec<Vec<NodeId>> = comps.into_iter().filter(|c| !c.is_empty()).collect();
    for c in &mut out {
        c.sort_unstable();
    }
    out.sort_by_key(|c| c[0]);
    out
}

/// Helper used by tests and the CLI `partition` command: pretty-print the
/// partition of a graph.
pub fn describe_components(g: &Graph, comps: &[Vec<NodeId>]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    for (i, c) in comps.iter().enumerate() {
        let _ = write!(s, "subgraph #{i}: ");
        for (k, &x) in c.iter().enumerate() {
            if k > 0 {
                let _ = write!(s, ", ");
            }
            let _ = write!(s, "%{x}:{}", g.nodes[x].kind.name());
        }
        let _ = writeln!(s);
    }
    s
}

// is_extraction used in partition; re-export convenience
pub(crate) fn _kind_is_extraction(k: &OpKind) -> bool {
    k.is_extraction()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aog::{FieldType, Graph, OpKind, Schema};

    /// Build a synthetic DAG with "supported" encoded per node; uses
    /// ExtInput-with-schema nodes as generic placeholders so we can shape
    /// arbitrary DAGs without type constraints.
    fn chain_graph(edges: &[(usize, usize)], n: usize) -> Graph {
        // node i = ExtInput if no inputs else Union (schema-compatible)
        let schema = Schema::of(&[("m", FieldType::Span)]);
        let mut g = Graph::new();
        for i in 0..n {
            let inputs: Vec<usize> = edges
                .iter()
                .filter(|(_, b)| *b == i)
                .map(|(a, _)| *a)
                .collect();
            if inputs.is_empty() {
                g.add(
                    OpKind::ExtInput {
                        slot: i,
                        schema: schema.clone(),
                    },
                    vec![],
                )
                .unwrap();
            } else {
                g.add(OpKind::Union, inputs).unwrap();
            }
        }
        g
    }

    #[test]
    fn convex_basic() {
        // 0 → 1 → 2; {0, 2} is not convex, {0,1,2} and {1,2} are
        let g = chain_graph(&[(0, 1), (1, 2)], 3);
        assert!(!is_convex(&g, &[true, false, true]));
        assert!(is_convex(&g, &[true, true, true]));
        assert!(is_convex(&g, &[false, true, true]));
        assert!(is_convex(&g, &[false, false, true]));
        assert!(is_convex(&g, &[false, false, false]));
    }

    #[test]
    fn convex_diamond() {
        // 0 → 1 → 3, 0 → 2 → 3; {0,1,3} not convex (path 0→2→3)
        let g = chain_graph(&[(0, 1), (0, 2), (1, 3), (2, 3)], 4);
        assert!(!is_convex(&g, &[true, true, false, true]));
        assert!(is_convex(&g, &[true, true, true, true]));
        assert!(is_convex(&g, &[false, true, false, false]));
        // {1, 3}: path 1→3 direct, but also 3's other input 2 from 0 —
        // 0→2→3 does not connect two members through a non-member
        // (0 is not a member), so convex.
        assert!(is_convex(&g, &[false, true, false, true]));
    }

    #[test]
    fn convex_long_detour() {
        // 0 → 1 → 2 → 3 and 0 → 3: {0,3} not convex (detour through 1,2)
        let g = chain_graph(&[(0, 1), (1, 2), (2, 3), (0, 3)], 4);
        assert!(!is_convex(&g, &[true, false, false, true]));
    }

    #[test]
    fn components_split_on_unsupported() {
        // 0 → 1 → 2 with 1 unsupported: components {0}, {2}
        let g = chain_graph(&[(0, 1), (1, 2)], 3);
        let comps = maximal_convex_components(&g, &[true, false, true]);
        assert_eq!(comps, vec![vec![0], vec![2]]);
    }

    #[test]
    fn components_merge_through_members() {
        let g = chain_graph(&[(0, 1), (1, 2)], 3);
        let comps = maximal_convex_components(&g, &[true, true, true]);
        assert_eq!(comps, vec![vec![0, 1, 2]]);
    }

    #[test]
    fn components_diamond_with_bad_middle() {
        // 0 → 1 → 3, 0 → 2 → 3, node 2 unsupported:
        // {0,1} convex; 3 cannot join (path 0→2→3);
        let g = chain_graph(&[(0, 1), (0, 2), (1, 3), (2, 3)], 4);
        let comps = maximal_convex_components(&g, &[true, true, false, true]);
        assert_eq!(comps, vec![vec![0, 1], vec![3]]);
        // every component is convex
        for c in &comps {
            let mut mask = vec![false; 4];
            for &x in c {
                mask[x] = true;
            }
            assert!(is_convex(&g, &mask));
        }
    }

    #[test]
    fn components_are_maximal() {
        // two parallel chains: 0→1, 2→3, all supported — independent chains
        // merge into ONE convex set (no path violates convexity).
        let g = chain_graph(&[(0, 1), (2, 3)], 4);
        let comps = maximal_convex_components(&g, &[true, true, true, true]);
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0], vec![0, 1, 2, 3]);
    }

    #[test]
    fn empty_supported_set() {
        let g = chain_graph(&[(0, 1)], 2);
        assert!(maximal_convex_components(&g, &[false, false]).is_empty());
    }

    #[test]
    fn describe_output() {
        let g = chain_graph(&[(0, 1)], 2);
        let comps = maximal_convex_components(&g, &[true, true]);
        let s = describe_components(&g, &comps);
        assert!(s.contains("subgraph #0"));
    }

    #[test]
    fn prop_components_always_convex_and_disjoint() {
        use crate::util::{prop, Prng};
        prop::check(
            2024,
            120,
            |r: &mut Prng| {
                // random DAG: n nodes, edges i<j with p=0.3; random support
                let n = r.range(2, 12);
                let mut edges = Vec::new();
                for j in 1..n {
                    for i in 0..j {
                        if r.chance(0.3) {
                            edges.push((i, j));
                        }
                    }
                    if !edges.iter().any(|&(_, b)| b == j) && r.chance(0.7) {
                        edges.push((j - 1, j));
                    }
                }
                let support: Vec<usize> =
                    (0..n).map(|_| usize::from(r.chance(0.6))).collect();
                (
                    edges.iter().flat_map(|&(a, b)| [a, b]).collect::<Vec<usize>>(),
                    support,
                )
            },
            |(flat, support)| {
                let edges: Vec<(usize, usize)> = flat
                    .chunks(2)
                    .filter(|c| c.len() == 2)
                    .map(|c| (c[0], c[1]))
                    .collect();
                let n = support.len();
                if edges.iter().any(|&(a, b)| a >= n || b >= n || a >= b) {
                    return true; // shrinker produced junk; skip
                }
                let g = chain_graph(&edges, n);
                let sup: Vec<bool> = support.iter().map(|&x| x == 1).collect();
                let comps = maximal_convex_components(&g, &sup);
                // disjoint + only supported nodes + convex
                let mut seen = vec![false; n];
                for c in &comps {
                    let mut mask = vec![false; n];
                    for &x in c {
                        if seen[x] || !sup[x] {
                            return false;
                        }
                        seen[x] = true;
                        mask[x] = true;
                    }
                    if !is_convex(&g, &mask) {
                        return false;
                    }
                }
                // covers every supported node
                (0..n).all(|i| !sup[i] || seen[i])
            },
        );
    }
}
