//! Rule-based graph optimizer plus the cost model.
//!
//! SystemT pairs its rule language with "cost-based rule optimization that
//! significantly improves extraction throughput" (paper §1). The compiler
//! deliberately lowers multi-source selects to cross-join + big-filter; the
//! optimizer then:
//!
//! 1. **dedups extraction operators** — identical regex/dictionary leaves
//!    are merged so each pattern streams over the document once (this also
//!    maximizes what a single accelerator pass can serve);
//! 2. **pushes predicates down** — single-source conjuncts move below the
//!    join; cross-source conjuncts become the join predicate (cross joins
//!    disappear);
//! 3. **prunes dead nodes** — views that are never output cost nothing.
//!
//! The cost model ([`estimate`]) is deliberately simple (selectivity
//! heuristics over an assumed document size); it feeds `explain` output and
//! the partitioner's tie-breaking, not correctness.

pub mod cost;

pub use cost::{estimate, CostReport, NodeCost};

use crate::aog::{Expr, Graph, Node, NodeId, OpKind};

/// Structured failure of an optimizer rewrite: which pass broke and what
/// it found. Historically these were `expect()` panics inside the
/// rebuild loops ("topological order", "output node dropped", ...); the
/// fallible `try_*` entry points surface them instead, and
/// [`crate::analysis`] turns them into `E201` diagnostics.
#[derive(Debug, Clone)]
pub struct RewriteError {
    /// The pass that failed (`"dedup"`, `"pushdown"`, `"prune"`).
    pub stage: &'static str,
    /// What the pass found wrong.
    pub detail: String,
}

impl std::fmt::Display for RewriteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "optimizer pass '{}' failed: {}", self.stage, self.detail)
    }
}

impl std::error::Error for RewriteError {}

fn rewrite_err(stage: &'static str, detail: impl Into<String>) -> RewriteError {
    RewriteError {
        stage,
        detail: detail.into(),
    }
}

/// Run all optimization passes, panicking on internal rewrite bugs — the
/// historical interface. [`try_optimize`] is the fallible equivalent the
/// engine builder uses so a rewrite bug becomes a diagnostic, not an abort.
pub fn optimize(g: &Graph) -> Graph {
    try_optimize(g).unwrap_or_else(|e| panic!("{e}"))
}

/// Run all optimization passes, surfacing rewrite bugs as [`RewriteError`].
pub fn try_optimize(g: &Graph) -> Result<Graph, RewriteError> {
    let g = try_dedup_extractions(g)?;
    let g = try_push_predicates(&g)?;
    try_prune_dead(&g)
}

/// Rebuild a graph keeping only nodes satisfying `keep`, remapping inputs.
/// Fails if a kept node depends on a dropped one.
fn rebuild_filtered(
    g: &Graph,
    keep: &[bool],
    stage: &'static str,
) -> Result<Graph, RewriteError> {
    let mut out = Graph::new();
    let mut remap: Vec<Option<NodeId>> = vec![None; g.nodes.len()];
    for node in &g.nodes {
        if !keep[node.id] {
            continue;
        }
        let inputs: Vec<NodeId> = node
            .inputs
            .iter()
            .map(|&i| {
                remap[i].ok_or_else(|| {
                    rewrite_err(stage, format!("kept node {} depends on dropped node {i}", node.id))
                })
            })
            .collect::<Result<_, _>>()?;
        let id = out
            .add(node.kind.clone(), inputs)
            .map_err(|e| rewrite_err(stage, format!("rebuild rejected: {e}")))?;
        if let Some(v) = &node.view {
            out.name_view(id, v.clone());
        }
        remap[node.id] = Some(id);
    }
    for (name, target) in &g.outputs {
        let t = remap[*target].ok_or_else(|| {
            rewrite_err(stage, format!("output '{name}' targets dropped node {target}"))
        })?;
        out.add_output(name.clone(), t)
            .map_err(|e| rewrite_err(stage, format!("output rewire: {e}")))?;
    }
    Ok(out)
}

/// Pass 3: drop nodes not reachable from any output (panicking wrapper).
pub fn prune_dead(g: &Graph) -> Graph {
    try_prune_dead(g).unwrap_or_else(|e| panic!("{e}"))
}

/// Pass 3, fallible: drop nodes not reachable from any output.
pub fn try_prune_dead(g: &Graph) -> Result<Graph, RewriteError> {
    let live = g.live_nodes();
    rebuild_filtered(g, &live, "prune")
}

/// Pass 1: merge identical extraction leaves (panicking wrapper).
pub fn dedup_extractions(g: &Graph) -> Graph {
    try_dedup_extractions(g).unwrap_or_else(|e| panic!("{e}"))
}

/// Pass 1, fallible: merge identical extraction leaves.
pub fn try_dedup_extractions(g: &Graph) -> Result<Graph, RewriteError> {
    use std::collections::HashMap;
    const STAGE: &str = "dedup";
    // identity key for extraction nodes
    fn key(node: &Node) -> Option<String> {
        match &node.kind {
            OpKind::RegexExtract { regex, .. } => Some(format!(
                "re:{}:{}",
                regex.pattern.source,
                // case-insensitivity is folded into the pattern classes at
                // parse time, so source+input suffices only with the flag:
                regex.search.num_states // distinguishes folded variants
            )),
            OpKind::DictExtract { dict, .. } => {
                // STRUCTURAL key, not the dictionary's name: two catalog
                // queries each declaring their own `OrgDict` with the same
                // entries must intern to one machine, while two same-named
                // dictionaries with different entries must not merge.
                // Entries cannot contain '\x01' (AQL string literals), so
                // the join is collision-free.
                Some(format!(
                    "dict:{:?}:{}",
                    dict.case,
                    dict.entries.join("\x01")
                ))
            }
            _ => None,
        }
    }
    // the extraction's output column name is NOT part of the structural
    // key (the scan is identical either way), but it IS part of the
    // node's schema — aliased nodes with a different column name get a
    // rename projection so downstream schemas stay byte-identical to an
    // unmerged compilation (catalog queries are authored independently
    // and must not adopt each other's column names)
    fn out_of(kind: &OpKind) -> Option<&String> {
        match kind {
            OpKind::RegexExtract { out, .. } | OpKind::DictExtract { out, .. } => Some(out),
            _ => None,
        }
    }
    let mut seen: HashMap<(NodeId, String), NodeId> = HashMap::new();
    let mut alias: Vec<NodeId> = (0..g.nodes.len()).collect();
    for node in &g.nodes {
        if let Some(k) = key(node) {
            let input = node.inputs[0];
            match seen.get(&(input, k.clone())) {
                Some(&first) => alias[node.id] = first,
                None => {
                    seen.insert((input, k), node.id);
                }
            }
        }
    }
    // rebuild with inputs redirected through `alias`; duplicate nodes
    // become dead and are dropped by a final prune.
    let mut out = Graph::new();
    let mut remap: Vec<Option<NodeId>> = vec![None; g.nodes.len()];
    // rename projections are themselves interned by (rep, column name)
    let mut renames: HashMap<(NodeId, String), NodeId> = HashMap::new();
    for node in &g.nodes {
        if alias[node.id] != node.id {
            let rep = remap[alias[node.id]].ok_or_else(|| {
                rewrite_err(
                    STAGE,
                    format!("representative {} of node {} not emitted first", alias[node.id], node.id),
                )
            })?;
            if out_of(&node.kind) == out_of(&g.nodes[alias[node.id]].kind) {
                remap[node.id] = Some(rep);
            } else {
                // same scan, different column name: share the machine,
                // rename on top
                let my_out = out_of(&node.kind)
                    .ok_or_else(|| {
                        rewrite_err(STAGE, format!("non-extraction node {} was aliased", node.id))
                    })?
                    .clone();
                let id = match renames.get(&(rep, my_out.clone())) {
                    Some(&id) => id,
                    None => {
                        let id = out
                            .add(
                                OpKind::Project {
                                    cols: vec![(my_out.clone(), Expr::Col(0))],
                                },
                                vec![rep],
                            )
                            .map_err(|e| {
                                rewrite_err(STAGE, format!("rename projection rejected: {e}"))
                            })?;
                        renames.insert((rep, my_out), id);
                        id
                    }
                };
                if let Some(v) = &node.view {
                    out.name_view(id, v.clone());
                }
                remap[node.id] = Some(id);
            }
            continue;
        }
        // consumers and outputs route through each node's OWN remap entry
        // (rep, or its rename projection) — never through `alias`
        // directly, which would bypass the rename and leak the
        // representative's column name into this node's consumers
        let inputs: Vec<NodeId> = node
            .inputs
            .iter()
            .map(|&i| {
                remap[i].ok_or_else(|| {
                    rewrite_err(STAGE, format!("node {i} consumed before emission"))
                })
            })
            .collect::<Result<_, _>>()?;
        let id = out
            .add(node.kind.clone(), inputs)
            .map_err(|e| rewrite_err(STAGE, format!("rebuild rejected: {e}")))?;
        if let Some(v) = &node.view {
            out.name_view(id, v.clone());
        }
        remap[node.id] = Some(id);
    }
    for (name, target) in &g.outputs {
        let t = remap[*target].ok_or_else(|| {
            rewrite_err(STAGE, format!("output '{name}' targets dropped node {target}"))
        })?;
        out.add_output(name.clone(), t)
            .map_err(|e| rewrite_err(STAGE, format!("output rewire: {e}")))?;
    }
    Ok(out)
}

/// Flatten a conjunction into conjuncts.
fn conjuncts(e: &Expr, out: &mut Vec<Expr>) {
    match e {
        Expr::And(a, b) => {
            conjuncts(a, out);
            conjuncts(b, out);
        }
        other => out.push(other.clone()),
    }
}

/// Rebuild a conjunction (empty → `true`).
fn conjoin(mut es: Vec<Expr>) -> Expr {
    match es.len() {
        0 => Expr::LitBool(true),
        1 => es.pop().unwrap(),
        _ => {
            let mut it = es.into_iter();
            let first = it.next().unwrap();
            it.fold(first, |acc, e| Expr::And(Box::new(acc), Box::new(e)))
        }
    }
}

/// Pass 2: predicate pushdown and join-predicate formation (panicking
/// wrapper).
pub fn push_predicates(g: &Graph) -> Graph {
    try_push_predicates(g).unwrap_or_else(|e| panic!("{e}"))
}

/// Pass 2, fallible: predicate pushdown and join-predicate formation.
///
/// Rewrites `Select(pred) ∘ Join(true)` trees: conjuncts that reference
/// only left (resp. right) columns are pushed below the join as selects
/// (recursively through left-deep cross-join chains); conjuncts spanning
/// both sides become the join predicate.
pub fn try_push_predicates(g: &Graph) -> Result<Graph, RewriteError> {
    const STAGE: &str = "pushdown";
    let consumers = g.consumers();
    // joins that will be rewritten at their consuming Select
    let mut deferred = vec![false; g.nodes.len()];
    for node in &g.nodes {
        if let OpKind::Select { .. } = node.kind {
            let input = node.inputs[0];
            if is_cross_join(g, input) && consumers[input].len() == 1 {
                mark_deferred_chain(g, input, &consumers, &mut deferred);
            }
        }
    }

    let mut out = Graph::new();
    let mut remap: Vec<Option<NodeId>> = vec![None; g.nodes.len()];
    for node in &g.nodes {
        if deferred[node.id] {
            continue; // emitted by the consuming Select
        }
        match &node.kind {
            OpKind::Select { pred }
                if deferred
                    .get(node.inputs[0])
                    .copied()
                    .unwrap_or(false) =>
            {
                let mut cs = Vec::new();
                conjuncts(pred, &mut cs);
                let (new_id, residual) =
                    emit_join_tree(g, node.inputs[0], cs, &mut out, &remap)?;
                let final_id = if residual.is_empty() {
                    new_id
                } else {
                    out.add(
                        OpKind::Select {
                            pred: conjoin(residual),
                        },
                        vec![new_id],
                    )
                    .map_err(|e| rewrite_err(STAGE, format!("residual select rejected: {e}")))?
                };
                if let Some(v) = &node.view {
                    out.name_view(final_id, v.clone());
                }
                remap[node.id] = Some(final_id);
            }
            _ => {
                let inputs: Vec<NodeId> = node
                    .inputs
                    .iter()
                    .map(|&i| {
                        remap[i].ok_or_else(|| {
                            rewrite_err(STAGE, format!("node {i} consumed before emission"))
                        })
                    })
                    .collect::<Result<_, _>>()?;
                let id = out
                    .add(node.kind.clone(), inputs)
                    .map_err(|e| rewrite_err(STAGE, format!("rebuild rejected: {e}")))?;
                if let Some(v) = &node.view {
                    out.name_view(id, v.clone());
                }
                remap[node.id] = Some(id);
            }
        }
    }
    for (name, target) in &g.outputs {
        let t = remap[*target].ok_or_else(|| {
            rewrite_err(STAGE, format!("output '{name}' targets dropped node {target}"))
        })?;
        out.add_output(name.clone(), t)
            .map_err(|e| rewrite_err(STAGE, format!("output rewire: {e}")))?;
    }
    Ok(out)
}

fn is_cross_join(g: &Graph, id: NodeId) -> bool {
    matches!(&g.nodes[id].kind, OpKind::Join { pred } if *pred == Expr::LitBool(true))
}

/// Mark a left-deep chain of single-consumer cross joins as deferred.
fn mark_deferred_chain(
    g: &Graph,
    id: NodeId,
    consumers: &[Vec<NodeId>],
    deferred: &mut [bool],
) {
    if !is_cross_join(g, id) || consumers[id].len() != 1 || deferred[id] {
        return;
    }
    deferred[id] = true;
    mark_deferred_chain(g, g.nodes[id].inputs[0], consumers, deferred);
}

/// Emit the rewritten join tree for deferred cross-join `id`, given the
/// conjunct pool. Returns the new node id and the conjuncts that could not
/// be attached anywhere below (to be applied as a residual select above).
fn emit_join_tree(
    g: &Graph,
    id: NodeId,
    conj: Vec<Expr>,
    out: &mut Graph,
    remap: &[Option<NodeId>],
) -> Result<(NodeId, Vec<Expr>), RewriteError> {
    const STAGE: &str = "pushdown";
    debug_assert!(is_cross_join(g, id));
    let node = &g.nodes[id];
    let (l, r) = (node.inputs[0], node.inputs[1]);
    let left_arity = g.nodes[l].schema.arity();
    let total_arity = node.schema.arity();

    // split the pool by column footprint
    let mut left_only = Vec::new();
    let mut right_only = Vec::new();
    let mut spanning = Vec::new();
    let mut floating = Vec::new(); // no column refs: keep at top
    for c in conj {
        let mut cols = Vec::new();
        c.columns(&mut cols);
        if cols.is_empty() {
            floating.push(c);
        } else if cols.iter().all(|&i| i < left_arity) {
            left_only.push(c);
        } else if cols.iter().all(|&i| i >= left_arity && i < total_arity) {
            right_only.push(c.remap_columns(&|i| i - left_arity));
        } else {
            spanning.push(c);
        }
    }

    // left subtree: recurse through deferred chains, else plain select
    let (new_l, mut leftover) = if is_cross_join(g, l) && remap[l].is_none() {
        emit_join_tree(g, l, left_only, out, remap)?
    } else {
        let base = remap[l].ok_or_else(|| {
            rewrite_err(STAGE, format!("left join input {l} not emitted"))
        })?;
        let id = if left_only.is_empty() {
            base
        } else {
            out.add(
                OpKind::Select {
                    pred: conjoin(left_only),
                },
                vec![base],
            )
            .map_err(|e| rewrite_err(STAGE, format!("left select rejected: {e}")))?
        };
        (id, Vec::new())
    };

    // right subtree (always a plain node: compiler builds left-deep chains)
    let base_r = remap[r].ok_or_else(|| {
        rewrite_err(STAGE, format!("right join input {r} not emitted"))
    })?;
    let new_r = if right_only.is_empty() {
        base_r
    } else {
        out.add(
            OpKind::Select {
                pred: conjoin(right_only),
            },
            vec![base_r],
        )
        .map_err(|e| rewrite_err(STAGE, format!("right select rejected: {e}")))?
    };

    // leftover conjuncts from the left recursion re-enter at this level as
    // spanning-or-left — they reference left columns only, so they can be
    // applied right here above the join. Cheaper: merge into spanning.
    spanning.append(&mut leftover);
    let join_id = out
        .add(
            OpKind::Join {
                pred: conjoin(spanning),
            },
            vec![new_l, new_r],
        )
        .map_err(|e| rewrite_err(STAGE, format!("join emit rejected: {e}")))?;
    Ok((join_id, floating))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::exec::{Executor, Profiler};
    use crate::text::Document;

    const THREE_WAY: &str = "
        create view A as extract regex /a+/ on d.text as m from Document d;
        create view B as extract regex /b+/ on d.text as m from Document d;
        create view C as extract regex /c+/ on d.text as m from Document d;
        create view V as
          select a.m as am, b.m as bm, c.m as cm
          from A a, B b, C c
          where Follows(a.m, b.m, 0, 5) and Follows(b.m, c.m, 0, 5)
                and GetLength(a.m) >= 2;
        output view V;
    ";

    fn run(g: &Graph, text: &str) -> Vec<Vec<String>> {
        let ex = Executor::new(Arc::new(g.clone()), Arc::new(Profiler::disabled()));
        let doc = Document::new(0, text);
        let out = ex.run_doc(&doc);
        let mut rows: Vec<Vec<String>> = out
            .views()
            .iter()
            .flat_map(|rows| {
                rows.iter().map(|t| {
                    t.iter()
                        .map(|v| format!("{v}"))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        rows.sort();
        rows
    }

    #[test]
    fn pushdown_forms_join_predicates() {
        let g = crate::aql::compile(THREE_WAY).unwrap();
        let opt = optimize(&g);
        // no cross joins remain
        for n in &opt.nodes {
            if let OpKind::Join { pred } = &n.kind {
                assert_ne!(*pred, Expr::LitBool(true), "cross join survived:\n{}", opt.dump());
            }
        }
        // the single-source conjunct became a Select below the joins
        let has_pushed_select = opt.nodes.iter().any(|n| {
            matches!(&n.kind, OpKind::Select { .. })
                && matches!(
                    opt.nodes[n.inputs[0]].kind,
                    OpKind::RegexExtract { .. }
                )
        });
        assert!(has_pushed_select, "{}", opt.dump());
    }

    #[test]
    fn optimized_graph_is_equivalent() {
        let g = crate::aql::compile(THREE_WAY).unwrap();
        let opt = optimize(&g);
        for text in [
            "aa b c",
            "aa bb cc aa b c",
            "c b aa",
            "",
            "aaa   bbb   ccc",
            "aabbcc aab bcc",
        ] {
            assert_eq!(run(&g, text), run(&opt, text), "text {text:?}");
        }
    }

    #[test]
    fn dedup_merges_identical_regexes() {
        let g = crate::aql::compile(
            "create view A as extract regex /x+/ on d.text as m from Document d;
             create view B as extract regex /x+/ on d.text as n from Document d;
             create view U as
               (select a.m as s from A a) union all (select b.n as s from B b);
             output view U;",
        )
        .unwrap();
        assert_eq!(g.op_counts()["RegularExpression"], 2);
        let opt = optimize(&g);
        assert_eq!(opt.op_counts()["RegularExpression"], 1, "{}", opt.dump());
        // still correct
        assert_eq!(run(&opt, "xx yy xx").len(), 4); // 2 matches × 2 branches
    }

    #[test]
    fn dedup_keeps_different_patterns() {
        let g = crate::aql::compile(
            "create view A as extract regex /x+/ on d.text as m from Document d;
             create view B as extract regex /y+/ on d.text as m from Document d;
             output view A; output view B;",
        )
        .unwrap();
        let opt = optimize(&g);
        assert_eq!(opt.op_counts()["RegularExpression"], 2);
    }

    #[test]
    fn dedup_merges_same_dictionary() {
        let g = crate::aql::compile(
            "create dictionary D as ('ibm');
             create view A as extract dictionary 'D' on d.text as m from Document d;
             create view B as extract dictionary 'D' on d.text as n from Document d;
             output view A; output view B;",
        )
        .unwrap();
        assert_eq!(g.op_counts()["Dictionary"], 2);
        let opt = optimize(&g);
        assert_eq!(opt.op_counts()["Dictionary"], 1);
    }

    #[test]
    fn dedup_rename_keeps_each_views_column_names() {
        // identical scans with different output column names: one machine,
        // but each view's schema keeps ITS OWN column name (a catalog
        // query must never adopt another query's column names)
        let g = crate::aql::compile(
            "create view A as extract regex /x+/ on d.text as m from Document d;
             create view B as extract regex /x+/ on d.text as n from Document d;
             output view A; output view B;",
        )
        .unwrap();
        let opt = optimize(&g);
        assert_eq!(opt.op_counts()["RegularExpression"], 1, "{}", opt.dump());
        let (_, a) = &opt.outputs[0];
        let (_, b) = &opt.outputs[1];
        assert_eq!(opt.nodes[*a].schema.fields[0].name, "m");
        assert_eq!(opt.nodes[*b].schema.fields[0].name, "n");
    }

    #[test]
    fn dedup_is_structural_over_dictionary_entries() {
        // same entries under different names: one machine (the catalog
        // case — every query declares its own OrgDict)
        let g = crate::aql::compile(
            "create dictionary D1 as ('ibm', 'acme');
             create dictionary D2 as ('ibm', 'acme');
             create view A as extract dictionary 'D1' on d.text as m from Document d;
             create view B as extract dictionary 'D2' on d.text as n from Document d;
             output view A; output view B;",
        )
        .unwrap();
        assert_eq!(optimize(&g).op_counts()["Dictionary"], 1);

        // same name shape, different entries: must NOT merge
        let g = crate::aql::compile(
            "create dictionary D1 as ('ibm');
             create dictionary D2 as ('acme');
             create view A as extract dictionary 'D1' on d.text as m from Document d;
             create view B as extract dictionary 'D2' on d.text as n from Document d;
             output view A; output view B;",
        )
        .unwrap();
        assert_eq!(optimize(&g).op_counts()["Dictionary"], 2);
    }

    #[test]
    fn dead_views_pruned() {
        let g = crate::aql::compile(
            "create view Dead as extract regex /zzz/ on d.text as m from Document d;
             create view Live as extract regex /y/ on d.text as m from Document d;
             output view Live;",
        )
        .unwrap();
        let opt = optimize(&g);
        assert_eq!(opt.op_counts().get("RegularExpression"), Some(&1));
        assert!(opt.nodes.iter().all(|n| n.view.as_deref() != Some("Dead")));
    }

    #[test]
    fn optimize_preserves_simple_selects() {
        // single-source select: no joins, pushdown is a no-op
        let g = crate::aql::compile(
            "create view A as extract regex /[a-z]+/ on d.text as m from Document d;
             create view V as select a.m as m from A a where GetLength(a.m) > 2;
             output view V;",
        )
        .unwrap();
        let opt = optimize(&g);
        assert_eq!(run(&g, "ab abc abcd"), run(&opt, "ab abc abcd"));
    }

    #[test]
    fn floating_conjuncts_survive() {
        // predicate with no column refs must not be lost
        let g = crate::aql::compile(
            "create view A as extract regex /a/ on d.text as m from Document d;
             create view B as extract regex /b/ on d.text as m from Document d;
             create view V as select a.m as am from A a, B b
               where Follows(a.m, b.m, 0, 9) and 1 = 2;
             output view V;",
        )
        .unwrap();
        let opt = optimize(&g);
        assert!(run(&opt, "a b").is_empty());
    }
}
