//! A simple cardinality/cost model for explain output and partitioner
//! tie-breaking.
//!
//! Costs are unitless "work per document"; cardinalities are expected
//! tuples per document. Both use fixed selectivity heuristics over an
//! assumed document length — crude, but all the optimizer needs is
//! relative ordering, and all the partitioner needs is a monotone proxy
//! for "how much software time does this node account for".

use crate::aog::{Graph, OpKind};

/// Per-node cost estimate.
#[derive(Debug, Clone, Copy)]
pub struct NodeCost {
    /// Expected output tuples per document.
    pub rows: f64,
    /// Unitless work per document.
    pub cost: f64,
}

/// Whole-graph estimate.
#[derive(Debug, Clone)]
pub struct CostReport {
    /// Per-node estimates, indexed by node id.
    pub per_node: Vec<NodeCost>,
    /// Sum of per-node costs.
    pub total_cost: f64,
}

impl CostReport {
    /// Fraction of estimated cost attributable to `nodes`.
    pub fn fraction_of(&self, nodes: &[usize]) -> f64 {
        if self.total_cost <= 0.0 {
            return 0.0;
        }
        nodes.iter().map(|&i| self.per_node[i].cost).sum::<f64>() / self.total_cost
    }
}

/// Estimate costs for `g` assuming documents of `doc_len` bytes.
pub fn estimate(g: &Graph, doc_len: usize) -> CostReport {
    let n = doc_len as f64;
    let mut per_node: Vec<NodeCost> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let in_rows = |k: usize| -> f64 { per_node[node.inputs[k]].rows };
        let nc = match &node.kind {
            OpKind::DocScan => NodeCost { rows: 1.0, cost: 1.0 },
            OpKind::RegexExtract { regex, .. } => {
                // software regex cost grows with pattern complexity (the
                // anchored rescans); matches assumed sparse
                let states = regex.anchored.num_states as f64;
                NodeCost {
                    rows: (n / 120.0).max(0.5),
                    cost: n * (1.0 + states / 8.0),
                }
            }
            OpKind::DictExtract { dict, .. } => NodeCost {
                rows: (n / 150.0).max(0.5) * (1.0 + dict.entries.len() as f64 / 50.0),
                cost: n,
            },
            OpKind::Select { .. } => NodeCost {
                rows: in_rows(0) * 0.25,
                cost: in_rows(0),
            },
            OpKind::Project { cols } => NodeCost {
                rows: in_rows(0),
                cost: in_rows(0) * cols.len() as f64 * 0.5,
            },
            OpKind::Join { .. } => {
                let (l, r) = (in_rows(0), in_rows(1));
                NodeCost {
                    rows: (l * r * 0.05).max(0.1),
                    cost: l * r,
                }
            }
            OpKind::Union => {
                let rows: f64 = (0..node.inputs.len()).map(in_rows).sum();
                NodeCost { rows, cost: rows * 0.2 }
            }
            OpKind::Difference => {
                let (l, r) = (in_rows(0), in_rows(1));
                NodeCost { rows: (l - r * 0.5).max(0.1), cost: l + r }
            }
            OpKind::Block { .. } => NodeCost {
                rows: in_rows(0) * 0.3,
                cost: in_rows(0),
            },
            OpKind::Consolidate { .. } => NodeCost {
                rows: in_rows(0) * 0.7,
                cost: in_rows(0) * (in_rows(0).log2().max(1.0)),
            },
            OpKind::Sort { .. } => NodeCost {
                rows: in_rows(0),
                cost: in_rows(0) * (in_rows(0).log2().max(1.0)),
            },
            OpKind::Limit { n: k } => NodeCost {
                rows: in_rows(0).min(*k as f64),
                cost: 1.0,
            },
            OpKind::GroupAgg { .. } => NodeCost {
                // grouping collapses repeated terms; hash build is linear
                rows: (in_rows(0) * 0.3).max(0.1),
                cost: in_rows(0),
            },
            OpKind::TopK { k, .. } => NodeCost {
                rows: in_rows(0).min(*k as f64),
                cost: in_rows(0) * (in_rows(0).log2().max(1.0)),
            },
            OpKind::SubgraphExec { .. } => NodeCost {
                // accounted separately by the accelerator model
                rows: (n / 120.0).max(0.5),
                cost: 0.0,
            },
            OpKind::ExtInput { .. } => NodeCost {
                rows: (n / 120.0).max(0.5),
                cost: 0.0,
            },
        };
        per_node.push(nc);
    }
    let total_cost = per_node.iter().map(|c| c.cost).sum();
    CostReport {
        per_node,
        total_cost,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(aql: &str) -> Graph {
        crate::aql::compile(aql).unwrap()
    }

    #[test]
    fn extraction_dominates_simple_query() {
        let g = graph(
            "create view A as extract regex /[A-Z][a-z]+/ on d.text as m from Document d;
             create view V as select a.m as m from A a where GetLength(a.m) > 3;
             output view V;",
        );
        let report = estimate(&g, 2048);
        let extraction: Vec<usize> = g
            .nodes
            .iter()
            .filter(|n| n.kind.is_extraction())
            .map(|n| n.id)
            .collect();
        assert!(report.fraction_of(&extraction) > 0.9);
    }

    #[test]
    fn join_cost_scales_with_inputs() {
        let g = graph(
            "create view A as extract regex /a/ on d.text as m from Document d;
             create view B as extract regex /b/ on d.text as m from Document d;
             create view V as select a.m as am from A a, B b where Follows(a.m, b.m, 0, 9);
             output view V;",
        );
        let small = estimate(&g, 256);
        let large = estimate(&g, 8192);
        assert!(large.total_cost > small.total_cost * 10.0);
    }

    #[test]
    fn fraction_bounds() {
        let g = graph(
            "create view A as extract regex /a/ on d.text as m from Document d;
             output view A;",
        );
        let r = estimate(&g, 1024);
        let all: Vec<usize> = (0..g.nodes.len()).collect();
        assert!((r.fraction_of(&all) - 1.0).abs() < 1e-9);
        assert_eq!(r.fraction_of(&[]), 0.0);
    }

    #[test]
    fn subgraph_exec_costs_nothing_in_sw() {
        use crate::aog::{FieldType, Graph as G, OpKind, Schema};
        let mut g = G::new();
        let d = g.add(OpKind::DocScan, vec![]).unwrap();
        let s = g
            .add(
                OpKind::SubgraphExec {
                    subgraph_id: 0,
                    output_idx: 0,
                    schema: Schema::of(&[("m", FieldType::Span)]),
                },
                vec![d],
            )
            .unwrap();
        g.add_output("V", s).unwrap();
        let r = estimate(&g, 2048);
        assert_eq!(r.per_node[s].cost, 0.0);
    }
}
