//! Compile-time plan verifier: a multi-pass static analyzer over the
//! AQL → AOG → optimizer → partition → hwcompile pipeline.
//!
//! The paper's core claim is that SystemT's *compile-time* analysis of
//! AQL — deciding statically what is safe and profitable to offload — is
//! what makes hardware acceleration deployable. This module is that
//! checking layer: it turns what used to be `expect()` panics deep inside
//! graph rebuilds into stable, coded diagnostics, surfaced at build time
//! ([`crate::coordinator::CatalogBuilder::build`] runs it, strict by
//! default) and standalone through the `repro check` CLI.
//!
//! # Passes
//!
//! 1. **Schema inference & type checking** — [`check_graph`] re-derives
//!    every node's schema through [`Graph::validate_node`] and compares it
//!    against the stored schema, so operator arity/type rules hold for
//!    graphs produced by rebuilds, not just by [`Graph::add`].
//! 2. **Graph invariants** — [`check_graph`] also enforces topological
//!    ids (`nodes[i].id == i`, inputs strictly smaller — together these
//!    imply acyclicity), in-range output references, and span provenance
//!    (every node whose schema carries a `Span` column must be reachable
//!    from a `DocScan` or `ExtInput` leaf).
//! 3. **Pass verification** — [`verify_rewrite`] re-runs the invariant and
//!    schema checks after each optimizer rewrite and asserts the output
//!    views survive with identical names and schemas; [`check_plan`] does
//!    the same across the partitioner's supergraph + subgraph split.
//! 4. **Hardware-feasibility lint** — [`lint_hardware`] checks each
//!    offloaded subgraph against the compiled artifact
//!    [`GEOMETRIES`](crate::hwcompiler::GEOMETRIES) (machine count and
//!    state budget) and warns when the cost model says the offloaded
//!    fraction is too small to pay for the round-trip, *before*
//!    [`crate::hwcompiler::compile_subgraph`] can fail.
//!
//! # Diagnostic codes
//!
//! | Code | Meaning |
//! |------|---------|
//! | E001 | AQL lex error |
//! | E002 | AQL parse error |
//! | E010 | unknown view |
//! | E011 | unknown dictionary |
//! | E012 | unknown function |
//! | E013 | unknown alias |
//! | E014 | unknown column |
//! | E015 | duplicate definition |
//! | E016 | regex literal failed to compile |
//! | E017 | unsupported AQL construct |
//! | E018 | `group by` references an unknown output column |
//! | E019 | `group by` key has a non-groupable type (e.g. raw span) |
//! | E020 | `top k` with k = 0 |
//! | E021 | `score` expression is not numeric |
//! | E022 | aggregate misuse (Count/CountDocs outside `group by`, aggregate view in a per-document context, …) |
//! | E101 | non-topological or dangling node input |
//! | E102 | expression type error |
//! | E103 | operator schema mismatch (arity, incompatible inputs, non-Boolean predicate) |
//! | E104 | column index out of range |
//! | E105 | output references a node outside the graph |
//! | E106 | span column without `DocScan`/`ExtInput` provenance |
//! | E107 | span-consuming operator over a non-span column |
//! | E201 | optimizer rewrite failed structurally |
//! | E202 | rewrite changed an output view's schema |
//! | E203 | rewrite dropped or renamed an output view |
//! | E204 | partition wiring error (supergraph/subgraph split inconsistent) |
//! | E301 | more extraction machines than any artifact geometry provides |
//! | E302 | DFA state count exceeds every artifact geometry |
//! | W310 | estimated hardware fraction below the offload threshold |
//! | W311 | estimated kernel VMEM footprint exceeds the device budget |
//!
//! Codes are stable: tests and external tooling match on them, so a code
//! is never reused for a different condition.

use std::fmt;

use crate::aog::{FieldType, Graph, GraphError, OpKind};
use crate::aql::{CompileError, TokenKind};
use crate::hwcompiler::{BLOCK_SIZES, GEOMETRIES, MAX_HW_STATES, STREAMS};
use crate::optimizer::RewriteError;
use crate::partition::{partition, PartitionMode, PartitionPlan};

/// Below this estimated cost fraction, offloading a plan is unlikely to
/// pay for the package round-trip — [`lint_hardware`] warns (`W310`).
/// Deliberately low: extraction-heavy plans (the paper's workload, and
/// T1–T5) sit far above it.
pub const MIN_HW_FRACTION: f64 = 0.25;

/// Device VMEM budget the kernel working set must fit
/// ([`crate::hwcompiler::AccelConfig::vmem_estimate`]); the largest
/// shipped geometry stays under it, so `W311` fires only if the artifact
/// menu ever outgrows the device.
pub const VMEM_BUDGET_BYTES: usize = 64 * 1024 * 1024;

/// Diagnostic severity. Strict builds fail on any [`Severity::Error`];
/// warnings are reported but never block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Advisory: the plan runs, but something is off.
    Warning,
    /// The program or plan is invalid.
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// A resolved source position inside an AQL program (byte offset plus the
/// 1-based line/column and the line's text, for rustc-style rendering).
#[derive(Debug, Clone)]
pub struct SourceLoc {
    /// Byte offset into the source.
    pub byte: usize,
    /// 1-based line number.
    pub line: usize,
    /// 1-based column (in bytes).
    pub col: usize,
    /// The full text of that line.
    pub line_text: String,
}

impl SourceLoc {
    /// Resolve a byte offset against `src`.
    pub fn at(src: &str, byte: usize) -> SourceLoc {
        let byte = byte.min(src.len());
        let before = &src[..byte];
        let line = before.matches('\n').count() + 1;
        let line_start = before.rfind('\n').map(|i| i + 1).unwrap_or(0);
        let col = byte - line_start + 1;
        let line_text = src[line_start..]
            .split('\n')
            .next()
            .unwrap_or("")
            .to_string();
        SourceLoc {
            byte,
            line,
            col,
            line_text,
        }
    }
}

/// One coded diagnostic — the unit everything in this module produces.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable code (`E###` / `W###`, see the module table).
    pub code: &'static str,
    /// Error or warning.
    pub severity: Severity,
    /// Human-readable description.
    pub message: String,
    /// The catalog query this diagnostic belongs to, when known.
    pub query: Option<String>,
    /// Source position, when the diagnostic maps back to AQL text.
    pub loc: Option<SourceLoc>,
}

impl Diagnostic {
    /// A located or unlocated error with the given code.
    pub fn error(code: &'static str, message: impl Into<String>) -> Diagnostic {
        Diagnostic {
            code,
            severity: Severity::Error,
            message: message.into(),
            query: None,
            loc: None,
        }
    }

    /// A warning with the given code.
    pub fn warning(code: &'static str, message: impl Into<String>) -> Diagnostic {
        Diagnostic {
            code,
            severity: Severity::Warning,
            message: message.into(),
            query: None,
            loc: None,
        }
    }

    /// Attach the owning query's name.
    pub fn for_query(mut self, name: &str) -> Diagnostic {
        self.query = Some(name.to_string());
        self
    }

    /// Attach a source location.
    pub fn at(mut self, loc: SourceLoc) -> Diagnostic {
        self.loc = Some(loc);
        self
    }

    /// Render rustc-style: severity, code, message, then the source line
    /// with a caret when a location is known.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = write!(s, "{}[{}]: {}", self.severity, self.code, self.message);
        if let Some(q) = &self.query {
            let _ = write!(s, " (query '{q}')");
        }
        if let Some(loc) = &self.loc {
            let _ = write!(s, "\n  --> {}:{}", loc.line, loc.col);
            let _ = write!(s, "\n   | {}", loc.line_text);
            let _ = write!(s, "\n   | {}^", " ".repeat(loc.col.saturating_sub(1)));
        }
        s
    }
}

/// The result of an analysis pass: an ordered list of diagnostics.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Diagnostics in emission order.
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    /// An empty (clean) report.
    pub fn new() -> Report {
        Report::default()
    }

    /// Append one diagnostic.
    pub fn push(&mut self, d: Diagnostic) {
        self.diagnostics.push(d);
    }

    /// Append every diagnostic of `other`.
    pub fn merge(&mut self, other: Report) {
        self.diagnostics.extend(other.diagnostics);
    }

    /// True when no diagnostics at all were produced.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// True when at least one [`Severity::Error`] diagnostic is present.
    pub fn has_errors(&self) -> bool {
        self.diagnostics
            .iter()
            .any(|d| d.severity == Severity::Error)
    }

    /// True when a diagnostic with this code is present.
    pub fn has_code(&self, code: &str) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }

    /// Render all diagnostics, one block per line group.
    pub fn render(&self) -> String {
        self.diagnostics
            .iter()
            .map(Diagnostic::render)
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Map a [`GraphError`] to its stable diagnostic code.
pub fn graph_error_code(e: &GraphError) -> &'static str {
    match e {
        GraphError::BadInput { .. } => "E101",
        GraphError::Type { .. } => "E102",
        GraphError::SchemaMismatch { .. } => "E103",
        GraphError::BadColumn { .. } => "E104",
        GraphError::DanglingOutput { .. } => "E105",
        GraphError::SpanRequired { .. } => "E107",
    }
}

/// Map a [`CompileError`] to its stable diagnostic code.
pub fn compile_error_code(e: &CompileError) -> &'static str {
    match e {
        CompileError::Lex(_) => "E001",
        CompileError::Parse(_) => "E002",
        CompileError::UnknownView(_) => "E010",
        CompileError::UnknownDictionary(_) => "E011",
        CompileError::UnknownFunction(_) => "E012",
        CompileError::UnknownAlias(_) => "E013",
        CompileError::UnknownColumn { .. } => "E014",
        CompileError::DuplicateName(_) => "E015",
        CompileError::Regex(_) => "E016",
        CompileError::Graph(ge) => graph_error_code(ge),
        CompileError::Unsupported(_) => "E017",
        CompileError::GroupByUnknownColumn(_) => "E018",
        CompileError::GroupByBadType { .. } => "E019",
        CompileError::TopKZero => "E020",
        CompileError::ScoreNotNumeric(_) => "E021",
        CompileError::AggregateContext(_) => "E022",
    }
}

/// Best-effort source location for a semantic compile error: the AST does
/// not carry positions, so re-lex the source and point at the first
/// identifier or string literal that names the offending entity.
fn locate_name(src: &str, name: &str) -> Option<SourceLoc> {
    let tokens = crate::aql::lex(src).ok()?;
    tokens
        .iter()
        .find(|t| match &t.kind {
            TokenKind::Ident(s) | TokenKind::Str(s) => s == name,
            _ => false,
        })
        .map(|t| SourceLoc::at(src, t.pos))
}

/// Turn a [`CompileError`] for query `name` over `src` into a located
/// diagnostic.
pub fn diagnostic_from_compile(name: &str, src: &str, e: &CompileError) -> Diagnostic {
    let d = Diagnostic::error(compile_error_code(e), e.to_string()).for_query(name);
    let loc = match e {
        CompileError::UnknownView(n)
        | CompileError::UnknownDictionary(n)
        | CompileError::UnknownFunction(n)
        | CompileError::UnknownAlias(n)
        | CompileError::DuplicateName(n) => locate_name(src, n),
        CompileError::UnknownColumn { col, .. } => locate_name(src, col),
        CompileError::GroupByUnknownColumn(col)
        | CompileError::GroupByBadType { col, .. } => locate_name(src, col),
        _ => None,
    };
    match loc {
        Some(l) => d.at(l),
        None => d,
    }
}

/// Turn a [`RewriteError`] into an `E201` diagnostic.
pub fn diagnostic_from_rewrite(e: &RewriteError) -> Diagnostic {
    Diagnostic::error("E201", e.to_string())
}

/// Lex + parse + compile `src`, producing either the graph or a report
/// with located `E0##` diagnostics — the compile-front entry the
/// `repro check` CLI and the engine builder share.
pub fn check_source(name: &str, src: &str) -> Result<Graph, Report> {
    let mut report = Report::new();
    let tokens = match crate::aql::lex(src) {
        Ok(t) => t,
        Err(e) => {
            report.push(
                Diagnostic::error("E001", e.to_string())
                    .for_query(name)
                    .at(SourceLoc::at(src, e.pos)),
            );
            return Err(report);
        }
    };
    let program = match crate::aql::parse_program(&tokens) {
        Ok(p) => p,
        Err(e) => {
            report.push(
                Diagnostic::error("E002", e.to_string())
                    .for_query(name)
                    .at(SourceLoc::at(src, e.pos)),
            );
            return Err(report);
        }
    };
    match crate::aql::compile_program(&program) {
        Ok(g) => Ok(g),
        Err(e) => {
            report.push(diagnostic_from_compile(name, src, &e));
            Err(report)
        }
    }
}

/// Passes 1–2: schema re-derivation plus structural invariants over one
/// graph. Clean on every graph [`Graph::add`] built — its value is
/// checking graphs produced by *rebuilds* (merge, optimizer, partitioner),
/// where a wiring bug historically died as an `expect()` panic mid-query.
pub fn check_graph(g: &Graph) -> Report {
    let mut report = Report::new();
    // ids must be their own index and inputs strictly smaller: together
    // this is the topological invariant, which implies acyclicity.
    for (i, n) in g.nodes.iter().enumerate() {
        if n.id != i {
            report.push(Diagnostic::error(
                "E101",
                format!("node at index {i} carries id {} (ids must be dense)", n.id),
            ));
            // downstream checks index by id; bail before they misfire
            return report;
        }
        for &inp in &n.inputs {
            if inp >= i {
                report.push(Diagnostic::error(
                    "E101",
                    format!(
                        "node {i} ({}): input {inp} is not an earlier node",
                        n.kind.name()
                    ),
                ));
            }
        }
    }
    // schema re-derivation: every operator rule must hold for the stored
    // wiring, and the stored schema must equal the derived one.
    for n in &g.nodes {
        if n.inputs.iter().any(|&i| i >= n.id) {
            continue; // already reported above; validate_node would panic
        }
        match g.validate_node(n.id) {
            Ok(derived) => {
                if derived != n.schema {
                    report.push(Diagnostic::error(
                        "E103",
                        format!(
                            "node {} ({}): stored schema {} differs from derived {}",
                            n.id,
                            n.kind.name(),
                            n.schema,
                            derived
                        ),
                    ));
                }
            }
            Err(e) => report.push(Diagnostic::error(graph_error_code(&e), e.to_string())),
        }
    }
    // outputs must reference in-range nodes
    for (name, target) in &g.outputs {
        if *target >= g.nodes.len() {
            report.push(Diagnostic::error(
                "E105",
                format!(
                    "output '{name}' references node {target}, but the graph has {} nodes",
                    g.nodes.len()
                ),
            ));
        }
    }
    // span provenance: a span column means document text flows through
    // this node, so it must be reachable from a DocScan (or an ExtInput,
    // which injects tuples the supergraph already traced).
    let mut doc_derived = vec![false; g.nodes.len()];
    for n in &g.nodes {
        doc_derived[n.id] = matches!(n.kind, OpKind::DocScan | OpKind::ExtInput { .. })
            || n.inputs.iter().any(|&i| doc_derived[i]);
        let has_span = n.schema.fields.iter().any(|f| f.ty == FieldType::Span);
        if has_span && !doc_derived[n.id] {
            report.push(Diagnostic::error(
                "E106",
                format!(
                    "node {} ({}): span column without DocScan/ExtInput provenance",
                    n.id,
                    n.kind.name()
                ),
            ));
        }
    }
    report
}

/// Pass 3: verify one rewrite step. Checks the rewritten graph's
/// invariants and asserts every output view of `before` survives in
/// `after` with the same name (E203) and schema (E202). `stage` labels
/// the rewrite in messages (`"dedup"`, `"pushdown"`, `"prune"`, ...).
pub fn verify_rewrite(stage: &str, before: &Graph, after: &Graph) -> Report {
    let mut report = check_graph(after);
    if before.outputs.len() != after.outputs.len() {
        report.push(Diagnostic::error(
            "E203",
            format!(
                "rewrite '{stage}' changed the output count: {} -> {}",
                before.outputs.len(),
                after.outputs.len()
            ),
        ));
        return report;
    }
    for ((bn, bt), (an, at)) in before.outputs.iter().zip(&after.outputs) {
        if bn != an {
            report.push(Diagnostic::error(
                "E203",
                format!("rewrite '{stage}' renamed output '{bn}' to '{an}'"),
            ));
            continue;
        }
        let (bs, as_) = match (before.nodes.get(*bt), after.nodes.get(*at)) {
            (Some(b), Some(a)) => (&b.schema, &a.schema),
            _ => continue, // dangling targets already reported as E105
        };
        if bs != as_ {
            report.push(Diagnostic::error(
                "E202",
                format!(
                    "rewrite '{stage}' changed the schema of output '{bn}': {bs} -> {as_}"
                ),
            ));
        }
    }
    report
}

/// Pass 3, partition edition: verify the supergraph + subgraph split
/// against the graph it was derived from. Structural problems in the
/// split itself are `E204`; the component graphs are also re-checked
/// (passes 1–2).
pub fn check_plan(g: &Graph, plan: &PartitionPlan) -> Report {
    let mut report = verify_rewrite("partition", g, &plan.supergraph);
    for spec in &plan.subgraphs {
        let body_report = check_graph(&spec.body);
        report.merge(body_report);
        if spec.outputs.len() != spec.body.outputs.len() {
            report.push(Diagnostic::error(
                "E204",
                format!(
                    "subgraph {}: {} output ids but {} registered body outputs",
                    spec.id,
                    spec.outputs.len(),
                    spec.body.outputs.len()
                ),
            ));
        }
        for (k, &out) in spec.outputs.iter().enumerate() {
            if out >= spec.body.nodes.len() {
                report.push(Diagnostic::error(
                    "E204",
                    format!(
                        "subgraph {}: output {k} references body node {out} of {}",
                        spec.id,
                        spec.body.nodes.len()
                    ),
                ));
            }
        }
        // ExtInput slots must be dense in [0, ext_inputs)
        for n in &spec.body.nodes {
            if let OpKind::ExtInput { slot, .. } = &n.kind {
                if *slot >= spec.ext_inputs {
                    report.push(Diagnostic::error(
                        "E204",
                        format!(
                            "subgraph {}: ExtInput slot {slot} outside declared {} slots",
                            spec.id, spec.ext_inputs
                        ),
                    ));
                }
            }
        }
    }
    // every SubgraphExec in the supergraph must point at a real subgraph
    // output and carry that output's schema
    for n in &plan.supergraph.nodes {
        if let OpKind::SubgraphExec {
            subgraph_id,
            output_idx,
            schema,
        } = &n.kind
        {
            let Some(spec) = plan.subgraphs.get(*subgraph_id) else {
                report.push(Diagnostic::error(
                    "E204",
                    format!(
                        "supergraph node {}: SubgraphExec references subgraph {subgraph_id} of {}",
                        n.id,
                        plan.subgraphs.len()
                    ),
                ));
                continue;
            };
            let Some(&body_out) = spec.outputs.get(*output_idx) else {
                report.push(Diagnostic::error(
                    "E204",
                    format!(
                        "supergraph node {}: SubgraphExec output {output_idx} outside subgraph {}'s {} outputs",
                        n.id,
                        subgraph_id,
                        spec.outputs.len()
                    ),
                ));
                continue;
            };
            if let Some(body_node) = spec.body.nodes.get(body_out) {
                if &body_node.schema != schema {
                    report.push(Diagnostic::error(
                        "E204",
                        format!(
                            "supergraph node {}: SubgraphExec schema {} differs from subgraph {}.{}'s {}",
                            n.id, schema, subgraph_id, output_idx, body_node.schema
                        ),
                    ));
                }
            }
        }
    }
    report
}

/// Pass 4: hardware-feasibility lint. Mirrors the geometry selection of
/// [`crate::hwcompiler::compile_subgraph`] — machine count and DFA state
/// budget against the artifact menu — *without* its expensive randomized
/// semantics validation, and adds the cost model's estimated hardware
/// fraction so an unprofitable offload warns before any compile fails.
/// `g` is the (optimized) graph the plan was derived from; `doc_len` the
/// assumed document size for the cost model.
pub fn lint_hardware(g: &Graph, plan: &PartitionPlan, doc_len: usize) -> Report {
    let mut report = Report::new();
    if plan.mode == PartitionMode::None {
        return report;
    }
    for spec in &plan.subgraphs {
        let mut machines = 0usize;
        let mut max_states = 2usize;
        for n in &spec.body.nodes {
            match &n.kind {
                OpKind::RegexExtract { regex, .. } => {
                    machines += 1;
                    max_states = max_states.max(regex.search.num_states as usize);
                }
                OpKind::DictExtract { matcher, .. } => {
                    machines += 1;
                    max_states = max_states.max(matcher.num_states as usize);
                }
                _ => {}
            }
        }
        let geometry = GEOMETRIES
            .iter()
            .copied()
            .filter(|&(m, s)| m >= machines.max(1) && s >= max_states)
            .min_by_key(|&(m, s)| m * s);
        match geometry {
            None if max_states > MAX_HW_STATES => report.push(Diagnostic::error(
                "E302",
                format!(
                    "subgraph {}: {max_states} DFA states exceed every artifact geometry (max {MAX_HW_STATES})",
                    spec.id
                ),
            )),
            None => report.push(Diagnostic::error(
                "E301",
                format!(
                    "subgraph {}: {machines} extraction machines exceed every artifact geometry (max {})",
                    spec.id,
                    GEOMETRIES.iter().map(|&(m, _)| m).max().unwrap_or(0)
                ),
            )),
            Some((m, s)) => {
                // VMEM working-set estimate at the chosen geometry for the
                // largest block size (AccelConfig::vmem_estimate's formula)
                let block = BLOCK_SIZES.iter().copied().max().unwrap_or(0);
                let vmem = m * s * 256 * 4 + m * s * 4 + STREAMS * block * 4 + m * STREAMS * block * 4;
                if vmem > VMEM_BUDGET_BYTES {
                    report.push(Diagnostic::warning(
                        "W311",
                        format!(
                            "subgraph {}: estimated VMEM working set {} MiB exceeds the {} MiB device budget at geometry ({m}, {s})",
                            spec.id,
                            vmem >> 20,
                            VMEM_BUDGET_BYTES >> 20
                        ),
                    ));
                }
            }
        }
    }
    // profitability: the fraction of estimated software cost the plan
    // offloads, summed over all subgraphs
    if !plan.subgraphs.is_empty() {
        let cost = crate::optimizer::estimate(g, doc_len);
        let frac: f64 = plan
            .subgraphs
            .iter()
            .map(|s| cost.fraction_of(&s.orig_nodes))
            .sum();
        if frac < MIN_HW_FRACTION {
            report.push(Diagnostic::warning(
                "W310",
                format!(
                    "estimated hardware fraction {frac:.2} is below the {MIN_HW_FRACTION} offload threshold — the round-trip likely costs more than it saves"
                ),
            ));
        }
    }
    report
}

/// Full-pipeline check of one AQL program: compile front (E0##), graph
/// invariants (E1##), every optimizer rewrite stage (E2##), the partition
/// split (E204), and the hardware lint (E3##/W3##) under `mode` and an
/// assumed `doc_len`. This is what `repro check` runs per query.
pub fn check_query(name: &str, src: &str, mode: PartitionMode, doc_len: usize) -> Report {
    let g = match check_source(name, src) {
        Ok(g) => g,
        Err(report) => return report,
    };
    let mut report = check_graph(&g);
    if report.has_errors() {
        return report;
    }
    // optimizer stages, each verified before the next runs
    let mut cur = g;
    type Stage = (
        &'static str,
        fn(&Graph) -> Result<Graph, RewriteError>,
    );
    let stages: [Stage; 3] = [
        ("dedup", crate::optimizer::try_dedup_extractions),
        ("pushdown", crate::optimizer::try_push_predicates),
        ("prune", crate::optimizer::try_prune_dead),
    ];
    for (stage, run) in stages {
        match run(&cur) {
            Ok(next) => {
                report.merge(verify_rewrite(stage, &cur, &next));
                cur = next;
            }
            Err(e) => {
                report.push(diagnostic_from_rewrite(&e).for_query(name));
                return report;
            }
        }
        if report.has_errors() {
            return report;
        }
    }
    // partition + hardware lint
    let plan = partition(&cur, mode);
    report.merge(check_plan(&cur, &plan));
    if report.has_errors() {
        return report;
    }
    report.merge(lint_hardware(&cur, &plan, doc_len));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    const CLEAN: &str = "
        create view Person as extract regex /[A-Z][a-z]+/ on d.text as name from Document d;
        output view Person;
    ";

    #[test]
    fn clean_program_is_clean() {
        let r = check_query("q", CLEAN, PartitionMode::ExtractOnly, 2048);
        assert!(r.is_clean(), "{}", r.render());
    }

    #[test]
    fn lex_error_is_e001_with_location() {
        let r = check_query("q", "create view V as @@;", PartitionMode::None, 2048);
        assert!(r.has_code("E001"), "{}", r.render());
        let d = &r.diagnostics[0];
        assert!(d.loc.is_some());
        assert!(d.render().contains("E001"));
    }

    #[test]
    fn unknown_view_is_e010_and_points_at_the_name() {
        let r = check_query("q", "output view Nope;", PartitionMode::None, 2048);
        assert!(r.has_code("E010"), "{}", r.render());
        let loc = r.diagnostics[0].loc.as_ref().expect("located");
        assert_eq!(loc.line, 1);
        assert!(loc.line_text.contains("Nope"));
    }

    #[test]
    fn check_graph_catches_corrupt_rebuild() {
        let mut g = crate::aql::compile(CLEAN).unwrap();
        assert!(check_graph(&g).is_clean());
        // simulate a buggy rebuild: dangling output
        g.outputs.push(("Ghost".into(), 99));
        let r = check_graph(&g);
        assert!(r.has_code("E105"), "{}", r.render());
    }

    #[test]
    fn verify_rewrite_catches_dropped_output() {
        let g = crate::aql::compile(CLEAN).unwrap();
        let mut after = g.clone();
        after.outputs.clear();
        let r = verify_rewrite("test", &g, &after);
        assert!(r.has_code("E203"), "{}", r.render());
    }

    #[test]
    fn verify_rewrite_catches_schema_change() {
        let g = crate::aql::compile(
            "create view A as extract regex /a/ on d.text as m from Document d;
             create view B as select a.m as m from A a;
             output view A; output view B;",
        )
        .unwrap();
        let mut after = g.clone();
        // point output 1 at a node with a different schema (the DocScan)
        after.outputs[1].1 = 0;
        let r = verify_rewrite("test", &g, &after);
        assert!(r.has_code("E202"), "{}", r.render());
    }

    #[test]
    fn source_loc_resolves_lines_and_columns() {
        let src = "abc\ndef\nghi";
        let l = SourceLoc::at(src, 5);
        assert_eq!((l.line, l.col), (2, 2));
        assert_eq!(l.line_text, "def");
        // clamped past the end
        let l = SourceLoc::at(src, 500);
        assert_eq!(l.line, 3);
    }

    #[test]
    fn severity_gating() {
        let mut r = Report::new();
        r.push(Diagnostic::warning("W310", "x"));
        assert!(!r.has_errors());
        assert!(!r.is_clean());
        r.push(Diagnostic::error("E101", "y"));
        assert!(r.has_errors());
    }
}
