//! Work-package packing: combine documents into the accelerator's
//! four-stream byte block (paper §3: "the communication thread collects
//! the data submitted by some of the worker threads and generates a larger
//! combined work package").
//!
//! Documents are placed contiguously in a stream, separated by NUL bytes —
//! the byte every transition table maps back to START, so no match can
//! cross a document boundary. Stream padding is also NUL.

use crate::exec::batch::{recycle_block, take_block};
use crate::hwcompiler::STREAMS;
use crate::text::Document;

/// Where one document landed in a package.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DocSlot {
    /// Index into the caller's submission list.
    pub doc_index: usize,
    /// Which of the [`STREAMS`] byte streams holds the document.
    pub stream: usize,
    /// Byte offset within the stream.
    pub offset: usize,
    /// Document length in bytes.
    pub len: usize,
}

/// One packed work package.
#[derive(Debug, Clone)]
pub struct WorkPackage {
    /// `STREAMS × block` int32 byte values, row-major.
    pub bytes: Vec<i32>,
    /// Bytes per stream.
    pub block: usize,
    /// Slots in placement order.
    pub slots: Vec<DocSlot>,
}

impl WorkPackage {
    /// Which slot (index into [`WorkPackage::slots`]) covers byte
    /// `(stream, pos)`, if any.
    pub fn slot_at(&self, stream: usize, pos: usize) -> Option<usize> {
        self.slots
            .iter()
            .position(|s| s.stream == stream && pos >= s.offset && pos < s.offset + s.len)
    }

    /// Total payload bytes (excluding separators/padding).
    pub fn payload(&self) -> usize {
        self.slots.iter().map(|s| s.len).sum()
    }
}

/// Per-stream sorted offset index over one package's slots — the
/// O(log slots) replacement for [`WorkPackage::slot_at`]'s linear scan.
///
/// The post-stage resolves every returned hit event back to its document;
/// doing that with the linear scan is O(events × slots), quadratic on
/// hit-dense packages. [`pack_group`] appends slots per stream at strictly
/// increasing offsets (each placement advances the stream cursor by at
/// least one), so a per-stream `(offset, len, slot)` list is sorted and a
/// binary search answers each lookup.
#[derive(Debug)]
pub struct SlotIndex {
    /// `(offset, len, slot index)` per stream, sorted by offset.
    streams: [Vec<(usize, usize, usize)>; STREAMS],
}

impl SlotIndex {
    /// Build the index for `wp` — once per package, before the hit loop.
    pub fn new(wp: &WorkPackage) -> SlotIndex {
        let mut streams: [Vec<(usize, usize, usize)>; STREAMS] =
            std::array::from_fn(|_| Vec::new());
        for (i, s) in wp.slots.iter().enumerate() {
            if s.stream < STREAMS {
                streams[s.stream].push((s.offset, s.len, i));
            }
        }
        for list in &mut streams {
            // already monotone for pack_group output; sort defensively so
            // hand-built packages index correctly too
            list.sort_unstable();
        }
        SlotIndex { streams }
    }

    /// Which slot covers byte `(stream, pos)` — identical answers to
    /// [`WorkPackage::slot_at`] (slot ranges never overlap: the stream
    /// cursor moves past each document plus its separator).
    pub fn slot_at(&self, stream: usize, pos: usize) -> Option<usize> {
        let list = self.streams.get(stream)?;
        // the last slot starting at or before `pos` is the only candidate
        let i = list.partition_point(|&(off, _, _)| off <= pos);
        let &(off, len, slot) = &list[i.checked_sub(1)?];
        if pos < off + len {
            Some(slot)
        } else {
            None
        }
    }
}

/// Pack documents (in order) into as few packages as possible.
/// Returns the packages plus the indices of documents too large for a
/// single stream (those are not packed; the caller must fail them).
///
/// Byte blocks come from the arena's block pool
/// ([`crate::exec::batch::take_block`], zeroed on checkout) and the
/// consumer returns each package's block via
/// [`crate::exec::batch::recycle_block`] once the scan is done, so
/// steady-state package assembly allocates nothing.
pub fn pack_group(docs: &[&Document], block: usize) -> (Vec<WorkPackage>, Vec<usize>) {
    let mut packages = Vec::new();
    let mut oversized = Vec::new();

    let mut bytes = take_block(STREAMS * block);
    let mut cursors = [0usize; STREAMS];
    let mut slots: Vec<DocSlot> = Vec::new();

    let flush = |bytes: &mut Vec<i32>,
                 cursors: &mut [usize; STREAMS],
                 slots: &mut Vec<DocSlot>,
                 packages: &mut Vec<WorkPackage>| {
        if !slots.is_empty() {
            packages.push(WorkPackage {
                bytes: std::mem::replace(bytes, take_block(STREAMS * block)),
                block,
                slots: std::mem::take(slots),
            });
            *cursors = [0; STREAMS];
        }
    };

    for (di, doc) in docs.iter().enumerate() {
        let len = doc.len();
        if len > block {
            oversized.push(di);
            continue;
        }
        // choose the emptiest stream that fits
        let candidate = (0..STREAMS)
            .filter(|&s| cursors[s] + len <= block)
            .min_by_key(|&s| cursors[s]);
        let stream = match candidate {
            Some(s) => s,
            None => {
                flush(&mut bytes, &mut cursors, &mut slots, &mut packages);
                0
            }
        };
        let offset = cursors[stream];
        for (i, b) in doc.text.bytes().enumerate() {
            bytes[stream * block + offset + i] = b as i32;
        }
        slots.push(DocSlot {
            doc_index: di,
            stream,
            offset,
            len,
        });
        // +1 for the NUL separator (implicit: buffer is zero-initialized)
        cursors[stream] = (offset + len + 1).min(block);
    }
    flush(&mut bytes, &mut cursors, &mut slots, &mut packages);
    // the final replacement block (or the original, when nothing was
    // packed) goes straight back — losing it here would leak one pooled
    // block per combining round and re-introduce a steady-state alloc
    recycle_block(bytes);
    (packages, oversized)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn docs(texts: &[&str]) -> Vec<Document> {
        texts
            .iter()
            .enumerate()
            .map(|(i, t)| Document::new(i as u64, *t))
            .collect()
    }

    #[test]
    fn single_doc_single_package() {
        let ds = docs(&["hello"]);
        let refs: Vec<&Document> = ds.iter().collect();
        let (pkgs, over) = pack_group(&refs, 64);
        assert!(over.is_empty());
        assert_eq!(pkgs.len(), 1);
        assert_eq!(pkgs[0].slots.len(), 1);
        assert_eq!(pkgs[0].payload(), 5);
        // bytes in stream 0
        let b = &pkgs[0].bytes;
        assert_eq!(b[0], 'h' as i32);
        assert_eq!(b[4], 'o' as i32);
        assert_eq!(b[5], 0); // separator/padding
    }

    #[test]
    fn spreads_across_streams() {
        let ds = docs(&["aaaa", "bbbb", "cccc", "dddd", "eeee"]);
        let refs: Vec<&Document> = ds.iter().collect();
        let (pkgs, _) = pack_group(&refs, 64);
        assert_eq!(pkgs.len(), 1);
        let streams: Vec<usize> = pkgs[0].slots.iter().map(|s| s.stream).collect();
        // all four streams are used; the fifth doc doubles up somewhere
        let mut sorted = streams.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
        // the doubled stream has the second doc after a separator
        let fifth = pkgs[0].slots[4];
        assert_eq!(fifth.offset, 5); // 4 bytes + 1 separator
    }

    #[test]
    fn separator_between_docs_in_stream() {
        let ds = docs(&["ab", "cd", "ef", "gh", "ij"]);
        let refs: Vec<&Document> = ds.iter().collect();
        let (pkgs, _) = pack_group(&refs, 16);
        let wp = &pkgs[0];
        let fifth = wp.slots[4];
        // byte before the fifth doc is NUL
        assert_eq!(wp.bytes[fifth.stream * 16 + fifth.offset - 1], 0);
    }

    #[test]
    fn overflow_starts_new_package() {
        // 4 streams × 8 bytes; docs of 6 bytes each + separator → 1/stream
        let texts: Vec<String> = (0..6).map(|i| format!("doc{i}xx")).collect();
        let ds: Vec<Document> = texts
            .iter()
            .enumerate()
            .map(|(i, t)| Document::new(i as u64, t.as_str()))
            .collect();
        let refs: Vec<&Document> = ds.iter().collect();
        let (pkgs, over) = pack_group(&refs, 8);
        assert!(over.is_empty());
        assert_eq!(pkgs.len(), 2);
        assert_eq!(pkgs[0].slots.len(), 4);
        assert_eq!(pkgs[1].slots.len(), 2);
        // doc_index mapping survives the split
        assert_eq!(pkgs[1].slots[0].doc_index, 4);
    }

    #[test]
    fn oversized_reported_not_packed() {
        let big = "x".repeat(100);
        let ds = vec![Document::new(0, "ok"), Document::new(1, big.as_str())];
        let refs: Vec<&Document> = ds.iter().collect();
        let (pkgs, over) = pack_group(&refs, 64);
        assert_eq!(over, vec![1]);
        assert_eq!(pkgs.len(), 1);
        assert_eq!(pkgs[0].slots.len(), 1);
    }

    #[test]
    fn exact_fit_no_separator_needed() {
        let t = "x".repeat(8);
        let ds = vec![Document::new(0, t.as_str())];
        let refs: Vec<&Document> = ds.iter().collect();
        let (pkgs, over) = pack_group(&refs, 8);
        assert!(over.is_empty());
        assert_eq!(pkgs[0].slots[0].len, 8);
    }

    #[test]
    fn slot_at_lookup() {
        let ds = docs(&["aaa", "bbb"]);
        let refs: Vec<&Document> = ds.iter().collect();
        let (pkgs, _) = pack_group(&refs, 64);
        let wp = &pkgs[0];
        let s0 = wp.slots[0];
        assert_eq!(wp.slot_at(s0.stream, s0.offset), Some(0));
        assert_eq!(wp.slot_at(s0.stream, s0.offset + 2), Some(0));
        assert_eq!(wp.slot_at(s0.stream, s0.offset + 3), None); // separator
    }

    #[test]
    fn slot_index_matches_linear_scan_on_dense_multi_doc_package() {
        // many short docs → several docs per stream, some empty, so the
        // index sees stacked offsets, zero-length slots, and separators
        let texts: Vec<String> = (0..12)
            .map(|i| "abcdefg"[..i % 5].to_string())
            .collect();
        let ds: Vec<Document> = texts
            .iter()
            .enumerate()
            .map(|(i, t)| Document::new(i as u64, t.as_str()))
            .collect();
        let refs: Vec<&Document> = ds.iter().collect();
        let (pkgs, over) = pack_group(&refs, 8);
        assert!(over.is_empty());
        assert!(!pkgs.is_empty());
        assert!(
            pkgs.iter().any(|wp| wp.slots.iter().any(|s| s.offset > 0)),
            "precondition: at least one stream holds multiple docs"
        );
        for wp in &pkgs {
            let idx = SlotIndex::new(wp);
            for stream in 0..STREAMS {
                for pos in 0..wp.block {
                    assert_eq!(
                        idx.slot_at(stream, pos),
                        wp.slot_at(stream, pos),
                        "attribution diverged at stream {stream} pos {pos}"
                    );
                }
            }
            // out-of-range stream answers None on both paths
            assert_eq!(idx.slot_at(STREAMS, 0), None);
            assert_eq!(wp.slot_at(STREAMS, 0), None);
        }
    }

    #[test]
    fn empty_doc_gets_slot() {
        let ds = docs(&["", "ab"]);
        let refs: Vec<&Document> = ds.iter().collect();
        let (pkgs, over) = pack_group(&refs, 16);
        assert!(over.is_empty());
        assert_eq!(pkgs[0].slots.len(), 2);
        assert_eq!(pkgs[0].slots[0].len, 0);
    }

    #[test]
    fn prop_packing_preserves_every_byte() {
        use crate::util::{prop, Prng};
        prop::check(
            808,
            100,
            |r: &mut Prng| {
                let n = r.range(1, 12);
                (0..n)
                    .map(|_| {
                        let len = r.below(30);
                        r.string_over(b"abcxyz ", len)
                    })
                    .collect::<Vec<String>>()
            },
            |texts| {
                let ds: Vec<Document> = texts
                    .iter()
                    .enumerate()
                    .map(|(i, t)| Document::new(i as u64, t.as_str()))
                    .collect();
                let refs: Vec<&Document> = ds.iter().collect();
                let (pkgs, over) = pack_group(&refs, 32);
                if !over.is_empty() {
                    return false; // nothing here exceeds 32 bytes? lens<30 ok
                }
                // every doc appears exactly once with its bytes intact
                let mut seen = vec![false; ds.len()];
                for wp in &pkgs {
                    for slot in &wp.slots {
                        if seen[slot.doc_index] {
                            return false;
                        }
                        seen[slot.doc_index] = true;
                        let d = &ds[slot.doc_index];
                        for (i, b) in d.text.bytes().enumerate() {
                            if wp.bytes[slot.stream * wp.block + slot.offset + i]
                                != b as i32
                            {
                                return false;
                            }
                        }
                        // byte after doc (if inside block) is NUL or next
                        // doc starts later — check separation from the next
                        // slot in the same stream
                    }
                }
                seen.iter().all(|&s| s)
            },
        );
    }
}
