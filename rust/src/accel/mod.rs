//! The multi-threaded HW/SW communication interface (paper Fig 3).
//!
//! SystemT worker threads execute the supergraph document-per-thread; when
//! one reaches a `SubgraphExec` operator it *submits* the document to the
//! dedicated **communication thread** and sleeps on a reply channel. The
//! communication thread drains pending submissions, combines them into a
//! **work package** (four parallel byte streams, documents separated by
//! NUL, per-document records), ships the package to the accelerator
//! ([`crate::runtime::PackageEngine`] — the PJRT-executed Pallas kernel),
//! reconstructs spans from the returned hit stream, evaluates the
//! subgraph's relational body, and wakes the workers whose documents
//! completed — exactly the paper's "status register + wake up the software
//! threads that belong to this work package" protocol.
//!
//! Submissions travel through the same bounded-queue machinery
//! ([`crate::runtime::queue`]) that feeds [`Session`] worker pools, so the
//! HW and SW paths share one scheduler primitive: when the communication
//! thread falls behind, `submit` blocks the worker (backpressure) instead
//! of buffering unboundedly.
//!
//! ## Buffer ownership across the HW/SW boundary
//!
//! Every tuple stream crossing this interface is a [`TupleBatch`] whose
//! column buffers are stamped with their origin arena shard
//! ([`crate::exec::batch::ArenaId`]): submissions carry worker-origin
//! batches that the communication thread drops after the relational body
//! runs (routed home to the worker's shard), and replies carry
//! comm-origin batches that workers clone out of and release (routed home
//! to the communication shard — the thread pins [`ArenaId::comm`] at
//! start-up). The per-(doc, subgraph) reply cache evicts an entry as soon
//! as its last output is consumed, so reply buffers go home *within* the
//! document that produced them and the accelerated route serves a warm
//! document with **zero fresh arena allocations** — the same steady state
//! as the software path (asserted in `rust/tests/columnar.rs`).
//!
//! [`ArenaId::comm`]: crate::exec::batch::ArenaId::comm
//! [`Session`]: crate::coordinator::Session

pub mod packing;

pub use packing::{pack_group, DocSlot, WorkPackage};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::aog::{Schema, Tuple};
use crate::exec::{Executor, Profiler, SubgraphRunner, TupleBatch};
use crate::hwcompiler::{AccelConfig, MatcherRef, BLOCK_SIZES};
use crate::metrics::{AccelMetrics, QueueSnapshot, QueueStats};
use crate::partition::PartitionPlan;
use crate::perfmodel::FpgaModel;
use crate::runtime::queue::{self, QueueRx, QueueTx};
use crate::runtime::{EngineSpec, PackageEngine, PackedPackage};
use crate::text::{Document, TokenIndex};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct AccelOptions {
    /// Maximum bytes per stream per package; must be one of
    /// [`crate::hwcompiler::BLOCK_SIZES`].
    pub block: usize,
    /// Pick the smallest compiled block variant that fits each batch
    /// (§Perf L3: small batches then scan a 4 KiB block instead of
    /// 16 KiB). Disable to pin `block` for experiments.
    pub adaptive_block: bool,
    /// Dispatch as soon as this many payload bytes are pending (the
    /// paper's ">1000 bytes" combining rule). The queue also flushes when
    /// it drains, so latency stays bounded.
    pub combine_min_bytes: usize,
    /// Bounded depth of the submission queue feeding the communication
    /// thread. When it fills (the accelerator falls behind), `submit`
    /// blocks the calling worker — the same backpressure rule as a
    /// [`Session`](crate::coordinator::Session) ingress queue.
    pub queue_depth: usize,
    /// Timing model used for the modeled-throughput metrics.
    pub model: FpgaModel,
}

impl Default for AccelOptions {
    fn default() -> Self {
        AccelOptions {
            block: 16384,
            adaptive_block: true,
            combine_min_bytes: 1000,
            queue_depth: 256,
            model: FpgaModel::paper(),
        }
    }
}

/// One queued request. External tuple streams travel columnar end to end
/// ([`TupleBatch`]), so the communication thread never touches row-shaped
/// tuples.
struct Submission {
    subgraph_id: usize,
    doc: Document,
    tokens: Arc<TokenIndex>,
    ext: Vec<TupleBatch>,
    reply: Sender<Result<Arc<Vec<TupleBatch>>, String>>,
}

/// A subgraph's pre-packed state, built once at service start.
struct Prepared {
    config: AccelConfig,
    tables: Arc<Vec<i32>>,
    accepts: Arc<Vec<i32>>,
    body_exec: Executor,
}

/// The accelerator service: owns the communication thread.
pub struct AccelService {
    tx: Mutex<Option<QueueTx<Submission>>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    metrics: Arc<AccelMetrics>,
    queue_stats: Arc<QueueStats>,
    stop: Arc<AtomicBool>,
    options: AccelOptions,
}

impl AccelService {
    /// Start the service for a set of compiled subgraphs. The engine is
    /// materialized from `spec` on the communication thread — the single
    /// thread that drives the device (paper Fig 3).
    pub fn start(
        configs: Vec<AccelConfig>,
        spec: EngineSpec,
        options: AccelOptions,
    ) -> Arc<AccelService> {
        assert!(
            BLOCK_SIZES.contains(&options.block),
            "block {} has no compiled artifact (menu: {:?})",
            options.block,
            BLOCK_SIZES
        );
        let prepared: Vec<Prepared> = configs
            .into_iter()
            .map(|config| {
                let (tables, accepts) = config.pack_tables();
                let (tables, accepts) = (Arc::new(tables), Arc::new(accepts));
                let body_exec = Executor::new(
                    Arc::new((*config.body).clone()),
                    Arc::new(Profiler::disabled()),
                );
                Prepared {
                    config,
                    tables,
                    accepts,
                    body_exec,
                }
            })
            .collect();
        let (tx, rx) = queue::bounded::<Submission>(options.queue_depth);
        let queue_stats = tx.stats().clone();
        let metrics = Arc::new(AccelMetrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let thread_metrics = metrics.clone();
        let thread_stop = stop.clone();
        let opts = options.clone();
        let handle = std::thread::Builder::new()
            .name("accel-comm".into())
            .spawn(move || {
                // home this thread on the reserved communication shard:
                // post-stage batches check out of (and return to) a pool
                // no worker contends on
                crate::exec::batch::pin_thread(crate::exec::batch::ArenaId::comm());
                match spec.build() {
                    Ok(engine) => {
                        comm_thread(rx, prepared, engine, opts, thread_metrics, thread_stop)
                    }
                    Err(e) => {
                        // engine failed to materialize: fail every
                        // submission rather than hanging the workers
                        let msg = format!("accelerator engine init failed: {e}");
                        while let Some(s) = rx.pop() {
                            let _ = s.reply.send(Err(msg.clone()));
                        }
                    }
                }
            })
            .expect("spawn communication thread");
        Arc::new(AccelService {
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(Some(handle)),
            metrics,
            queue_stats,
            stop,
            options,
        })
    }

    /// Submit one document for subgraph `id`; returns the receiver the
    /// worker blocks on (document-per-thread: the worker sleeps while the
    /// accelerator works). Blocks while the bounded submission queue is
    /// full — backpressure on the worker, per the shared scheduler rule.
    pub fn submit(
        &self,
        subgraph_id: usize,
        doc: Document,
        tokens: Arc<TokenIndex>,
        ext: Vec<TupleBatch>,
    ) -> Receiver<Result<Arc<Vec<TupleBatch>>, String>> {
        let (reply, rx) = channel();
        // clone the producer handle out of the lock so a full queue blocks
        // only this worker, not everyone behind the mutex
        let tx = self.tx.lock().unwrap().clone();
        if let Some(tx) = tx {
            // a push error means the service shut down; dropping the
            // submission drops `reply`, and the worker's recv fails cleanly
            let _ = tx.push(Submission {
                subgraph_id,
                doc,
                tokens,
                ext,
                reply,
            });
        }
        rx
    }

    /// The service's metrics.
    pub fn metrics(&self) -> &Arc<AccelMetrics> {
        &self.metrics
    }

    /// Gauges of the bounded submission queue (depth, high-water, stalls).
    pub fn queue_snapshot(&self) -> QueueSnapshot {
        self.queue_stats.snapshot()
    }

    /// Service options (block size etc.).
    pub fn options(&self) -> &AccelOptions {
        &self.options
    }

    /// Stop the communication thread and wait for it.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.tx.lock().unwrap().take(); // close the channel
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for AccelService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The communication thread main loop.
fn comm_thread(
    rx: QueueRx<Submission>,
    prepared: Vec<Prepared>,
    engine: Box<dyn PackageEngine>,
    options: AccelOptions,
    metrics: Arc<AccelMetrics>,
    stop: Arc<AtomicBool>,
) {
    // pending submissions per subgraph
    let mut pending: Vec<Vec<Submission>> = (0..prepared.len()).map(|_| Vec::new()).collect();
    let mut pending_bytes: Vec<usize> = vec![0; prepared.len()];
    // drained submissions land here first: one receiver lock per
    // combining round instead of one per submission, and the scratch
    // capacity is recycled across rounds
    let mut drained: Vec<Submission> = Vec::new();
    loop {
        // Block for the first submission (or queue close), then drain
        // whatever else is queued — "collects the data submitted by some of
        // the worker threads".
        match rx.pop() {
            Some(s) => {
                let gi = s.subgraph_id;
                pending_bytes[gi] += s.doc.len() + 1;
                pending[gi].push(s);
            }
            None => break, // all producers gone
        }
        rx.drain_into(&mut drained);
        for s in drained.drain(..) {
            let gi = s.subgraph_id;
            pending_bytes[gi] += s.doc.len() + 1;
            pending[gi].push(s);
            // don't hoard unboundedly: dispatch eagerly when a group can
            // fill a package
            if pending_bytes[gi] >= crate::hwcompiler::STREAMS * options.block {
                dispatch_group(
                    &mut pending[gi],
                    &prepared[gi],
                    engine.as_ref(),
                    &options,
                    &metrics,
                );
                pending_bytes[gi] = 0;
            }
        }
        // queue drained: flush every group with work (paper: "sends the
        // data to the accelerator's work queue and starts again")
        for gi in 0..prepared.len() {
            if !pending[gi].is_empty() {
                dispatch_group(
                    &mut pending[gi],
                    &prepared[gi],
                    engine.as_ref(),
                    &options,
                    &metrics,
                );
                pending_bytes[gi] = 0;
            }
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    // final flush on shutdown
    for (gi, group) in pending.iter_mut().enumerate() {
        if !group.is_empty() {
            dispatch_group(group, &prepared[gi], engine.as_ref(), &options, &metrics);
        }
    }
}

/// Pack, execute and post-process one group of submissions (possibly as
/// several packages if they exceed one package's capacity).
fn dispatch_group(
    group: &mut Vec<Submission>,
    prep: &Prepared,
    engine: &dyn PackageEngine,
    options: &AccelOptions,
    metrics: &AccelMetrics,
) {
    let mut subs = std::mem::take(group);
    let docs: Vec<&Document> = subs.iter().map(|s| &s.doc).collect();
    // adaptive block: smallest compiled variant that holds the batch
    let block = if options.adaptive_block {
        let max_len = docs.iter().map(|d| d.len()).max().unwrap_or(0);
        let need = docs.iter().map(|d| d.len() + 1).sum::<usize>();
        BLOCK_SIZES
            .iter()
            .copied()
            .filter(|&b| b <= options.block && b >= max_len)
            .find(|&b| need <= b * crate::hwcompiler::STREAMS)
            .unwrap_or(options.block)
    } else {
        options.block
    };
    let (packages, oversized) = pack_group(&docs, block);
    for di in oversized {
        let _ = subs[di].reply.send(Err(format!(
            "document {} is {} bytes, larger than the package block ({})",
            subs[di].doc.id,
            subs[di].doc.len(),
            options.block
        )));
    }
    for wp in packages {
        let batch: Vec<&Submission> =
            wp.slots.iter().map(|s| &subs[s.doc_index]).collect();
        run_package(wp, &batch, prep, engine, options, metrics);
    }
    // dropping the submissions here routes their ext batches back to the
    // worker shards that built them; the emptied container goes back to
    // the pending slot so steady-state combining reallocates neither
    subs.clear();
    *group = subs;
}

/// Execute one packed work package and wake its workers.
fn run_package(
    mut wp: WorkPackage,
    batch: &[&Submission],
    prep: &Prepared,
    engine: &dyn PackageEngine,
    options: &AccelOptions,
    metrics: &AccelMetrics,
) {
    let (m_pad, s_pad) = prep.config.geometry;
    let mut pkg = PackedPackage {
        // the package owns the byte block outright — moving it out of the
        // WorkPackage avoids re-allocating and copying STREAMS × block
        // ints per package on the steady-state path
        bytes: std::mem::take(&mut wp.bytes),
        block: wp.block,
        tables: prep.tables.clone(),
        accepts: prep.accepts.clone(),
        machines: m_pad,
        states: s_pad,
    };
    let key = prep.config.artifact_key(wp.block);
    let t0 = Instant::now();
    let result = engine.run(key, &pkg);
    let engine_ns = t0.elapsed().as_nanos() as u64;
    // the scan is done with the byte block: return it to the arena's
    // block pool so the next `pack_group` round checks it back out
    // instead of allocating (satisfies the zero-fresh invariant for
    // package assembly; see `exec::batch::take_block`). Recycled on the
    // error path too — a failing package must not drain the pool.
    crate::exec::batch::recycle_block(std::mem::take(&mut pkg.bytes));

    let hits = match result {
        Ok(h) => h,
        Err(e) => {
            let msg = format!("accelerator package failed: {e}");
            for s in batch {
                let _ = s.reply.send(Err(msg.clone()));
            }
            return;
        }
    };

    let t1 = Instant::now();
    // Normalize the hit stream before reconstruction: the transport layer
    // may deliver records duplicated or out of order (the simulator's
    // fault-injection hooks model exactly this), and span reconstruction
    // requires per-document ends in increasing order with no repeats.
    // Sorting by (machine, stream, position, state) and deduping makes the
    // stream canonical whatever the device did.
    let mut events = hits.hits;
    events.sort_unstable();
    events.dedup();

    // Group hits per (doc, machine): slots are sorted by (stream, offset).
    let mut per_doc_machine: Vec<Vec<Vec<(usize, u32)>>> =
        vec![vec![Vec::new(); prep.config.machines.len()]; batch.len()];
    for &(m, stream, pos, state) in &events {
        if m >= prep.config.machines.len() {
            continue; // padding machine can never hit, but be defensive
        }
        // find the doc slot containing (stream, pos)
        if let Some(di) = wp.slot_at(stream, pos) {
            let slot = &wp.slots[di];
            let local_end = pos + 1 - slot.offset;
            per_doc_machine[di][m].push((local_end, state));
        }
    }

    let mut total_hits = 0u64;
    // replies are deferred until the metrics are recorded, so a caller
    // that joins its workers observes complete counters
    let mut replies: Vec<(
        &Sender<Result<Arc<Vec<TupleBatch>>, String>>,
        Arc<Vec<TupleBatch>>,
    )> = Vec::with_capacity(batch.len());
    for (di, sub) in batch.iter().enumerate() {
        let mut overrides: HashMap<usize, TupleBatch> = HashMap::new();
        for (mi, machine) in prep.config.machines.iter().enumerate() {
            let events = &per_doc_machine[di][mi];
            total_hits += events.len() as u64;
            // reconstruction emits spans straight into an arena-backed
            // span column — the hit stream never becomes row tuples
            let mut spans = TupleBatch::single_span();
            match &machine.matcher {
                MatcherRef::Regex(re) => {
                    let ends: Vec<usize> = events.iter().map(|&(e, _)| e).collect();
                    spans.fill_spans(|out| {
                        re.from_hw_ends_spans_into(&sub.doc.text, &ends, out)
                    });
                }
                MatcherRef::Dict(ac) => {
                    spans.fill_spans(|out| {
                        ac.from_hw_states_spans_into(sub.doc.text.as_bytes(), events, out)
                    });
                }
            }
            overrides.insert(machine.body_node, spans);
        }
        let ext_refs: Vec<&TupleBatch> = sub.ext.iter().collect();
        let out =
            prep.body_exec
                .run_doc_batched(&sub.doc, &sub.tokens, &ext_refs, &overrides);
        // body outputs are registered positionally (`out0`, `out1`, …), so
        // the typed result's view order IS the output_idx order
        let mut outputs = out.into_batches();
        outputs.truncate(prep.config.outputs.len());
        replies.push((&sub.reply, Arc::new(outputs)));
    }
    let post_ns = t1.elapsed().as_nanos() as u64;

    let payload: usize = wp.slots.iter().map(|s| s.len).sum();
    // every engine reports the fixed-size block scan it performs
    // (PackageHits::cycles is always the full-block figure), so the
    // modeled time charges cycles, not payload bytes
    let modeled = options.model.package_time_cycles(hits.cycles, wp.slots.len());
    metrics.record_package(
        wp.slots.len() as u64,
        payload as u64,
        total_hits,
        engine_ns,
        post_ns,
        (modeled * 1e9) as u64,
        hits.cycles,
    );
    // status-register signal: wake the workers of this package
    for (reply, outputs) in replies {
        let _ = reply.send(Ok(outputs));
    }
}

/// One cached reply: a subgraph's outputs for one document, plus how many
/// more `SubgraphExec` reads remain before the entry is evicted.
struct CacheEntry {
    outputs: Arc<Vec<TupleBatch>>,
    remaining: usize,
}

/// [`SubgraphRunner`] backed by the service: submits and sleeps, with a
/// per-(doc, subgraph) result cache so multi-output subgraphs execute
/// once per document.
///
/// The cache is **self-evicting**: a subgraph with `K` outputs is read
/// exactly `K` times per document (once per `SubgraphExec` node), so the
/// entry is inserted with `K - 1` remaining reads and removed by the
/// last one. Eviction is what lets the reply's comm-origin column
/// buffers route home (the batches drop on the worker, return-to-origin
/// sends them to the communication shard) *within* the document that
/// produced them — parking replies indefinitely would starve the
/// communication thread's pools and force fresh allocations per package.
///
/// Construction takes the [`PartitionPlan`] the service was compiled from,
/// so every `SubgraphExec` reference is validated against the plan's
/// subgraph/output shape instead of silently yielding empty tuples on a
/// miswired graph.
pub struct AccelSubgraphRunner {
    service: Arc<AccelService>,
    /// Output count per subgraph id, from the plan.
    subgraph_outputs: Vec<usize>,
    /// `ExtInput` slot schemas per subgraph id, from the plan — used to
    /// type row-shaped injections at the legacy `run` boundary
    /// ([`Graph::ext_input_schemas`](crate::aog::Graph::ext_input_schemas)
    /// semantics: `None` slots get an empty placeholder, matching the
    /// executor's own boundary).
    ext_schemas: Vec<Vec<Option<Schema>>>,
    /// Keyed by (doc id, doc text allocation, subgraph id): the Session
    /// API accepts arbitrary caller-built documents, so ids alone are not
    /// unique and must not alias cache entries across different texts.
    cache: Mutex<HashMap<(u64, usize, usize), CacheEntry>>,
}

impl AccelSubgraphRunner {
    /// Wrap a running service compiled from `plan`.
    pub fn new(service: Arc<AccelService>, plan: &PartitionPlan) -> AccelSubgraphRunner {
        let ext_schemas = plan
            .subgraphs
            .iter()
            .map(|s| s.body.ext_input_schemas())
            .collect();
        AccelSubgraphRunner {
            service,
            subgraph_outputs: plan.subgraphs.iter().map(|s| s.outputs.len()).collect(),
            ext_schemas,
            cache: Mutex::new(HashMap::new()),
        }
    }

    fn cache_key(doc: &Document, id: usize) -> (u64, usize, usize) {
        (doc.id, Arc::as_ptr(&doc.text) as *const u8 as usize, id)
    }

    /// Validate a `SubgraphExec` reference against the compiled plan —
    /// called unconditionally by both entry points so a miswired graph
    /// fails loudly instead of yielding empty tuples.
    fn validate(&self, id: usize, output_idx: usize) {
        assert!(
            id < self.subgraph_outputs.len(),
            "graph references subgraph #{id} but the plan compiled only {}",
            self.subgraph_outputs.len()
        );
        assert!(
            output_idx < self.subgraph_outputs[id],
            "subgraph #{id} has {} outputs, output_idx {output_idx} is out of range",
            self.subgraph_outputs[id]
        );
    }

    /// Consult the cache — called *before* any ext-stream conversion, so
    /// cache hits (every output after the first of a multi-output
    /// subgraph) do zero copying. Each hit burns one remaining read; the
    /// last one evicts the entry, releasing the reply batches to route
    /// home to the communication shard.
    fn take_cached(&self, id: usize, doc: &Document) -> Option<Arc<Vec<TupleBatch>>> {
        let key = Self::cache_key(doc, id);
        let mut cache = self.cache.lock().unwrap();
        let entry = cache.get_mut(&key)?;
        let outputs = entry.outputs.clone();
        entry.remaining -= 1;
        if entry.remaining == 0 {
            cache.remove(&key);
        }
        Some(outputs)
    }

    /// Submit-and-sleep, filling the per-(doc, subgraph) cache — shared
    /// by the row and batch entry points (which check
    /// [`Self::take_cached`] first).
    fn fetch(
        &self,
        id: usize,
        doc: &Document,
        tokens: &TokenIndex,
        ext: Vec<TupleBatch>,
    ) -> Arc<Vec<TupleBatch>> {
        let rx = self
            .service
            .submit(id, doc.clone(), Arc::new(tokens.clone()), ext);
        // document-per-thread: sleep until the package completes
        match rx.recv() {
            Ok(Ok(outputs)) => {
                // this call is the first of the subgraph's `uses` reads
                // for this document; cache only what later reads need
                let uses = self.subgraph_outputs[id];
                if uses > 1 {
                    let mut cache = self.cache.lock().unwrap();
                    if cache.len() > 1024 {
                        // backstop for outputs that are dead in the
                        // supergraph and therefore never read to zero
                        cache.clear();
                    }
                    cache.insert(
                        Self::cache_key(doc, id),
                        CacheEntry {
                            outputs: outputs.clone(),
                            remaining: uses - 1,
                        },
                    );
                }
                outputs
            }
            Ok(Err(e)) => panic!("accelerator error: {e}"),
            Err(_) => panic!("accelerator service shut down while waiting"),
        }
    }

    /// Number of replies currently parked in the cache (tests assert the
    /// self-eviction leaves nothing behind after a document completes).
    #[cfg(test)]
    fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl SubgraphRunner for AccelSubgraphRunner {
    fn run(
        &self,
        id: usize,
        output_idx: usize,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&[Tuple]],
    ) -> Vec<Tuple> {
        self.validate(id, output_idx);
        if let Some(r) = self.take_cached(id, doc) {
            return r[output_idx].to_tuples();
        }
        let ext_batches: Vec<TupleBatch> = ext
            .iter()
            .enumerate()
            .map(|(slot, rows)| match self.ext_schemas[id].get(slot) {
                Some(Some(schema)) => TupleBatch::from_rows(schema, rows),
                _ => TupleBatch::empty(),
            })
            .collect();
        self.fetch(id, doc, tokens, ext_batches)[output_idx].to_tuples()
    }

    fn run_batch(
        &self,
        id: usize,
        output_idx: usize,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&TupleBatch],
        _schema: &Schema,
    ) -> TupleBatch {
        self.validate(id, output_idx);
        if let Some(r) = self.take_cached(id, doc) {
            return r[output_idx].clone();
        }
        let ext_batches: Vec<TupleBatch> = ext.iter().map(|b| (*b).clone()).collect();
        let outputs = self.fetch(id, doc, tokens, ext_batches);
        // single-output subgraphs are not cached (fetch keeps no clone),
        // so this Arc is the sole owner: move the reply batch out instead
        // of deep-copying it — the reply's comm-origin buffers then flow
        // into the document result and go home when IT drops
        match Arc::try_unwrap(outputs) {
            Ok(mut v) => v.swap_remove(output_idx),
            Err(shared) => shared[output_idx].clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwcompiler::compile_subgraph;
    use crate::partition::{partition, PartitionMode, SoftwareSubgraphRunner};
    use crate::runtime::EngineSpec;

    const PERSON_ORG: &str = r#"
        create dictionary Orgs as ('IBM', 'IBM Research', 'Columbia University');
        create view Org as
          extract dictionary 'Orgs' on d.text as match from Document d;
        create view Person as
          extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name from Document d;
        create view PersonOrg as
          select p.name as person, o.match as org,
                 CombineSpans(p.name, o.match) as ctx
          from Person p, Org o
          where FollowsTok(p.name, o.match, 0, 4)
          consolidate on ctx using 'ContainedWithin';
        output view PersonOrg;
    "#;

    fn rows(out: &crate::exec::DocResult) -> Vec<Vec<String>> {
        let mut rows: Vec<Vec<String>> = out
            .views()
            .iter()
            .flat_map(|rows| rows.iter().map(|t| t.iter().map(|v| v.to_string()).collect()))
            .collect();
        rows.sort();
        rows
    }

    fn accel_vs_software(mode: PartitionMode, texts: &[&str]) {
        let g = crate::optimizer::optimize(&crate::aql::compile(PERSON_ORG).unwrap());
        let plan = partition(&g, mode);
        let configs: Vec<AccelConfig> = plan
            .subgraphs
            .iter()
            .map(|s| compile_subgraph(s).unwrap())
            .collect();
        let service = AccelService::start(
            configs,
            EngineSpec::Native,
            AccelOptions::default(),
        );
        let accel_exec = Executor::new(
            Arc::new(plan.supergraph.clone()),
            Arc::new(Profiler::disabled()),
        )
        .with_subgraph_runner(Arc::new(AccelSubgraphRunner::new(service.clone(), &plan)));
        let sw_exec = Executor::new(
            Arc::new(plan.supergraph.clone()),
            Arc::new(Profiler::disabled()),
        )
        .with_subgraph_runner(Arc::new(SoftwareSubgraphRunner::new(&plan)));

        for (i, t) in texts.iter().enumerate() {
            let doc = Document::new(i as u64, *t);
            assert_eq!(
                rows(&accel_exec.run_doc(&doc)),
                rows(&sw_exec.run_doc(&doc)),
                "mode {:?}, text {t:?}",
                mode
            );
        }
        let snap = service.metrics().snapshot();
        assert!(snap.packages > 0);
        assert!(snap.docs as usize >= texts.iter().filter(|t| !t.is_empty()).count());
        service.shutdown();
    }

    const SAMPLES: &[&str] = &[
        "Laura Chiticariu works at IBM Research in Almaden.",
        "Fred Reiss and Huaiyu Zhu are at IBM Research today.",
        "nothing in this one",
        "Eva Sitaridi is at Columbia University. Peter Hofstee visits IBM.",
        "",
    ];

    #[test]
    fn accel_equals_software_extract_only() {
        accel_vs_software(PartitionMode::ExtractOnly, SAMPLES);
    }

    #[test]
    fn accel_equals_software_single_subgraph() {
        accel_vs_software(PartitionMode::SingleSubgraph, SAMPLES);
    }

    #[test]
    fn accel_equals_software_multi_subgraph() {
        accel_vs_software(PartitionMode::MultiSubgraph, SAMPLES);
    }

    #[test]
    fn concurrent_workers_get_combined_packages() {
        let g = crate::optimizer::optimize(&crate::aql::compile(PERSON_ORG).unwrap());
        let plan = partition(&g, PartitionMode::SingleSubgraph);
        let configs: Vec<AccelConfig> = plan
            .subgraphs
            .iter()
            .map(|s| compile_subgraph(s).unwrap())
            .collect();
        let service = AccelService::start(
            configs,
            EngineSpec::Native,
            AccelOptions::default(),
        );
        let runner = Arc::new(AccelSubgraphRunner::new(service.clone(), &plan));
        let exec = Arc::new(
            Executor::new(
                Arc::new(plan.supergraph.clone()),
                Arc::new(Profiler::disabled()),
            )
            .with_subgraph_runner(runner),
        );
        let mut handles = Vec::new();
        for w in 0..8 {
            let exec = exec.clone();
            handles.push(std::thread::spawn(move || {
                for k in 0..16u64 {
                    let doc = Document::new(
                        w * 1000 + k,
                        format!("Laura Chiticariu works at IBM Research (doc {k})."),
                    );
                    let out = exec.run_doc(&doc);
                    assert_eq!(out["PersonOrg"].len(), 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = service.metrics().snapshot();
        assert_eq!(snap.docs, 8 * 16);
        // combining must happen: fewer packages than documents
        assert!(
            snap.packages < snap.docs,
            "no combining: {} packages for {} docs",
            snap.packages,
            snap.docs
        );
        service.shutdown();
    }

    #[test]
    fn reply_cache_self_evicts_once_every_output_is_read() {
        // ExtractOnly folds both extraction leaves into ONE subgraph with
        // several outputs: the reply must be cached across the reads of
        // one document and evicted by the last one, so the comm-origin
        // batches go home instead of parking in the cache
        let g = crate::optimizer::optimize(&crate::aql::compile(PERSON_ORG).unwrap());
        let plan = partition(&g, PartitionMode::ExtractOnly);
        assert!(
            plan.subgraphs.iter().any(|s| s.outputs.len() > 1),
            "precondition: a multi-output subgraph exercises the cache"
        );
        let configs: Vec<AccelConfig> = plan
            .subgraphs
            .iter()
            .map(|s| compile_subgraph(s).unwrap())
            .collect();
        let service = AccelService::start(configs, EngineSpec::Native, AccelOptions::default());
        let runner = Arc::new(AccelSubgraphRunner::new(service.clone(), &plan));
        let exec = Executor::new(
            Arc::new(plan.supergraph.clone()),
            Arc::new(Profiler::disabled()),
        )
        .with_subgraph_runner(runner.clone());
        for (i, text) in SAMPLES.iter().enumerate() {
            let out = exec.run_doc(&Document::new(i as u64, *text));
            let _ = out.total_tuples();
            assert_eq!(
                runner.cache_len(),
                0,
                "cache must be empty after doc {i} completes"
            );
        }
        service.shutdown();
    }

    #[test]
    fn oversized_document_is_rejected_cleanly() {
        let g = crate::optimizer::optimize(&crate::aql::compile(PERSON_ORG).unwrap());
        let plan = partition(&g, PartitionMode::ExtractOnly);
        let configs: Vec<AccelConfig> = plan
            .subgraphs
            .iter()
            .map(|s| compile_subgraph(s).unwrap())
            .collect();
        let service = AccelService::start(
            configs,
            EngineSpec::Native,
            AccelOptions::default(),
        );
        let big = "x".repeat(17000); // exceeds block=16384
        let doc = Document::new(0, big);
        let rx = service.submit(0, doc, Arc::new(TokenIndex::default()), vec![]);
        let res = rx.recv().unwrap();
        assert!(res.is_err(), "oversized doc must fail, not hang");
        service.shutdown();
    }
}
