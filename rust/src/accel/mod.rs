//! The multi-threaded HW/SW communication interface (paper Fig 3),
//! generalized to a **pool of N accelerator devices**.
//!
//! SystemT worker threads execute the supergraph document-per-thread; when
//! one reaches a `SubgraphExec` operator it *submits* the document to a
//! **communication thread** and sleeps on a reply channel. Each pool
//! device owns one communication thread, one bounded submission queue,
//! and one [`crate::runtime::PackageEngine`] materialized from its own
//! [`EngineSpec`]. The thread drains pending submissions, combines them
//! into a **work package** (four parallel byte streams, documents
//! separated by NUL, per-document records), ships the package to its
//! device, reconstructs spans from the returned hit stream, evaluates the
//! subgraph's relational body, and wakes the workers whose documents
//! completed — exactly the paper's "status register + wake up the software
//! threads that belong to this work package" protocol, multiplied by N.
//!
//! Submissions travel through the same bounded-queue machinery
//! ([`crate::runtime::queue`]) that feeds [`Session`] worker pools, so the
//! HW and SW paths share one scheduler primitive: when a communication
//! thread falls behind, `submit` blocks the worker (backpressure) instead
//! of buffering unboundedly.
//!
//! ## Dispatch, failover, and adaptive routing
//!
//! `submit` routes each document to the device with the **smallest
//! current queue depth**, breaking ties round-robin from a rotating start
//! so an idle pool still interleaves. When a device errors mid-run (a
//! bricked device is modeled by
//! [`FaultPlan::fail_every`](crate::runtime::FaultPlan) `= 1`), its
//! communication thread walks a failover chain instead of failing the
//! workers: re-queue the package's documents on the least-loaded sibling
//! (non-blocking, at most `N - 1` attempts per document), then re-scan
//! the *same* packed package on the host CPU
//! ([`NativePackageEngine`] shares the device's exact hit semantics), so
//! views stay byte-identical through device failure. Single-device
//! services keep the original contract: the error goes to the submitting
//! workers. [`AccelSubgraphRunner`] additionally routes *new* calls
//! straight to the software executors when every device queue is
//! saturated and the cost model says offload would not pay
//! ([`crate::optimizer::cost`]); per-device gauges and the routing
//! counters surface through [`AccelService::device_snapshots`] and
//! [`AccelService::pool_snapshot`].
//!
//! ## Buffer ownership across the HW/SW boundary
//!
//! Every tuple stream crossing this interface is a [`TupleBatch`] whose
//! column buffers are stamped with their origin arena shard
//! ([`crate::exec::batch::ArenaId`]): submissions carry worker-origin
//! batches that the communication thread drops after the relational body
//! runs (routed home to the worker's shard), and replies carry
//! comm-origin batches that workers clone out of and release (routed home
//! to the communication shard — device `d`'s thread pins
//! [`ArenaId::comm_for`]`(d)` at start-up, so each device's reply batches
//! refill the pool that produced them, not a shared shard all devices
//! contend on). The per-(doc, subgraph) reply cache evicts an entry as soon
//! as its last output is consumed, so reply buffers go home *within* the
//! document that produced them and the accelerated route serves a warm
//! document with **zero fresh arena allocations** — the same steady state
//! as the software path (asserted in `rust/tests/columnar.rs`).
//!
//! [`ArenaId::comm_for`]: crate::exec::batch::ArenaId::comm_for
//! [`Session`]: crate::coordinator::Session
//! [`NativePackageEngine`]: crate::runtime::NativePackageEngine

pub mod packing;

pub use packing::{pack_group, DocSlot, SlotIndex, WorkPackage};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::aog::{Schema, Tuple};
use crate::exec::{Executor, Profiler, SubgraphRunner, TupleBatch};
use crate::hwcompiler::{AccelConfig, ArtifactKey, MatcherRef, BLOCK_SIZES};
use crate::metrics::{
    AccelDeviceSnapshot, AccelMetrics, PoolMetrics, PoolSnapshot, QueueSnapshot, QueueStats,
};
use crate::partition::{PartitionPlan, SoftwareSubgraphRunner};
use crate::perfmodel::FpgaModel;
use crate::runtime::fault::{self, BreakerSnapshot, CircuitBreaker, DeadlinePanic, Watchdog};
use crate::runtime::queue::{self, QueueRx, QueueTx};
use crate::runtime::{EngineSpec, NativePackageEngine, PackageEngine, PackageHits, PackedPackage};
use crate::text::{Document, TokenIndex};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct AccelOptions {
    /// Maximum bytes per stream per package; must be one of
    /// [`crate::hwcompiler::BLOCK_SIZES`].
    pub block: usize,
    /// Pick the smallest compiled block variant that fits each batch
    /// (§Perf L3: small batches then scan a 4 KiB block instead of
    /// 16 KiB). Disable to pin `block` for experiments.
    pub adaptive_block: bool,
    /// Dispatch as soon as this many payload bytes are pending (the
    /// paper's ">1000 bytes" combining rule). The queue also flushes when
    /// it drains, so latency stays bounded.
    pub combine_min_bytes: usize,
    /// Bounded depth of the submission queue feeding the communication
    /// thread. When it fills (the accelerator falls behind), `submit`
    /// blocks the calling worker — the same backpressure rule as a
    /// [`Session`](crate::coordinator::Session) ingress queue.
    pub queue_depth: usize,
    /// Timing model used for the modeled-throughput metrics.
    pub model: FpgaModel,
    /// Number of accelerator devices in the pool (≥ 1). Each device gets
    /// its own communication thread, bounded submission queue, engine,
    /// and pinned arena shard; [`AccelService::submit`] dispatches by
    /// least queue depth. `1` is the paper's single-device configuration.
    pub devices: usize,
    /// Consecutive device errors before that device's circuit breaker
    /// trips `Open` and dispatch stops routing to it.
    pub breaker_threshold: u32,
    /// How long a tripped breaker stays `Open` before it admits one
    /// half-open probe package.
    pub breaker_cooldown: Duration,
    /// Register each communication thread's heartbeat here, when set —
    /// [`Engine`](crate::coordinator::Engine) wires its own watchdog in so
    /// `GET /healthz` covers the comm threads alongside session workers.
    pub watchdog: Option<Arc<Watchdog>>,
}

impl Default for AccelOptions {
    fn default() -> Self {
        AccelOptions {
            block: 16384,
            adaptive_block: true,
            combine_min_bytes: 1000,
            queue_depth: 256,
            model: FpgaModel::paper(),
            devices: 1,
            breaker_threshold: fault::DEFAULT_BREAKER_THRESHOLD,
            breaker_cooldown: fault::DEFAULT_BREAKER_COOLDOWN,
            watchdog: None,
        }
    }
}

/// Why the service answered a submission with an error instead of views.
#[derive(Debug, Clone)]
pub enum SubmitError {
    /// The document's deadline expired while it moved through the
    /// accelerator path — the work was shed (at comm-thread dequeue or
    /// after the post-stage), not attempted further.
    Deadline {
        /// Time since the submission was created when it was shed.
        waited: Duration,
    },
    /// The device (and every failover rung behind it) failed the package.
    Failed(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Deadline { waited } => write!(
                f,
                "submission deadline expired after {:.1} ms",
                waited.as_secs_f64() * 1e3
            ),
            SubmitError::Failed(msg) => f.write_str(msg),
        }
    }
}

/// What a worker's reply channel delivers: the subgraph's output batches
/// or a structured [`SubmitError`].
pub type SubmitResult = Result<Arc<Vec<TupleBatch>>, SubmitError>;

/// One queued request. External tuple streams travel columnar end to end
/// ([`TupleBatch`]), so the communication thread never touches row-shaped
/// tuples.
struct Submission {
    subgraph_id: usize,
    doc: Document,
    tokens: Arc<TokenIndex>,
    ext: Vec<TupleBatch>,
    /// Devices that have already failed this submission — bounds the
    /// failover chain at `devices - 1` sibling hops.
    attempts: u32,
    /// When the submission was created (for deadline-expiry reporting).
    submitted: Instant,
    /// Absolute deadline captured from the worker's thread-local at
    /// submit time; expired submissions are shed, not run.
    deadline: Option<Instant>,
    reply: Sender<SubmitResult>,
}

impl Submission {
    /// True when this submission's deadline has passed.
    fn expired(&self) -> bool {
        self.deadline.is_some_and(|dl| Instant::now() > dl)
    }

    /// Answer the worker with a deadline error.
    fn shed(self) {
        let _ = self.reply.send(Err(SubmitError::Deadline {
            waited: self.submitted.elapsed(),
        }));
    }
}

/// A subgraph's pre-packed state, built once at service start.
struct Prepared {
    config: AccelConfig,
    tables: Arc<Vec<i32>>,
    accepts: Arc<Vec<i32>>,
    body_exec: Executor,
}

/// State shared between the dispatcher and every communication thread:
/// the per-device producer handles (for dispatch *and* sibling
/// forwarding on failover) and the pool-level routing counters.
struct PoolShared {
    /// Producer handle per device; `None` once the service shuts down.
    txs: Vec<Mutex<Option<QueueTx<Submission>>>>,
    /// Per-device submission-queue gauges (shared with the queue halves).
    queues: Vec<Arc<QueueStats>>,
    /// Retry/failover/software-routing counters.
    pool: Arc<PoolMetrics>,
    /// One circuit breaker per device: K consecutive package errors trip
    /// it `Open`, dispatch skips it, and after the cooldown one probe
    /// package decides whether the device is re-admitted.
    breakers: Vec<Arc<CircuitBreaker>>,
}

impl PoolShared {
    fn devices(&self) -> usize {
        self.txs.len()
    }

    /// Clone out device `d`'s producer handle, if the service still runs.
    /// Cloning out of the lock means a full queue blocks only the caller,
    /// never everyone behind the mutex.
    fn tx(&self, d: usize) -> Option<QueueTx<Submission>> {
        self.txs[d].lock().unwrap().clone()
    }

    /// The least-loaded device whose breaker admits work, scanning from
    /// `start` so equal depths break round-robin. Candidates are tried in
    /// depth order and `admit()` is only called on a device we would
    /// actually pick, so half-open probe slots are never burned on
    /// devices that lose the depth comparison anyway. `skip` excludes the
    /// failing device when a communication thread forwards to a sibling.
    /// Returns `None` when no breaker admits (or `skip` eliminates the
    /// pool) — callers fall back to the host CPU or to
    /// [`PoolShared::pick_any`].
    fn pick(&self, start: usize, skip: Option<usize>) -> Option<usize> {
        let n = self.devices();
        let mut order: Vec<(u64, usize)> = Vec::with_capacity(n);
        for k in 0..n {
            let d = (start + k) % n;
            if Some(d) == skip {
                continue;
            }
            order.push((self.queues[d].snapshot().depth, d));
        }
        // stable sort: the rotated scan order above is the tie-break
        order.sort_by_key(|&(depth, _)| depth);
        order
            .into_iter()
            .find(|&(_, d)| self.breakers[d].admit())
            .map(|(_, d)| d)
    }

    /// The least-loaded device regardless of breaker state — the
    /// dispatcher's last resort when every breaker rejects, so work still
    /// flows (and fails over) rather than erroring at submit.
    fn pick_any(&self, start: usize) -> usize {
        let n = self.devices();
        let mut best: Option<(u64, usize)> = None;
        for k in 0..n {
            let d = (start + k) % n;
            let depth = self.queues[d].snapshot().depth;
            if best.map_or(true, |(bd, _)| depth < bd) {
                best = Some((depth, d));
            }
        }
        best.map(|(_, d)| d).unwrap_or(0)
    }
}

/// The accelerator service: owns the pool's communication threads.
pub struct AccelService {
    shared: Arc<PoolShared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Aggregate package counters across the whole pool.
    metrics: Arc<AccelMetrics>,
    /// Per-device package counters, indexed by device.
    device_metrics: Vec<Arc<AccelMetrics>>,
    /// Rotating dispatch start for round-robin tie-breaks.
    rr: AtomicUsize,
    stop: Arc<AtomicBool>,
    options: AccelOptions,
}

impl AccelService {
    /// Start the service for a set of compiled subgraphs.
    /// `options.devices` sizes the pool: device 0 is materialized from
    /// `spec` verbatim (so a simulator spec's shared stats keep feeding
    /// [`Engine::sim_snapshot`](crate::coordinator::Engine::sim_snapshot)),
    /// and devices `1..N` from [`EngineSpec::fork`]. Engines are built on
    /// their own communication threads — the one thread that drives each
    /// device (paper Fig 3).
    pub fn start(
        configs: Vec<AccelConfig>,
        spec: EngineSpec,
        options: AccelOptions,
    ) -> Arc<AccelService> {
        let devices = options.devices.max(1);
        let specs: Vec<EngineSpec> = (0..devices)
            .map(|d| if d == 0 { spec.clone() } else { spec.fork(d as u64) })
            .collect();
        Self::start_pool(configs, specs, options)
    }

    /// Start a pool with an explicit spec per device — one communication
    /// thread, bounded queue, engine, and pinned arena shard each. The
    /// device-failover tests use this to brick exactly one device
    /// (`FaultPlan::fail_every = 1`) while its siblings stay healthy;
    /// `options.devices` is overridden by `specs.len()`.
    pub fn start_pool(
        configs: Vec<AccelConfig>,
        specs: Vec<EngineSpec>,
        mut options: AccelOptions,
    ) -> Arc<AccelService> {
        assert!(!specs.is_empty(), "a pool needs at least one device");
        assert!(
            BLOCK_SIZES.contains(&options.block),
            "block {} has no compiled artifact (menu: {:?})",
            options.block,
            BLOCK_SIZES
        );
        options.devices = specs.len();
        let mut txs = Vec::with_capacity(specs.len());
        let mut queues = Vec::with_capacity(specs.len());
        let mut rxs = Vec::with_capacity(specs.len());
        for _ in 0..specs.len() {
            let (tx, rx) = queue::bounded::<Submission>(options.queue_depth);
            queues.push(tx.stats().clone());
            txs.push(Mutex::new(Some(tx)));
            rxs.push(rx);
        }
        let breakers: Vec<Arc<CircuitBreaker>> = (0..specs.len())
            .map(|_| {
                Arc::new(CircuitBreaker::new(
                    options.breaker_threshold,
                    options.breaker_cooldown,
                ))
            })
            .collect();
        let shared = Arc::new(PoolShared {
            txs,
            queues,
            pool: Arc::new(PoolMetrics::default()),
            breakers,
        });
        let metrics = Arc::new(AccelMetrics::default());
        let device_metrics: Vec<Arc<AccelMetrics>> = (0..specs.len())
            .map(|_| Arc::new(AccelMetrics::default()))
            .collect();
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::with_capacity(specs.len());
        for (d, (spec, rx)) in specs.into_iter().zip(rxs).enumerate() {
            // each device pre-packs its own table set and body executors:
            // Prepared is not Send-shareable (executors), and per-device
            // copies keep every post-stage read local to its thread
            let prepared: Vec<Prepared> = configs
                .iter()
                .map(|config| {
                    let config = config.clone();
                    let (tables, accepts) = config.pack_tables();
                    let (tables, accepts) = (Arc::new(tables), Arc::new(accepts));
                    let body_exec = Executor::new(
                        Arc::new((*config.body).clone()),
                        Arc::new(Profiler::disabled()),
                    );
                    Prepared {
                        config,
                        tables,
                        accepts,
                        body_exec,
                    }
                })
                .collect();
            let ctx = CommCtx {
                device: d,
                shared: shared.clone(),
                aggregate: metrics.clone(),
                device_metrics: device_metrics[d].clone(),
            };
            let opts = options.clone();
            let thread_stop = stop.clone();
            let heartbeat = options
                .watchdog
                .as_ref()
                .map(|wd| wd.register(format!("accel-comm-{d}")));
            let handle = std::thread::Builder::new()
                .name(format!("accel-comm-{d}"))
                .spawn(move || {
                    // home this thread on its device's reserved
                    // communication shard: post-stage batches check out of
                    // (and return to) a pool neither workers nor sibling
                    // devices contend on
                    crate::exec::batch::pin_thread(crate::exec::batch::ArenaId::comm_for(d));
                    match spec.build() {
                        Ok(engine) => {
                            comm_thread(rx, prepared, engine, opts, ctx, thread_stop, heartbeat.as_deref())
                        }
                        Err(e) => {
                            // engine failed to materialize: fail every
                            // submission rather than hanging the workers
                            let msg = format!("accelerator engine init failed: {e}");
                            while let Some(s) = rx.pop() {
                                let _ = s.reply.send(Err(SubmitError::Failed(msg.clone())));
                            }
                        }
                    }
                    if let Some(hb) = &heartbeat {
                        hb.retire();
                    }
                })
                .expect("spawn communication thread");
            handles.push(handle);
        }
        Arc::new(AccelService {
            shared,
            handles: Mutex::new(handles),
            metrics,
            device_metrics,
            rr: AtomicUsize::new(0),
            stop,
            options,
        })
    }

    /// Submit one document for subgraph `id`; returns the receiver the
    /// worker blocks on (document-per-thread: the worker sleeps while the
    /// accelerator works). The submission is dispatched to the device
    /// with the smallest current queue depth (round-robin on ties);
    /// blocks while that device's bounded queue is full — backpressure on
    /// the worker, per the shared scheduler rule.
    pub fn submit(
        &self,
        subgraph_id: usize,
        doc: Document,
        tokens: Arc<TokenIndex>,
        ext: Vec<TupleBatch>,
    ) -> Receiver<SubmitResult> {
        let (reply, rx) = channel();
        let d = self.pick_device();
        if let Some(tx) = self.shared.tx(d) {
            // a push error means the service shut down; dropping the
            // submission drops `reply`, and the worker's recv fails cleanly
            let _ = tx.push(Submission {
                subgraph_id,
                doc,
                tokens,
                ext,
                attempts: 0,
                submitted: Instant::now(),
                // the session worker installed the document's deadline in
                // its thread-local before reaching the SubgraphExec node
                deadline: fault::doc_deadline(),
                reply,
            });
        }
        rx
    }

    /// Least-queue-depth dispatch with a rotating tie-break start,
    /// honoring the per-device circuit breakers. When every breaker
    /// rejects, falls back to the least-loaded device regardless — the
    /// failover chain (sibling, then host CPU) still answers the work,
    /// and [`AccelSubgraphRunner`] routes around a fully-dark pool before
    /// it ever gets here.
    fn pick_device(&self) -> usize {
        let n = self.shared.devices();
        if n == 1 {
            // single device: the breaker still gates probes vs. storms on
            // the failover-less path, but dispatch has nowhere else to go
            return 0;
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        self.shared
            .pick(start, None)
            .unwrap_or_else(|| self.shared.pick_any(start))
    }

    /// The service's aggregate metrics across every device.
    pub fn metrics(&self) -> &Arc<AccelMetrics> {
        &self.metrics
    }

    /// Gauges of the bounded submission queues, merged across devices
    /// (counters and depth sum; high-water takes the per-device max).
    pub fn queue_snapshot(&self) -> QueueSnapshot {
        let mut merged = QueueSnapshot::default();
        for q in &self.shared.queues {
            merged.merge(&q.snapshot());
        }
        merged
    }

    /// Per-device gauges: each device's package counters and submission
    /// queue, in device order.
    pub fn device_snapshots(&self) -> Vec<AccelDeviceSnapshot> {
        (0..self.shared.devices())
            .map(|d| AccelDeviceSnapshot {
                device: d,
                accel: self.device_metrics[d].snapshot(),
                queue: self.shared.queues[d].snapshot(),
            })
            .collect()
    }

    /// Pool-level routing counters (retries, failovers, software
    /// fallbacks, software-routed calls, deadline sheds) plus the
    /// summed circuit-breaker counters — the breakers themselves are
    /// authoritative, so the sum is taken at snapshot time instead of
    /// double-booked into [`PoolMetrics`].
    pub fn pool_snapshot(&self) -> PoolSnapshot {
        let mut snap = self.shared.pool.snapshot();
        for b in &self.shared.breakers {
            let s = b.snapshot();
            snap.breaker_trips += s.trips;
            snap.breaker_probes += s.probes;
            snap.breaker_readmits += s.readmits;
        }
        snap
    }

    /// Per-device circuit-breaker counters, in device order.
    pub fn breaker_snapshots(&self) -> Vec<BreakerSnapshot> {
        self.shared.breakers.iter().map(|b| b.snapshot()).collect()
    }

    /// True when no device would currently admit work (every breaker is
    /// `Open` inside its cooldown, or holding a half-open probe) — the
    /// adaptive router's signal to run subgraphs on the host instead of
    /// queueing onto dark devices.
    pub fn all_dark(&self) -> bool {
        !self.shared.breakers.iter().any(|b| b.would_admit())
    }

    /// Number of devices in the pool.
    pub fn devices(&self) -> usize {
        self.shared.devices()
    }

    /// Smallest current submission-queue depth across the pool — the
    /// adaptive router's load signal.
    pub fn min_queue_depth(&self) -> u64 {
        self.shared
            .queues
            .iter()
            .map(|q| q.snapshot().depth)
            .min()
            .unwrap_or(0)
    }

    /// Service options (block size, pool size etc.).
    pub fn options(&self) -> &AccelOptions {
        &self.options
    }

    /// Stop every communication thread and wait for them. The producer
    /// handles in [`PoolShared`] are cleared first — communication
    /// threads hold the shared pool state for sibling forwarding, so
    /// leaving a handle behind would keep every channel open and the
    /// threads waiting forever.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for tx in &self.shared.txs {
            tx.lock().unwrap().take(); // close this device's channel
        }
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for AccelService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One communication thread's identity and links: which device it is,
/// the shared pool state (sibling queues, routing counters), and where
/// its package counters go (the aggregate plus its own device row).
struct CommCtx {
    device: usize,
    shared: Arc<PoolShared>,
    aggregate: Arc<AccelMetrics>,
    device_metrics: Arc<AccelMetrics>,
}

/// Validate and file one incoming submission. A `subgraph_id` beyond the
/// compiled plan answers `Err` on its own reply channel — indexing with
/// it would panic and take the whole communication thread (and every
/// in-flight worker) down with it. A submission whose deadline already
/// expired while queued is shed here (dequeue-time check): answered with
/// a deadline error instead of burning device time on a result nobody
/// will wait for. Returns the group index filed into.
fn intake(
    s: Submission,
    pending: &mut [Vec<Submission>],
    pending_bytes: &mut [usize],
    pool: &PoolMetrics,
) -> Option<usize> {
    let gi = s.subgraph_id;
    if gi >= pending.len() {
        let _ = s.reply.send(Err(SubmitError::Failed(format!(
            "invalid subgraph id {gi}: this service compiled {} subgraphs",
            pending.len()
        ))));
        return None;
    }
    if s.expired() {
        pool.deadline_expired.fetch_add(1, Ordering::Relaxed);
        s.shed();
        return None;
    }
    pending_bytes[gi] += s.doc.len() + 1;
    pending[gi].push(s);
    Some(gi)
}

/// One device's communication thread main loop.
fn comm_thread(
    rx: QueueRx<Submission>,
    prepared: Vec<Prepared>,
    engine: Box<dyn PackageEngine>,
    options: AccelOptions,
    ctx: CommCtx,
    stop: Arc<AtomicBool>,
    heartbeat: Option<&fault::Heartbeat>,
) {
    // pending submissions per subgraph
    let mut pending: Vec<Vec<Submission>> = (0..prepared.len()).map(|_| Vec::new()).collect();
    let mut pending_bytes: Vec<usize> = vec![0; prepared.len()];
    // drained submissions land here first: one receiver lock per
    // combining round instead of one per submission, and the scratch
    // capacity is recycled across rounds
    let mut drained: Vec<Submission> = Vec::new();
    loop {
        // Block for the first submission (or queue close), then drain
        // whatever else is queued — "collects the data submitted by some of
        // the worker threads".
        if let Some(hb) = heartbeat {
            hb.idle(); // blocking on an empty submission queue is healthy
        }
        match rx.pop() {
            Some(s) => {
                if let Some(hb) = heartbeat {
                    hb.beat();
                }
                intake(s, &mut pending, &mut pending_bytes, &ctx.shared.pool);
            }
            None => break, // all producers gone
        }
        rx.drain_into(&mut drained);
        for s in drained.drain(..) {
            let Some(gi) = intake(s, &mut pending, &mut pending_bytes, &ctx.shared.pool) else {
                continue;
            };
            // don't hoard unboundedly: dispatch eagerly when a group can
            // fill a package
            if pending_bytes[gi] >= crate::hwcompiler::STREAMS * options.block {
                dispatch_group(&mut pending[gi], &prepared[gi], engine.as_ref(), &options, &ctx);
                pending_bytes[gi] = 0;
            }
        }
        // queue drained: flush every group with work (paper: "sends the
        // data to the accelerator's work queue and starts again")
        for gi in 0..prepared.len() {
            if !pending[gi].is_empty() {
                dispatch_group(&mut pending[gi], &prepared[gi], engine.as_ref(), &options, &ctx);
                pending_bytes[gi] = 0;
            }
        }
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    // final flush on shutdown
    for (gi, group) in pending.iter_mut().enumerate() {
        if !group.is_empty() {
            dispatch_group(group, &prepared[gi], engine.as_ref(), &options, &ctx);
        }
    }
}

/// Pack, execute and post-process one group of submissions (possibly as
/// several packages if they exceed one package's capacity).
fn dispatch_group(
    group: &mut Vec<Submission>,
    prep: &Prepared,
    engine: &dyn PackageEngine,
    options: &AccelOptions,
    ctx: &CommCtx,
) {
    let docs: Vec<&Document> = group.iter().map(|s| &s.doc).collect();
    // adaptive block: smallest compiled variant that holds the batch
    let block = if options.adaptive_block {
        let max_len = docs.iter().map(|d| d.len()).max().unwrap_or(0);
        let need = docs.iter().map(|d| d.len() + 1).sum::<usize>();
        BLOCK_SIZES
            .iter()
            .copied()
            .filter(|&b| b <= options.block && b >= max_len)
            .find(|&b| need <= b * crate::hwcompiler::STREAMS)
            .unwrap_or(options.block)
    } else {
        options.block
    };
    let (packages, oversized) = pack_group(&docs, block);
    drop(docs); // release the borrow of `group` before draining it
    // move the submissions out of the group so each package can own (and,
    // on failover, forward to a sibling device) its documents; draining
    // keeps the group's capacity for the next combining round
    let mut subs: Vec<Option<Submission>> = group.drain(..).map(Some).collect();
    for di in oversized {
        if let Some(s) = subs[di].take() {
            let _ = s.reply.send(Err(format!(
                "document {} is {} bytes, larger than the package block ({})",
                s.doc.id,
                s.doc.len(),
                options.block
            )));
        }
    }
    for wp in packages {
        let batch: Vec<Submission> = wp
            .slots
            .iter()
            .map(|s| {
                subs[s.doc_index]
                    .take()
                    .expect("pack_group places each document in exactly one slot")
            })
            .collect();
        run_package(wp, batch, prep, engine, options, ctx);
    }
    // submissions not claimed by any package (there are none today) and
    // the processed ones alike drop on this thread, routing their ext
    // batches back to the worker shards that built them
}

/// The failover chain for a device error. Single-device services keep
/// the original contract — the error goes to every submission in the
/// package. A pool first re-queues the documents on the least-loaded
/// sibling (bounded by `attempts`, non-blocking so two failing devices
/// can never deadlock forwarding at each other), then re-scans the SAME
/// packed bytes on the host CPU: [`NativePackageEngine`] is the
/// reference implementation every device is differentially tested
/// against, so views stay byte-identical through a bricked device.
/// Returns hits to post-process for whatever submissions remain in
/// `batch`, or `None` when nothing is left to do here.
fn recover_package(
    batch: &mut [Option<Submission>],
    pkg: &PackedPackage,
    key: ArtifactKey,
    ctx: &CommCtx,
    err: &anyhow::Error,
) -> Option<PackageHits> {
    let devices = ctx.shared.devices();
    if devices < 2 {
        let msg = format!("accelerator package failed: {err}");
        for s in batch.iter_mut().filter_map(|s| s.take()) {
            let _ = s.reply.send(Err(SubmitError::Failed(msg.clone())));
        }
        return None;
    }
    // rung 1: forward to the least-loaded sibling. `try_push` only — a
    // communication thread must never block on another communication
    // thread's queue; a full sibling keeps the document for rung 2.
    let max_attempts = (devices - 1) as u32;
    if let Some(d) = ctx.shared.pick(ctx.device + 1, Some(ctx.device)) {
        if let Some(tx) = ctx.shared.tx(d) {
            for slot in batch.iter_mut() {
                if !slot.as_ref().is_some_and(|s| s.attempts < max_attempts) {
                    continue;
                }
                let mut s = slot.take().expect("checked is_some above");
                s.attempts += 1;
                match tx.try_push(s) {
                    Ok(()) => {
                        ctx.shared.pool.retries.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(s) => *slot = Some(s),
                }
            }
        }
    }
    if batch.iter().all(Option::is_none) {
        return None; // every document found a sibling
    }
    // rung 2: host CPU re-scan of the same package
    match NativePackageEngine.run(key, pkg) {
        Ok(h) => {
            ctx.shared.pool.sw_fallbacks.fetch_add(1, Ordering::Relaxed);
            Some(h)
        }
        Err(e2) => {
            let msg =
                format!("accelerator package failed: {err} (host fallback also failed: {e2})");
            for s in batch.iter_mut().filter_map(|s| s.take()) {
                let _ = s.reply.send(Err(SubmitError::Failed(msg.clone())));
            }
            None
        }
    }
}

/// Execute one packed work package and wake its workers.
fn run_package(
    mut wp: WorkPackage,
    batch: Vec<Submission>,
    prep: &Prepared,
    engine: &dyn PackageEngine,
    options: &AccelOptions,
    ctx: &CommCtx,
) {
    let (m_pad, s_pad) = prep.config.geometry;
    let mut pkg = PackedPackage {
        // the package owns the byte block outright — moving it out of the
        // WorkPackage avoids re-allocating and copying STREAMS × block
        // ints per package on the steady-state path
        bytes: std::mem::take(&mut wp.bytes),
        block: wp.block,
        tables: prep.tables.clone(),
        accepts: prep.accepts.clone(),
        machines: m_pad,
        states: s_pad,
    };
    let key = prep.config.artifact_key(wp.block);
    let t0 = Instant::now();
    let result = engine.run(key, &pkg);
    let engine_ns = t0.elapsed().as_nanos() as u64;

    // the device's breaker sees every package verdict — success resets
    // the error run (and re-admits a half-open device), an error extends
    // it (and re-opens a failed probe). Recovery rungs below answer the
    // *work*; the breaker tracks the *device*.
    let breaker = &ctx.shared.breakers[ctx.device];
    match &result {
        Ok(_) => breaker.record_success(),
        Err(_) => breaker.record_error(),
    }

    // `None` slots below are documents this thread no longer owns
    // (forwarded to a sibling on failover); index-aligned with wp.slots
    let mut batch: Vec<Option<Submission>> = batch.into_iter().map(Some).collect();
    let hits = match result {
        Ok(h) => h,
        Err(e) => match recover_package(&mut batch, &pkg, key, ctx, &e) {
            Some(h) => h,
            None => {
                // nothing left to post-process; the block still goes back
                // to the pool — a failing package must not drain it
                crate::exec::batch::recycle_block(std::mem::take(&mut pkg.bytes));
                return;
            }
        },
    };
    // the scan is done with the byte block (the host-fallback rescan
    // included): return it to the arena's block pool so the next
    // `pack_group` round checks it back out instead of allocating
    // (satisfies the zero-fresh invariant for package assembly; see
    // `exec::batch::take_block`)
    crate::exec::batch::recycle_block(std::mem::take(&mut pkg.bytes));

    let t1 = Instant::now();
    // Normalize the hit stream before reconstruction: the transport layer
    // may deliver records duplicated or out of order (the simulator's
    // fault-injection hooks model exactly this), and span reconstruction
    // requires per-document ends in increasing order with no repeats.
    // Sorting by (machine, stream, position, state) and deduping makes the
    // stream canonical whatever the device did.
    let mut events = hits.hits;
    events.sort_unstable();
    events.dedup();

    // Group hits per (doc, machine). The sorted per-stream offset index
    // makes each attribution a binary search instead of the former
    // O(slots) linear scan over every slot per hit.
    let slot_index = SlotIndex::new(&wp);
    let mut per_doc_machine: Vec<Vec<Vec<(usize, u32)>>> =
        vec![vec![Vec::new(); prep.config.machines.len()]; batch.len()];
    for &(m, stream, pos, state) in &events {
        if m >= prep.config.machines.len() {
            continue; // padding machine can never hit, but be defensive
        }
        // find the doc slot containing (stream, pos)
        if let Some(di) = slot_index.slot_at(stream, pos) {
            let slot = &wp.slots[di];
            let local_end = pos + 1 - slot.offset;
            per_doc_machine[di][m].push((local_end, state));
        }
    }

    let mut total_hits = 0u64;
    let mut docs_done = 0u64;
    let mut failover_done = false;
    // replies are deferred until the metrics are recorded, so a caller
    // that joins its workers observes complete counters
    let mut replies: Vec<(&Sender<SubmitResult>, SubmitResult)> =
        Vec::with_capacity(batch.len());
    for (di, sub) in batch.iter().enumerate() {
        // forwarded to a sibling device on failover — its slot's hits (if
        // any) belong to the retry, not to this thread
        let Some(sub) = sub else { continue };
        // post-stage deadline check: the scan happened, but a document
        // whose budget expired mid-package is still answered as an
        // expiry — running the relational body for it would only delay
        // the rest of the package's workers further
        if sub.expired() {
            ctx.shared
                .pool
                .deadline_expired
                .fetch_add(1, Ordering::Relaxed);
            replies.push((
                &sub.reply,
                Err(SubmitError::Deadline {
                    waited: sub.submitted.elapsed(),
                }),
            ));
            continue;
        }
        let mut overrides: HashMap<usize, TupleBatch> = HashMap::new();
        for (mi, machine) in prep.config.machines.iter().enumerate() {
            let events = &per_doc_machine[di][mi];
            total_hits += events.len() as u64;
            // reconstruction emits spans straight into an arena-backed
            // span column — the hit stream never becomes row tuples
            let mut spans = TupleBatch::single_span();
            match &machine.matcher {
                MatcherRef::Regex(re) => {
                    let ends: Vec<usize> = events.iter().map(|&(e, _)| e).collect();
                    spans.fill_spans(|out| {
                        re.from_hw_ends_spans_into(&sub.doc.text, &ends, out)
                    });
                }
                MatcherRef::Dict(ac) => {
                    spans.fill_spans(|out| {
                        ac.from_hw_states_spans_into(sub.doc.text.as_bytes(), events, out)
                    });
                }
            }
            overrides.insert(machine.body_node, spans);
        }
        let ext_refs: Vec<&TupleBatch> = sub.ext.iter().collect();
        let out =
            prep.body_exec
                .run_doc_batched(&sub.doc, &sub.tokens, &ext_refs, &overrides);
        // body outputs are registered positionally (`out0`, `out1`, …), so
        // the typed result's view order IS the output_idx order
        let mut outputs = out.into_batches();
        outputs.truncate(prep.config.outputs.len());
        docs_done += 1;
        if sub.attempts > 0 {
            failover_done = true;
        }
        replies.push((&sub.reply, Ok(Arc::new(outputs))));
    }
    let post_ns = t1.elapsed().as_nanos() as u64;

    // only the documents this thread actually answered count — a
    // forwarded document is counted by the sibling that answers it
    let payload: usize = wp
        .slots
        .iter()
        .enumerate()
        .filter(|(di, _)| batch[*di].is_some())
        .map(|(_, s)| s.len)
        .sum();
    // every engine reports the fixed-size block scan it performs
    // (PackageHits::cycles is always the full-block figure), so the
    // modeled time charges cycles, not payload bytes
    let modeled = options.model.package_time_cycles(hits.cycles, docs_done as usize);
    ctx.aggregate.record_package(
        docs_done,
        payload as u64,
        total_hits,
        engine_ns,
        post_ns,
        (modeled * 1e9) as u64,
        hits.cycles,
    );
    ctx.device_metrics.record_package(
        docs_done,
        payload as u64,
        total_hits,
        engine_ns,
        post_ns,
        (modeled * 1e9) as u64,
        hits.cycles,
    );
    if failover_done {
        // a package that carried at least one retried document completed:
        // that is one successful failover end to end
        ctx.shared.pool.failovers.fetch_add(1, Ordering::Relaxed);
    }
    // status-register signal: wake the workers of this package
    for (reply, outcome) in replies {
        let _ = reply.send(outcome);
    }
}

/// One cached reply: a subgraph's outputs for one document, plus how many
/// more `SubgraphExec` reads remain before the entry is evicted.
struct CacheEntry {
    outputs: Arc<Vec<TupleBatch>>,
    remaining: usize,
}

/// [`SubgraphRunner`] backed by the service: submits and sleeps, with a
/// per-(doc, subgraph) result cache so multi-output subgraphs execute
/// once per document.
///
/// The cache is **self-evicting**: a subgraph with `K` outputs is read
/// exactly `K` times per document (once per `SubgraphExec` node), so the
/// entry is inserted with `K - 1` remaining reads and removed by the
/// last one. Eviction is what lets the reply's comm-origin column
/// buffers route home (the batches drop on the worker, return-to-origin
/// sends them to the communication shard) *within* the document that
/// produced them — parking replies indefinitely would starve the
/// communication thread's pools and force fresh allocations per package.
///
/// Construction takes the [`PartitionPlan`] the service was compiled from,
/// so every `SubgraphExec` reference is validated against the plan's
/// subgraph/output shape instead of silently yielding empty tuples on a
/// miswired graph.
pub struct AccelSubgraphRunner {
    service: Arc<AccelService>,
    /// Output count per subgraph id, from the plan.
    subgraph_outputs: Vec<usize>,
    /// `ExtInput` slot schemas per subgraph id, from the plan — used to
    /// type row-shaped injections at the legacy `run` boundary
    /// ([`Graph::ext_input_schemas`](crate::aog::Graph::ext_input_schemas)
    /// semantics: `None` slots get an empty placeholder, matching the
    /// executor's own boundary).
    ext_schemas: Vec<Vec<Option<Schema>>>,
    /// Keyed by (doc id, doc text allocation, subgraph id): the Session
    /// API accepts arbitrary caller-built documents, so ids alone are not
    /// unique and must not alias cache entries across different texts.
    cache: Mutex<HashMap<(u64, usize, usize), CacheEntry>>,
    /// Host-CPU route for subgraphs the adaptive router keeps off the
    /// devices — stateless per call, so sharing one across workers is
    /// free.
    software: SoftwareSubgraphRunner,
    /// Per-subgraph fraction of the plan's total modeled cost
    /// ([`crate::optimizer::cost::estimate`] at a nominal document
    /// length). Amdahl's argument: offloading a fraction `f` of the work
    /// caps end-to-end speedup at `1/(1-f)`, so when `f < 0.5` the
    /// subgraph cannot even double throughput and is the first to route
    /// to the host once the devices saturate.
    hw_fraction: Vec<f64>,
    /// Queue depth at which the pool counts as saturated (half the
    /// configured submission-queue bound): past this point the modeled
    /// queue wait exceeds [`perfmodel::FpgaModel`]'s per-package fixed
    /// cost and cheap subgraphs stop paying for the trip.
    saturation_depth: u64,
}

impl AccelSubgraphRunner {
    /// Wrap a running service compiled from `plan`.
    pub fn new(service: Arc<AccelService>, plan: &PartitionPlan) -> AccelSubgraphRunner {
        let ext_schemas = plan
            .subgraphs
            .iter()
            .map(|s| s.body.ext_input_schemas())
            .collect();
        // cost shares are shape-driven; the exact document length only
        // scales every term, so a nominal length is enough for ratios
        const NOMINAL_DOC: usize = 2048;
        let body_costs: Vec<f64> = plan
            .subgraphs
            .iter()
            .map(|s| crate::optimizer::cost::estimate(&s.body, NOMINAL_DOC).total_cost)
            .collect();
        let total = crate::optimizer::cost::estimate(&plan.supergraph, NOMINAL_DOC).total_cost
            + body_costs.iter().sum::<f64>();
        let hw_fraction = body_costs
            .iter()
            .map(|&c| if total > 0.0 { c / total } else { 1.0 })
            .collect();
        let saturation_depth = (service.options().queue_depth as u64 / 2).max(1);
        AccelSubgraphRunner {
            subgraph_outputs: plan.subgraphs.iter().map(|s| s.outputs.len()).collect(),
            ext_schemas,
            cache: Mutex::new(HashMap::new()),
            software: SoftwareSubgraphRunner::new(plan),
            hw_fraction,
            saturation_depth,
            service,
        }
    }

    /// Dynamic SW-vs-HW routing, decided per submission from observed
    /// queue load rather than statically at partition time. Only active
    /// on a multi-device pool (single-device behaviour is unchanged) and
    /// only for single-output subgraphs — multi-output subgraphs go
    /// through the reply cache, and splitting their reads across two
    /// routes would leave parked entries behind. A subgraph routes to the
    /// host when its cost share is below the Amdahl break-even (< 0.5)
    /// AND every device queue is at least half full.
    fn route_software(&self, id: usize) -> bool {
        // a fully-dark pool (every circuit breaker rejecting) routes ALL
        // subgraphs to the host until a breaker's cooldown elapses and a
        // probe can go out — queueing onto dark devices would only feed
        // the failover chain. Safe for multi-output subgraphs too: the
        // software route recomputes per output read and never touches
        // the reply cache.
        if self.service.all_dark() {
            return true;
        }
        if self.service.devices() < 2 || self.subgraph_outputs[id] != 1 {
            return false;
        }
        if self.hw_fraction.get(id).copied().unwrap_or(1.0) >= 0.5 {
            return false;
        }
        self.service.min_queue_depth() >= self.saturation_depth
    }

    fn cache_key(doc: &Document, id: usize) -> (u64, usize, usize) {
        (doc.id, Arc::as_ptr(&doc.text) as *const u8 as usize, id)
    }

    /// Validate a `SubgraphExec` reference against the compiled plan —
    /// called unconditionally by both entry points so a miswired graph
    /// fails loudly instead of yielding empty tuples.
    fn validate(&self, id: usize, output_idx: usize) {
        assert!(
            id < self.subgraph_outputs.len(),
            "graph references subgraph #{id} but the plan compiled only {}",
            self.subgraph_outputs.len()
        );
        assert!(
            output_idx < self.subgraph_outputs[id],
            "subgraph #{id} has {} outputs, output_idx {output_idx} is out of range",
            self.subgraph_outputs[id]
        );
    }

    /// Consult the cache — called *before* any ext-stream conversion, so
    /// cache hits (every output after the first of a multi-output
    /// subgraph) do zero copying. Each hit burns one remaining read; the
    /// last one evicts the entry, releasing the reply batches to route
    /// home to the communication shard.
    fn take_cached(&self, id: usize, doc: &Document) -> Option<Arc<Vec<TupleBatch>>> {
        let key = Self::cache_key(doc, id);
        let mut cache = self.cache.lock().unwrap();
        let entry = cache.get_mut(&key)?;
        let outputs = entry.outputs.clone();
        entry.remaining -= 1;
        if entry.remaining == 0 {
            cache.remove(&key);
        }
        Some(outputs)
    }

    /// Submit-and-sleep, filling the per-(doc, subgraph) cache — shared
    /// by the row and batch entry points (which check
    /// [`Self::take_cached`] first).
    fn fetch(
        &self,
        id: usize,
        doc: &Document,
        tokens: &TokenIndex,
        ext: Vec<TupleBatch>,
    ) -> Arc<Vec<TupleBatch>> {
        let rx = self
            .service
            .submit(id, doc.clone(), Arc::new(tokens.clone()), ext);
        // document-per-thread: sleep until the package completes
        match rx.recv() {
            Ok(Ok(outputs)) => {
                // this call is the first of the subgraph's `uses` reads
                // for this document; cache only what later reads need
                let uses = self.subgraph_outputs[id];
                if uses > 1 {
                    let mut cache = self.cache.lock().unwrap();
                    if cache.len() > 1024 {
                        // backstop for outputs that are dead in the
                        // supergraph and therefore never read to zero
                        cache.clear();
                    }
                    cache.insert(
                        Self::cache_key(doc, id),
                        CacheEntry {
                            outputs: outputs.clone(),
                            remaining: uses - 1,
                        },
                    );
                }
                outputs
            }
            Ok(Err(SubmitError::Deadline { waited })) => {
                // typed unwind: the session worker's catch_unwind
                // classifies this as DocError::DeadlineExceeded instead
                // of a poison-document panic
                std::panic::panic_any(DeadlinePanic {
                    budget: fault::doc_budget().unwrap_or_default(),
                    waited,
                })
            }
            Ok(Err(e)) => panic!("accelerator error: {e}"),
            Err(_) => panic!("accelerator service shut down while waiting"),
        }
    }

    /// Number of replies currently parked in the cache (tests assert the
    /// self-eviction leaves nothing behind after a document completes).
    #[cfg(test)]
    fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl SubgraphRunner for AccelSubgraphRunner {
    fn run(
        &self,
        id: usize,
        output_idx: usize,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&[Tuple]],
    ) -> Vec<Tuple> {
        self.validate(id, output_idx);
        if let Some(r) = self.take_cached(id, doc) {
            return r[output_idx].to_tuples();
        }
        if self.route_software(id) {
            self.service.shared.pool.sw_routed.fetch_add(1, Ordering::Relaxed);
            return self.software.run(id, output_idx, doc, tokens, ext);
        }
        let ext_batches: Vec<TupleBatch> = ext
            .iter()
            .enumerate()
            .map(|(slot, rows)| match self.ext_schemas[id].get(slot) {
                Some(Some(schema)) => TupleBatch::from_rows(schema, rows),
                _ => TupleBatch::empty(),
            })
            .collect();
        self.fetch(id, doc, tokens, ext_batches)[output_idx].to_tuples()
    }

    fn run_batch(
        &self,
        id: usize,
        output_idx: usize,
        doc: &Document,
        tokens: &TokenIndex,
        ext: &[&TupleBatch],
        schema: &Schema,
    ) -> TupleBatch {
        self.validate(id, output_idx);
        if let Some(r) = self.take_cached(id, doc) {
            return r[output_idx].clone();
        }
        if self.route_software(id) {
            self.service.shared.pool.sw_routed.fetch_add(1, Ordering::Relaxed);
            return self.software.run_batch(id, output_idx, doc, tokens, ext, schema);
        }
        let ext_batches: Vec<TupleBatch> = ext.iter().map(|b| (*b).clone()).collect();
        let outputs = self.fetch(id, doc, tokens, ext_batches);
        // single-output subgraphs are not cached (fetch keeps no clone),
        // so this Arc is the sole owner: move the reply batch out instead
        // of deep-copying it — the reply's comm-origin buffers then flow
        // into the document result and go home when IT drops
        match Arc::try_unwrap(outputs) {
            Ok(mut v) => v.swap_remove(output_idx),
            Err(shared) => shared[output_idx].clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwcompiler::compile_subgraph;
    use crate::partition::{partition, PartitionMode, SoftwareSubgraphRunner};
    use crate::runtime::EngineSpec;

    const PERSON_ORG: &str = r#"
        create dictionary Orgs as ('IBM', 'IBM Research', 'Columbia University');
        create view Org as
          extract dictionary 'Orgs' on d.text as match from Document d;
        create view Person as
          extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name from Document d;
        create view PersonOrg as
          select p.name as person, o.match as org,
                 CombineSpans(p.name, o.match) as ctx
          from Person p, Org o
          where FollowsTok(p.name, o.match, 0, 4)
          consolidate on ctx using 'ContainedWithin';
        output view PersonOrg;
    "#;

    fn rows(out: &crate::exec::DocResult) -> Vec<Vec<String>> {
        let mut rows: Vec<Vec<String>> = out
            .views()
            .iter()
            .flat_map(|rows| rows.iter().map(|t| t.iter().map(|v| v.to_string()).collect()))
            .collect();
        rows.sort();
        rows
    }

    fn accel_vs_software(mode: PartitionMode, texts: &[&str]) {
        let g = crate::optimizer::optimize(&crate::aql::compile(PERSON_ORG).unwrap());
        let plan = partition(&g, mode);
        let configs: Vec<AccelConfig> = plan
            .subgraphs
            .iter()
            .map(|s| compile_subgraph(s).unwrap())
            .collect();
        let service = AccelService::start(
            configs,
            EngineSpec::Native,
            AccelOptions::default(),
        );
        let accel_exec = Executor::new(
            Arc::new(plan.supergraph.clone()),
            Arc::new(Profiler::disabled()),
        )
        .with_subgraph_runner(Arc::new(AccelSubgraphRunner::new(service.clone(), &plan)));
        let sw_exec = Executor::new(
            Arc::new(plan.supergraph.clone()),
            Arc::new(Profiler::disabled()),
        )
        .with_subgraph_runner(Arc::new(SoftwareSubgraphRunner::new(&plan)));

        for (i, t) in texts.iter().enumerate() {
            let doc = Document::new(i as u64, *t);
            assert_eq!(
                rows(&accel_exec.run_doc(&doc)),
                rows(&sw_exec.run_doc(&doc)),
                "mode {:?}, text {t:?}",
                mode
            );
        }
        let snap = service.metrics().snapshot();
        assert!(snap.packages > 0);
        assert!(snap.docs as usize >= texts.iter().filter(|t| !t.is_empty()).count());
        service.shutdown();
    }

    const SAMPLES: &[&str] = &[
        "Laura Chiticariu works at IBM Research in Almaden.",
        "Fred Reiss and Huaiyu Zhu are at IBM Research today.",
        "nothing in this one",
        "Eva Sitaridi is at Columbia University. Peter Hofstee visits IBM.",
        "",
    ];

    #[test]
    fn accel_equals_software_extract_only() {
        accel_vs_software(PartitionMode::ExtractOnly, SAMPLES);
    }

    #[test]
    fn accel_equals_software_single_subgraph() {
        accel_vs_software(PartitionMode::SingleSubgraph, SAMPLES);
    }

    #[test]
    fn accel_equals_software_multi_subgraph() {
        accel_vs_software(PartitionMode::MultiSubgraph, SAMPLES);
    }

    #[test]
    fn concurrent_workers_get_combined_packages() {
        let g = crate::optimizer::optimize(&crate::aql::compile(PERSON_ORG).unwrap());
        let plan = partition(&g, PartitionMode::SingleSubgraph);
        let configs: Vec<AccelConfig> = plan
            .subgraphs
            .iter()
            .map(|s| compile_subgraph(s).unwrap())
            .collect();
        let service = AccelService::start(
            configs,
            EngineSpec::Native,
            AccelOptions::default(),
        );
        let runner = Arc::new(AccelSubgraphRunner::new(service.clone(), &plan));
        let exec = Arc::new(
            Executor::new(
                Arc::new(plan.supergraph.clone()),
                Arc::new(Profiler::disabled()),
            )
            .with_subgraph_runner(runner),
        );
        let mut handles = Vec::new();
        for w in 0..8 {
            let exec = exec.clone();
            handles.push(std::thread::spawn(move || {
                for k in 0..16u64 {
                    let doc = Document::new(
                        w * 1000 + k,
                        format!("Laura Chiticariu works at IBM Research (doc {k})."),
                    );
                    let out = exec.run_doc(&doc);
                    assert_eq!(out["PersonOrg"].len(), 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = service.metrics().snapshot();
        assert_eq!(snap.docs, 8 * 16);
        // combining must happen: fewer packages than documents
        assert!(
            snap.packages < snap.docs,
            "no combining: {} packages for {} docs",
            snap.packages,
            snap.docs
        );
        service.shutdown();
    }

    #[test]
    fn reply_cache_self_evicts_once_every_output_is_read() {
        // ExtractOnly folds both extraction leaves into ONE subgraph with
        // several outputs: the reply must be cached across the reads of
        // one document and evicted by the last one, so the comm-origin
        // batches go home instead of parking in the cache
        let g = crate::optimizer::optimize(&crate::aql::compile(PERSON_ORG).unwrap());
        let plan = partition(&g, PartitionMode::ExtractOnly);
        assert!(
            plan.subgraphs.iter().any(|s| s.outputs.len() > 1),
            "precondition: a multi-output subgraph exercises the cache"
        );
        let configs: Vec<AccelConfig> = plan
            .subgraphs
            .iter()
            .map(|s| compile_subgraph(s).unwrap())
            .collect();
        let service = AccelService::start(configs, EngineSpec::Native, AccelOptions::default());
        let runner = Arc::new(AccelSubgraphRunner::new(service.clone(), &plan));
        let exec = Executor::new(
            Arc::new(plan.supergraph.clone()),
            Arc::new(Profiler::disabled()),
        )
        .with_subgraph_runner(runner.clone());
        for (i, text) in SAMPLES.iter().enumerate() {
            let out = exec.run_doc(&Document::new(i as u64, *text));
            let _ = out.total_tuples();
            assert_eq!(
                runner.cache_len(),
                0,
                "cache must be empty after doc {i} completes"
            );
        }
        service.shutdown();
    }

    #[test]
    fn oversized_document_is_rejected_cleanly() {
        let g = crate::optimizer::optimize(&crate::aql::compile(PERSON_ORG).unwrap());
        let plan = partition(&g, PartitionMode::ExtractOnly);
        let configs: Vec<AccelConfig> = plan
            .subgraphs
            .iter()
            .map(|s| compile_subgraph(s).unwrap())
            .collect();
        let service = AccelService::start(
            configs,
            EngineSpec::Native,
            AccelOptions::default(),
        );
        let big = "x".repeat(17000); // exceeds block=16384
        let doc = Document::new(0, big);
        let rx = service.submit(0, doc, Arc::new(TokenIndex::default()), vec![]);
        let res = rx.recv().unwrap();
        assert!(res.is_err(), "oversized doc must fail, not hang");
        service.shutdown();
    }
}
