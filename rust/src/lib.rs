//! # boost — "Giving Text Analytics a Boost", reproduced
//!
//! A SystemT-like declarative text-analytics engine (AQL subset → operator
//! graph → optimizer → multithreaded runtime) extended with the paper's
//! contribution: partitioning the operator graph into a software supergraph
//! and hardware-accelerated subgraphs (maximal convex subgraphs), a hardware
//! query compiler that configures a streaming multi-pattern matcher, and a
//! multi-threaded HW/SW communication interface that batches documents into
//! work packages.
//!
//! ## The streaming `Session` API
//!
//! The user-facing surface is a push-based pipeline: compile a query into
//! an [`Engine`](coordinator::Engine), resolve typed
//! [`ViewHandle`](exec::ViewHandle)s for the output views you care about,
//! open a [`Session`](coordinator::Session), and push documents as they
//! arrive. A bounded queue feeds the worker pool, so a producer that
//! outruns the engine blocks (`push` applies backpressure) instead of
//! exhausting memory — with queue depth `Q` and `T` threads, at most
//! `Q + T` documents are ever in flight. Results are delivered per
//! document through a [`ResultSink`](coordinator::ResultSink) (count-only,
//! collect, or callback) and per-view subscriptions:
//!
//! ```no_run
//! use std::sync::Arc;
//! use boost::prelude::*;
//!
//! # fn main() -> anyhow::Result<()> {
//! let engine = Engine::compile_aql(
//!     "create view Caps as extract regex /[A-Z][a-z]+/ on d.text as w \
//!      from Document d; output view Caps;",
//! )?;
//! let caps = engine.view("Caps")?; // typed handle, resolved once
//!
//! let sink = Arc::new(CollectSink::default());
//! let mut session = engine
//!     .session()
//!     .threads(4)
//!     .queue_depth(8) // ≤ 8 queued + 4 in workers, then push blocks
//!     .sink(sink.clone())
//!     .start();
//! for (i, text) in ["Alice met Bob", "nothing here"].iter().enumerate() {
//!     session.push(Document::new(i as u64, *text))?;
//! }
//! let report = session.finish();
//! for (_doc, result) in sink.take() {
//!     println!("{} tuples: {:?}", result.total_tuples(), result[&caps]);
//! }
//! println!("{} docs at {:.1} MB/s", report.docs, report.throughput() / 1e6);
//! # Ok(())
//! # }
//! ```
//!
//! One-off evaluation ([`Engine::run_doc`](coordinator::Engine::run_doc))
//! and whole-corpus runs ([`Engine::run_corpus`](coordinator::Engine::run_corpus))
//! are thin layers over the same machinery, and the accelerator's package
//! submissions flow through the same bounded-queue scheduler
//! ([`runtime::queue`]).
//!
//! ### Migrating from `DocOutput.views`
//!
//! The stringly-typed `DocOutput { views: HashMap<String, Vec<Tuple>> }`
//! surface is deprecated. `run_doc` now returns a typed
//! [`DocResult`](exec::DocResult): index it with a `ViewHandle`
//! (`result[&handle]`), by name (`result["Caps"]`, panicking, or
//! `result.by_name("Caps")`, fallible), or iterate `result.iter()`.
//! Code that genuinely needs the old shape can call
//! `DocResult::into_output()` during the transition.
//!
//! The "reconfigurable device" of the paper (a Stratix IV FPGA) is realised
//! as an AOT-compiled JAX/Pallas byte-stream DFA kernel executed through the
//! PJRT C API (`xla` crate, behind the `pjrt` cargo feature);
//! reconfiguration is table-driven (transition tables are runtime inputs),
//! and a calibrated performance model ([`perfmodel`]) reproduces the
//! paper's FPGA timing for the figures.
//!
//! ## The accelerator simulator and the differential test strategy
//!
//! When `pjrt` is off (the default build), the accelerator is the
//! **deterministic simulator** [`runtime::sim::SimPackageEngine`]: it
//! interprets the hwcompiler's compiled artifacts directly over packed
//! work packages and emits the exact hit-stream encoding the Pallas
//! kernel produces, plus device modelling the kernel can't give you in
//! CI — package validation (truncated transfers are rejected, not
//! scanned), cycle accounting (fed to [`perfmodel`] for modeled
//! throughput), configurable per-package latency (backpressure tests),
//! and deterministic fault injection (duplicated/reordered hit records,
//! failing packages) to drive the robustness path.
//!
//! Correctness rests on a three-route differential harness
//! (`rust/tests/differential.rs`): pure-software execution, the full
//! `Session` + `AccelService` pipeline over the simulator, and
//! synchronous `run_doc` on the simulated engine must produce
//! byte-identical views on randomized corpora. Golden-view snapshots
//! (`rust/tests/golden_views.rs`) pin the bundled queries' output shapes
//! over a committed corpus. See `TESTING.md` at the repo root for how to
//! switch engines, bless snapshots, and add golden queries.
//!
//! ## Layer map
//! * L3 (this crate): coordination — everything under [`aql`], [`aog`],
//!   [`exec`], [`partition`], [`hwcompiler`], [`accel`], [`coordinator`].
//! * L2 (build time): `python/compile/model.py` — the accelerated subgraph
//!   as a JAX function.
//! * L1 (build time): `python/compile/kernels/dfa_scan.py` — the Pallas
//!   multi-machine DFA scan kernel.

pub mod accel;
pub mod aog;
pub mod aql;
pub mod bench;
pub mod coordinator;
pub mod corpus;
pub mod dict;
pub mod exec;
pub mod hwcompiler;
pub mod metrics;
pub mod optimizer;
pub mod partition;
pub mod perfmodel;
pub mod queries;
pub mod regex;
pub mod runtime;
pub mod text;
pub mod util;

/// Convenience re-exports for the common user-facing API surface.
pub mod prelude {
    pub use crate::aog::{Graph, Schema, Tuple, Value};
    pub use crate::coordinator::{
        CallbackSink, CollectSink, CountingSink, Engine, EngineConfig, ResultSink, RunReport,
        Session, SessionBuilder,
    };
    pub use crate::corpus::{Corpus, CorpusSpec, Document};
    pub use crate::exec::{DocResult, Profile, ViewCatalog, ViewHandle};
    pub use crate::partition::PartitionPlan;
    pub use crate::perfmodel::FpgaModel;
    pub use crate::runtime::{EngineSpec, FaultPlan, SimSpec};
    pub use crate::text::Span;
}
