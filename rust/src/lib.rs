//! # boost — "Giving Text Analytics a Boost", reproduced
//!
//! A SystemT-like declarative text-analytics engine (AQL subset → operator
//! graph → optimizer → multithreaded runtime) extended with the paper's
//! contribution: partitioning the operator graph into a software supergraph
//! and hardware-accelerated subgraphs (maximal convex subgraphs), a hardware
//! query compiler that configures a streaming multi-pattern matcher, and a
//! multi-threaded HW/SW communication interface that batches documents into
//! work packages.
//!
//! The "reconfigurable device" of the paper (a Stratix IV FPGA) is realised
//! as an AOT-compiled JAX/Pallas byte-stream DFA kernel executed through the
//! PJRT C API (`xla` crate); reconfiguration is table-driven (transition
//! tables are runtime inputs), and a calibrated performance model
//! ([`perfmodel`]) reproduces the paper's FPGA timing for the figures.
//!
//! ## Layer map
//! * L3 (this crate): coordination — everything under [`aql`], [`aog`],
//!   [`exec`], [`partition`], [`hwcompiler`], [`accel`], [`coordinator`].
//! * L2 (build time): `python/compile/model.py` — the accelerated subgraph
//!   as a JAX function.
//! * L1 (build time): `python/compile/kernels/dfa_scan.py` — the Pallas
//!   multi-machine DFA scan kernel.

pub mod accel;
pub mod aog;
pub mod aql;
pub mod bench;
pub mod coordinator;
pub mod corpus;
pub mod dict;
pub mod exec;
pub mod hwcompiler;
pub mod metrics;
pub mod optimizer;
pub mod partition;
pub mod perfmodel;
pub mod queries;
pub mod regex;
pub mod runtime;
pub mod text;
pub mod util;

/// Convenience re-exports for the common user-facing API surface.
pub mod prelude {
    pub use crate::aog::{Graph, Schema, Tuple, Value};
    pub use crate::coordinator::{Engine, EngineConfig, RunReport};
    pub use crate::corpus::{Corpus, CorpusSpec, Document};
    pub use crate::exec::Profile;
    pub use crate::partition::PartitionPlan;
    pub use crate::perfmodel::FpgaModel;
    pub use crate::text::Span;
}
