//! # boost — "Giving Text Analytics a Boost", reproduced
//!
//! A SystemT-like declarative text-analytics engine (AQL subset → operator
//! graph → optimizer → multithreaded runtime) extended with the paper's
//! contribution: partitioning the operator graph into a software supergraph
//! and hardware-accelerated subgraphs (maximal convex subgraphs), a hardware
//! query compiler that configures a streaming multi-pattern matcher, and a
//! multi-threaded HW/SW communication interface that batches documents into
//! work packages.
//!
//! ## The query catalog: one engine, many AQL programs
//!
//! The paper's deployment is *not* one accelerator per query: SystemT's
//! extended compilation flow folds the regex and dictionary extraction
//! operators of **all** deployed queries into a single FPGA image, shared
//! by every query's document stream (§III–IV). The user-facing surface
//! mirrors that: register any number of AQL programs in a
//! [`CatalogBuilder`](coordinator::CatalogBuilder), and the engine merges
//! them into one shared operator supergraph — common `DocScan`,
//! structurally-interned extraction leaves (identical patterns across
//! queries compile to **one** machine) — then optimizes, partitions, and
//! hardware-compiles the merged graph **once**. Every pushed document is
//! evaluated against all registered queries in a single pass; results are
//! addressed through namespaced handles
//! ([`QueryHandle`](coordinator::QueryHandle) →
//! [`ViewHandle`](exec::ViewHandle)):
//!
//! ```no_run
//! use std::sync::Arc;
//! use boost::prelude::*;
//!
//! # fn main() -> anyhow::Result<()> {
//! let engine = Engine::builder()
//!     .register_builtin("t1") // named entities
//!     .register_builtin("t2") // contact information
//!     .register(
//!         "caps",
//!         "create view Caps as extract regex /[A-Z][a-z]+/ on d.text as w \
//!          from Document d; output view Caps;",
//!     )
//!     .config(EngineConfig::simulated(PartitionMode::ExtractOnly))
//!     .build()?; // ONE plan, ONE artifact set, ONE AccelService
//!
//! // namespaced, typed handles — resolved once
//! let entities = engine.query("t1")?.view("EntitiesClean")?;
//! let caps = engine.query("caps")?.view("Caps")?;
//!
//! let sink = Arc::new(CollectSink::default());
//! let mut session = engine
//!     .session()
//!     .threads(4)
//!     .queue_depth(8) // ≤ 8 queued + 4 in workers, then push blocks
//!     .sink(sink.clone())
//!     .start();
//! for (i, text) in ["Alice met Bob at IBM", "nothing here"].iter().enumerate() {
//!     session.push(Document::new(i as u64, *text))?;
//! }
//! let report = session.finish();
//! for (_doc, result) in sink.take() {
//!     // one evaluation pass produced every query's views
//!     println!("{} entities, {} caps", result[&entities].len(), result[&caps].len());
//! }
//! println!("{} docs at {:.1} MB/s", report.docs, report.throughput() / 1e6);
//! # Ok(())
//! # }
//! ```
//!
//! A bounded queue feeds the worker pool, so a producer that outruns the
//! engine blocks (`push` applies backpressure) instead of exhausting
//! memory — with queue depth `Q` and `T` threads, at most `Q + T`
//! documents are ever in flight. Results are delivered per document
//! through a [`ResultSink`](coordinator::ResultSink) (count-only,
//! collect, or callback), per-view subscriptions
//! ([`SessionBuilder::subscribe`](coordinator::SessionBuilder::subscribe)),
//! and per-query subscriptions
//! ([`SessionBuilder::subscribe_query`](coordinator::SessionBuilder::subscribe_query)).
//!
//! One-off evaluation ([`Engine::run_doc`](coordinator::Engine::run_doc))
//! and whole-corpus runs ([`Engine::run_corpus`](coordinator::Engine::run_corpus))
//! are thin layers over the same machinery, and the accelerator's package
//! submissions flow through the same bounded-queue scheduler
//! ([`runtime::queue`]).
//!
//! ### Migrating from single-query `compile_aql`
//!
//! [`Engine::compile_aql`](coordinator::Engine::compile_aql) remains as
//! the one-entry convenience wrapper: view names stay unqualified,
//! existing [`ViewHandle`](exec::ViewHandle)s stay valid, and
//! `engine.view("Caps")` resolves exactly as before. In a multi-query
//! catalog, views are namespaced (`"t1.Entities"`);
//! [`Engine::view`](coordinator::Engine::view) still accepts the bare
//! name when it is unambiguous across the catalog, and errors with the
//! qualified candidates when it is not.
//!
//! ### Migrating from `DocOutput.views`
//!
//! The stringly-typed `DocOutput { views: HashMap<String, Vec<Tuple>> }`
//! surface is deprecated. `run_doc` returns a typed
//! [`DocResult`](exec::DocResult): index it with a `ViewHandle`
//! (`result[&handle]`), by name (`result["Caps"]`, panicking, or
//! `result.by_name("Caps")`, fallible), or iterate `result.iter()`.
//! Code that genuinely needs the old shape can call
//! `DocResult::into_output()` during the transition.
//!
//! ## The `TupleBatch` boundary and the return-to-origin arena
//!
//! Internally the software executor is **columnar**: every operator
//! consumes and produces [`exec::TupleBatch`]es — one typed buffer per
//! column (spans/ints/floats/bools/strings + a lazily-allocated null
//! bitmap), with buffers recycled through a process-level **sharded
//! arena** ([`exec::batch`]) instead of allocating per tuple per
//! operator. Every thread is homed on one shard (session workers and the
//! accelerator's communication thread pin stable shards), every
//! checked-out buffer is stamped with its origin shard, and dropping a
//! buffer routes it **back to its origin** — so batches that cross the
//! HW/SW boundary (submissions, replies, collected results) refill the
//! pools their producers draw from, and *both* execution routes serve a
//! warm document with zero fresh buffer allocations. Rows
//! (`Tuple = Vec<Value>`) exist only at the API boundary: a `DocResult`
//! holds batches and materializes `Vec<Tuple>` views **lazily on first
//! row-shaped access** (`result[&handle]`, `result.views()`, view
//! subscriptions), while counting ([`exec::DocResult::total_tuples`]) and
//! columnar access ([`exec::DocResult::view_batch`]) never convert. The
//! seed's row-at-a-time pipeline survives behind
//! [`exec::ExecStrategy::LegacyRows`] purely as the reference baseline
//! for the columnar differential suite (`rust/tests/columnar.rs`) and
//! `repro bench`'s old-vs-new measurement (`BENCH_5.json`); see
//! `PERFORMANCE.md` at the repo root for the layout, the arena lifecycle
//! and how to read the benchmark output, and `ARCHITECTURE.md` for how
//! the modules map onto the paper.
//!
//! The "reconfigurable device" of the paper (a Stratix IV FPGA) is realised
//! as an AOT-compiled JAX/Pallas byte-stream DFA kernel executed through the
//! PJRT C API (`xla` crate, behind the `pjrt` cargo feature);
//! reconfiguration is table-driven (transition tables are runtime inputs),
//! and a calibrated performance model ([`perfmodel`]) reproduces the
//! paper's FPGA timing for the figures.
//!
//! ## The accelerator simulator and the differential test strategy
//!
//! When `pjrt` is off (the default build), the accelerator is the
//! **deterministic simulator** [`runtime::sim::SimPackageEngine`]: it
//! interprets the hwcompiler's compiled artifacts directly over packed
//! work packages and emits the exact hit-stream encoding the Pallas
//! kernel produces, plus device modelling the kernel can't give you in
//! CI — package validation (truncated transfers are rejected, not
//! scanned), cycle accounting (fed to [`perfmodel`] for modeled
//! throughput), configurable per-package latency (backpressure tests),
//! and deterministic fault injection (duplicated/reordered hit records,
//! failing packages) to drive the robustness path.
//!
//! ## The serving tier: many clients, one engine
//!
//! `repro serve` ([`serve`]) exposes the engine over TCP: a
//! thread-per-connection server (std only, no async runtime) where each
//! accepted connection gets its own bounded-queue `Session` onto the
//! **shared** engine, so N clients multiplex onto one supergraph, one
//! accelerator service, one arena. The wire protocol is length-prefixed
//! binary frames; result batches cross the wire **columnar** (spans as
//! i32 pairs, mirroring `accel/packing`) without re-materializing rows.
//! Backpressure is per-connection — a slow reader blocks only its own
//! connection's pipeline, accounted in `blocked_ns` — admission control
//! answers `Busy` past a connection cap, and a second port serves
//! `GET /metrics` (hand-rolled HTTP/1.0) with JSON gauges.
//! `repro serve --selftest` spins the server on an ephemeral port,
//! drives it with K concurrent clients over a randomized corpus, and
//! verifies the results byte-identical to `run_doc` (`BENCH_6.json`).
//!
//! Correctness rests on a three-route differential harness
//! (`rust/tests/differential.rs`): pure-software execution, the full
//! `Session` + `AccelService` pipeline over the simulator, and
//! synchronous `run_doc` on the simulated engine must produce
//! byte-identical views on randomized corpora. Golden-view snapshots
//! (`rust/tests/golden_views.rs`) pin the bundled queries' output shapes
//! over a committed corpus. See `TESTING.md` at the repo root for how to
//! switch engines, bless snapshots, and add golden queries.
//!
//! ## Layer map
//! * L3 (this crate): coordination — everything under [`aql`], [`aog`],
//!   [`exec`], [`partition`], [`hwcompiler`], [`accel`], [`coordinator`].
//! * L2 (build time): `python/compile/model.py` — the accelerated subgraph
//!   as a JAX function.
//! * L1 (build time): `python/compile/kernels/dfa_scan.py` — the Pallas
//!   multi-machine DFA scan kernel.

#![warn(missing_docs)]

/// Counting global allocator (see `util::alloc`): lets `repro bench` and
/// the columnar tests report measured allocations/document.
#[cfg(feature = "bench-alloc")]
#[global_allocator]
static GLOBAL_ALLOCATOR: util::alloc::CountingAllocator = util::alloc::CountingAllocator;

pub mod accel;
pub mod analysis;
pub mod aog;
pub mod aql;
pub mod bench;
pub mod coordinator;
pub mod corpus;
pub mod dict;
pub mod exec;
pub mod hwcompiler;
pub mod metrics;
pub mod optimizer;
pub mod partition;
pub mod perfmodel;
pub mod queries;
pub mod regex;
pub mod runtime;
pub mod serve;
pub mod text;
pub mod util;

/// Convenience re-exports for the common user-facing API surface.
pub mod prelude {
    pub use crate::analysis::{Diagnostic, Report, Severity};
    pub use crate::aog::{Graph, Schema, Tuple, Value};
    pub use crate::coordinator::{
        CallbackSink, CatalogBuilder, CollectSink, CountingSink, Engine, EngineConfig,
        QueryHandle, RejectedQuery, ResultSink, RunReport, Session, SessionBuilder,
    };
    pub use crate::corpus::{Corpus, CorpusSpec, Document};
    pub use crate::exec::{
        DocResult, ExecStrategy, Profile, TupleBatch, ViewCatalog, ViewHandle,
    };
    pub use crate::partition::{PartitionMode, PartitionPlan};
    pub use crate::perfmodel::FpgaModel;
    pub use crate::runtime::{EngineSpec, FaultPlan, SimSpec};
    pub use crate::text::Span;
}
