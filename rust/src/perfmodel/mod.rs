//! Calibrated FPGA timing model + the paper's Eq. 1 estimator.
//!
//! We have no Stratix IV; functional correctness runs through the PJRT
//! path, while the *figures* use this model, calibrated to the paper's own
//! constants and measurements:
//!
//! * accelerator clock 250 MHz, four parallel streams at one byte per
//!   cycle per stream → raw streaming bandwidth `BW_RAW = 1 GB/s`;
//! * measured interface ceiling `PEAK = 500 MB/s` (paper §4.2: "maximum
//!   peak bandwidth of 500 MB/s" with four streams);
//! * per-package fixed cost `T_PKG = 5 µs` (doorbell + work-queue entry;
//!   the paper's ">1000 bytes should be transferred at once" rule exists
//!   to amortize exactly this);
//! * per-document interface overhead `T_DOC`: software CAPI address
//!   translation happens in the communication thread per submission
//!   (paper §3), plus per-document record bookkeeping. Calibrated from
//!   Fig 6 at the paper's 16 KiB package size: 256-byte documents reach
//!   one fifth of peak (100 MB/s) →
//!   `T_PKG + 64·T_DOC + 16384/BW_RAW = 16384/100 MB/s` → `T_DOC = 2.226 µs`.
//!   Cross-checks: 128 B → 53.5 MB/s (the paper's "factor of ten" below
//!   peak); 2 kB → 84 % of peak and 4 kB → peak (the paper reports peak
//!   "at 2 kB or larger"; the model is conservative by ~15 % at exactly
//!   2 kB, which we accept as within figure-reading error);
//! * bus DMA bandwidth 2.5 GB/s (paper §4) — not the bottleneck, but it
//!   caps un-combined tiny transfers.
//!
//! Model: a package of `n` documents totalling `B` bytes takes
//! `T_PKG + n·T_DOC + B / BW_RAW` seconds, and sustained throughput is
//! additionally capped at `PEAK`.

/// Seconds-based timing model.
#[derive(Debug, Clone, Copy)]
pub struct FpgaModel {
    /// Raw streaming bandwidth, bytes/s (4 streams × 250 MHz × 1 B).
    pub bw_raw: f64,
    /// Sustained interface ceiling, bytes/s.
    pub peak: f64,
    /// Host bus DMA bandwidth, bytes/s.
    pub bw_bus: f64,
    /// Per-document interface overhead, s.
    pub t_doc: f64,
    /// Per-package fixed cost, s.
    pub t_pkg: f64,
}

impl FpgaModel {
    /// The paper-calibrated model (see module docs for the derivation).
    pub fn paper() -> FpgaModel {
        FpgaModel {
            bw_raw: 1.0e9,
            peak: 500.0e6,
            bw_bus: 2.5e9,
            t_doc: 2.226e-6,
            t_pkg: 5.0e-6,
        }
    }

    /// Modeled execution time of one work package (seconds).
    pub fn package_time(&self, total_bytes: usize, n_docs: usize) -> f64 {
        let b = total_bytes as f64;
        let stream = b / self.bw_raw;
        let dma = b / self.bw_bus;
        self.t_pkg + n_docs as f64 * self.t_doc + stream.max(dma)
    }

    /// The device clock implied by the model's raw bandwidth (4 streams at
    /// one byte per cycle per stream → `bw_raw / STREAMS` Hz; 250 MHz with
    /// the paper constants).
    pub fn clock_hz(&self) -> f64 {
        self.bw_raw / crate::hwcompiler::STREAMS as f64
    }

    /// Modeled package time from a *cycle count* reported by a simulated
    /// scan ([`crate::runtime::PackageHits::cycles`]) instead of payload
    /// bytes. A full package (payload = 4 × block) gives exactly
    /// [`FpgaModel::package_time`]; partial packages honestly charge the
    /// fixed-size block scan the device actually performs.
    pub fn package_time_cycles(&self, cycles: u64, n_docs: usize) -> f64 {
        self.t_pkg + n_docs as f64 * self.t_doc + cycles as f64 / self.clock_hz()
    }

    /// Sustained accelerator throughput (bytes/s) for uniform documents of
    /// `doc_size` bytes combined into packages of `pkg_bytes` — Fig 6.
    pub fn throughput(&self, doc_size: usize, pkg_bytes: usize) -> f64 {
        let n = (pkg_bytes / doc_size.max(1)).max(1);
        let bytes = n * doc_size;
        let t = self.package_time(bytes, n);
        (bytes as f64 / t).min(self.peak)
    }

    /// Throughput when each document is sent as its own package (the
    /// no-combining ablation — what the >1000 B rule prevents).
    pub fn throughput_uncombined(&self, doc_size: usize) -> f64 {
        let t = self.package_time(doc_size, 1);
        (doc_size as f64 / t).min(self.peak)
    }

    /// The paper's Eq. (1):
    /// `tp_est = 1 / (1/tp_HW + rt_SW / tp_SW)`
    /// where `rt_SW` is the *fraction* of software runtime that remains on
    /// the CPU after offload.
    pub fn eq1(tp_hw: f64, tp_sw: f64, rt_sw: f64) -> f64 {
        1.0 / (1.0 / tp_hw + rt_sw / tp_sw)
    }

    /// Fig 7 estimate for one scenario.
    ///
    /// * `tp_sw` — measured software throughput (64 threads), bytes/s;
    /// * `offload_frac` — profiled fraction of software time covered by
    ///   the offloaded operators (`1 - rt_SW`);
    /// * `doc_size`, `pkg_bytes` — accelerator operating point;
    /// * `passes` — accelerator passes over the document stream (1 for
    ///   extract-only and single-subgraph; the number of subgraphs for the
    ///   multi-subgraph scenario when modeled pessimistically — the paper
    ///   models it optimistically as 1, which we also default to).
    pub fn estimate(
        &self,
        tp_sw: f64,
        offload_frac: f64,
        doc_size: usize,
        pkg_bytes: usize,
        passes: usize,
    ) -> f64 {
        let tp_hw = self.throughput(doc_size, pkg_bytes) / passes.max(1) as f64;
        Self::eq1(tp_hw, tp_sw, (1.0 - offload_frac).max(0.0))
    }
}

impl Default for FpgaModel {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_shape_reproduced() {
        let m = FpgaModel::paper();
        let pkg = 16384;
        let tp_peak = m.throughput(4096, pkg);
        let tp_2048 = m.throughput(2048, pkg);
        let tp_256 = m.throughput(256, pkg);
        let tp_128 = m.throughput(128, pkg);
        // peak reached for large docs
        assert!((tp_peak - 500.0e6).abs() < 1.0, "{tp_peak}");
        // 2 kB docs near peak (model is ~15 % conservative at exactly 2 kB)
        assert!(tp_2048 > 0.80 * m.peak, "{tp_2048}");
        // 256 B ≈ peak / 5
        let r256 = m.peak / tp_256;
        assert!((4.5..5.5).contains(&r256), "peak/tp(256) = {r256}");
        // 128 B ≈ peak / 10
        let r128 = m.peak / tp_128;
        assert!((8.5..11.0).contains(&r128), "peak/tp(128) = {r128}");
        // monotone in document size
        let mut last = 0.0;
        for d in [128, 256, 512, 1024, 2048, 4096, 8192] {
            let tp = m.throughput(d, pkg);
            assert!(tp >= last, "throughput not monotone at {d}");
            last = tp;
        }
    }

    #[test]
    fn combining_beats_uncombined_for_small_docs() {
        let m = FpgaModel::paper();
        // the >1000 B rule: small docs pay T_PKG each without combining
        assert!(m.throughput(128, 16384) > 1.5 * m.throughput_uncombined(128));
        // large docs amortize T_PKG on their own, gap shrinks
        let ratio = m.throughput(4096, 16384) / m.throughput_uncombined(4096);
        assert!(ratio < 1.6, "{ratio}");
    }

    #[test]
    fn eq1_limits() {
        // no software remainder: estimate = hw throughput
        assert!((FpgaModel::eq1(500.0, 10.0, 0.0) - 500.0).abs() < 1e-9);
        // all software remains: estimate < sw throughput
        let e = FpgaModel::eq1(500.0, 10.0, 1.0);
        assert!(e < 10.0);
        // estimate is always below both bounds
        let e = FpgaModel::eq1(400.0, 50.0, 0.3);
        assert!(e < 400.0 && e < 50.0 / 0.3);
    }

    #[test]
    fn fig7_headline_magnitudes() {
        // With a T1-like profile (97 % of time in hw-supported operators)
        // and a software baseline ~28 MB/s at 64 threads, the 2 kB estimate
        // lands in the paper's ~16× band and 256 B in the ~10× band.
        let m = FpgaModel::paper();
        let tp_sw = 28.0e6;
        let est_2k = m.estimate(tp_sw, 0.97, 2048, 16384, 1);
        let est_256 = m.estimate(tp_sw, 0.97, 256, 16384, 1);
        let s2k = est_2k / tp_sw;
        let s256 = est_256 / tp_sw;
        assert!((10.0..18.0).contains(&s2k), "2 kB speedup {s2k}");
        assert!((2.0..12.0).contains(&s256), "256 B speedup {s256}");
        assert!(s2k > s256);
    }

    #[test]
    fn relational_heavy_query_gains_little_from_extract_only() {
        // T5: extraction is <20 % of runtime → extract-only offload caps
        // the speedup near 1/(1-0.2) = 1.25
        let m = FpgaModel::paper();
        let tp_sw = 80.0e6;
        let est = m.estimate(tp_sw, 0.18, 2048, 16384, 1);
        let speedup = est / tp_sw;
        assert!(speedup < 1.3, "{speedup}");
        // multi-subgraph offloading 85 % helps substantially (~3×)
        let est_multi = m.estimate(tp_sw, 0.85, 2048, 16384, 1);
        let s_multi = est_multi / tp_sw;
        assert!((2.0..7.0).contains(&s_multi), "{s_multi}");
    }

    #[test]
    fn package_time_components() {
        let m = FpgaModel::paper();
        let t = m.package_time(16384, 8);
        // fixed + 8 docs + stream time
        let expect = 5.0e-6 + 8.0 * 2.226e-6 + 16384.0 / 1.0e9;
        assert!((t - expect).abs() < 1e-12);
        // DMA cap engages only when bus slower than stream — never with
        // paper constants
        assert!(m.bw_bus > m.bw_raw);
    }

    #[test]
    fn cycle_model_agrees_with_byte_model_on_full_packages() {
        let m = FpgaModel::paper();
        assert!((m.clock_hz() - 250.0e6).abs() < 1.0);
        for &block in crate::hwcompiler::BLOCK_SIZES {
            // a full package streams 4 × block payload bytes in `block`
            // cycles — the two accountings must coincide
            let by_bytes = m.package_time(4 * block, 8);
            let by_cycles = m.package_time_cycles(block as u64, 8);
            assert!(
                (by_bytes - by_cycles).abs() < 1e-12,
                "block {block}: {by_bytes} vs {by_cycles}"
            );
            // a half-full package still pays the full block scan
            assert!(m.package_time_cycles(block as u64, 4) > m.package_time(2 * block, 4));
        }
    }

    #[test]
    fn passes_scale_down_throughput() {
        let m = FpgaModel::paper();
        let e1 = m.estimate(30.0e6, 0.9, 2048, 16384, 1);
        let e3 = m.estimate(30.0e6, 0.9, 2048, 16384, 3);
        assert!(e1 > e3);
    }
}
