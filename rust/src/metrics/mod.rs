//! Lightweight counters for the accelerator service and end-to-end runs.

use std::sync::atomic::{AtomicU64, Ordering};

/// Accumulated accelerator-side counters (one instance per service).
#[derive(Debug, Default)]
pub struct AccelMetrics {
    /// Work packages dispatched.
    pub packages: AtomicU64,
    /// Documents processed.
    pub docs: AtomicU64,
    /// Payload bytes shipped (excluding padding).
    pub bytes: AtomicU64,
    /// Sparse hit events returned by the engine.
    pub hits: AtomicU64,
    /// Wall nanoseconds spent in engine execution.
    pub engine_wall_ns: AtomicU64,
    /// Wall nanoseconds spent in the post-stage (span reconstruction +
    /// relational body).
    pub post_wall_ns: AtomicU64,
    /// Modeled FPGA nanoseconds (perfmodel package_time accumulation).
    pub modeled_ns: AtomicU64,
}

/// A point-in-time copy of [`AccelMetrics`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AccelSnapshot {
    pub packages: u64,
    pub docs: u64,
    pub bytes: u64,
    pub hits: u64,
    pub engine_wall_ns: u64,
    pub post_wall_ns: u64,
    pub modeled_ns: u64,
}

impl AccelMetrics {
    /// Add one package's worth of counters.
    pub fn record_package(
        &self,
        docs: u64,
        bytes: u64,
        hits: u64,
        engine_wall_ns: u64,
        post_wall_ns: u64,
        modeled_ns: u64,
    ) {
        self.packages.fetch_add(1, Ordering::Relaxed);
        self.docs.fetch_add(docs, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.hits.fetch_add(hits, Ordering::Relaxed);
        self.engine_wall_ns.fetch_add(engine_wall_ns, Ordering::Relaxed);
        self.post_wall_ns.fetch_add(post_wall_ns, Ordering::Relaxed);
        self.modeled_ns.fetch_add(modeled_ns, Ordering::Relaxed);
    }

    /// Snapshot all counters.
    pub fn snapshot(&self) -> AccelSnapshot {
        AccelSnapshot {
            packages: self.packages.load(Ordering::Relaxed),
            docs: self.docs.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            engine_wall_ns: self.engine_wall_ns.load(Ordering::Relaxed),
            post_wall_ns: self.post_wall_ns.load(Ordering::Relaxed),
            modeled_ns: self.modeled_ns.load(Ordering::Relaxed),
        }
    }
}

impl AccelSnapshot {
    /// Modeled accelerator throughput over the run (bytes/s).
    pub fn modeled_throughput(&self) -> f64 {
        if self.modeled_ns == 0 {
            0.0
        } else {
            self.bytes as f64 / (self.modeled_ns as f64 / 1e9)
        }
    }

    /// Average documents per package (the combining factor).
    pub fn docs_per_package(&self) -> f64 {
        if self.packages == 0 {
            0.0
        } else {
            self.docs as f64 / self.packages as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let m = AccelMetrics::default();
        m.record_package(8, 16384, 12, 1000, 500, 30_000);
        m.record_package(4, 8192, 3, 900, 400, 20_000);
        let s = m.snapshot();
        assert_eq!(s.packages, 2);
        assert_eq!(s.docs, 12);
        assert_eq!(s.bytes, 24576);
        assert_eq!(s.hits, 15);
        assert_eq!(s.docs_per_package(), 6.0);
        let tp = s.modeled_throughput();
        assert!((tp - 24576.0 / 50e-6).abs() / tp < 1e-9);
    }

    #[test]
    fn empty_snapshot_safe() {
        let s = AccelMetrics::default().snapshot();
        assert_eq!(s.modeled_throughput(), 0.0);
        assert_eq!(s.docs_per_package(), 0.0);
    }
}
