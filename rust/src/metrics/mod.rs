//! Lightweight counters for the accelerator service, bounded queues,
//! arena shards, and end-to-end runs.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Gauges for one bounded queue (a session's document-ingress queue or the
/// accelerator's submission queue). Shared by the producer and consumer
/// halves of [`crate::runtime::queue`]; all updates are relaxed — these
/// are observability counters, not synchronization.
#[derive(Debug, Default)]
pub struct QueueStats {
    /// Items accepted into the queue over its lifetime.
    pub pushed: AtomicU64,
    /// Pushes that found the queue full and had to block (backpressure
    /// events on the producer side).
    pub stalls: AtomicU64,
    /// Total wall nanoseconds producers spent blocked on a full queue —
    /// the backpressure *time*, complementing the stall *count*.
    pub blocked_ns: AtomicU64,
    /// Items currently queued (may transiently read negative under
    /// producer/consumer races; clamped to zero in snapshots).
    depth: AtomicI64,
    /// Maximum observed queue depth.
    high_water: AtomicI64,
}

impl QueueStats {
    /// Record one accepted push.
    pub fn on_push(&self) {
        self.pushed.fetch_add(1, Ordering::Relaxed);
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.high_water.fetch_max(d, Ordering::Relaxed);
    }

    /// Record one producer stall (queue was full).
    pub fn on_stall(&self) {
        self.stalls.fetch_add(1, Ordering::Relaxed);
    }

    /// Record how long a stalled producer stayed blocked.
    pub fn on_blocked(&self, ns: u64) {
        self.blocked_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one pop.
    pub fn on_pop(&self) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
    }

    /// Point-in-time copy.
    pub fn snapshot(&self) -> QueueSnapshot {
        QueueSnapshot {
            pushed: self.pushed.load(Ordering::Relaxed),
            stalls: self.stalls.load(Ordering::Relaxed),
            blocked_ns: self.blocked_ns.load(Ordering::Relaxed),
            depth: self.depth.load(Ordering::Relaxed).max(0) as u64,
            high_water: self.high_water.load(Ordering::Relaxed).max(0) as u64,
        }
    }
}

/// A point-in-time copy of [`QueueStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueSnapshot {
    /// Items accepted over the queue's lifetime.
    pub pushed: u64,
    /// Producer stalls (pushes that found the queue full).
    pub stalls: u64,
    /// Total producer-blocked wall time, ns.
    pub blocked_ns: u64,
    /// Items currently queued.
    pub depth: u64,
    /// Maximum observed depth.
    pub high_water: u64,
}

impl QueueSnapshot {
    /// Fold another queue's snapshot into this one — how the accelerator
    /// pool aggregates its per-device submission queues into the single
    /// queue view older callers expect. Counters and depth sum;
    /// `high_water` takes the max (per-queue peaks on different devices
    /// are not simultaneous, so summing them would overstate pressure).
    pub fn merge(&mut self, other: &QueueSnapshot) {
        self.pushed += other.pushed;
        self.stalls += other.stalls;
        self.blocked_ns += other.blocked_ns;
        self.depth += other.depth;
        self.high_water = self.high_water.max(other.high_water);
    }
}

/// Accumulated accelerator-side counters (one instance per service).
#[derive(Debug, Default)]
pub struct AccelMetrics {
    /// Work packages dispatched.
    pub packages: AtomicU64,
    /// Documents processed.
    pub docs: AtomicU64,
    /// Payload bytes shipped (excluding padding).
    pub bytes: AtomicU64,
    /// Sparse hit events returned by the engine.
    pub hits: AtomicU64,
    /// Wall nanoseconds spent in engine execution.
    pub engine_wall_ns: AtomicU64,
    /// Wall nanoseconds spent in the post-stage (span reconstruction +
    /// relational body).
    pub post_wall_ns: AtomicU64,
    /// Modeled FPGA nanoseconds (perfmodel package_time accumulation).
    pub modeled_ns: AtomicU64,
    /// Simulated device cycles reported by the package engine (zero when
    /// the engine has no cycle model).
    pub cycles: AtomicU64,
}

/// A point-in-time copy of [`AccelMetrics`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AccelSnapshot {
    /// Work packages dispatched.
    pub packages: u64,
    /// Documents processed.
    pub docs: u64,
    /// Payload bytes shipped.
    pub bytes: u64,
    /// Hit events returned.
    pub hits: u64,
    /// Wall nanoseconds in engine execution.
    pub engine_wall_ns: u64,
    /// Wall nanoseconds in the post-stage.
    pub post_wall_ns: u64,
    /// Modeled FPGA nanoseconds.
    pub modeled_ns: u64,
    /// Simulated device cycles.
    pub cycles: u64,
}

impl AccelMetrics {
    /// Add one package's worth of counters.
    #[allow(clippy::too_many_arguments)]
    pub fn record_package(
        &self,
        docs: u64,
        bytes: u64,
        hits: u64,
        engine_wall_ns: u64,
        post_wall_ns: u64,
        modeled_ns: u64,
        cycles: u64,
    ) {
        self.packages.fetch_add(1, Ordering::Relaxed);
        self.docs.fetch_add(docs, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.hits.fetch_add(hits, Ordering::Relaxed);
        self.engine_wall_ns.fetch_add(engine_wall_ns, Ordering::Relaxed);
        self.post_wall_ns.fetch_add(post_wall_ns, Ordering::Relaxed);
        self.modeled_ns.fetch_add(modeled_ns, Ordering::Relaxed);
        self.cycles.fetch_add(cycles, Ordering::Relaxed);
    }

    /// Snapshot all counters.
    pub fn snapshot(&self) -> AccelSnapshot {
        AccelSnapshot {
            packages: self.packages.load(Ordering::Relaxed),
            docs: self.docs.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            engine_wall_ns: self.engine_wall_ns.load(Ordering::Relaxed),
            post_wall_ns: self.post_wall_ns.load(Ordering::Relaxed),
            modeled_ns: self.modeled_ns.load(Ordering::Relaxed),
            cycles: self.cycles.load(Ordering::Relaxed),
        }
    }
}

impl AccelSnapshot {
    /// Modeled accelerator throughput over the run (bytes/s).
    pub fn modeled_throughput(&self) -> f64 {
        if self.modeled_ns == 0 {
            0.0
        } else {
            self.bytes as f64 / (self.modeled_ns as f64 / 1e9)
        }
    }

    /// Average documents per package (the combining factor).
    pub fn docs_per_package(&self) -> f64 {
        if self.packages == 0 {
            0.0
        } else {
            self.docs as f64 / self.packages as f64
        }
    }
}

/// Pool-level dispatch counters for a multi-device
/// [`AccelService`](crate::accel::AccelService) — the failover and
/// adaptive-routing evidence, kept apart from the per-package
/// [`AccelMetrics`] because they count *routing decisions*, not work.
#[derive(Debug, Default)]
pub struct PoolMetrics {
    /// Packages re-queued on a sibling device after a device error.
    pub retries: AtomicU64,
    /// Packages that ultimately succeeded on a device other than the one
    /// that first failed them (a completed failover).
    pub failovers: AtomicU64,
    /// Packages re-executed on the host CPU after every eligible device
    /// attempt failed (the last rung of the failover chain).
    pub sw_fallbacks: AtomicU64,
    /// Subgraph calls the adaptive router sent straight to the software
    /// route because every device queue was saturated and the cost model
    /// said offload would not pay.
    pub sw_routed: AtomicU64,
    /// Submissions answered with a deadline error instead of being run
    /// (expired at dequeue or after the post-stage).
    pub deadline_expired: AtomicU64,
}

impl PoolMetrics {
    /// Point-in-time copy. The three `breaker_*` fields are zero here —
    /// the breakers themselves are authoritative for those counts, and
    /// [`AccelService::pool_snapshot`](crate::accel::AccelService::pool_snapshot)
    /// sums them in on top of this.
    pub fn snapshot(&self) -> PoolSnapshot {
        PoolSnapshot {
            retries: self.retries.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            sw_fallbacks: self.sw_fallbacks.load(Ordering::Relaxed),
            sw_routed: self.sw_routed.load(Ordering::Relaxed),
            breaker_trips: 0,
            breaker_probes: 0,
            breaker_readmits: 0,
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of [`PoolMetrics`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolSnapshot {
    /// Packages re-queued on a sibling after a device error.
    pub retries: u64,
    /// Packages completed on a device other than the first one tried.
    pub failovers: u64,
    /// Packages re-executed on the host CPU after device attempts failed.
    pub sw_fallbacks: u64,
    /// Subgraph calls routed to software by the adaptive router.
    pub sw_routed: u64,
    /// Circuit-breaker trips across all devices.
    pub breaker_trips: u64,
    /// Half-open probe packages dispatched.
    pub breaker_probes: u64,
    /// Devices re-admitted after a successful probe.
    pub breaker_readmits: u64,
    /// Submissions answered with a deadline error instead of being run.
    pub deadline_expired: u64,
}

/// One pool device's gauges: its private package counters and its
/// submission queue, labeled with the device index. The aggregate across
/// devices remains available through the service-wide [`AccelMetrics`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AccelDeviceSnapshot {
    /// Device index within the pool (`0..devices`).
    pub device: usize,
    /// This device's package counters.
    pub accel: AccelSnapshot,
    /// This device's submission-queue gauges.
    pub queue: QueueSnapshot,
}

/// Counters for the serving tier (`crate::serve`). The server keeps one
/// aggregate instance for its whole lifetime plus one per live
/// connection; both are plain relaxed atomics, updated from the
/// connection's reader/writer threads and the session workers encoding
/// results.
#[derive(Debug, Default)]
pub struct ServeStats {
    /// Connections accepted past admission control.
    pub accepted: AtomicU64,
    /// Connections rejected with a `Busy` frame (over the cap).
    pub rejected: AtomicU64,
    /// Connections currently being served (gauge; aggregate only).
    pub active: AtomicI64,
    /// `Doc` frames accepted into a session.
    pub docs: AtomicU64,
    /// Document payload bytes received.
    pub bytes_in: AtomicU64,
    /// `Result` frames produced.
    pub results: AtomicU64,
    /// Bytes written to clients (frames, including prefixes).
    pub bytes_out: AtomicU64,
    /// Connections torn down on a malformed/unexpected frame.
    pub protocol_errors: AtomicU64,
    /// Connections that vanished mid-stream (EOF or socket error before
    /// `Finish`). The server survives these by design.
    pub disconnects: AtomicU64,
    /// Producer stalls accumulated from closed connections' result
    /// queues (live queues are visible per connection).
    pub result_stalls: AtomicU64,
    /// Producer blocked-time accumulated from closed connections'
    /// result queues, ns — the backpressure evidence.
    pub result_blocked_ns: AtomicU64,
    /// `DocErr` frames produced: documents answered with a structured
    /// per-document error (deadline expiry, quarantined panic) instead of
    /// a `Result` frame.
    pub doc_errors: AtomicU64,
    /// The subset of `doc_errors` that were deadline expiries.
    pub deadline_expired: AtomicU64,
}

impl ServeStats {
    /// Point-in-time copy.
    pub fn snapshot(&self) -> ServeSnapshot {
        ServeSnapshot {
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed).max(0) as u64,
            docs: self.docs.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            results: self.results.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            disconnects: self.disconnects.load(Ordering::Relaxed),
            result_stalls: self.result_stalls.load(Ordering::Relaxed),
            result_blocked_ns: self.result_blocked_ns.load(Ordering::Relaxed),
            doc_errors: self.doc_errors.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
        }
    }

    /// Fold a closed connection's result-queue gauges into the
    /// aggregate, so backpressure evidence survives the connection.
    pub fn absorb_queue(&self, q: &QueueSnapshot) {
        self.result_stalls.fetch_add(q.stalls, Ordering::Relaxed);
        self.result_blocked_ns
            .fetch_add(q.blocked_ns, Ordering::Relaxed);
    }
}

/// A point-in-time copy of [`ServeStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeSnapshot {
    /// Connections accepted.
    pub accepted: u64,
    /// Connections rejected with `Busy`.
    pub rejected: u64,
    /// Connections currently active.
    pub active: u64,
    /// Documents accepted.
    pub docs: u64,
    /// Document payload bytes received.
    pub bytes_in: u64,
    /// Result frames produced.
    pub results: u64,
    /// Bytes written to clients.
    pub bytes_out: u64,
    /// Connections torn down on protocol errors.
    pub protocol_errors: u64,
    /// Mid-stream disconnects survived.
    pub disconnects: u64,
    /// Result-queue producer stalls (closed connections).
    pub result_stalls: u64,
    /// Result-queue producer blocked time, ns (closed connections).
    pub result_blocked_ns: u64,
    /// Documents answered with a structured `DocErr` frame.
    pub doc_errors: u64,
    /// The subset of `doc_errors` that were deadline expiries.
    pub deadline_expired: u64,
}

/// Process-wide gauges of the package byte-block pool (see
/// [`crate::exec::batch::take_block`]): the `STREAMS × block` `Vec<i32>`
/// buffers work packages are assembled into. Kept separate from
/// [`ArenaShardSnapshot`] — blocks live beside the five typed column
/// freelists but are a different currency (i32 byte-stream blocks, one
/// per in-flight package, checked out and returned on the communication
/// thread).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BlockPoolSnapshot {
    /// Block checkouts (pool hits + fresh allocations).
    pub checkouts: u64,
    /// Checkouts that had to allocate because every pool was empty.
    /// Flat after warm-up — the package-assembly half of the
    /// zero-fresh-allocation invariant.
    pub fresh: u64,
    /// Blocks returned to a pool.
    pub returns: u64,
    /// Blocks currently parked across all shard pools (thread-local
    /// caches excluded).
    pub pooled: usize,
}

/// Point-in-time gauges of ONE global arena shard (see
/// [`crate::exec::batch`] for the sharded return-to-origin arena these
/// describe). Produced by [`crate::exec::batch::shard_stats`]; one entry
/// per shard, in shard order.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaShardSnapshot {
    /// Shard index (stable for the life of the process).
    pub shard: usize,
    /// Buffer checkouts that reached this shard — freelist hits plus
    /// fresh allocations. Thread-local cache hits never touch the shard
    /// (no lock, no shared atomics) and are tracked per thread in
    /// [`crate::exec::batch::ArenaStats`] instead.
    pub checkouts: u64,
    /// Checkouts that had to allocate a fresh buffer (both the local
    /// cache and the shard freelist were empty). After warm-up this stops
    /// growing on BOTH execution routes — the invariant the steady-state
    /// tests pin.
    pub fresh: u64,
    /// Buffers returned by a thread homed on this shard (the fast,
    /// lock-free path through the thread-local cache).
    pub returns_local: u64,
    /// Buffers returned **home** by a thread homed on a *different* shard
    /// — the return-to-origin traffic: accelerator submissions dropped on
    /// the communication thread, reply batches released by workers,
    /// results collected on consumer threads.
    pub returns_cross: u64,
    /// Buffers currently parked in this shard's global freelists (the
    /// thread-local caches of threads homed here are not included).
    pub pooled: usize,
}

/// Process-wide totals across every arena shard.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaSnapshot {
    /// Total shard-reaching checkouts (freelist hits + fresh; local
    /// cache hits are per-thread, see [`ArenaShardSnapshot::checkouts`]).
    pub checkouts: u64,
    /// Total fresh (pool-miss) allocations.
    pub fresh: u64,
    /// Total same-shard returns.
    pub returns_local: u64,
    /// Total cross-shard (return-to-origin) returns.
    pub returns_cross: u64,
    /// Total buffers parked across all shard freelists.
    pub pooled: usize,
}

impl ArenaSnapshot {
    /// Sum per-shard gauges into process-wide totals.
    pub fn from_shards(shards: &[ArenaShardSnapshot]) -> ArenaSnapshot {
        let mut t = ArenaSnapshot::default();
        for s in shards {
            t.checkouts += s.checkouts;
            t.fresh += s.fresh;
            t.returns_local += s.returns_local;
            t.returns_cross += s.returns_cross;
            t.pooled += s.pooled;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let m = AccelMetrics::default();
        m.record_package(8, 16384, 12, 1000, 500, 30_000, 16384);
        m.record_package(4, 8192, 3, 900, 400, 20_000, 4096);
        let s = m.snapshot();
        assert_eq!(s.packages, 2);
        assert_eq!(s.docs, 12);
        assert_eq!(s.bytes, 24576);
        assert_eq!(s.hits, 15);
        assert_eq!(s.cycles, 16384 + 4096);
        assert_eq!(s.docs_per_package(), 6.0);
        let tp = s.modeled_throughput();
        assert!((tp - 24576.0 / 50e-6).abs() / tp < 1e-9);
    }

    #[test]
    fn empty_snapshot_safe() {
        let s = AccelMetrics::default().snapshot();
        assert_eq!(s.modeled_throughput(), 0.0);
        assert_eq!(s.docs_per_package(), 0.0);
    }

    #[test]
    fn queue_stats_track_depth_and_high_water() {
        let q = QueueStats::default();
        q.on_push();
        q.on_push();
        q.on_push();
        q.on_pop();
        q.on_stall();
        q.on_blocked(1_500);
        q.on_blocked(500);
        let s = q.snapshot();
        assert_eq!(s.pushed, 3);
        assert_eq!(s.depth, 2);
        assert_eq!(s.high_water, 3);
        assert_eq!(s.stalls, 1);
        assert_eq!(s.blocked_ns, 2_000);
    }

    #[test]
    fn arena_snapshot_aggregates_shards() {
        let shards = [
            ArenaShardSnapshot {
                shard: 0,
                checkouts: 10,
                fresh: 2,
                returns_local: 7,
                returns_cross: 1,
                pooled: 4,
            },
            ArenaShardSnapshot {
                shard: 1,
                checkouts: 5,
                fresh: 1,
                returns_local: 3,
                returns_cross: 2,
                pooled: 6,
            },
        ];
        let t = ArenaSnapshot::from_shards(&shards);
        assert_eq!(t.checkouts, 15);
        assert_eq!(t.fresh, 3);
        assert_eq!(t.returns_local, 10);
        assert_eq!(t.returns_cross, 3);
        assert_eq!(t.pooled, 10);
        assert_eq!(ArenaSnapshot::from_shards(&[]), ArenaSnapshot::default());
    }

    #[test]
    fn queue_snapshot_merge_sums_counters_and_maxes_high_water() {
        let mut a = QueueSnapshot {
            pushed: 10,
            stalls: 2,
            blocked_ns: 1_000,
            depth: 3,
            high_water: 5,
        };
        let b = QueueSnapshot {
            pushed: 4,
            stalls: 1,
            blocked_ns: 500,
            depth: 1,
            high_water: 7,
        };
        a.merge(&b);
        assert_eq!(a.pushed, 14);
        assert_eq!(a.stalls, 3);
        assert_eq!(a.blocked_ns, 1_500);
        assert_eq!(a.depth, 4);
        assert_eq!(a.high_water, 7, "peaks max, not sum");
        // merging the empty snapshot is the identity
        let before = a;
        a.merge(&QueueSnapshot::default());
        assert_eq!(a, before);
    }

    #[test]
    fn pool_metrics_snapshot_round_trips() {
        let p = PoolMetrics::default();
        p.retries.fetch_add(3, Ordering::Relaxed);
        p.failovers.fetch_add(2, Ordering::Relaxed);
        p.sw_fallbacks.fetch_add(1, Ordering::Relaxed);
        p.sw_routed.fetch_add(5, Ordering::Relaxed);
        let s = p.snapshot();
        assert_eq!(
            s,
            PoolSnapshot {
                retries: 3,
                failovers: 2,
                sw_fallbacks: 1,
                sw_routed: 5,
                ..PoolSnapshot::default()
            }
        );
        assert_eq!(PoolMetrics::default().snapshot(), PoolSnapshot::default());
    }

    #[test]
    fn queue_stats_negative_depth_clamped() {
        // a pop can be recorded before its push under producer/consumer
        // races; snapshots must clamp, not wrap
        let q = QueueStats::default();
        q.on_pop();
        let s = q.snapshot();
        assert_eq!(s.depth, 0);
        assert_eq!(s.high_water, 0);
    }
}
