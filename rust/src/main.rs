//! `repro` — CLI for the text-analytics accelerator reproduction.
//!
//! Subcommands (no external arg-parsing crate in the offline vendor set;
//! parsing is hand-rolled):
//!
//! ```text
//! repro queries                         list built-in queries T1–T5
//! repro explain   --query t1            dump the optimized operator graph + costs
//! repro partition --query t1 --mode multi   show supergraph + subgraphs (Fig 1)
//! repro profile   --query t1 [--docs N --doc-size B --threads T]   Fig 4 rows
//! repro run       --query t1 --mode single --engine pjrt [...]     end-to-end
//! repro stream    --query t1 [--threads T --queue Q --per-doc]     stdin firehose
//! ```

use std::collections::HashMap;
use std::io::BufRead;
use std::process::ExitCode;

use boost::coordinator::{CallbackSink, Engine, EngineConfig};
use boost::corpus::CorpusSpec;
use boost::partition::{partition, PartitionMode};
use boost::perfmodel::FpgaModel;
use boost::runtime::EngineSpec;
use boost::text::Document;
use boost::util::fmt_mbps;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let flags = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "queries" => cmd_queries(),
        "explain" => cmd_explain(&flags),
        "partition" => cmd_partition(&flags),
        "profile" => cmd_profile(&flags),
        "run" => cmd_run(&flags),
        "stream" => cmd_stream(&flags),
        "-h" | "--help" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "usage: repro <queries|explain|partition|profile|run|stream> [flags]
  --query <t1..t5>       built-in query (default t1)
  --aql <file>           AQL file instead of a built-in
  --mode <none|extract|single|multi>   offload scenario (default none)
  --engine <sim|native|pjrt>  accelerator backend (default sim — the
                         deterministic simulator; native is the minimal
                         reference scan; pjrt needs --features pjrt)
  --sim-latency-us <n>   simulator per-package latency (default 0)
  --artifacts <dir>      artifacts directory (default ./artifacts)
  --docs <n>             corpus size (default 200)
  --doc-size <bytes>     document size (default 2048)
  --kind <news|tweets|logs>  corpus kind (default news)
  --threads <n>          worker threads (default 8)
  --queue <n>            session queue depth (default 2x threads)
  --block <4096|16384>   package block bytes (default 16384)
stream reads one document per stdin line through a Session, e.g.:
  journalctl -f | repro stream --query t2 --threads 4 --per-doc
  --per-doc              print per-document tuple counts as they complete
  --view <name>          print each match of this output view";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            // boolean flags (next token absent or another --flag) get ""
            match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    m.insert(key.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    m.insert(key.to_string(), String::new());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    m
}

fn load_aql(flags: &HashMap<String, String>) -> Result<(String, String), String> {
    if let Some(path) = flags.get("aql") {
        let aql = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        return Ok((path.clone(), aql));
    }
    let name = flags.get("query").map(|s| s.as_str()).unwrap_or("t1");
    let q = boost::queries::builtin(name)
        .ok_or_else(|| format!("unknown query '{name}' (try `repro queries`)"))?;
    Ok((q.name.to_string(), q.aql))
}

fn corpus_for(flags: &HashMap<String, String>) -> CorpusSpec {
    let docs: usize = flags
        .get("docs")
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let size: usize = flags
        .get("doc-size")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2048);
    match flags.get("kind").map(|s| s.as_str()).unwrap_or("news") {
        "tweets" => CorpusSpec::tweets(docs, size),
        "logs" => CorpusSpec::logs(docs, size),
        _ => CorpusSpec::news(docs, size),
    }
}

fn engine_config(flags: &HashMap<String, String>) -> Result<EngineConfig, String> {
    let mode = PartitionMode::parse(flags.get("mode").map(|s| s.as_str()).unwrap_or("none"))
        .ok_or("bad --mode")?;
    let engine = match flags.get("engine").map(|s| s.as_str()).unwrap_or("sim") {
        "sim" => {
            let mut spec = boost::runtime::SimSpec::default();
            if let Some(v) = flags.get("sim-latency-us") {
                let us: u64 = v
                    .parse()
                    .map_err(|_| format!("bad --sim-latency-us '{v}' (expected microseconds)"))?;
                spec = spec.with_latency(std::time::Duration::from_micros(us));
            }
            EngineSpec::Sim(spec)
        }
        "native" => EngineSpec::Native,
        "pjrt" => EngineSpec::Pjrt {
            artifacts_dir: flags
                .get("artifacts")
                .cloned()
                .unwrap_or_else(|| "artifacts".into())
                .into(),
        },
        other => return Err(format!("bad --engine '{other}'")),
    };
    if flags.contains_key("sim-latency-us") && !matches!(engine, EngineSpec::Sim(_)) {
        return Err("--sim-latency-us only applies to --engine sim".into());
    }
    let mut cfg = EngineConfig::accelerated(mode, engine);
    if let Some(b) = flags.get("block").and_then(|s| s.parse().ok()) {
        cfg.accel.block = b;
    }
    Ok(cfg)
}

fn cmd_queries() -> Result<(), String> {
    for q in boost::queries::all() {
        println!("{:4}  {:26}  {}", q.name, q.title, q.profile_hint);
    }
    Ok(())
}

fn cmd_explain(flags: &HashMap<String, String>) -> Result<(), String> {
    let (name, aql) = load_aql(flags)?;
    let g = boost::aql::compile(&aql).map_err(|e| e.to_string())?;
    let opt = boost::optimizer::optimize(&g);
    println!("query {name}: {} nodes after optimization", opt.nodes.len());
    println!("{}", opt.dump());
    let cost = boost::optimizer::estimate(&opt, 2048);
    println!("estimated cost (2048 B docs): {:.0} units", cost.total_cost);
    Ok(())
}

fn cmd_partition(flags: &HashMap<String, String>) -> Result<(), String> {
    let (name, aql) = load_aql(flags)?;
    let mode = PartitionMode::parse(flags.get("mode").map(|s| s.as_str()).unwrap_or("multi"))
        .ok_or("bad --mode")?;
    let g = boost::optimizer::optimize(&boost::aql::compile(&aql).map_err(|e| e.to_string())?);
    let plan = partition(&g, mode);
    println!("query {name}, mode {}:", mode.name());
    println!("== supergraph ==\n{}", plan.supergraph.dump());
    for sg in &plan.subgraphs {
        println!(
            "== subgraph #{} ({} nodes, {} ext inputs, {} outputs) ==",
            sg.id,
            sg.orig_nodes.len(),
            sg.ext_inputs,
            sg.outputs.len()
        );
        println!("{}", sg.body.dump());
        match boost::hwcompiler::compile_subgraph(sg) {
            Ok(cfg) => println!(
                "   hw: {} machines, geometry {}x{}, artifact {} / {}, VMEM est {} KiB\n",
                cfg.machines.len(),
                cfg.geometry.0,
                cfg.geometry.1,
                cfg.artifact_key(4096).file_name(),
                cfg.artifact_key(16384).file_name(),
                cfg.vmem_estimate(16384) / 1024,
            ),
            Err(e) => println!("   hw compile FAILED: {e}\n"),
        }
    }
    Ok(())
}

fn cmd_profile(flags: &HashMap<String, String>) -> Result<(), String> {
    let (name, aql) = load_aql(flags)?;
    let threads: usize = flags
        .get("threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let engine = Engine::compile_aql(&aql).map_err(|e| e.to_string())?;
    let corpus = corpus_for(flags).generate();
    let report = engine.run_corpus(&corpus, threads);
    let profile = engine.profile();
    println!(
        "query {name}: {} docs x {} B, {} threads, {} tuples, {}",
        report.docs,
        corpus.docs.first().map(|d| d.len()).unwrap_or(0),
        report.threads,
        report.tuples,
        fmt_mbps(report.throughput()),
    );
    println!("-- relative operator time (Fig 4) --");
    for (op, pct) in profile.fig4_rows() {
        println!("  {op:20} {pct:5.1}%  {}", bar(pct));
    }
    println!(
        "  extraction fraction: {:.1}%",
        profile.fraction_extraction() * 100.0
    );
    Ok(())
}

fn bar(pct: f64) -> String {
    "#".repeat((pct / 2.0).round() as usize)
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<(), String> {
    let (name, aql) = load_aql(flags)?;
    let threads: usize = flags
        .get("threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let queue: usize = flags
        .get("queue")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2 * threads.max(1));
    let cfg = engine_config(flags)?;
    let mode = cfg.mode;
    let engine_name = cfg.engine.name();
    let engine = Engine::with_config(&aql, cfg).map_err(|e| e.to_string())?;
    let corpus = corpus_for(flags).generate();
    let mut session = engine
        .session()
        .threads(threads)
        .queue_depth(queue)
        .start();
    for doc in corpus.docs.iter().cloned() {
        session
            .push(doc)
            .map_err(|e| format!("session push failed: {e}"))?;
    }
    let report = session.finish();
    println!(
        "query {name} | mode {} | engine {engine_name} | {} docs x {} B | {} threads",
        mode.name(),
        report.docs,
        corpus.docs.first().map(|d| d.len()).unwrap_or(0),
        report.threads,
    );
    println!(
        "  wall {:8.1} ms   throughput {}   {} tuples",
        report.wall.as_secs_f64() * 1e3,
        fmt_mbps(report.throughput()),
        report.tuples,
    );
    if let Some(a) = report.accel {
        println!(
            "  accel: {} packages, {:.1} docs/pkg, {} hits, engine {:.1} ms, post {:.1} ms",
            a.packages,
            a.docs_per_package(),
            a.hits,
            a.engine_wall_ns as f64 / 1e6,
            a.post_wall_ns as f64 / 1e6,
        );
        println!(
            "  modeled FPGA throughput: {}",
            fmt_mbps(a.modeled_throughput())
        );
        if let Some(sim) = engine.sim_snapshot() {
            println!(
                "  sim: {} packages, {} device cycles, {} faults injected",
                sim.packages, sim.cycles, sim.faults
            );
        }
        let doc_size = corpus.docs.first().map(|d| d.len()).unwrap_or(2048);
        let profile_frac = 0.97; // conservative hw-supported fraction
        let est = FpgaModel::paper().estimate(
            report.throughput(),
            profile_frac,
            doc_size,
            engine.config().accel.block,
            1,
        );
        println!("  Eq.1 system estimate at this SW baseline: {}", fmt_mbps(est));
    }
    engine.shutdown();
    Ok(())
}

/// `repro stream`: the firehose scenario end-to-end — one document per
/// stdin line, pushed through a bounded [`Session`] so a fast producer is
/// throttled instead of exhausting memory.
///
/// [`Session`]: boost::coordinator::Session
fn cmd_stream(flags: &HashMap<String, String>) -> Result<(), String> {
    let (name, aql) = load_aql(flags)?;
    let threads: usize = flags
        .get("threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let queue: usize = flags
        .get("queue")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2 * threads.max(1));
    let per_doc = flags.contains_key("per-doc");
    let cfg = engine_config(flags)?;
    let mode = cfg.mode;
    let engine_name = cfg.engine.name();
    let engine = Engine::with_config(&aql, cfg).map_err(|e| e.to_string())?;

    let mut builder = engine.session().threads(threads).queue_depth(queue);
    if per_doc {
        builder = builder.sink(std::sync::Arc::new(CallbackSink::new(|doc, result| {
            println!("doc {}: {} tuples", doc.id, result.total_tuples());
        })));
    }
    if let Some(view_name) = flags.get("view") {
        let handle = engine.view(view_name).map_err(|e| e.to_string())?;
        let view_name = view_name.clone();
        builder = builder.subscribe(&handle, move |doc, rows| {
            for t in rows {
                let cells: Vec<String> = t
                    .iter()
                    .map(|v| match v {
                        boost::aog::Value::Span(s) => {
                            format!("{:?}", s.text(&doc.text))
                        }
                        other => other.to_string(),
                    })
                    .collect();
                println!("{view_name} doc {}: {}", doc.id, cells.join(" | "));
            }
        });
    }
    let mut session = builder.start();

    eprintln!(
        "streaming stdin through {name} | mode {} | engine {engine_name} | {threads} threads, queue {queue}",
        mode.name()
    );
    let stdin = std::io::stdin();
    for (i, line) in stdin.lock().lines().enumerate() {
        let line = line.map_err(|e| format!("stdin read failed: {e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        session
            .push(Document::new(i as u64, line))
            .map_err(|e| format!("session push failed: {e}"))?;
    }
    let queue_snap = session.queue_snapshot();
    let report = session.finish();
    eprintln!(
        "{} docs, {} tuples, {:.1} ms, {} | backpressure stalls: {}, queue high-water: {}",
        report.docs,
        report.tuples,
        report.wall.as_secs_f64() * 1e3,
        fmt_mbps(report.throughput()),
        queue_snap.stalls,
        queue_snap.high_water,
    );
    if let Some(a) = report.accel {
        eprintln!(
            "accel: {} packages, {:.1} docs/pkg, submit-queue stalls {}",
            a.packages,
            a.docs_per_package(),
            engine
                .accel_queue_snapshot()
                .map(|q| q.stalls)
                .unwrap_or(0),
        );
    }
    engine.shutdown();
    Ok(())
}
