//! `repro` — CLI for the text-analytics accelerator reproduction.
//!
//! Subcommands (no external arg-parsing crate in the offline vendor set;
//! parsing is hand-rolled):
//!
//! ```text
//! repro queries                         list built-in queries T1–T7
//! repro check     --queries t1,t2 | --aql f.aql   static plan verifier (E###/W###)
//! repro explain   --query t1            dump the optimized operator graph + costs
//! repro explain   --merged [--queries t1,t2]  dump the merged catalog supergraph
//! repro partition --query t1 --mode multi   show supergraph + subgraphs (Fig 1)
//! repro profile   --query t1 [--docs N --doc-size B --threads T]   Fig 4 rows
//! repro run       --query t1 --mode single --engine pjrt [...]     end-to-end
//! repro run       --queries t1,t2,t3 [...]  one engine, many queries, one pass
//! repro stream    --query t1 [--threads T --queue Q --per-doc]     stdin firehose
//! repro bench     [--json FILE]         perf trajectory rows → BENCH_5.json
//!                                       + corpus-agg row → BENCH_8.json
//! repro serve     [--addr H:P --admin H:P --max-conns N]  TCP serving tier
//! repro serve     --selftest [--clients K]  loopback load run → BENCH_6.json
//! repro chaos     [--seed N --duration S]   seeded fault-injection harness
//! ```

use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use boost::coordinator::{CallbackSink, Engine, EngineConfig, RunReport};
use boost::corpus::CorpusSpec;
use boost::partition::{partition, PartitionMode};
use boost::perfmodel::FpgaModel;
use boost::runtime::EngineSpec;
use boost::text::Document;
use boost::util::fmt_mbps;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let flags = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "queries" => cmd_queries(),
        "check" => cmd_check(&flags),
        "explain" => cmd_explain(&flags),
        "partition" => cmd_partition(&flags),
        "profile" => cmd_profile(&flags),
        "run" => cmd_run(&flags),
        "stream" => cmd_stream(&flags),
        "bench" => cmd_bench(&flags),
        "serve" => cmd_serve(&flags),
        "chaos" => cmd_chaos(&flags),
        "-h" | "--help" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "usage: repro <queries|check|explain|partition|profile|run|stream|bench|serve|chaos> [flags]
  --query <t1..t7>       built-in query (default t1)
  --queries <t1,t2,...>  register several built-ins in ONE catalog engine
                         (merged supergraph, one partition plan, one
                         accelerator image; run/explain)
  --merged               explain: dump the merged catalog (default: all seven)
  --aql <file>           AQL file instead of a built-in
  --mode <none|extract|single|multi>   offload scenario (default none)
  --engine <sim|native|pjrt>  accelerator backend (default sim — the
                         deterministic simulator; native is the minimal
                         reference scan; pjrt needs --features pjrt)
  --sim-latency-us <n>   simulator per-package latency (default 0)
  --artifacts <dir>      artifacts directory (default ./artifacts)
  --docs <n>             corpus size (default 200)
  --doc-size <bytes>     document size (default 2048)
  --kind <news|tweets|logs>  corpus kind (default news)
  --threads <n>          worker threads (default 8)
  --queue <n>            session queue depth (default 2x threads)
  --block <4096|16384>   package block bytes (default 16384)
  --devices <n>          accelerator pool size (default 1); each device gets
                         its own comm thread, queue, engine and arena shard,
                         submissions route to the least-loaded device
  --exec <columnar|legacy>  software executor pipeline (default columnar;
                         legacy is the row-at-a-time Vec<Tuple> baseline)
check runs the static plan verifier over each program — compile front,
graph invariants, per-rewrite verification, partition check, hardware
lint — printing coded diagnostics (E###/W###); nonzero exit on errors.
Defaults to mode extract so the hardware lint runs; --doc-size sets the
cost model's assumed document length.
stream reads one document per stdin line through a Session, e.g.:
  journalctl -f | repro stream --query t2 --threads 4 --per-doc
  --per-doc              print per-document tuple counts as they complete
  --view <name>          print each match of this output view
bench measures software vs sim-accelerated, single-query vs merged catalog,
and columnar vs the legacy row pipeline (old-vs-new, same run); with
--features bench-alloc it also reports measured allocations/document PER
PATH (legacy rows, columnar software, sim-accelerated) plus the arena's
fresh-buffer and return-to-origin gauges.
Machine-readable rows always land in BENCH_5.json:
  --json <file>          override the output path
bench always ends with the TextBenDS-style corpus-aggregation row — the
T6+T7 catalog (top-k terms, per-dictionary doc frequency) over the same
corpus, with the merged corpus tables — written to BENCH_8.json:
  --agg-json <file>      override the aggregation-row output path
with --devices N > 1, bench also measures the N-device pool against the
single-device baseline and writes the comparison to BENCH_7.json:
  --pool-json <file>     override the pool-comparison output path
serve exposes the engine over TCP — many clients, ONE shared engine:
  --addr <host:port>     protocol address (default 127.0.0.1:7171; port 0
                         picks an ephemeral port)
  --admin <host:port>    also serve GET /metrics (HTTP/1.0 JSON) here
  --max-conns <n>        admission cap; extra connections get Busy (default 64)
  --selftest             loopback self-test: ephemeral server + K concurrent
                         clients over a randomized corpus, results verified
                         byte-identical to run_doc, row written to BENCH_6.json
  --clients <k>          selftest client connections (default 8)
chaos drives the loopback server through seeded fault injection — poison
documents (injected panics), zero-budget deadlines, and a bricked device
window exercising the circuit breakers — and fails (nonzero exit) on any
hang, wrong error code, or survivor that is not byte-identical to the
pure-software reference:
  --seed <n>             fault-selection seed (default 42)
  --duration <s>         keep cycling rounds for at least this long (default 20)";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            // boolean flags (next token absent or another --flag) get ""
            match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    m.insert(key.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    m.insert(key.to_string(), String::new());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    m
}

fn load_aql(flags: &HashMap<String, String>) -> Result<(String, String), String> {
    if let Some(path) = flags.get("aql") {
        let aql = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        return Ok((path.clone(), aql));
    }
    let name = flags.get("query").map(|s| s.as_str()).unwrap_or("t1");
    let q = boost::queries::builtin(name)
        .ok_or_else(|| format!("unknown query '{name}' (try `repro queries`)"))?;
    Ok((q.name.to_string(), q.aql))
}

/// Parse `--queries t1,t2,...` into catalog entry names.
fn catalog_names(flags: &HashMap<String, String>) -> Option<Vec<String>> {
    flags.get("queries").map(|s| {
        s.split(',')
            .map(|q| q.trim().to_string())
            .filter(|q| !q.is_empty())
            .collect()
    })
}

/// Register `names` (built-ins) in one catalog engine.
fn build_catalog(names: &[String], config: EngineConfig) -> Result<Engine, String> {
    let mut b = Engine::builder().config(config);
    for n in names {
        b = b.register_builtin(n.clone());
    }
    b.build().map_err(|e| e.to_string())
}

/// The corpus kind actually used (unknown `--kind` values fall back to
/// news) — single source of truth for [`corpus_for`] and the bench JSON.
fn corpus_kind(flags: &HashMap<String, String>) -> &'static str {
    match flags.get("kind").map(|s| s.as_str()) {
        Some("tweets") => "tweets",
        Some("logs") => "logs",
        _ => "news",
    }
}

fn corpus_for(flags: &HashMap<String, String>) -> CorpusSpec {
    let docs: usize = flags
        .get("docs")
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let size: usize = flags
        .get("doc-size")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2048);
    match corpus_kind(flags) {
        "tweets" => CorpusSpec::tweets(docs, size),
        "logs" => CorpusSpec::logs(docs, size),
        _ => CorpusSpec::news(docs, size),
    }
}

fn engine_config(flags: &HashMap<String, String>) -> Result<EngineConfig, String> {
    let mode = PartitionMode::parse(flags.get("mode").map(|s| s.as_str()).unwrap_or("none"))
        .ok_or("bad --mode")?;
    let engine = match flags.get("engine").map(|s| s.as_str()).unwrap_or("sim") {
        "sim" => {
            let mut spec = boost::runtime::SimSpec::default();
            if let Some(v) = flags.get("sim-latency-us") {
                let us: u64 = v
                    .parse()
                    .map_err(|_| format!("bad --sim-latency-us '{v}' (expected microseconds)"))?;
                spec = spec.with_latency(std::time::Duration::from_micros(us));
            }
            EngineSpec::Sim(spec)
        }
        "native" => EngineSpec::Native,
        "pjrt" => EngineSpec::Pjrt {
            artifacts_dir: flags
                .get("artifacts")
                .cloned()
                .unwrap_or_else(|| "artifacts".into())
                .into(),
        },
        other => return Err(format!("bad --engine '{other}'")),
    };
    if flags.contains_key("sim-latency-us") && !matches!(engine, EngineSpec::Sim(_)) {
        return Err("--sim-latency-us only applies to --engine sim".into());
    }
    let mut cfg = EngineConfig::accelerated(mode, engine);
    if let Some(b) = flags.get("block").and_then(|s| s.parse().ok()) {
        cfg.accel.block = b;
    }
    if let Some(v) = flags.get("devices") {
        let n: usize = v
            .parse()
            .map_err(|_| format!("bad --devices '{v}' (expected a pool size ≥ 1)"))?;
        if n == 0 {
            return Err("--devices must be at least 1".into());
        }
        cfg.accel.devices = n;
    }
    if let Some(s) = flags.get("exec") {
        cfg.strategy = boost::exec::ExecStrategy::parse(s)
            .ok_or_else(|| format!("bad --exec '{s}' (columnar|legacy)"))?;
    }
    Ok(cfg)
}

fn cmd_queries() -> Result<(), String> {
    for q in boost::queries::all() {
        println!("{:4}  {:26}  {}", q.name, q.title, q.profile_hint);
    }
    Ok(())
}

/// `repro check`: the static plan verifier as a standalone gate. Runs
/// [`boost::analysis::check_query`] — compile front, graph invariants,
/// per-rewrite verification, partition check, hardware-feasibility lint —
/// over each named built-in (`--queries t1,t2,...`) or one `--aql` file,
/// printing coded diagnostics. Exit is nonzero when any program has an
/// error-severity diagnostic, so CI can gate on it.
fn cmd_check(flags: &HashMap<String, String>) -> Result<(), String> {
    // default to extract-only offload so the hardware lint actually runs
    let mode = PartitionMode::parse(flags.get("mode").map(|s| s.as_str()).unwrap_or("extract"))
        .ok_or("bad --mode")?;
    let doc_len: usize = flags
        .get("doc-size")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2048);
    let programs: Vec<(String, String)> = if let Some(names) = catalog_names(flags) {
        let mut v = Vec::with_capacity(names.len());
        for n in &names {
            let q = boost::queries::builtin(n)
                .ok_or_else(|| format!("unknown query '{n}' (try `repro queries`)"))?;
            v.push((q.name.to_string(), q.aql));
        }
        v
    } else {
        vec![load_aql(flags)?]
    };
    let mut failed = 0usize;
    let mut warnings = 0usize;
    for (name, src) in &programs {
        let report = boost::analysis::check_query(name, src, mode, doc_len);
        if report.is_clean() {
            println!("{name}: ok");
            continue;
        }
        println!("{name}:");
        println!("{}", report.render());
        if report.has_errors() {
            failed += 1;
        } else {
            warnings += 1;
        }
    }
    println!(
        "checked {} program(s) under mode {}: {} rejected, {} with warnings",
        programs.len(),
        mode.name(),
        failed,
        warnings,
    );
    if failed > 0 {
        return Err(format!("{failed} program(s) rejected by static analysis"));
    }
    Ok(())
}

fn cmd_explain(flags: &HashMap<String, String>) -> Result<(), String> {
    if flags.contains_key("merged") || flags.contains_key("queries") {
        return cmd_explain_merged(flags);
    }
    let (name, aql) = load_aql(flags)?;
    let g = boost::aql::compile(&aql).map_err(|e| e.to_string())?;
    let opt = boost::optimizer::optimize(&g);
    println!("query {name}: {} nodes after optimization", opt.nodes.len());
    println!("{}", opt.dump());
    let cost = boost::optimizer::estimate(&opt, 2048);
    println!("estimated cost (2048 B docs): {:.0} units", cost.total_cost);
    Ok(())
}

/// `repro explain --merged [--queries t1,t2]`: the catalog supergraph —
/// how many extraction leaves the merge interned, the shared plan, and
/// each query's namespaced views.
fn cmd_explain_merged(flags: &HashMap<String, String>) -> Result<(), String> {
    let names: Vec<String> = catalog_names(flags).unwrap_or_else(|| {
        boost::queries::all()
            .iter()
            .map(|q| q.name.to_string())
            .collect()
    });
    let mut single_leaves = 0usize;
    for n in &names {
        let q = boost::queries::builtin(n)
            .ok_or_else(|| format!("unknown query '{n}' (try `repro queries`)"))?;
        let g = boost::optimizer::optimize(
            &boost::aql::compile(&q.aql).map_err(|e| e.to_string())?,
        );
        single_leaves += g.extraction_leaves();
    }
    let engine = build_catalog(&names, EngineConfig::default())?;
    let g = engine.graph();
    println!(
        "merged catalog [{}]: {} nodes, {} output views",
        names.join(","),
        g.nodes.len(),
        g.outputs.len()
    );
    println!(
        "extraction leaves: {} merged vs {} across independent engines ({} interned away)",
        g.extraction_leaves(),
        single_leaves,
        single_leaves - g.extraction_leaves()
    );
    for q in engine.queries() {
        println!("  query {}: views {}", q.name(), q.view_names().join(", "));
    }
    println!("{}", g.dump());
    let cost = boost::optimizer::estimate(g, 2048);
    println!("estimated cost (2048 B docs): {:.0} units", cost.total_cost);
    Ok(())
}

fn cmd_partition(flags: &HashMap<String, String>) -> Result<(), String> {
    let (name, aql) = load_aql(flags)?;
    let mode = PartitionMode::parse(flags.get("mode").map(|s| s.as_str()).unwrap_or("multi"))
        .ok_or("bad --mode")?;
    let g = boost::optimizer::optimize(&boost::aql::compile(&aql).map_err(|e| e.to_string())?);
    let plan = partition(&g, mode);
    println!("query {name}, mode {}:", mode.name());
    println!("== supergraph ==\n{}", plan.supergraph.dump());
    for sg in &plan.subgraphs {
        println!(
            "== subgraph #{} ({} nodes, {} ext inputs, {} outputs) ==",
            sg.id,
            sg.orig_nodes.len(),
            sg.ext_inputs,
            sg.outputs.len()
        );
        println!("{}", sg.body.dump());
        match boost::hwcompiler::compile_subgraph(sg) {
            Ok(cfg) => println!(
                "   hw: {} machines, geometry {}x{}, artifact {} / {}, VMEM est {} KiB\n",
                cfg.machines.len(),
                cfg.geometry.0,
                cfg.geometry.1,
                cfg.artifact_key(4096).file_name(),
                cfg.artifact_key(16384).file_name(),
                cfg.vmem_estimate(16384) / 1024,
            ),
            Err(e) => println!("   hw compile FAILED: {e}\n"),
        }
    }
    Ok(())
}

fn cmd_profile(flags: &HashMap<String, String>) -> Result<(), String> {
    let (name, aql) = load_aql(flags)?;
    let threads: usize = flags
        .get("threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let engine = Engine::compile_aql(&aql).map_err(|e| e.to_string())?;
    let corpus = corpus_for(flags).generate();
    let report = engine.run_corpus(&corpus, threads);
    let profile = engine.profile();
    println!(
        "query {name}: {} docs x {} B, {} threads, {} tuples, {}",
        report.docs,
        corpus.docs.first().map(|d| d.len()).unwrap_or(0),
        report.threads,
        report.tuples,
        fmt_mbps(report.throughput()),
    );
    println!("-- relative operator time (Fig 4) --");
    for (op, pct) in profile.fig4_rows() {
        println!("  {op:20} {pct:5.1}%  {}", bar(pct));
    }
    println!(
        "  extraction fraction: {:.1}%",
        profile.fraction_extraction() * 100.0
    );
    Ok(())
}

fn bar(pct: f64) -> String {
    "#".repeat((pct / 2.0).round() as usize)
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<(), String> {
    if let Some(names) = catalog_names(flags) {
        return cmd_run_catalog(&names, flags);
    }
    let (name, aql) = load_aql(flags)?;
    let threads: usize = flags
        .get("threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let queue: usize = flags
        .get("queue")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2 * threads.max(1));
    let cfg = engine_config(flags)?;
    let mode = cfg.mode;
    let engine_name = cfg.engine.name();
    let engine = Engine::with_config(&aql, cfg).map_err(|e| e.to_string())?;
    let corpus = corpus_for(flags).generate();
    let mut session = engine
        .session()
        .threads(threads)
        .queue_depth(queue)
        .start();
    for doc in corpus.docs.iter().cloned() {
        session
            .push(doc)
            .map_err(|e| format!("session push failed: {e}"))?;
    }
    let report = session.finish();
    println!(
        "query {name} | mode {} | engine {engine_name} | {} docs x {} B | {} threads",
        mode.name(),
        report.docs,
        corpus.docs.first().map(|d| d.len()).unwrap_or(0),
        report.threads,
    );
    println!(
        "  wall {:8.1} ms   throughput {}   {} tuples",
        report.wall.as_secs_f64() * 1e3,
        fmt_mbps(report.throughput()),
        report.tuples,
    );
    if let Some(a) = report.accel {
        println!(
            "  accel: {} packages, {:.1} docs/pkg, {} hits, engine {:.1} ms, post {:.1} ms",
            a.packages,
            a.docs_per_package(),
            a.hits,
            a.engine_wall_ns as f64 / 1e6,
            a.post_wall_ns as f64 / 1e6,
        );
        println!(
            "  modeled FPGA throughput: {}",
            fmt_mbps(a.modeled_throughput())
        );
        if let Some(sim) = engine.sim_snapshot() {
            println!(
                "  sim: {} packages, {} device cycles, {} faults injected",
                sim.packages, sim.cycles, sim.faults
            );
        }
        if let Some(devices) = engine.accel_device_snapshots() {
            if devices.len() > 1 {
                for d in &devices {
                    println!(
                        "  device {}: {} packages, {} docs, queue high-water {}",
                        d.device, d.accel.packages, d.accel.docs, d.queue.high_water
                    );
                }
                if let Some(pool) = engine.accel_pool_snapshot() {
                    println!(
                        "  pool: {} retries, {} failovers, {} host fallbacks, {} sw-routed",
                        pool.retries, pool.failovers, pool.sw_fallbacks, pool.sw_routed
                    );
                }
            }
        }
        let doc_size = corpus.docs.first().map(|d| d.len()).unwrap_or(2048);
        let profile_frac = 0.97; // conservative hw-supported fraction
        let est = FpgaModel::paper().estimate(
            report.throughput(),
            profile_frac,
            doc_size,
            engine.config().accel.block,
            1,
        );
        println!("  Eq.1 system estimate at this SW baseline: {}", fmt_mbps(est));
    }
    engine.shutdown();
    Ok(())
}

/// `repro run --queries t1,t2,...`: the paper's deployment shape — one
/// engine serving every registered query from a single merged supergraph,
/// one partition plan, one accelerator image, one pass over the corpus.
fn cmd_run_catalog(names: &[String], flags: &HashMap<String, String>) -> Result<(), String> {
    let threads: usize = flags
        .get("threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let queue: usize = flags
        .get("queue")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2 * threads.max(1));
    let cfg = engine_config(flags)?;
    let mode = cfg.mode;
    let engine_name = cfg.engine.name();
    let engine = build_catalog(names, cfg)?;
    let corpus = corpus_for(flags).generate();

    // per-query tuple counters through query subscriptions
    let counters: Vec<Arc<AtomicUsize>> = engine
        .queries()
        .iter()
        .map(|_| Arc::new(AtomicUsize::new(0)))
        .collect();
    let mut builder = engine.session().threads(threads).queue_depth(queue);
    for (q, c) in engine.queries().iter().zip(&counters) {
        let c = c.clone();
        builder = builder.subscribe_query(q, move |_doc, qh, result| {
            c.fetch_add(qh.total_tuples(result), Ordering::Relaxed);
        });
    }
    let mut session = builder.start();
    for doc in corpus.docs.iter().cloned() {
        session
            .push(doc)
            .map_err(|e| format!("session push failed: {e}"))?;
    }
    let report = session.finish();
    println!(
        "catalog [{}] | mode {} | engine {engine_name} | {} docs x {} B | {} threads | ONE pass",
        names.join(","),
        mode.name(),
        report.docs,
        corpus.docs.first().map(|d| d.len()).unwrap_or(0),
        report.threads,
    );
    println!(
        "  wall {:8.1} ms   throughput {}   {} tuples total",
        report.wall.as_secs_f64() * 1e3,
        fmt_mbps(report.throughput()),
        report.tuples,
    );
    for (q, c) in engine.queries().iter().zip(&counters) {
        println!("    {:8} {:8} tuples", q.name(), c.load(Ordering::Relaxed));
    }
    if let Some(plan) = engine.plan() {
        println!(
            "  one partition plan: {} hw subgraph(s) | shared artifact set: {}",
            plan.subgraphs.len(),
            engine
                .artifact_keys()
                .iter()
                .map(|k| k.file_name())
                .collect::<Vec<_>>()
                .join(", "),
        );
    }
    if let Some(a) = report.accel {
        println!(
            "  accel: {} packages, {:.1} docs/pkg, {} hits, engine {:.1} ms, post {:.1} ms",
            a.packages,
            a.docs_per_package(),
            a.hits,
            a.engine_wall_ns as f64 / 1e6,
            a.post_wall_ns as f64 / 1e6,
        );
        if let Some(sim) = engine.sim_snapshot() {
            println!(
                "  sim: {} packages, {} device cycles, {} faults injected",
                sim.packages, sim.cycles, sim.faults
            );
        }
    }
    engine.shutdown();
    Ok(())
}

/// Steady-state allocations per document on single-threaded `run_doc`
/// (bench-alloc builds only) — the shared protocol in
/// `boost::util::alloc::allocations_per_unit`. For accelerated engines
/// the count covers the whole process, communication thread included, so
/// the number is honest about what the path really costs.
#[cfg(feature = "bench-alloc")]
fn allocs_per_doc(engine: &Engine, corpus: &boost::corpus::Corpus, reps: usize) -> f64 {
    boost::util::alloc::allocations_per_unit(
        || {
            for d in &corpus.docs {
                let _ = engine.run_doc(d);
            }
        },
        reps,
        corpus.docs.len(),
    )
}

/// Steady-state fresh **arena** (column-buffer) allocations per document:
/// warm one unmeasured pass, then difference the process-wide shard
/// `fresh` counters over `reps` measured passes. Zero on both execution
/// routes once the return-to-origin arena is warm.
#[cfg(feature = "bench-alloc")]
fn arena_fresh_per_doc(engine: &Engine, corpus: &boost::corpus::Corpus, reps: usize) -> f64 {
    for d in &corpus.docs {
        let _ = engine.run_doc(d); // warm-up, unmeasured
    }
    let before = engine.arena_snapshot().fresh;
    for _ in 0..reps.max(1) {
        for d in &corpus.docs {
            let _ = engine.run_doc(d);
        }
    }
    let after = engine.arena_snapshot().fresh;
    (after - before) as f64 / (reps.max(1) * corpus.docs.len().max(1)) as f64
}

/// Steady-state fresh **package-block** allocations over `reps` measured
/// passes (absolute count, not per-doc — the gate is that it stays 0):
/// the byte-block half of the allocation-free package-assembly claim.
/// Counters are process-global, so this runs right after
/// [`arena_fresh_per_doc`] left the pool warm.
#[cfg(feature = "bench-alloc")]
fn block_fresh_delta(engine: &Engine, corpus: &boost::corpus::Corpus, reps: usize) -> u64 {
    for d in &corpus.docs {
        let _ = engine.run_doc(d); // warm-up, unmeasured
    }
    let before = boost::exec::batch::block_pool_stats().fresh;
    for _ in 0..reps.max(1) {
        for d in &corpus.docs {
            let _ = engine.run_doc(d);
        }
    }
    boost::exec::batch::block_pool_stats().fresh - before
}

/// `repro bench`: the perf-trajectory rows — docs/sec and MB/s for
/// software vs sim-accelerated execution, each query alone vs the merged
/// T1–T7 catalog, and the columnar executor vs the legacy row pipeline
/// (old-vs-new, measured in the same run) — serialized to `BENCH_5.json`
/// (override with `--json <file>`). With `--features bench-alloc`, also
/// reports measured steady-state allocations/document on T1 for every
/// path — legacy rows, columnar software, and the sim-accelerated route —
/// plus the arena's fresh-buffer-per-doc and return-to-origin gauges.
/// Always ends with the TextBenDS-style corpus-aggregation row — the
/// T6+T7 catalog (top-k terms + per-dictionary document frequency) over
/// the same corpus, reporting docs/sec and the finished corpus tables —
/// written to `BENCH_8.json` (override with `--agg-json <file>`).
fn cmd_bench(flags: &HashMap<String, String>) -> Result<(), String> {
    let threads: usize = flags
        .get("threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let corpus = corpus_for(flags).generate();
    let doc_size = corpus.docs.first().map(|d| d.len()).unwrap_or(0);
    let kind = corpus_kind(flags);
    let names: Vec<String> = boost::queries::all()
        .iter()
        .map(|q| q.name.to_string())
        .collect();
    let sim_mode = PartitionMode::ExtractOnly;

    // unmeasured warm-up sweep (both pipelines over the full corpus)
    // before any measured row: the first engine to run must not absorb
    // one-time process costs (page faults, CPU frequency ramp, allocator
    // arena growth) that would bias the old-vs-new comparison
    for cfg in [EngineConfig::legacy_rows(), EngineConfig::default()] {
        let warm = build_catalog(&names, cfg)?;
        let _ = warm.run_corpus(&corpus, threads);
    }

    let mut rows: Vec<(String, &'static str, RunReport)> = Vec::new();
    for n in &names {
        let q = boost::queries::builtin(n).unwrap();
        let sw = Engine::compile_aql(&q.aql).map_err(|e| e.to_string())?;
        rows.push((n.clone(), "software", sw.run_corpus(&corpus, threads)));
        let hw = Engine::with_config(&q.aql, EngineConfig::simulated(sim_mode))
            .map_err(|e| e.to_string())?;
        rows.push((n.clone(), "sim", hw.run_corpus(&corpus, threads)));
        hw.shutdown();
    }
    let merged_name = "merged-t1..t7".to_string();
    // old-vs-new on the same catalog, same corpus, same process: the
    // legacy row pipeline first, then the columnar default
    let legacy = build_catalog(&names, EngineConfig::legacy_rows())?;
    rows.push((
        merged_name.clone(),
        "sw-legacy",
        legacy.run_corpus(&corpus, threads),
    ));
    let sw = build_catalog(&names, EngineConfig::default())?;
    rows.push((merged_name.clone(), "software", sw.run_corpus(&corpus, threads)));
    let hw = build_catalog(&names, EngineConfig::simulated(sim_mode))?;
    rows.push((merged_name.clone(), "sim", hw.run_corpus(&corpus, threads)));
    hw.shutdown();

    println!(
        "bench: {} docs x {doc_size} B, {threads} threads, sim mode {}",
        corpus.docs.len(),
        sim_mode.name()
    );
    println!(
        "  {:14} {:9} {:>10} {:>9} {:>10} {:>9}",
        "config", "engine", "docs/s", "MB/s", "tuples", "wall ms"
    );
    for (config, engine, r) in &rows {
        println!(
            "  {:14} {:9} {:>10.0} {:>9.2} {:>10} {:>9.1}",
            config,
            engine,
            r.docs_per_sec(),
            r.throughput() / 1e6,
            r.tuples,
            r.wall.as_secs_f64() * 1e3,
        );
    }
    let total_wall = |eng: &str| -> f64 {
        rows.iter()
            .filter(|(c, e, _)| *e == eng && !c.starts_with("merged"))
            .map(|(_, _, r)| r.wall.as_secs_f64())
            .sum()
    };
    let merged_row = |eng: &str| -> Option<&RunReport> {
        rows.iter()
            .find(|(c, e, _)| *e == eng && c.starts_with("merged"))
            .map(|(_, _, r)| r)
    };
    let (sw_single, sim_single) = (total_wall("software"), total_wall("sim"));
    let sw_merged = merged_row("software").map(|r| r.wall.as_secs_f64()).unwrap_or(f64::NAN);
    let sim_merged = merged_row("sim").map(|r| r.wall.as_secs_f64()).unwrap_or(f64::NAN);
    let columnar_dps = merged_row("software").map(|r| r.docs_per_sec()).unwrap_or(f64::NAN);
    let legacy_dps = merged_row("sw-legacy").map(|r| r.docs_per_sec()).unwrap_or(f64::NAN);
    let columnar_speedup = columnar_dps / legacy_dps;
    println!(
        "  five passes vs one: software {:.1} ms -> {:.1} ms ({:.2}x), sim {:.1} ms -> {:.1} ms ({:.2}x)",
        sw_single * 1e3,
        sw_merged * 1e3,
        sw_single / sw_merged,
        sim_single * 1e3,
        sim_merged * 1e3,
        sim_single / sim_merged,
    );
    println!(
        "  columnar vs legacy rows (merged catalog): {:.0} docs/s vs {:.0} docs/s ({:.2}x)",
        columnar_dps, legacy_dps, columnar_speedup,
    );

    // steady-state allocations/document on T1, per path (measured only
    // when the counting allocator is compiled in): legacy rows vs the
    // columnar software route vs the sim-accelerated route, plus the
    // arena's own fresh-buffer and return-to-origin gauges
    #[cfg(feature = "bench-alloc")]
    let alloc_json = {
        let q = boost::queries::builtin("t1").unwrap();
        let alloc_docs = 16usize;
        let alloc_doc_size = doc_size.max(256);
        let alloc_corpus = boost::corpus::CorpusSpec::news(alloc_docs, alloc_doc_size).generate();
        let leg = Engine::with_config(&q.aql, EngineConfig::legacy_rows())
            .map_err(|e| e.to_string())?;
        let col = Engine::compile_aql(&q.aql).map_err(|e| e.to_string())?;
        let sim = Engine::with_config(&q.aql, EngineConfig::simulated(sim_mode))
            .map_err(|e| e.to_string())?;
        let legacy_apd = allocs_per_doc(&leg, &alloc_corpus, 3);
        let columnar_apd = allocs_per_doc(&col, &alloc_corpus, 3);
        let sim_apd = allocs_per_doc(&sim, &alloc_corpus, 3);
        let columnar_afd = arena_fresh_per_doc(&col, &alloc_corpus, 3);
        let sim_afd = arena_fresh_per_doc(&sim, &alloc_corpus, 3);
        let sim_bfd = block_fresh_delta(&sim, &alloc_corpus, 3);
        let arena = sim.arena_snapshot();
        sim.shutdown();
        println!(
            "  allocations/doc (t1, steady state): legacy {legacy_apd:.0}, \
             columnar {columnar_apd:.0} ({:.1}x fewer), sim-accel {sim_apd:.0}",
            legacy_apd / columnar_apd,
        );
        println!(
            "  arena fresh buffers/doc: columnar {columnar_afd:.2}, \
             sim-accel {sim_afd:.2} (cross-thread returns routed home: {})",
            arena.returns_cross,
        );
        println!(
            "  package byte-block fresh allocs (steady state): {sim_bfd} \
             (pool {} blocks)",
            boost::exec::batch::block_pool_stats().pooled,
        );
        // the alloc measurement uses its own (smaller, single-threaded)
        // corpus — record it so the committed number documents its own
        // conditions even after CI merges sections from separate runs
        format!(
            "{{\"corpus\": {{\"docs\": {alloc_docs}, \"doc_size\": {alloc_doc_size}, \
             \"kind\": \"news\"}}, \
             \"t1_legacy_allocs_per_doc\": {legacy_apd:.2}, \
             \"t1_columnar_allocs_per_doc\": {columnar_apd:.2}, \
             \"t1_sim_allocs_per_doc\": {sim_apd:.2}, \
             \"t1_columnar_arena_fresh_per_doc\": {columnar_afd:.4}, \
             \"t1_sim_arena_fresh_per_doc\": {sim_afd:.4}, \
             \"t1_sim_block_fresh_delta\": {sim_bfd}, \
             \"arena_returns_cross\": {}, \
             \"reduction\": {:.2}}}",
            arena.returns_cross,
            legacy_apd / columnar_apd,
        )
    };
    #[cfg(not(feature = "bench-alloc"))]
    let alloc_json = "null".to_string();

    // machine-readable trajectory point
    let path = match flags.get("json") {
        Some(p) if !p.is_empty() => p.as_str(),
        _ => "BENCH_5.json",
    };
    let mut json = String::new();
    json.push_str("{\n  \"schema\": \"boost-bench-v3\",\n  \"measured\": true,\n");
    json.push_str(&format!(
        "  \"corpus\": {{\"docs\": {}, \"doc_size\": {doc_size}, \"kind\": \"{kind}\"}},\n",
        corpus.docs.len(),
    ));
    json.push_str(&format!(
        "  \"threads\": {threads},\n  \"sim_mode\": \"{}\",\n  \"runs\": [\n",
        sim_mode.name()
    ));
    for (i, (config, engine, r)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"config\": \"{config}\", \"engine\": \"{engine}\", \
             \"wall_s\": {:.6}, \"docs_per_sec\": {:.3}, \"mb_per_sec\": {:.6}, \
             \"tuples\": {}}}{}\n",
            r.wall.as_secs_f64(),
            r.docs_per_sec(),
            r.throughput() / 1e6,
            r.tuples,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"alloc\": {alloc_json},\n"));
    json.push_str(&format!(
        "  \"summary\": {{\"single_software_wall_s\": {sw_single:.6}, \
         \"merged_software_wall_s\": {sw_merged:.6}, \
         \"merged_vs_single_software_speedup\": {:.4}, \
         \"single_sim_wall_s\": {sim_single:.6}, \
         \"merged_sim_wall_s\": {sim_merged:.6}, \
         \"merged_vs_single_sim_speedup\": {:.4}, \
         \"merged_legacy_docs_per_sec\": {legacy_dps:.3}, \
         \"merged_columnar_docs_per_sec\": {columnar_dps:.3}, \
         \"columnar_vs_legacy_speedup\": {columnar_speedup:.4}}}\n}}\n",
        sw_single / sw_merged,
        sim_single / sim_merged,
    ));
    std::fs::write(path, json).map_err(|e| format!("write {path}: {e}"))?;
    println!("  wrote {path}");

    // pool-vs-single comparison: the same merged catalog and corpus
    // through a single simulated device and through an N-device pool
    // (least-queue-depth dispatch, per-device comm threads and arena
    // shards). Only measured when the pool is actually requested.
    let devices: usize = flags
        .get("devices")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    if devices > 1 {
        let single = build_catalog(&names, EngineConfig::simulated(sim_mode))?;
        let single_report = single.run_corpus(&corpus, threads);
        single.shutdown();
        let mut pool_cfg = EngineConfig::simulated(sim_mode);
        pool_cfg.accel.devices = devices;
        let pool = build_catalog(&names, pool_cfg)?;
        let pool_report = pool.run_corpus(&corpus, threads);
        let device_rows = pool.accel_device_snapshots().unwrap_or_default();
        let pool_counters = pool.accel_pool_snapshot().unwrap_or_default();
        pool.shutdown();
        let speedup = pool_report.docs_per_sec() / single_report.docs_per_sec();
        println!(
            "  pool vs single device ({devices} devices, merged catalog): \
             {:.0} docs/s vs {:.0} docs/s ({speedup:.2}x)",
            pool_report.docs_per_sec(),
            single_report.docs_per_sec(),
        );
        let pool_path = match flags.get("pool-json") {
            Some(p) if !p.is_empty() => p.as_str(),
            _ => "BENCH_7.json",
        };
        let mut pj = String::new();
        pj.push_str("{\n  \"schema\": \"boost-pool-bench-v1\",\n  \"measured\": true,\n");
        pj.push_str(&format!(
            "  \"corpus\": {{\"docs\": {}, \"doc_size\": {doc_size}, \"kind\": \"{kind}\"}},\n",
            corpus.docs.len(),
        ));
        pj.push_str(&format!(
            "  \"threads\": {threads},\n  \"sim_mode\": \"{}\",\n  \"devices\": {devices},\n",
            sim_mode.name()
        ));
        pj.push_str(&format!(
            "  \"single_docs_per_sec\": {:.3},\n  \"single_wall_s\": {:.6},\n",
            single_report.docs_per_sec(),
            single_report.wall.as_secs_f64(),
        ));
        pj.push_str(&format!(
            "  \"pool_docs_per_sec\": {:.3},\n  \"pool_wall_s\": {:.6},\n",
            pool_report.docs_per_sec(),
            pool_report.wall.as_secs_f64(),
        ));
        pj.push_str(&format!("  \"pool_vs_single_speedup\": {speedup:.4},\n"));
        pj.push_str(&format!(
            "  \"pool_counters\": {{\"retries\": {}, \"failovers\": {}, \
             \"sw_fallbacks\": {}, \"sw_routed\": {}}},\n",
            pool_counters.retries,
            pool_counters.failovers,
            pool_counters.sw_fallbacks,
            pool_counters.sw_routed,
        ));
        pj.push_str("  \"per_device\": [\n");
        for (i, d) in device_rows.iter().enumerate() {
            pj.push_str(&format!(
                "    {{\"device\": {}, \"packages\": {}, \"docs\": {}, \
                 \"queue_pushed\": {}, \"queue_high_water\": {}}}{}\n",
                d.device,
                d.accel.packages,
                d.accel.docs,
                d.queue.pushed,
                d.queue.high_water,
                if i + 1 < device_rows.len() { "," } else { "" },
            ));
        }
        pj.push_str("  ]\n}\n");
        std::fs::write(pool_path, pj).map_err(|e| format!("write {pool_path}: {e}"))?;
        println!("  wrote {pool_path}");
    }

    // TextBenDS-style corpus-aggregation row: the T6+T7 catalog (top-k
    // terms and per-dictionary document frequency) over the same corpus.
    // Unlike the per-document rows above, the payload here is the merged
    // corpus-level tables that Session::finish() folds from per-worker
    // partials — so this row also exercises the AggPartial merge path
    // under the bench thread count.
    let agg_names = vec!["t6".to_string(), "t7".to_string()];
    let agg = build_catalog(&agg_names, EngineConfig::default())?;
    let agg_report = agg.run_corpus(&corpus, threads);
    println!(
        "  corpus aggregation (t6+t7): {:.0} docs/s, {} corpus tables",
        agg_report.docs_per_sec(),
        agg_report.corpus.len(),
    );
    for t in &agg_report.corpus {
        let head: Vec<String> = t
            .rows
            .iter()
            .take(3)
            .map(|row| {
                row.iter()
                    .map(|v| v.to_string())
                    .collect::<Vec<_>>()
                    .join("|")
            })
            .collect();
        println!(
            "    {:20} {:>5} rows  top: {}",
            t.view,
            t.rows.len(),
            head.join("  ")
        );
    }
    let agg_path = match flags.get("agg-json") {
        Some(p) if !p.is_empty() => p.as_str(),
        _ => "BENCH_8.json",
    };
    let jstr = |s: &str| -> String {
        let mut out = String::with_capacity(s.len() + 2);
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
        out
    };
    let mut aj = String::new();
    aj.push_str("{\n  \"schema\": \"boost-agg-bench-v1\",\n  \"measured\": true,\n");
    aj.push_str(&format!(
        "  \"corpus\": {{\"docs\": {}, \"doc_size\": {doc_size}, \"kind\": \"{kind}\"}},\n",
        corpus.docs.len(),
    ));
    aj.push_str(&format!(
        "  \"threads\": {threads},\n  \"queries\": [\"t6\", \"t7\"],\n"
    ));
    aj.push_str(&format!(
        "  \"wall_s\": {:.6},\n  \"docs_per_sec\": {:.3},\n  \"mb_per_sec\": {:.6},\n",
        agg_report.wall.as_secs_f64(),
        agg_report.docs_per_sec(),
        agg_report.throughput() / 1e6,
    ));
    aj.push_str("  \"tables\": [\n");
    for (i, t) in agg_report.corpus.iter().enumerate() {
        let head: Vec<String> = t
            .rows
            .iter()
            .take(5)
            .map(|row| {
                jstr(
                    &row.iter()
                        .map(|v| v.to_string())
                        .collect::<Vec<_>>()
                        .join("|"),
                )
            })
            .collect();
        aj.push_str(&format!(
            "    {{\"view\": {}, \"rows\": {}, \"top\": [{}]}}{}\n",
            jstr(&t.view),
            t.rows.len(),
            head.join(", "),
            if i + 1 < agg_report.corpus.len() { "," } else { "" },
        ));
    }
    aj.push_str("  ]\n}\n");
    std::fs::write(agg_path, aj).map_err(|e| format!("write {agg_path}: {e}"))?;
    println!("  wrote {agg_path}");
    Ok(())
}

/// `repro stream`: the firehose scenario end-to-end — one document per
/// stdin line, pushed through a bounded [`Session`] so a fast producer is
/// throttled instead of exhausting memory.
///
/// [`Session`]: boost::coordinator::Session
fn cmd_stream(flags: &HashMap<String, String>) -> Result<(), String> {
    let (name, aql) = load_aql(flags)?;
    let threads: usize = flags
        .get("threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let queue: usize = flags
        .get("queue")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2 * threads.max(1));
    let per_doc = flags.contains_key("per-doc");
    let cfg = engine_config(flags)?;
    let mode = cfg.mode;
    let engine_name = cfg.engine.name();
    let engine = Engine::with_config(&aql, cfg).map_err(|e| e.to_string())?;

    let mut builder = engine.session().threads(threads).queue_depth(queue);
    if per_doc {
        builder = builder.sink(std::sync::Arc::new(CallbackSink::new(|doc, result| {
            println!("doc {}: {} tuples", doc.id, result.total_tuples());
        })));
    }
    if let Some(view_name) = flags.get("view") {
        let handle = engine.view(view_name).map_err(|e| e.to_string())?;
        let view_name = view_name.clone();
        builder = builder.subscribe(&handle, move |doc, rows| {
            for t in rows {
                let cells: Vec<String> = t
                    .iter()
                    .map(|v| match v {
                        boost::aog::Value::Span(s) => {
                            format!("{:?}", s.text(&doc.text))
                        }
                        other => other.to_string(),
                    })
                    .collect();
                println!("{view_name} doc {}: {}", doc.id, cells.join(" | "));
            }
        });
    }
    let mut session = builder.start();

    eprintln!(
        "streaming stdin through {name} | mode {} | engine {engine_name} | {threads} threads, queue {queue}",
        mode.name()
    );
    let stdin = std::io::stdin();
    // line → Document framing is shared with the server's frame decoder
    // (boost::corpus::framing): one doc per non-blank line, ids = line index
    for doc in boost::corpus::framing::docs_from_lines(stdin.lock()) {
        let doc = doc.map_err(|e| format!("stdin read failed: {e}"))?;
        session
            .push(doc)
            .map_err(|e| format!("session push failed: {e}"))?;
    }
    let queue_snap = session.queue_snapshot();
    let report = session.finish();
    eprintln!(
        "{} docs, {} tuples, {:.1} ms, {} | backpressure stalls: {}, queue high-water: {}",
        report.docs,
        report.tuples,
        report.wall.as_secs_f64() * 1e3,
        fmt_mbps(report.throughput()),
        queue_snap.stalls,
        queue_snap.high_water,
    );
    if let Some(a) = report.accel {
        eprintln!(
            "accel: {} packages, {:.1} docs/pkg, submit-queue stalls {}",
            a.packages,
            a.docs_per_package(),
            engine
                .accel_queue_snapshot()
                .map(|q| q.stalls)
                .unwrap_or(0),
        );
    }
    engine.shutdown();
    Ok(())
}

/// `repro serve`: expose one catalog engine over TCP — N concurrent
/// client connections, each with its own bounded-queue [`Session`] onto
/// the shared engine; per-connection backpressure, admission control,
/// and an optional `GET /metrics` admin port. `--selftest` instead runs
/// the loopback load harness (see [`cmd_serve_selftest`]).
///
/// [`Session`]: boost::coordinator::Session
fn cmd_serve(flags: &HashMap<String, String>) -> Result<(), String> {
    if flags.contains_key("selftest") {
        return cmd_serve_selftest(flags);
    }
    let names = catalog_names(flags).unwrap_or_else(|| {
        boost::queries::all()
            .iter()
            .map(|q| q.name.to_string())
            .collect()
    });
    let engine = Arc::new(build_catalog(&names, engine_config(flags)?)?);
    let mut cfg = boost::serve::ServeConfig::default();
    if let Some(a) = flags.get("addr") {
        cfg.addr = a.clone();
    }
    cfg.admin_addr = flags.get("admin").cloned();
    if let Some(n) = flags.get("max-conns").and_then(|s| s.parse().ok()) {
        cfg.max_connections = n;
    }
    if let Some(q) = flags.get("queue").and_then(|s| s.parse().ok()) {
        cfg.queue_depth = q;
    }
    if let Some(t) = flags.get("threads").and_then(|s| s.parse().ok()) {
        cfg.threads_per_connection = t;
    }
    let max_conns = cfg.max_connections;
    let server = boost::serve::Server::start(engine, cfg).map_err(|e| e.to_string())?;
    eprintln!(
        "serving {} on {} ({max_conns} max connections)",
        names.join(","),
        server.local_addr(),
    );
    if let Some(admin) = server.admin_addr() {
        eprintln!("admin: GET http://{admin}/metrics");
    }
    server.wait();
    Ok(())
}

/// `repro serve --selftest`: spin the server on an ephemeral loopback
/// port, drive it with K concurrent clients over the randomized corpus,
/// verify every result frame byte-identical to synchronous
/// [`Engine::run_doc`] on the same engine, survive a mid-stream
/// disconnect, probe `GET /metrics`, and write the throughput row to
/// `BENCH_6.json`. Any check failing is an `Err` (nonzero exit) — CI's
/// result-equivalence gate is this command's exit code.
fn cmd_serve_selftest(flags: &HashMap<String, String>) -> Result<(), String> {
    use boost::serve::{run_load, Client, ServeConfig, Server};

    let clients: usize = flags
        .get("clients")
        .and_then(|s| s.parse().ok())
        .unwrap_or(8)
        .max(1);
    let corpus = corpus_for(flags).generate();
    let doc_size = corpus.docs.first().map(|d| d.len()).unwrap_or(0);
    let kind = corpus_kind(flags);
    let names = catalog_names(flags).unwrap_or_else(|| {
        boost::queries::all()
            .iter()
            .map(|q| q.name.to_string())
            .collect()
    });
    let engine = Arc::new(build_catalog(&names, engine_config(flags)?)?);

    // the reference: synchronous run_doc over the same engine, encoded
    // with the same wire encoder, over the same view table the server
    // builds for an empty Hello (all queries, all views, query order)
    let table: Vec<boost::exec::ViewHandle> = engine
        .queries()
        .iter()
        .flat_map(|q| q.views().iter().cloned())
        .collect();
    let mut reference: HashMap<u64, Vec<(u16, Vec<u8>)>> =
        HashMap::with_capacity(corpus.docs.len());
    for doc in &corpus.docs {
        let result = engine.run_doc(doc);
        let mut views = Vec::with_capacity(table.len());
        for (vi, h) in table.iter().enumerate() {
            let mut buf = Vec::new();
            boost::serve::protocol::encode_batch(result.view_batch(h), &mut buf);
            views.push((vi as u16, buf));
        }
        reference.insert(doc.id, views);
    }

    let server = Server::start(
        engine.clone(),
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            admin_addr: Some("127.0.0.1:0".into()),
            max_connections: (clients + 2).max(16),
            ..ServeConfig::default()
        },
    )
    .map_err(|e| e.to_string())?;
    let addr = server.local_addr();
    let admin = server.admin_addr().expect("admin addr configured");
    eprintln!("selftest: server on {addr}, admin on {admin}, {clients} clients");

    // mid-stream disconnect: one doc, no Finish, dropped socket — the
    // server must account a disconnect and keep serving
    {
        let mut rogue =
            Client::connect(addr, &[], &[]).map_err(|e| format!("rogue connect: {e}"))?;
        rogue
            .send(u64::MAX, "rogue client leaves mid-stream")
            .map_err(|e| format!("rogue send: {e}"))?;
        drop(rogue);
    }
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while server.stats().disconnects == 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let disconnect_survived = server.stats().disconnects >= 1;

    // the load run: K concurrent clients partition the corpus
    let report =
        run_load(addr, &corpus.docs, clients, &[]).map_err(|e| format!("load run: {e}"))?;

    // verify: every document answered exactly once, byte-identical
    let expect_names: Vec<&str> = table.iter().map(|h| h.name()).collect();
    if report.view_table != expect_names {
        return Err(format!(
            "view table mismatch: server {:?} vs run_doc {:?}",
            report.view_table, expect_names
        ));
    }
    let mut checked = 0usize;
    for rf in &report.results {
        let want = reference
            .remove(&rf.doc_id)
            .ok_or_else(|| format!("doc {} answered twice or unknown", rf.doc_id))?;
        if rf.views != want {
            return Err(format!(
                "results for doc {} are not byte-identical to run_doc",
                rf.doc_id
            ));
        }
        checked += 1;
    }
    if checked != corpus.docs.len() {
        return Err(format!(
            "only {checked}/{} documents answered",
            corpus.docs.len()
        ));
    }
    if !disconnect_survived {
        return Err("server never accounted the mid-stream disconnect".into());
    }

    // admin probe: GET /metrics must answer 200 with a serve section
    let admin_ok = {
        use std::io::{Read as _, Write as _};
        let probe = || -> std::io::Result<String> {
            let mut s = std::net::TcpStream::connect(admin)?;
            s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n")?;
            let mut body = String::new();
            s.read_to_string(&mut body)?;
            Ok(body)
        };
        match probe() {
            Ok(resp) => resp.starts_with("HTTP/1.0 200") && resp.contains("\"serve\""),
            Err(_) => false,
        }
    };
    if !admin_ok {
        return Err("admin GET /metrics probe failed".into());
    }

    let stats = server.stats();
    println!(
        "selftest: {} docs x {doc_size} B over {clients} clients: \
         {:.0} docs/s, {:.2} MB/s ({:.1} ms wall)",
        report.docs,
        report.docs_per_sec(),
        report.mb_per_sec(),
        report.wall.as_secs_f64() * 1e3,
    );
    println!(
        "  byte-identical to run_doc: yes ({checked} docs, {} views) | \
         disconnect survived: yes | admin metrics: ok",
        table.len(),
    );
    println!(
        "  server: {} accepted, {} results, {} bytes out, result-queue stalls {}",
        stats.accepted, stats.results, stats.bytes_out, stats.result_stalls,
    );

    let path = match flags.get("json") {
        Some(p) if !p.is_empty() => p.as_str(),
        _ => "BENCH_6.json",
    };
    let json = format!(
        "{{\n  \"schema\": \"boost-serve-bench-v1\",\n  \"measured\": true,\n  \
         \"corpus\": {{\"docs\": {}, \"doc_size\": {doc_size}, \"kind\": \"{kind}\"}},\n  \
         \"clients\": {clients},\n  \"queries\": [{}],\n  \
         \"row\": {{\"wall_s\": {:.6}, \"docs_per_sec\": {:.3}, \"mb_per_sec\": {:.6}, \
         \"results\": {}, \"bytes_out\": {}}},\n  \
         \"checks\": {{\"byte_identical\": true, \"docs_checked\": {checked}, \
         \"views\": {}, \"disconnect_survived\": true, \"admin_metrics_ok\": true}}\n}}\n",
        corpus.docs.len(),
        names
            .iter()
            .map(|n| format!("\"{n}\""))
            .collect::<Vec<_>>()
            .join(", "),
        report.wall.as_secs_f64(),
        report.docs_per_sec(),
        report.mb_per_sec(),
        stats.results,
        stats.bytes_out,
        table.len(),
    );
    std::fs::write(path, json).map_err(|e| format!("write {path}: {e}"))?;
    println!("  wrote {path}");
    Ok(())
}

/// `repro chaos --seed N --duration S`: the seeded fault-containment
/// harness. Each round drives the loopback server through three phases —
/// injected poison documents, zero-budget deadlines, and a bricked
/// accelerator window that must trip, probe, and re-admit the circuit
/// breakers — then verifies no document hangs, every affected document
/// carries the right error-taxonomy code, every survivor is
/// byte-identical to a pure-software reference engine, and the breaker
/// counters are visible through both `GET /metrics` and `GET /healthz`.
/// Rounds repeat (with a derived seed) until `--duration` elapses.
fn cmd_chaos(flags: &HashMap<String, String>) -> Result<(), String> {
    use boost::runtime::{ChaosPlan, FaultPlan, SimSpec};
    use boost::serve::protocol::{ERR_DEADLINE, ERR_DOC_PANIC};
    use boost::serve::{run_load, run_load_with_budget, ServeConfig, Server};
    use std::time::{Duration, Instant};

    let seed: u64 = flags.get("seed").and_then(|s| s.parse().ok()).unwrap_or(42);
    let duration = Duration::from_secs(
        flags
            .get("duration")
            .and_then(|s| s.parse().ok())
            .unwrap_or(20),
    );
    let clients: usize = flags
        .get("clients")
        .and_then(|s| s.parse().ok())
        .unwrap_or(4)
        .max(1);
    let names = catalog_names(flags).unwrap_or_else(|| vec!["t1".into(), "t3".into()]);
    let corpus = corpus_for(flags).generate();
    if corpus.docs.is_empty() {
        return Err("chaos needs a non-empty corpus".into());
    }

    // the reference: a pure-software engine over the same catalog; every
    // surviving document must match these bytes exactly, no matter which
    // faults fired around it
    let reference_engine = build_catalog(&names, EngineConfig::default())?;
    let table: Vec<boost::exec::ViewHandle> = reference_engine
        .queries()
        .iter()
        .flat_map(|q| q.views().iter().cloned())
        .collect();
    let mut reference: HashMap<u64, Vec<(u16, Vec<u8>)>> =
        HashMap::with_capacity(corpus.docs.len());
    for doc in &corpus.docs {
        let result = reference_engine.run_doc(doc);
        let mut views = Vec::with_capacity(table.len());
        for (vi, h) in table.iter().enumerate() {
            let mut buf = Vec::new();
            boost::serve::protocol::encode_batch(result.view_batch(h), &mut buf);
            views.push((vi as u16, buf));
        }
        reference.insert(doc.id, views);
    }
    let verify_survivors = |results: &[boost::serve::ResultFrame]| -> Result<(), String> {
        for rf in results {
            let want = reference
                .get(&rf.doc_id)
                .ok_or_else(|| format!("doc {} answered but never submitted", rf.doc_id))?;
            if &rf.views != want {
                return Err(format!(
                    "survivor doc {} is not byte-identical to the software reference",
                    rf.doc_id
                ));
            }
        }
        Ok(())
    };

    let start = Instant::now();
    let mut rounds = 0u64;
    let (mut panics_total, mut deadlines_total) = (0u64, 0u64);
    let (mut trips_total, mut readmits_total) = (0u64, 0u64);
    loop {
        rounds += 1;
        let round_seed = seed.wrapping_add(rounds - 1);

        // --- phase 1: poison documents -------------------------------
        // a seeded ~1/13 of the corpus panics inside the session worker;
        // each must come back as a doc-panic DocErr (and land in the
        // quarantine) while every other document survives byte-identical
        let plan = Arc::new(ChaosPlan::new(round_seed).panic_every(13));
        let engine = Arc::new(build_catalog(
            &names,
            EngineConfig::accelerated(PartitionMode::ExtractOnly, EngineSpec::Sim(SimSpec::default())),
        )?);
        let server = Server::start(
            engine.clone(),
            ServeConfig {
                addr: "127.0.0.1:0".into(),
                chaos: Some(plan.clone()),
                ..ServeConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        let report = run_load(server.local_addr(), &corpus.docs, clients, &[])
            .map_err(|e| format!("round {rounds} poison phase: {e}"))?;
        let answered = report.results.len() + report.doc_errors.len();
        if answered != corpus.docs.len() {
            return Err(format!(
                "round {rounds} poison phase: {answered}/{} documents answered",
                corpus.docs.len()
            ));
        }
        for e in &report.doc_errors {
            if e.code != ERR_DOC_PANIC {
                return Err(format!(
                    "round {rounds} poison phase: doc {} got code {} ({}), expected doc-panic",
                    e.doc_id,
                    e.code,
                    boost::serve::protocol::error_code_name(e.code)
                ));
            }
            if !plan.panics(e.doc_id) {
                return Err(format!(
                    "round {rounds} poison phase: doc {} failed without a planned fault",
                    e.doc_id
                ));
            }
        }
        let planned = corpus.docs.iter().filter(|d| plan.panics(d.id)).count();
        if report.doc_errors.len() != planned {
            return Err(format!(
                "round {rounds} poison phase: {} doc errors vs {planned} planned panics",
                report.doc_errors.len()
            ));
        }
        verify_survivors(&report.results)?;
        if engine.quarantine().total() < planned as u64 {
            return Err(format!(
                "round {rounds} poison phase: quarantine holds {} < {planned} planned panics",
                engine.quarantine().total()
            ));
        }
        panics_total += planned as u64;
        drop(server);

        // --- phase 2: deadlines --------------------------------------
        // a zero budget expires every document at dequeue; each must be
        // shed with a deadline DocErr — never a hang, never a result
        let engine = Arc::new(build_catalog(&names, EngineConfig::default())?);
        let server = Server::start(
            engine.clone(),
            ServeConfig {
                addr: "127.0.0.1:0".into(),
                ..ServeConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        let report = run_load_with_budget(server.local_addr(), &corpus.docs, clients, &[], Some(0))
            .map_err(|e| format!("round {rounds} deadline phase: {e}"))?;
        if !report.results.is_empty() {
            return Err(format!(
                "round {rounds} deadline phase: {} documents beat a zero budget",
                report.results.len()
            ));
        }
        if report.doc_errors.len() != corpus.docs.len() {
            return Err(format!(
                "round {rounds} deadline phase: {}/{} documents shed",
                report.doc_errors.len(),
                corpus.docs.len()
            ));
        }
        for e in &report.doc_errors {
            if e.code != ERR_DEADLINE {
                return Err(format!(
                    "round {rounds} deadline phase: doc {} got code {} ({}), expected deadline",
                    e.doc_id,
                    e.code,
                    boost::serve::protocol::error_code_name(e.code)
                ));
            }
        }
        deadlines_total += report.doc_errors.len() as u64;
        drop(server);

        // --- phase 3: bricked device + circuit breakers --------------
        // every pool engine's packages 1..=3 fail (a device dark at
        // startup, then recovered); the breakers must trip, the pool must
        // keep answering via failover/software, and after the cooldown a
        // half-open probe must re-admit the device — all visible in
        // /metrics and /healthz
        let mut cfg = EngineConfig::accelerated(
            PartitionMode::ExtractOnly,
            EngineSpec::Sim(SimSpec::default().with_seed(round_seed).with_fault(FaultPlan {
                brick_from: 1,
                brick_until: 3,
                ..FaultPlan::none()
            })),
        );
        cfg.accel.devices = 2;
        cfg.accel.breaker_threshold = 2;
        cfg.accel.breaker_cooldown = Duration::from_millis(20);
        let engine = Arc::new(build_catalog(&names, cfg)?);
        let server = Server::start(
            engine.clone(),
            ServeConfig {
                addr: "127.0.0.1:0".into(),
                admin_addr: Some("127.0.0.1:0".into()),
                ..ServeConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        let admin = server.admin_addr().expect("admin addr configured");
        // repeated passes with a cooldown-sized pause between them: early
        // passes trip the breakers inside the brick window, later passes
        // give the half-open probes packages past the window to succeed
        // on. Bounded — a harness about hangs must not hang itself.
        let mut brick_results = Vec::new();
        let mut pool = engine
            .accel_pool_snapshot()
            .ok_or_else(|| format!("round {rounds} brick phase: no pool snapshot"))?;
        for pass in 0..8 {
            let report = run_load(server.local_addr(), &corpus.docs, clients, &[])
                .map_err(|e| format!("round {rounds} brick phase pass {pass}: {e}"))?;
            if !report.doc_errors.is_empty() {
                return Err(format!(
                    "round {rounds} brick phase pass {pass}: {} doc errors — device faults \
                     must be absorbed by failover, not surfaced",
                    report.doc_errors.len()
                ));
            }
            if report.results.len() != corpus.docs.len() {
                return Err(format!(
                    "round {rounds} brick phase pass {pass}: {}/{} documents answered",
                    report.results.len(),
                    corpus.docs.len()
                ));
            }
            brick_results.extend(report.results);
            pool = engine
                .accel_pool_snapshot()
                .ok_or_else(|| format!("round {rounds} brick phase: no pool snapshot"))?;
            if pool.breaker_trips > 0 && pool.breaker_readmits > 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(40));
        }
        verify_survivors(&brick_results)?;
        if pool.breaker_trips == 0 {
            return Err(format!(
                "round {rounds} brick phase: breakers never tripped on a bricked window"
            ));
        }
        if pool.breaker_readmits == 0 {
            return Err(format!(
                "round {rounds} brick phase: no half-open probe ever re-admitted a device"
            ));
        }
        trips_total += pool.breaker_trips;
        readmits_total += pool.breaker_readmits;

        // the operator's view of the same story: breaker counters in
        // /metrics, liveness + breaker states in /healthz (200 == healthy)
        let probe = |path: &str| -> std::io::Result<String> {
            use std::io::{Read as _, Write as _};
            let mut s = std::net::TcpStream::connect(admin)?;
            s.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())?;
            let mut body = String::new();
            s.read_to_string(&mut body)?;
            Ok(body)
        };
        let metrics = probe("/metrics").map_err(|e| format!("GET /metrics: {e}"))?;
        if !(metrics.starts_with("HTTP/1.0 200") && metrics.contains("\"breaker_trips\"")) {
            return Err("GET /metrics is missing the breaker counters".into());
        }
        let healthz = probe("/healthz").map_err(|e| format!("GET /healthz: {e}"))?;
        if !(healthz.starts_with("HTTP/1.0 200")
            && healthz.contains("\"healthy\":true")
            && healthz.contains("\"breakers\""))
        {
            return Err("GET /healthz did not report healthy with breaker states".into());
        }
        drop(server);

        eprintln!(
            "chaos round {rounds} (seed {round_seed}): {planned} poisoned, {} shed on deadline, \
             {} trips / {} readmits",
            corpus.docs.len(),
            pool.breaker_trips,
            pool.breaker_readmits,
        );
        if start.elapsed() >= duration {
            break;
        }
    }

    println!(
        "chaos: {rounds} rounds over {:.1}s (seed {seed}) — {} docs/round, \
         {panics_total} panics contained, {deadlines_total} deadline sheds, \
         {trips_total} breaker trips, {readmits_total} re-admissions; \
         all survivors byte-identical to the software reference",
        start.elapsed().as_secs_f64(),
        corpus.docs.len(),
    );
    Ok(())
}
