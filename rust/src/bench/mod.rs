//! Minimal benchmark harness (criterion is not in the offline vendor set).
//!
//! Benches are `harness = false` binaries that print the same rows/series
//! as the paper's tables and figures; `cargo bench` runs them all and the
//! final numbers land in `bench_output.txt` / EXPERIMENTS.md.

use std::time::{Duration, Instant};

/// Run `f` once and return the elapsed wall time.
pub fn time_once<F: FnOnce()>(f: F) -> Duration {
    let t0 = Instant::now();
    f();
    t0.elapsed()
}

/// Median wall time over `reps` runs after `warmup` runs.
pub fn time_median<F: FnMut()>(mut f: F, warmup: usize, reps: usize) -> Duration {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = (0..reps.max(1)).map(|_| time_once(&mut f)).collect();
    samples.sort();
    samples[samples.len() / 2]
}

/// A printable, aligned results table.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringify everything up front).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let head: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>w$}", h, w = widths[i]))
            .collect();
        println!("  {}", head.join("  "));
        println!(
            "  {}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("  {}", cells.join("  "));
        }
    }
}

/// Format bytes/s as MB/s with one decimal.
pub fn mbps(x: f64) -> String {
    format!("{:.1}", x / 1.0e6)
}

/// Format a speedup factor.
pub fn speedup(x: f64) -> String {
    format!("{x:.1}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_positive() {
        let d = time_median(
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
            1,
            3,
        );
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: must not panic
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(mbps(500.0e6), "500.0");
        assert_eq!(speedup(16.04), "16.0x");
    }
}
