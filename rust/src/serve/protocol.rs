//! The length-prefixed binary wire protocol of the serving tier.
//!
//! Every frame on the wire is `[u32 LE length][u8 type][payload]`, where
//! `length` counts the type byte plus the payload. The protocol is
//! deliberately dumb: no compression, no negotiation beyond a version
//! byte in `Hello`, no partial frames — a reader either gets a whole
//! frame or a clean [`ProtocolError`].
//!
//! | frame | dir | type | payload |
//! |-------|-----|------|---------|
//! | `Hello` | c→s | `0x01` | version u8, query names (u16 count × str16), view subscriptions (u16 count × str16), deadline flag u8 (`1` ⇒ default budget ms u64 follows) |
//! | `Doc` | c→s | `0x02` | doc id u64, deadline flag u8 (`1` ⇒ budget ms u64 follows), UTF-8 text (rest of frame) |
//! | `Finish` | c→s | `0x03` | empty |
//! | `Welcome` | s→c | `0x81` | view table (u16 count × str16 qualified names) |
//! | `Result` | s→c | `0x82` | doc id u64, u16 count × (view-table index u16, batch length u32, encoded [`TupleBatch`]) |
//! | `Busy` | s→c | `0x83` | active u32, cap u32 |
//! | `Error` | s→c | `0x84` | code u16, message str16 |
//! | `Done` | s→c | `0x85` | docs processed u64, u16 count × (view-table index u16, batch length u32, encoded [`TupleBatch`]) — the finished corpus-level aggregate tables |
//! | `DocErr` | s→c | `0x86` | doc id u64, code u16, message str16 |
//!
//! (`str16` = u16 length + UTF-8 bytes; `str32` the same with a u32.)
//!
//! ## Error taxonomy
//!
//! Two error surfaces share one code space ([`ERROR_TAXONOMY`]): the
//! connection-terminal `Error` frame and the per-document `DocErr` frame
//! (after which the connection keeps serving). Codes are **stable wire
//! contract** — never renumber, only append (asserted by a test).
//!
//! | code | name | frame | meaning |
//! |------|------|-------|---------|
//! | 1 | `protocol` | `Error` | the frame stream itself was malformed |
//! | 2 | `bad-hello` | `Error` | the `Hello` handshake was missing or invalid |
//! | 3 | `unknown-query` | `Error` | `Hello` named a query the catalog doesn't register |
//! | 4 | `unknown-view` | `Error` | `Hello` subscribed to a view outside its namespaces |
//! | 5 | `bad-doc` | `Error` | a `Doc` frame carried invalid (non-UTF-8) text |
//! | 6 | `server` | `Error` | the server failed internally while processing |
//! | 7 | `query-rejected` | `Error` | `Hello` named a query the build-time analyzer quarantined |
//! | 8 | `deadline` | `DocErr` | the document's deadline budget expired; the doc was shed |
//! | 9 | `doc-panic` | `DocErr` | execution panicked on the document; it was quarantined |
//!
//! Result payloads serialize [`TupleBatch`] **columns**, not rows: per
//! column a type tag, an optional null bitmap (u64 words, same packing
//! as the in-memory `NullMask`), then the dense buffer — spans as
//! `(i32, i32)` pairs exactly like the accelerator's packed streams
//! (`accel::packing`), ints/float-bits as u64, bools as bytes, strings
//! as str32. Results therefore cross the wire without ever
//! re-materializing `Vec<Tuple>` rows on the server; the encoding is
//! canonical (no padding, fixed field order), so "byte-identical
//! results" can be asserted by comparing encoded payloads.

use std::fmt;
use std::io::{self, Read, Write};
use std::sync::Arc;

use crate::aog::{Tuple, Value};
use crate::exec::{ColumnData, TupleBatch};
use crate::text::Span;

/// Protocol version carried in `Hello`. Bump on any wire change.
/// (v2: deadline fields on `Hello`/`Doc`, per-document `DocErr` frames.
/// v3: `Done` carries the finished corpus-level aggregate tables.)
pub const PROTOCOL_VERSION: u8 = 3;

/// Upper bound on a single frame's length field (type byte + payload).
/// Anything larger is rejected before buffering — a garbage length
/// prefix must not turn into a multi-gigabyte allocation.
pub const MAX_FRAME: usize = 64 << 20;

/// `Hello` frame type byte (client → server).
pub const FRAME_HELLO: u8 = 0x01;
/// `Doc` frame type byte (client → server).
pub const FRAME_DOC: u8 = 0x02;
/// `Finish` frame type byte (client → server).
pub const FRAME_FINISH: u8 = 0x03;
/// `Welcome` frame type byte (server → client).
pub const FRAME_WELCOME: u8 = 0x81;
/// `Result` frame type byte (server → client).
pub const FRAME_RESULT: u8 = 0x82;
/// `Busy` frame type byte (server → client).
pub const FRAME_BUSY: u8 = 0x83;
/// `Error` frame type byte (server → client).
pub const FRAME_ERROR: u8 = 0x84;
/// `Done` frame type byte (server → client).
pub const FRAME_DONE: u8 = 0x85;
/// `DocErr` frame type byte (server → client).
pub const FRAME_DOC_ERR: u8 = 0x86;

/// `Error` code: the frame stream itself was malformed.
pub const ERR_PROTOCOL: u16 = 1;
/// `Error` code: the `Hello` handshake was missing or invalid.
pub const ERR_BAD_HELLO: u16 = 2;
/// `Error` code: `Hello` named a query the catalog doesn't register.
pub const ERR_UNKNOWN_QUERY: u16 = 3;
/// `Error` code: `Hello` subscribed to a view outside its namespaces.
pub const ERR_UNKNOWN_VIEW: u16 = 4;
/// `Error` code: a `Doc` frame carried invalid (non-UTF-8) text.
pub const ERR_BAD_DOC: u16 = 5;
/// `Error` code: the server failed internally while processing.
pub const ERR_SERVER: u16 = 6;
/// `Error` code: `Hello` named a query the static analyzer rejected at
/// build time (lenient catalogs quarantine bad entries instead of
/// failing); the message carries the first diagnostic's code and text.
pub const ERR_QUERY_REJECTED: u16 = 7;
/// `DocErr` code: the document's deadline budget expired — checked at
/// dequeue and after the post-stage — and the document was shed. The
/// connection keeps serving.
pub const ERR_DEADLINE: u16 = 8;
/// `DocErr` code: execution panicked on the document; the panic was
/// contained, the document quarantined, and the connection keeps serving.
pub const ERR_DOC_PANIC: u16 = 9;

/// The full error-code space: `(code, stable name, description)` per
/// entry, in code order. One table for both the `Error` and `DocErr`
/// frames — see the module docs for which codes ride which frame.
pub const ERROR_TAXONOMY: &[(u16, &str, &str)] = &[
    (ERR_PROTOCOL, "protocol", "the frame stream itself was malformed"),
    (ERR_BAD_HELLO, "bad-hello", "the Hello handshake was missing or invalid"),
    (
        ERR_UNKNOWN_QUERY,
        "unknown-query",
        "Hello named a query the catalog doesn't register",
    ),
    (
        ERR_UNKNOWN_VIEW,
        "unknown-view",
        "Hello subscribed to a view outside its namespaces",
    ),
    (ERR_BAD_DOC, "bad-doc", "a Doc frame carried invalid (non-UTF-8) text"),
    (ERR_SERVER, "server", "the server failed internally while processing"),
    (
        ERR_QUERY_REJECTED,
        "query-rejected",
        "Hello named a query the build-time analyzer quarantined",
    ),
    (
        ERR_DEADLINE,
        "deadline",
        "the document's deadline budget expired; the document was shed",
    ),
    (
        ERR_DOC_PANIC,
        "doc-panic",
        "execution panicked on the document; it was quarantined",
    ),
];

/// The stable name of an error code (`"deadline"`, `"doc-panic"`, …), or
/// `"unknown"` for a code outside the taxonomy.
pub fn error_code_name(code: u16) -> &'static str {
    ERROR_TAXONOMY
        .iter()
        .find(|(c, _, _)| *c == code)
        .map(|(_, name, _)| *name)
        .unwrap_or("unknown")
}

/// Everything that can go wrong reading or decoding a frame.
#[derive(Debug)]
pub enum ProtocolError {
    /// Underlying socket error.
    Io(io::Error),
    /// A length prefix of zero or larger than [`MAX_FRAME`].
    BadLength(usize),
    /// The peer closed the connection in the middle of a frame.
    Truncated,
    /// An unrecognized frame type byte.
    UnknownFrame(u8),
    /// A structurally invalid payload (what, in the message).
    Malformed(&'static str),
    /// The peer sent an `Error` frame; carried through so callers can
    /// surface the remote code + message.
    Remote {
        /// One of the `ERR_*` codes.
        code: u16,
        /// Human-readable description from the peer.
        message: String,
    },
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolError::Io(e) => write!(f, "i/o error: {e}"),
            ProtocolError::BadLength(n) => {
                write!(f, "frame length {n} outside 1..={MAX_FRAME}")
            }
            ProtocolError::Truncated => write!(f, "connection closed mid-frame"),
            ProtocolError::UnknownFrame(t) => write!(f, "unknown frame type 0x{t:02x}"),
            ProtocolError::Malformed(what) => write!(f, "malformed frame: {what}"),
            ProtocolError::Remote { code, message } => {
                write!(f, "peer reported error {code}: {message}")
            }
        }
    }
}

impl std::error::Error for ProtocolError {}

impl From<io::Error> for ProtocolError {
    fn from(e: io::Error) -> Self {
        ProtocolError::Io(e)
    }
}

/// A decoded wire frame. See the module docs for the layout table.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Connection handshake: which catalog namespaces this client wants
    /// (empty = all registered queries) and which views it subscribes to
    /// (empty = every view of the selected queries).
    Hello {
        /// Registered query (namespace) names, e.g. `["t1", "t3"]`.
        queries: Vec<String>,
        /// View subscriptions, qualified (`"t1.Entities"`) or bare.
        views: Vec<String>,
        /// Default per-document deadline budget in milliseconds for every
        /// doc on this connection; `None` = no deadline.
        budget_ms: Option<u64>,
    },
    /// One document to analyze.
    Doc {
        /// Client-chosen stable id, echoed back in `Result`.
        id: u64,
        /// Per-document deadline budget in milliseconds, overriding the
        /// `Hello`-level default for this doc; `None` = use the default.
        budget_ms: Option<u64>,
        /// Raw document text; must be valid UTF-8.
        bytes: Vec<u8>,
    },
    /// End of the client's stream: process everything queued, send the
    /// remaining `Result` frames, then `Done`.
    Finish,
    /// Handshake accepted; the per-connection view table, in the order
    /// `Result` frames index it.
    Welcome {
        /// Fully qualified view names.
        views: Vec<String>,
    },
    /// All subscribed views for one document.
    Result {
        /// The id from the matching `Doc` frame.
        doc_id: u64,
        /// `(view-table index, encoded batch)` per subscribed view, in
        /// view-table order. Payloads come from [`encode_batch`].
        views: Vec<(u16, Vec<u8>)>,
    },
    /// Admission control: the server is at its connection cap.
    Busy {
        /// Connections currently being served.
        active: u32,
        /// The configured cap.
        cap: u32,
    },
    /// Terminal error; the server closes the connection after sending it.
    Error {
        /// One of the `ERR_*` codes.
        code: u16,
        /// Human-readable description.
        message: String,
    },
    /// Clean end of stream after `Finish`.
    Done {
        /// Documents processed on this connection.
        docs: u64,
        /// Finished corpus-level aggregate tables, one per subscribed
        /// aggregate view: `(view-table index, encoded batch)` exactly
        /// like `Result`, built from the merged worker partials at
        /// session drain. Empty when no subscribed view aggregates.
        corpus: Vec<(u16, Vec<u8>)>,
    },
    /// Per-document failure: this one document was shed or quarantined;
    /// the connection keeps serving the rest of the stream.
    DocErr {
        /// The id from the matching `Doc` frame.
        doc_id: u64,
        /// [`ERR_DEADLINE`] or [`ERR_DOC_PANIC`] (see [`ERROR_TAXONOMY`]).
        code: u16,
        /// Human-readable description of the failure.
        message: String,
    },
}

// ---------------------------------------------------------------------------
// little-endian put/get helpers
// ---------------------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_i32(out: &mut Vec<u8>, v: i32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str16(out: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= u16::MAX as usize);
    put_u16(out, s.len() as u16);
    out.extend_from_slice(s.as_bytes());
}

fn put_str32(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked sequential reader over one frame payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtocolError> {
        if self.buf.len() - self.pos < n {
            return Err(ProtocolError::Malformed("payload shorter than declared"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtocolError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, ProtocolError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, ProtocolError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtocolError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32, ProtocolError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn str16(&mut self) -> Result<String, ProtocolError> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ProtocolError::Malformed("string is not UTF-8"))
    }

    fn str32(&mut self) -> Result<String, ProtocolError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| ProtocolError::Malformed("string is not UTF-8"))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    fn done(&self) -> Result<(), ProtocolError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(ProtocolError::Malformed("trailing bytes after payload"))
        }
    }
}

// ---------------------------------------------------------------------------
// frame encode / decode
// ---------------------------------------------------------------------------

fn encode_payload(frame: &Frame, out: &mut Vec<u8>) {
    match frame {
        Frame::Hello { queries, views, budget_ms } => {
            out.push(FRAME_HELLO);
            out.push(PROTOCOL_VERSION);
            put_u16(out, queries.len() as u16);
            for q in queries {
                put_str16(out, q);
            }
            put_u16(out, views.len() as u16);
            for v in views {
                put_str16(out, v);
            }
            put_budget(out, *budget_ms);
        }
        Frame::Doc { id, budget_ms, bytes } => {
            out.push(FRAME_DOC);
            put_u64(out, *id);
            put_budget(out, *budget_ms);
            out.extend_from_slice(bytes);
        }
        Frame::Finish => out.push(FRAME_FINISH),
        Frame::Welcome { views } => {
            out.push(FRAME_WELCOME);
            put_u16(out, views.len() as u16);
            for v in views {
                put_str16(out, v);
            }
        }
        Frame::Result { doc_id, views } => {
            out.push(FRAME_RESULT);
            put_u64(out, *doc_id);
            put_u16(out, views.len() as u16);
            for (idx, batch) in views {
                put_u16(out, *idx);
                put_u32(out, batch.len() as u32);
                out.extend_from_slice(batch);
            }
        }
        Frame::Busy { active, cap } => {
            out.push(FRAME_BUSY);
            put_u32(out, *active);
            put_u32(out, *cap);
        }
        Frame::Error { code, message } => {
            out.push(FRAME_ERROR);
            put_u16(out, *code);
            put_str16(out, message);
        }
        Frame::Done { docs, corpus } => {
            out.push(FRAME_DONE);
            put_u64(out, *docs);
            put_u16(out, corpus.len() as u16);
            for (idx, batch) in corpus {
                put_u16(out, *idx);
                put_u32(out, batch.len() as u32);
                out.extend_from_slice(batch);
            }
        }
        Frame::DocErr { doc_id, code, message } => {
            out.push(FRAME_DOC_ERR);
            put_u64(out, *doc_id);
            put_u16(out, *code);
            put_str16(out, message);
        }
    }
}

/// Deadline budget field: a flag byte, then the budget in ms when set.
/// The flag keeps `Doc` decodable even though its text consumes the rest
/// of the frame.
fn put_budget(out: &mut Vec<u8>, budget_ms: Option<u64>) {
    match budget_ms {
        Some(ms) => {
            out.push(1);
            put_u64(out, ms);
        }
        None => out.push(0),
    }
}

/// Write one frame (length prefix + type + payload) and return how many
/// bytes hit the wire. The caller flushes.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> io::Result<usize> {
    let mut body = Vec::with_capacity(64);
    encode_payload(frame, &mut body);
    debug_assert!(body.len() <= MAX_FRAME);
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&body)?;
    Ok(4 + body.len())
}

fn decode_frame(body: &[u8]) -> Result<Frame, ProtocolError> {
    let mut c = Cursor::new(body);
    let ty = c.u8()?;
    let frame = match ty {
        FRAME_HELLO => {
            let version = c.u8()?;
            if version != PROTOCOL_VERSION {
                return Err(ProtocolError::Malformed("unsupported protocol version"));
            }
            let nq = c.u16()? as usize;
            let mut queries = Vec::with_capacity(nq);
            for _ in 0..nq {
                queries.push(c.str16()?);
            }
            let nv = c.u16()? as usize;
            let mut views = Vec::with_capacity(nv);
            for _ in 0..nv {
                views.push(c.str16()?);
            }
            let budget_ms = read_budget(&mut c)?;
            Frame::Hello { queries, views, budget_ms }
        }
        FRAME_DOC => {
            let id = c.u64()?;
            let budget_ms = read_budget(&mut c)?;
            let bytes = c.rest().to_vec();
            Frame::Doc { id, budget_ms, bytes }
        }
        FRAME_FINISH => Frame::Finish,
        FRAME_WELCOME => {
            let n = c.u16()? as usize;
            let mut views = Vec::with_capacity(n);
            for _ in 0..n {
                views.push(c.str16()?);
            }
            Frame::Welcome { views }
        }
        FRAME_RESULT => {
            let doc_id = c.u64()?;
            let n = c.u16()? as usize;
            let mut views = Vec::with_capacity(n);
            for _ in 0..n {
                let idx = c.u16()?;
                let len = c.u32()? as usize;
                views.push((idx, c.take(len)?.to_vec()));
            }
            Frame::Result { doc_id, views }
        }
        FRAME_BUSY => Frame::Busy {
            active: c.u32()?,
            cap: c.u32()?,
        },
        FRAME_ERROR => Frame::Error {
            code: c.u16()?,
            message: c.str16()?,
        },
        FRAME_DONE => {
            let docs = c.u64()?;
            let n = c.u16()? as usize;
            let mut corpus = Vec::with_capacity(n);
            for _ in 0..n {
                let idx = c.u16()?;
                let len = c.u32()? as usize;
                corpus.push((idx, c.take(len)?.to_vec()));
            }
            Frame::Done { docs, corpus }
        }
        FRAME_DOC_ERR => Frame::DocErr {
            doc_id: c.u64()?,
            code: c.u16()?,
            message: c.str16()?,
        },
        other => return Err(ProtocolError::UnknownFrame(other)),
    };
    c.done()?;
    Ok(frame)
}

/// Decode counterpart of [`put_budget`].
fn read_budget(c: &mut Cursor<'_>) -> Result<Option<u64>, ProtocolError> {
    match c.u8()? {
        0 => Ok(None),
        1 => Ok(Some(c.u64()?)),
        _ => Err(ProtocolError::Malformed("bad deadline flag")),
    }
}

/// Read one frame, blocking until it is complete. `Ok(None)` means the
/// peer closed the connection **cleanly at a frame boundary**; closing
/// mid-frame is [`ProtocolError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>, ProtocolError> {
    let mut prefix = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut prefix[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(None)
                } else {
                    Err(ProtocolError::Truncated)
                };
            }
            Ok(n) => got += n,
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ProtocolError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(ProtocolError::BadLength(len));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            ProtocolError::Truncated
        } else {
            ProtocolError::Io(e)
        }
    })?;
    decode_frame(&body)
}

// ---------------------------------------------------------------------------
// TupleBatch wire encoding
// ---------------------------------------------------------------------------

const TAG_SPANS: u8 = 0;
const TAG_INTS: u8 = 1;
const TAG_FLOATS: u8 = 2;
const TAG_BOOLS: u8 = 3;
const TAG_STRS: u8 = 4;

/// Serialize one columnar batch: `rows u32, cols u16`, then per column
/// `tag u8, has_nulls u8, [null words u64 × ceil(rows/64)], data`. Spans
/// go out as `(i32, i32)` pairs — the same shape the accelerator's
/// packed streams use — so the hot span case is a flat memcpy-ish loop
/// and the encoding is canonical: identical batches encode to identical
/// bytes, which is what the selftest's byte-equality check relies on.
pub fn encode_batch(batch: &TupleBatch, out: &mut Vec<u8>) {
    let rows = batch.len();
    put_u32(out, rows as u32);
    put_u16(out, batch.num_columns() as u16);
    for ci in 0..batch.num_columns() {
        let col = batch.column(ci);
        let (tag, _) = tag_of(col.data());
        out.push(tag);
        let has_nulls = (0..rows).any(|i| col.is_null(i));
        out.push(has_nulls as u8);
        if has_nulls {
            for w in 0..rows.div_ceil(64) {
                let mut word = 0u64;
                for b in 0..64 {
                    let i = w * 64 + b;
                    if i < rows && col.is_null(i) {
                        word |= 1 << b;
                    }
                }
                put_u64(out, word);
            }
        }
        match col.data() {
            ColumnData::Spans(v) => {
                for s in v {
                    put_i32(out, s.begin as i32);
                    put_i32(out, s.end as i32);
                }
            }
            ColumnData::Ints(v) => {
                for x in v {
                    put_u64(out, *x as u64);
                }
            }
            ColumnData::Floats(v) => {
                for x in v {
                    put_u64(out, x.to_bits());
                }
            }
            ColumnData::Bools(v) => {
                for x in v {
                    out.push(*x as u8);
                }
            }
            ColumnData::Strs(v) => {
                for s in v {
                    put_str32(out, s);
                }
            }
        }
    }
}

fn tag_of(data: &ColumnData) -> (u8, &'static str) {
    match data {
        ColumnData::Spans(_) => (TAG_SPANS, "spans"),
        ColumnData::Ints(_) => (TAG_INTS, "ints"),
        ColumnData::Floats(_) => (TAG_FLOATS, "floats"),
        ColumnData::Bools(_) => (TAG_BOOLS, "bools"),
        ColumnData::Strs(_) => (TAG_STRS, "strs"),
    }
}

/// Decode an [`encode_batch`] payload into row-shaped tuples (the
/// client-side convenience — servers never materialize rows). Rejects
/// structurally invalid input with a clean error; adversarial payloads
/// must not panic or over-allocate.
pub fn decode_batch(buf: &[u8]) -> Result<Vec<Tuple>, ProtocolError> {
    let mut c = Cursor::new(buf);
    let rows = c.u32()? as usize;
    let cols = c.u16()? as usize;
    // A row costs ≥ 1 byte per column on the wire; anything claiming
    // more rows than the payload could hold is garbage.
    if rows > buf.len().max(1) * 64 {
        return Err(ProtocolError::Malformed("row count exceeds payload"));
    }
    let mut columns: Vec<Vec<Value>> = Vec::with_capacity(cols);
    for _ in 0..cols {
        let tag = c.u8()?;
        let has_nulls = c.u8()? != 0;
        let mut nulls = vec![false; if has_nulls { rows } else { 0 }];
        if has_nulls {
            for w in 0..rows.div_ceil(64) {
                let word = c.u64()?;
                for (b, slot) in nulls.iter_mut().skip(w * 64).take(64).enumerate() {
                    *slot = (word >> b) & 1 == 1;
                }
            }
        }
        let mut vals = Vec::with_capacity(rows.min(4096));
        for i in 0..rows {
            let v = match tag {
                TAG_SPANS => {
                    let begin = c.i32()? as u32;
                    let end = c.i32()? as u32;
                    if begin > end {
                        return Err(ProtocolError::Malformed("span begin after end"));
                    }
                    Value::Span(Span::new(begin, end))
                }
                TAG_INTS => Value::Int(c.u64()? as i64),
                TAG_FLOATS => Value::Float(f64::from_bits(c.u64()?)),
                TAG_BOOLS => Value::Bool(c.u8()? != 0),
                TAG_STRS => Value::Str(Arc::from(c.str32()?)),
                _ => return Err(ProtocolError::Malformed("unknown column tag")),
            };
            vals.push(if has_nulls && nulls[i] { Value::Null } else { v });
        }
        columns.push(vals);
    }
    c.done()?;
    Ok((0..rows)
        .map(|i| columns.iter().map(|col| col[i].clone()).collect())
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aog::{Field, FieldType, Schema};

    fn roundtrip(frame: Frame) {
        let mut wire = Vec::new();
        write_frame(&mut wire, &frame).unwrap();
        let mut r = &wire[..];
        let back = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(back, frame);
        assert_eq!(read_frame(&mut r).unwrap(), None, "no trailing frame");
    }

    #[test]
    fn frames_roundtrip() {
        roundtrip(Frame::Hello {
            queries: vec!["t1".into(), "t3".into()],
            views: vec!["t1.Entities".into()],
            budget_ms: None,
        });
        roundtrip(Frame::Hello {
            queries: vec![],
            views: vec![],
            budget_ms: Some(250),
        });
        roundtrip(Frame::Doc {
            id: 42,
            budget_ms: None,
            bytes: b"Alice visited Paris.".to_vec(),
        });
        roundtrip(Frame::Doc {
            id: 43,
            budget_ms: Some(10),
            bytes: b"Bob visited Rome.".to_vec(),
        });
        roundtrip(Frame::Finish);
        roundtrip(Frame::Welcome {
            views: vec!["t1.Entities".into(), "t3.Mentions".into()],
        });
        roundtrip(Frame::Result {
            doc_id: 7,
            views: vec![(0, vec![1, 2, 3]), (1, vec![])],
        });
        roundtrip(Frame::Busy { active: 8, cap: 8 });
        roundtrip(Frame::Error {
            code: ERR_BAD_DOC,
            message: "document 3 is not UTF-8".into(),
        });
        roundtrip(Frame::Done {
            docs: 1000,
            corpus: vec![],
        });
        roundtrip(Frame::Done {
            docs: 12,
            corpus: vec![(0, vec![4, 5, 6]), (2, vec![])],
        });
        roundtrip(Frame::DocErr {
            doc_id: 9,
            code: ERR_DEADLINE,
            message: "deadline exceeded after 31ms (budget 10ms)".into(),
        });
    }

    #[test]
    fn error_taxonomy_is_unique_and_stable() {
        // every declared ERR_* constant appears exactly once
        let codes: Vec<u16> = ERROR_TAXONOMY.iter().map(|(c, _, _)| *c).collect();
        let mut sorted = codes.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), codes.len(), "duplicate error codes");
        // codes are a stable wire contract: dense 1..=N, in order
        assert_eq!(codes, (1..=codes.len() as u16).collect::<Vec<_>>());
        // name assignments must never change once shipped
        assert_eq!(error_code_name(ERR_PROTOCOL), "protocol");
        assert_eq!(error_code_name(ERR_BAD_HELLO), "bad-hello");
        assert_eq!(error_code_name(ERR_UNKNOWN_QUERY), "unknown-query");
        assert_eq!(error_code_name(ERR_UNKNOWN_VIEW), "unknown-view");
        assert_eq!(error_code_name(ERR_BAD_DOC), "bad-doc");
        assert_eq!(error_code_name(ERR_SERVER), "server");
        assert_eq!(error_code_name(ERR_QUERY_REJECTED), "query-rejected");
        assert_eq!(error_code_name(ERR_DEADLINE), "deadline");
        assert_eq!(error_code_name(ERR_DOC_PANIC), "doc-panic");
        assert_eq!(error_code_name(0xffff), "unknown");
        // names are unique too
        let mut names: Vec<&str> = ERROR_TAXONOMY.iter().map(|(_, n, _)| *n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), codes.len(), "duplicate error names");
    }

    #[test]
    fn bad_deadline_flag_rejected() {
        // a Doc frame whose deadline flag byte is neither 0 nor 1
        let mut body = vec![FRAME_DOC];
        put_u64(&mut body, 7);
        body.push(2); // bad flag
        body.extend_from_slice(b"text");
        let mut wire = (body.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&body);
        let mut r = &wire[..];
        assert!(matches!(read_frame(&mut r), Err(ProtocolError::Malformed(_))));
    }

    #[test]
    fn eof_at_boundary_is_none_mid_frame_is_truncated() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &Frame::Finish).unwrap();
        // clean boundary
        let mut r = &wire[..];
        assert!(read_frame(&mut r).unwrap().is_some());
        assert!(read_frame(&mut r).unwrap().is_none());
        // cut inside the prefix and inside the body
        for cut in [1, 2, 4] {
            let mut r = &wire[..cut.min(wire.len() - 1)];
            assert!(matches!(
                read_frame(&mut r),
                Err(ProtocolError::Truncated)
            ));
        }
    }

    #[test]
    fn garbage_length_and_type_rejected_cleanly() {
        // length 0
        let mut r = &[0u8, 0, 0, 0][..];
        assert!(matches!(read_frame(&mut r), Err(ProtocolError::BadLength(0))));
        // absurd length
        let mut r = &[0xff, 0xff, 0xff, 0xff][..];
        assert!(matches!(read_frame(&mut r), Err(ProtocolError::BadLength(_))));
        // unknown type byte
        let mut wire = vec![1, 0, 0, 0, 0x7f];
        let mut r = &wire[..];
        assert!(matches!(
            read_frame(&mut r),
            Err(ProtocolError::UnknownFrame(0x7f))
        ));
        // trailing junk after a valid Finish payload
        wire = vec![3, 0, 0, 0, FRAME_FINISH, 9, 9];
        let mut r = &wire[..];
        assert!(matches!(read_frame(&mut r), Err(ProtocolError::Malformed(_))));
    }

    #[test]
    fn batch_roundtrips_with_nulls() {
        let schema = Schema {
            fields: vec![
                Field {
                    name: "m".into(),
                    ty: FieldType::Span,
                },
                Field {
                    name: "n".into(),
                    ty: FieldType::Int,
                },
                Field {
                    name: "w".into(),
                    ty: FieldType::Str,
                },
            ],
        };
        let rows: Vec<Tuple> = vec![
            vec![
                Value::Span(Span::new(0, 5)),
                Value::Int(3),
                Value::Str(Arc::from("abc")),
            ],
            vec![Value::Span(Span::new(7, 12)), Value::Null, Value::Null],
        ];
        let batch = TupleBatch::from_rows(&schema, &rows);
        let mut wire = Vec::new();
        encode_batch(&batch, &mut wire);
        assert_eq!(decode_batch(&wire).unwrap(), rows);
        // canonical: same batch encodes to the same bytes
        let mut again = Vec::new();
        encode_batch(&batch, &mut again);
        assert_eq!(wire, again);
    }

    #[test]
    fn empty_batch_roundtrips() {
        let schema = Schema {
            fields: vec![Field {
                name: "m".into(),
                ty: FieldType::Span,
            }],
        };
        let batch = TupleBatch::for_schema(&schema);
        let mut wire = Vec::new();
        encode_batch(&batch, &mut wire);
        assert_eq!(decode_batch(&wire).unwrap(), Vec::<Tuple>::new());
    }

    #[test]
    fn adversarial_batch_payloads_error_not_panic() {
        // claims 2^32-1 rows in a 6-byte payload
        let mut bad = Vec::new();
        put_u32(&mut bad, u32::MAX);
        put_u16(&mut bad, 1);
        assert!(decode_batch(&bad).is_err());
        // span with begin > end must not hit Span::new's invariant
        let mut bad = Vec::new();
        put_u32(&mut bad, 1);
        put_u16(&mut bad, 1);
        bad.push(TAG_SPANS);
        bad.push(0);
        put_i32(&mut bad, 9);
        put_i32(&mut bad, 3);
        assert!(decode_batch(&bad).is_err());
        // truncated in the middle of a column
        let mut ok = Vec::new();
        put_u32(&mut ok, 1);
        put_u16(&mut ok, 1);
        ok.push(TAG_INTS);
        ok.push(0);
        put_u64(&mut ok, 77);
        assert!(decode_batch(&ok).is_ok());
        assert!(decode_batch(&ok[..ok.len() - 3]).is_err());
    }
}
