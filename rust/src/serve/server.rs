//! The thread-per-connection TCP server multiplexing many client
//! streams onto ONE shared [`Engine`].
//!
//! Each accepted connection gets its own [`Session`] over the shared
//! engine — the paper's multi-threaded communication interface lifted
//! one layer up: N connections × `threads_per_connection` workers all
//! feed the same supergraph, the same accelerator service, the same
//! arena. Backpressure composes end to end, per connection:
//!
//! ```text
//! slow client socket → writer thread blocks → bounded result queue
//! fills (blocked_ns accounted) → session workers block in the sink →
//! session ingress queue fills → reader stops reading the socket →
//! TCP receive window closes → the client's sends block
//! ```
//!
//! Only that connection's reader is affected; every queue is
//! per-connection, so one slow consumer cannot stall its neighbours.
//! Admission control caps concurrent connections with a clean `Busy`
//! frame, and a mid-stream disconnect tears down one session while the
//! server keeps serving.

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{Engine, QueryHandle, ResultSink};
use crate::corpus::framing;
use crate::exec::{DocResult, ViewHandle};
use crate::metrics::{QueueSnapshot, QueueStats, ServeSnapshot, ServeStats};
use crate::runtime::chaos::ChaosPlan;
use crate::runtime::fault::DocError;
use crate::runtime::queue;
use crate::serve::admin;
use crate::serve::protocol::{
    self, Frame, ProtocolError, ERR_BAD_DOC, ERR_BAD_HELLO, ERR_DEADLINE, ERR_DOC_PANIC,
    ERR_PROTOCOL, ERR_QUERY_REJECTED, ERR_SERVER, ERR_UNKNOWN_QUERY, ERR_UNKNOWN_VIEW,
};
use crate::text::Document;

/// Server configuration. All knobs have serving-appropriate defaults;
/// the selftest and the loopback tests bind port 0 for an ephemeral
/// address.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Address to bind for the document protocol (`host:port`; port 0
    /// picks an ephemeral port).
    pub addr: String,
    /// Optional address for the HTTP/1.0 `GET /metrics` admin endpoint.
    pub admin_addr: Option<String>,
    /// Admission-control cap: connections past this count get a `Busy`
    /// frame and are closed.
    pub max_connections: usize,
    /// Depth of each connection's bounded result queue (frames encoded
    /// but not yet written).
    pub queue_depth: usize,
    /// Session worker threads per connection.
    pub threads_per_connection: usize,
    /// Server-side default per-document deadline budget, applied when the
    /// client's `Hello` doesn't set one. `None` = no deadline.
    pub default_budget: Option<Duration>,
    /// Seeded fault-injection plan applied to every connection's session —
    /// the chaos harness behind `repro chaos`. `None` in production.
    pub chaos: Option<Arc<ChaosPlan>>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7171".to_string(),
            admin_addr: None,
            max_connections: 64,
            queue_depth: 32,
            threads_per_connection: 2,
            default_budget: None,
            chaos: None,
        }
    }
}

/// Live gauges of one active connection (see [`Server::connections`]).
#[derive(Debug, Clone)]
pub struct ConnSnapshot {
    /// Server-assigned connection id (monotonic).
    pub id: u64,
    /// Peer address.
    pub peer: String,
    /// This connection's counters.
    pub stats: ServeSnapshot,
    /// This connection's result-queue gauges (`blocked_ns` is the
    /// backpressure evidence).
    pub queue: QueueSnapshot,
}

/// One registered live connection: per-connection stats plus the result
/// queue's gauges, visible to the admin endpoint while the connection
/// lives and folded into the aggregate when it closes.
pub(crate) struct ConnEntry {
    pub(crate) id: u64,
    pub(crate) peer: String,
    pub(crate) stats: Arc<ServeStats>,
    pub(crate) queue: Arc<QueueStats>,
}

/// State shared by the accept loop, connection handlers, and the admin
/// endpoint.
pub(crate) struct ServerShared {
    pub(crate) engine: Arc<Engine>,
    pub(crate) config: ServeConfig,
    pub(crate) stats: ServeStats,
    pub(crate) conns: Mutex<Vec<Arc<ConnEntry>>>,
    next_conn_id: AtomicU64,
    stopping: AtomicBool,
}

impl ServerShared {
    /// Whether shutdown has been requested (accept loops poll this after
    /// every accept).
    pub(crate) fn stopping(&self) -> bool {
        self.stopping.load(Ordering::SeqCst)
    }
}

/// A running serving tier: accept loop + admin endpoint + one handler
/// thread per connection, all over one shared engine. Dropping the
/// server shuts it down (idempotent with [`Server::shutdown`]).
pub struct Server {
    shared: Arc<ServerShared>,
    local_addr: SocketAddr,
    admin_addr: Option<SocketAddr>,
    accept: Option<JoinHandle<()>>,
    admin: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind the configured addresses and start accepting connections.
    pub fn start(engine: Arc<Engine>, config: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&config.addr)
            .with_context(|| format!("binding serve address {}", config.addr))?;
        let local_addr = listener.local_addr()?;
        let admin_listener = match &config.admin_addr {
            Some(addr) => Some(
                TcpListener::bind(addr)
                    .with_context(|| format!("binding admin address {addr}"))?,
            ),
            None => None,
        };
        let admin_addr = match &admin_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };

        let shared = Arc::new(ServerShared {
            engine,
            config,
            stats: ServeStats::default(),
            conns: Mutex::new(Vec::new()),
            next_conn_id: AtomicU64::new(0),
            stopping: AtomicBool::new(false),
        });
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let accept = {
            let shared = shared.clone();
            let handlers = handlers.clone();
            std::thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || accept_loop(listener, shared, handlers))?
        };
        let admin = match admin_listener {
            Some(l) => {
                let shared = shared.clone();
                Some(
                    std::thread::Builder::new()
                        .name("serve-admin".into())
                        .spawn(move || admin::run(l, shared))?,
                )
            }
            None => None,
        };

        Ok(Server {
            shared,
            local_addr,
            admin_addr,
            accept: Some(accept),
            admin: Some(admin_handle_or_none(admin)),
            handlers,
        })
    }

    /// The bound protocol address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The bound admin address, when an admin endpoint was configured.
    pub fn admin_addr(&self) -> Option<SocketAddr> {
        self.admin_addr
    }

    /// Aggregate serving counters.
    pub fn stats(&self) -> ServeSnapshot {
        self.shared.stats.snapshot()
    }

    /// Gauges of every currently active connection.
    pub fn connections(&self) -> Vec<ConnSnapshot> {
        self.shared
            .conns
            .lock()
            .unwrap()
            .iter()
            .map(|c| ConnSnapshot {
                id: c.id,
                peer: c.peer.clone(),
                stats: c.stats.snapshot(),
                queue: c.queue.snapshot(),
            })
            .collect()
    }

    /// Block on the accept loop — `repro serve`'s foreground mode. The
    /// loop only exits on [`Server::shutdown`] (from another thread) or
    /// a listener error.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, then join the accept loop, every connection
    /// handler, and the admin thread. Active connections are joined, not
    /// killed — callers in tests disconnect their clients first.
    pub fn shutdown(&mut self) {
        self.shared.stopping.store(true, Ordering::SeqCst);
        // unblock the accept loops with one throwaway connection each
        let _ = TcpStream::connect(self.local_addr);
        if let Some(addr) = self.admin_addr {
            let _ = TcpStream::connect(addr);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.admin.take() {
            let _ = h.join();
        }
        let handlers: Vec<_> = std::mem::take(&mut *self.handlers.lock().unwrap());
        for h in handlers {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// `Option<Option<JoinHandle>>` flattening without pulling in a helper
// trait; keeps Server::start readable.
fn admin_handle_or_none(h: Option<JoinHandle<()>>) -> JoinHandle<()> {
    match h {
        Some(h) => h,
        None => std::thread::spawn(|| {}),
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<ServerShared>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(x) => x,
            Err(_) => {
                if shared.stopping.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if shared.stopping.load(Ordering::SeqCst) {
            return;
        }
        // admission control: reject past the cap with a clean Busy frame
        let active = shared.stats.active.load(Ordering::SeqCst);
        let active = if active < 0 {
            // the gauge is an invariant, not a best-effort estimate: a
            // negative reading means an accounting bug (a decrement
            // without its increment), so repair it instead of papering
            // over the sign with a saturating cast every reader must
            // remember to apply
            debug_assert!(false, "active connection gauge underflowed: {active}");
            shared.stats.active.store(0, Ordering::SeqCst);
            0
        } else {
            active
        };
        let cap = shared.config.max_connections;
        if active >= cap as i64 {
            shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
            let mut w = BufWriter::new(stream);
            let _ = protocol::write_frame(
                &mut w,
                &Frame::Busy {
                    active: active as u32,
                    cap: cap as u32,
                },
            );
            let _ = w.flush();
            continue; // dropping the stream closes it
        }
        shared.stats.accepted.fetch_add(1, Ordering::Relaxed);
        shared.stats.active.fetch_add(1, Ordering::SeqCst);
        let id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
        let shared2 = shared.clone();
        // clone the stream BEFORE moving it into the handler closure: if
        // the spawn fails, the moved-in original is already gone with the
        // dropped closure, and the clone is the only way to tell the
        // client anything rather than silently hanging up on an accepted
        // connection
        let spawn_err_stream = stream.try_clone().ok();
        let handle = std::thread::Builder::new()
            .name(format!("serve-conn-{id}"))
            .spawn(move || handle_connection(stream, peer.to_string(), shared2, id));
        match handle {
            Ok(h) => handlers.lock().unwrap().push(h),
            Err(e) => {
                // could not spawn: undo the admission and give the peer a
                // clean terminal Error frame instead of a bare hangup
                shared.stats.active.fetch_sub(1, Ordering::SeqCst);
                shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
                if let Some(s) = spawn_err_stream {
                    send_error_now(
                        &s,
                        ERR_SERVER,
                        &format!("server cannot spawn a connection handler: {e}"),
                    );
                }
            }
        }
    }
}

/// Decrements the active gauge and unregisters the connection however
/// the handler exits (clean finish, protocol error, panic).
struct ActiveGuard {
    shared: Arc<ServerShared>,
    id: u64,
}

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.shared.stats.active.fetch_sub(1, Ordering::SeqCst);
        let mut conns = self.shared.conns.lock().unwrap();
        if let Some(pos) = conns.iter().position(|c| c.id == self.id) {
            let entry = conns.swap_remove(pos);
            // fold the dying connection's backpressure evidence into the
            // aggregate so tests and the admin endpoint still see it
            self.shared.stats.absorb_queue(&entry.queue.snapshot());
        }
    }
}

/// What the writer thread pumps: encoded frames in order, then `Done`
/// (answered-doc count + finished corpus aggregate tables) or a
/// terminal `Error`.
enum Out {
    Result(Frame),
    DocErr(u64, u16, String),
    Done(u64, Vec<(u16, Vec<u8>)>),
    Error(u16, String),
}

/// The per-connection [`ResultSink`]: successes become `Result` frames,
/// contained per-document failures become `DocErr` frames — the
/// connection keeps serving after either. Runs on session workers.
struct ConnSink {
    tx: Mutex<queue::QueueTx<Out>>,
    table: Arc<[ViewHandle]>,
    conn: Arc<ServeStats>,
    agg: Arc<ServerShared>,
    abort: Arc<AtomicBool>,
}

impl ConnSink {
    fn push(&self, out: Out) {
        let tx = self.tx.lock().unwrap().clone();
        // a failed push means the connection is tearing down; frames for
        // a dead client are dropped by design
        let _ = tx.push(out);
    }
}

impl ResultSink for ConnSink {
    fn on_result(&self, doc: &Document, result: &DocResult) {
        if self.abort.load(Ordering::Relaxed) {
            return;
        }
        let mut views = Vec::with_capacity(self.table.len());
        for (vi, h) in self.table.iter().enumerate() {
            let mut buf = Vec::new();
            protocol::encode_batch(result.view_batch(h), &mut buf);
            views.push((vi as u16, buf));
        }
        self.conn.results.fetch_add(1, Ordering::Relaxed);
        self.agg.stats.results.fetch_add(1, Ordering::Relaxed);
        self.push(Out::Result(Frame::Result {
            doc_id: doc.id,
            views,
        }));
    }

    fn on_error(&self, doc: &Document, error: &DocError) {
        self.conn.doc_errors.fetch_add(1, Ordering::Relaxed);
        self.agg.stats.doc_errors.fetch_add(1, Ordering::Relaxed);
        let code = if error.is_deadline() {
            self.conn.deadline_expired.fetch_add(1, Ordering::Relaxed);
            self.agg.stats.deadline_expired.fetch_add(1, Ordering::Relaxed);
            ERR_DEADLINE
        } else {
            ERR_DOC_PANIC
        };
        if self.abort.load(Ordering::Relaxed) {
            return;
        }
        self.push(Out::DocErr(doc.id, code, error.to_string()));
    }
}

fn handle_connection(stream: TcpStream, peer: String, shared: Arc<ServerShared>, id: u64) {
    let _guard = ActiveGuard {
        shared: shared.clone(),
        id,
    };
    let _ = stream.set_nodelay(true);
    serve_connection(stream, peer, &shared, id);
}

/// Everything after accept: handshake, session, read loop, teardown.
/// Errors are per-connection — this function never panics the server.
fn serve_connection(stream: TcpStream, peer: String, shared: &Arc<ServerShared>, id: u64) {
    let agg = &shared.stats;
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };

    // --- handshake: Hello must be the first frame ---
    let (queries, views, hello_budget) = match protocol::read_frame(&mut reader) {
        Ok(Some(Frame::Hello {
            queries,
            views,
            budget_ms,
        })) => (queries, views, budget_ms),
        Ok(Some(_)) => {
            agg.protocol_errors.fetch_add(1, Ordering::Relaxed);
            send_error_now(&stream, ERR_BAD_HELLO, "expected Hello as the first frame");
            return;
        }
        Ok(None) => return, // connected and left; not an error
        Err(_) => {
            agg.protocol_errors.fetch_add(1, Ordering::Relaxed);
            send_error_now(&stream, ERR_PROTOCOL, "malformed handshake frame");
            return;
        }
    };
    let table = match resolve_views(&shared.engine, &queries, &views) {
        Ok(t) => t,
        Err((code, msg)) => {
            agg.protocol_errors.fetch_add(1, Ordering::Relaxed);
            send_error_now(&stream, code, &msg);
            return;
        }
    };

    // --- Welcome (written before the writer thread exists) ---
    let mut write_half = match stream.try_clone() {
        Ok(s) => BufWriter::new(s),
        Err(_) => return,
    };
    let names: Vec<String> = table.iter().map(|h| h.name().to_string()).collect();
    if protocol::write_frame(&mut write_half, &Frame::Welcome { views: names })
        .and_then(|_| write_half.flush())
        .is_err()
    {
        return;
    }

    // --- per-connection plumbing: result queue, writer thread, session ---
    let conn_stats = Arc::new(ServeStats::default());
    let (tx, rx) = queue::bounded::<Out>(shared.config.queue_depth.max(1));
    let entry = Arc::new(ConnEntry {
        id,
        peer,
        stats: conn_stats.clone(),
        queue: tx.stats().clone(),
    });
    shared.conns.lock().unwrap().push(entry);

    let writer = {
        let agg_bytes = shared.clone();
        std::thread::Builder::new()
            .name(format!("serve-write-{id}"))
            .spawn(move || writer_loop(write_half, rx, agg_bytes))
    };
    let writer = match writer {
        Ok(w) => w,
        Err(_) => return,
    };

    // The sink runs on session workers (Sync required); QueueTx wraps a
    // SyncSender (not Sync), so the sink keeps it under a mutex and
    // clones a handle out per push — the established pattern from the
    // accelerator's submission path.
    let abort = Arc::new(AtomicBool::new(false));
    let sink = ConnSink {
        tx: Mutex::new(tx.clone()),
        table: table.clone().into(),
        conn: conn_stats.clone(),
        agg: shared.clone(),
        abort: abort.clone(),
    };
    // Hello's budget overrides the server-side default for the whole
    // connection; a Doc frame can still override per document.
    let conn_budget = hello_budget
        .map(Duration::from_millis)
        .or(shared.config.default_budget);
    let mut builder = shared
        .engine
        .session()
        .threads(shared.config.threads_per_connection.max(1))
        .queue_depth(shared.config.queue_depth.max(1))
        .sink(Arc::new(sink));
    if let Some(budget) = conn_budget {
        builder = builder.deadline(budget);
    }
    if let Some(plan) = &shared.config.chaos {
        builder = builder.chaos(plan.clone());
    }
    let mut session = builder.start();

    // --- read loop ---
    enum Ended {
        Finished,
        Disconnected,
        Protocol(u16, String),
    }
    let mut ended = loop {
        match protocol::read_frame(&mut reader) {
            Ok(Some(Frame::Doc {
                id: doc_id,
                budget_ms,
                bytes,
            })) => {
                let len = bytes.len() as u64;
                match framing::doc_from_bytes(doc_id, bytes) {
                    Ok(doc) => {
                        conn_stats.docs.fetch_add(1, Ordering::Relaxed);
                        conn_stats.bytes_in.fetch_add(len, Ordering::Relaxed);
                        agg.docs.fetch_add(1, Ordering::Relaxed);
                        agg.bytes_in.fetch_add(len, Ordering::Relaxed);
                        // blocks when the session queue is full — the
                        // last link of the backpressure chain
                        let pushed = match budget_ms {
                            Some(ms) => session
                                .push_with_deadline(doc, Duration::from_millis(ms)),
                            None => session.push(doc),
                        };
                        if pushed.is_err() {
                            break Ended::Protocol(
                                ERR_SERVER,
                                "session workers unavailable".to_string(),
                            );
                        }
                    }
                    Err(e) => {
                        break Ended::Protocol(ERR_BAD_DOC, format!("doc {doc_id}: {e}"))
                    }
                }
            }
            Ok(Some(Frame::Finish)) => break Ended::Finished,
            Ok(Some(_)) => {
                break Ended::Protocol(ERR_PROTOCOL, "unexpected frame type".to_string())
            }
            Ok(None) => break Ended::Disconnected,
            Err(ProtocolError::Io(_)) => break Ended::Disconnected,
            Err(e) => break Ended::Protocol(ERR_PROTOCOL, e.to_string()),
        }
    };

    // --- teardown ---
    if let Ended::Finished = ended {
        // drain every queued document; the sink pushes the remaining
        // results before finish() returns. Done counts every answered
        // document — successes plus per-doc errors (a DocErr is an
        // answer, not a dropped doc).
        let report = session.finish();
        // corpus-level aggregate tables ride the Done frame, addressed by
        // this connection's view-table indices (same scheme as Result);
        // views the client didn't subscribe to are simply not shipped
        let mut corpus = Vec::new();
        for (vi, h) in table.iter().enumerate() {
            if let Some(c) = report.corpus.iter().find(|c| c.view == h.name()) {
                let batch = crate::exec::TupleBatch::from_rows(&c.schema, &c.rows);
                let mut buf = Vec::new();
                protocol::encode_batch(&batch, &mut buf);
                corpus.push((vi as u16, buf));
            }
        }
        let _ = tx.push(Out::Done((report.docs + report.errors) as u64, corpus));
    } else {
        // disconnect or protocol error: stop producing results, drain
        // the session without writing, then (on protocol errors) tell
        // the client what happened
        abort.store(true, Ordering::Relaxed);
        drop(session);
        match &mut ended {
            Ended::Protocol(code, msg) => {
                agg.protocol_errors.fetch_add(1, Ordering::Relaxed);
                conn_stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let _ = tx.push(Out::Error(*code, std::mem::take(msg)));
            }
            Ended::Disconnected => {
                agg.disconnects.fetch_add(1, Ordering::Relaxed);
                conn_stats.disconnects.fetch_add(1, Ordering::Relaxed);
            }
            Ended::Finished => unreachable!(),
        }
    }
    drop(tx);
    let _ = writer.join();
}

/// The per-connection writer: pops encoded frames and writes them. On a
/// write error it keeps draining (so blocked producers unblock) but
/// stops writing — the queue closes once the sink and reader drop their
/// producer handles.
fn writer_loop(mut w: BufWriter<TcpStream>, rx: queue::QueueRx<Out>, shared: Arc<ServerShared>) {
    let mut dead = false;
    while let Some(out) = rx.pop() {
        if dead {
            continue;
        }
        let frame = match out {
            Out::Result(f) => f,
            Out::DocErr(doc_id, code, message) => Frame::DocErr {
                doc_id,
                code,
                message,
            },
            Out::Done(docs, corpus) => Frame::Done { docs, corpus },
            Out::Error(code, message) => Frame::Error { code, message },
        };
        match protocol::write_frame(&mut w, &frame).and_then(|n| w.flush().map(|_| n)) {
            Ok(n) => {
                shared.stats.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
            }
            Err(_) => dead = true,
        }
    }
}

/// Best-effort terminal error before the writer thread exists (handshake
/// failures write directly; there is no concurrent writer yet).
fn send_error_now(stream: &TcpStream, code: u16, message: &str) {
    if let Ok(s) = stream.try_clone() {
        let mut w = BufWriter::new(s);
        let _ = protocol::write_frame(
            &mut w,
            &Frame::Error {
                code,
                message: message.to_string(),
            },
        );
        let _ = w.flush();
    }
}

/// Resolve the Hello's namespaces + view subscriptions against the
/// engine's catalog: empty query list = every registered query, empty
/// view list = every view of the selected queries. A subscribed view
/// must live inside the selected namespaces — per-tenant isolation, not
/// just name resolution.
fn resolve_views(
    engine: &Engine,
    queries: &[String],
    views: &[String],
) -> Result<Vec<ViewHandle>, (u16, String)> {
    let selected: Vec<QueryHandle> = if queries.is_empty() {
        engine.queries().to_vec()
    } else {
        let mut qs = Vec::with_capacity(queries.len());
        for name in queries {
            match engine.query(name) {
                Ok(q) => qs.push(q),
                Err(_) => {
                    // a quarantined entry gets a structured rejection
                    // carrying its first diagnostic, not "unknown query"
                    if let Some(r) = engine.rejected_query(name) {
                        let detail = r
                            .report
                            .diagnostics
                            .first()
                            .map(|d| format!("{}: {}", d.code, d.message))
                            .unwrap_or_else(|| "rejected by static analysis".into());
                        return Err((
                            ERR_QUERY_REJECTED,
                            format!("query '{name}' was rejected at build time ({detail})"),
                        ));
                    }
                    return Err((
                        ERR_UNKNOWN_QUERY,
                        format!("no query '{name}' in the catalog"),
                    ));
                }
            }
        }
        qs
    };
    if views.is_empty() {
        return Ok(selected
            .iter()
            .flat_map(|q| q.views().iter().cloned())
            .collect());
    }
    let mut table = Vec::with_capacity(views.len());
    for name in views {
        let found = selected.iter().find_map(|q| {
            q.views()
                .iter()
                .find(|h| h.name() == name)
                .cloned()
                .or_else(|| q.view(name).ok())
        });
        match found {
            Some(h) => table.push(h),
            None => {
                return Err((
                    ERR_UNKNOWN_VIEW,
                    format!("no view '{name}' in the subscribed namespaces"),
                ))
            }
        }
    }
    Ok(table)
}
