//! Hand-rolled HTTP/1.0 admin endpoint. `GET /metrics` returns one JSON
//! snapshot of the serving tier plus the engine's queue, arena,
//! block-pool, accelerator, circuit-breaker, and quarantine gauges.
//! `GET /healthz` is the liveness probe: 200 with per-thread heartbeat
//! detail when every busy worker is beating, 503 when any has stalled
//! past the watchdog window. No HTTP library — request-line parse, fixed
//! headers, `Connection: close` — because the only client is `curl`/a CI
//! probe.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::exec::batch;
use crate::metrics::{AccelSnapshot, QueueSnapshot, ServeSnapshot};
use crate::serve::server::ServerShared;

/// Accept loop for the admin listener; one short-lived thread per
/// request. Exits when the listener errors (shutdown closes it via a
/// throwaway connect + the stopping flag).
pub(crate) fn run(listener: TcpListener, shared: Arc<ServerShared>) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if shared.stopping() {
                    return;
                }
                continue;
            }
        };
        if shared.stopping() {
            return;
        }
        let shared = shared.clone();
        let _ = std::thread::Builder::new()
            .name("serve-admin-req".into())
            .spawn(move || handle_request(stream, &shared));
    }
}

fn handle_request(stream: TcpStream, shared: &ServerShared) {
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut request_line = String::new();
    if reader.read_line(&mut request_line).is_err() {
        return;
    }
    // drain headers (bounded — a peer streaming garbage can't pin us)
    for _ in 0..64 {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) if line == "\r\n" || line == "\n" => break,
            Ok(_) => continue,
            Err(_) => return,
        }
    }

    let mut w = stream;
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method == "GET" && (path == "/metrics" || path == "/metrics/") {
        let body = metrics_json(shared);
        let _ = write!(
            w,
            "HTTP/1.0 200 OK\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        );
    } else if method == "GET" && (path == "/healthz" || path == "/healthz/") {
        let (healthy, body) = healthz_json(shared);
        let status = if healthy {
            "200 OK"
        } else {
            "503 Service Unavailable"
        };
        let _ = write!(
            w,
            "HTTP/1.0 {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            status,
            body.len(),
            body
        );
    } else {
        let body = "{\"error\":\"not found; try GET /metrics or GET /healthz\"}";
        let _ = write!(
            w,
            "HTTP/1.0 404 Not Found\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        );
    }
    let _ = w.flush();
    // half-close politely; ignore whatever else the peer sent
    let _ = w.shutdown(std::net::Shutdown::Write);
    let mut sink = [0u8; 256];
    let _ = reader.read(&mut sink);
}

// --- JSON rendering (hand-built, same idiom as cmd_bench) ---

fn serve_json(s: &ServeSnapshot) -> String {
    format!(
        "{{\"accepted\":{},\"rejected\":{},\"active\":{},\"docs\":{},\"bytes_in\":{},\"results\":{},\"bytes_out\":{},\"protocol_errors\":{},\"disconnects\":{},\"doc_errors\":{},\"deadline_expired\":{},\"result_stalls\":{},\"result_blocked_ns\":{}}}",
        s.accepted,
        s.rejected,
        s.active,
        s.docs,
        s.bytes_in,
        s.results,
        s.bytes_out,
        s.protocol_errors,
        s.disconnects,
        s.doc_errors,
        s.deadline_expired,
        s.result_stalls,
        s.result_blocked_ns
    )
}

fn queue_json(q: &QueueSnapshot) -> String {
    format!(
        "{{\"pushed\":{},\"stalls\":{},\"blocked_ns\":{},\"depth\":{},\"high_water\":{}}}",
        q.pushed, q.stalls, q.blocked_ns, q.depth, q.high_water
    )
}

fn accel_json(a: &AccelSnapshot) -> String {
    format!(
        "{{\"packages\":{},\"docs\":{},\"bytes\":{},\"hits\":{},\"engine_wall_ns\":{},\"post_wall_ns\":{},\"modeled_ns\":{},\"cycles\":{}}}",
        a.packages,
        a.docs,
        a.bytes,
        a.hits,
        a.engine_wall_ns,
        a.post_wall_ns,
        a.modeled_ns,
        a.cycles
    )
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The whole `/metrics` document: serving aggregate + live connections,
/// then the engine-side gauges every other CLI mode also reports.
pub(crate) fn metrics_json(shared: &ServerShared) -> String {
    let agg = shared.stats.snapshot();
    let mut out = String::with_capacity(1024);
    out.push_str("{\"serve\":{\"aggregate\":");
    out.push_str(&serve_json(&agg));
    out.push_str(",\"connections\":[");
    let conns = shared.conns.lock().unwrap();
    for (i, c) in conns.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let s = c.stats.snapshot();
        out.push_str(&format!(
            "{{\"id\":{},\"peer\":\"{}\",\"docs\":{},\"bytes_in\":{},\"results\":{},\"queue\":{}}}",
            c.id,
            json_escape(&c.peer),
            s.docs,
            s.bytes_in,
            s.results,
            queue_json(&c.queue.snapshot())
        ));
    }
    drop(conns);
    out.push_str("]},\"accel\":");
    match shared.engine.accel_snapshot() {
        Some(a) => out.push_str(&accel_json(&a)),
        None => out.push_str("null"),
    }
    out.push_str(",\"accel_queue\":");
    match shared.engine.accel_queue_snapshot() {
        Some(q) => out.push_str(&queue_json(&q)),
        None => out.push_str("null"),
    }
    // per-device rows and pool-level routing counters (retries,
    // failovers, host fallbacks, software-routed calls); both null when
    // no accelerator service is attached
    out.push_str(",\"accel_devices\":");
    match shared.engine.accel_device_snapshots() {
        Some(devices) => {
            out.push('[');
            for (i, d) in devices.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "{{\"device\":{},\"accel\":{},\"queue\":{}}}",
                    d.device,
                    accel_json(&d.accel),
                    queue_json(&d.queue)
                ));
            }
            out.push(']');
        }
        None => out.push_str("null"),
    }
    out.push_str(",\"accel_pool\":");
    match shared.engine.accel_pool_snapshot() {
        Some(p) => out.push_str(&format!(
            "{{\"retries\":{},\"failovers\":{},\"sw_fallbacks\":{},\"sw_routed\":{},\"breaker_trips\":{},\"breaker_probes\":{},\"breaker_readmits\":{},\"deadline_expired\":{}}}",
            p.retries,
            p.failovers,
            p.sw_fallbacks,
            p.sw_routed,
            p.breaker_trips,
            p.breaker_probes,
            p.breaker_readmits,
            p.deadline_expired
        )),
        None => out.push_str("null"),
    }
    // per-device breaker states (null without an accelerator service)
    out.push_str(",\"breakers\":");
    match shared.engine.accel_breaker_snapshots() {
        Some(breakers) => {
            out.push('[');
            for (i, b) in breakers.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "{{\"device\":{},\"state\":\"{}\",\"consecutive_errors\":{},\"trips\":{},\"probes\":{},\"readmits\":{}}}",
                    i,
                    b.state.name(),
                    b.consecutive_errors,
                    b.trips,
                    b.probes,
                    b.readmits
                ));
            }
            out.push(']');
        }
        None => out.push_str("null"),
    }
    // poison-document quarantine (bounded registry; total ≥ held)
    let quarantine = shared.engine.quarantine();
    out.push_str(&format!(
        ",\"quarantine\":{{\"total\":{},\"held\":{}}}",
        quarantine.total(),
        quarantine.len()
    ));
    let arena = shared.engine.arena_snapshot();
    out.push_str(&format!(
        ",\"arena\":{{\"checkouts\":{},\"fresh\":{},\"returns_local\":{},\"returns_cross\":{},\"pooled\":{}}}",
        arena.checkouts, arena.fresh, arena.returns_local, arena.returns_cross, arena.pooled
    ));
    let blocks = batch::block_pool_stats();
    out.push_str(&format!(
        ",\"blocks\":{{\"checkouts\":{},\"fresh\":{},\"returns\":{},\"pooled\":{}}}",
        blocks.checkouts, blocks.fresh, blocks.returns, blocks.pooled
    ));
    out.push('}');
    out
}

/// The `/healthz` document plus the verdict that picks the HTTP status:
/// per-thread heartbeat detail from the engine watchdog, breaker states,
/// and the quarantine gauges — everything an operator needs to tell a
/// wedged worker from a dark device from a poison document.
pub(crate) fn healthz_json(shared: &ServerShared) -> (bool, String) {
    let report = shared.engine.health();
    let mut out = String::with_capacity(512);
    out.push_str(&format!(
        "{{\"healthy\":{},\"threads\":[",
        report.healthy
    ));
    for (i, t) in report.threads.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"beats\":{},\"idle\":{},\"age_ms\":{},\"stalled\":{}}}",
            json_escape(&t.name),
            t.beats,
            t.idle,
            t.age_ms,
            t.stalled
        ));
    }
    out.push(']');
    out.push_str(",\"breakers\":");
    match shared.engine.accel_breaker_snapshots() {
        Some(breakers) => {
            out.push('[');
            for (i, b) in breakers.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "{{\"device\":{},\"state\":\"{}\",\"trips\":{},\"readmits\":{}}}",
                    i,
                    b.state.name(),
                    b.trips,
                    b.readmits
                ));
            }
            out.push(']');
        }
        None => out.push_str("null"),
    }
    let quarantine = shared.engine.quarantine();
    out.push_str(&format!(
        ",\"quarantine\":{{\"total\":{},\"held\":{}}}}}",
        quarantine.total(),
        quarantine.len()
    ));
    (report.healthy, out)
}
