//! Built-in protocol client and multi-connection load generator.
//!
//! [`Client`] speaks the wire protocol over one connection: handshake,
//! pipelined `Doc` frames, a background reader collecting `Result`
//! frames, and a `finish` that waits for `Done`. [`run_load`] fans a
//! document set across K concurrent clients and measures throughput —
//! the driver behind `repro serve --selftest` and the loopback tests.

use std::fmt;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::serve::protocol::{self, Frame, ProtocolError};
use crate::text::Document;

/// Why a client operation failed.
#[derive(Debug)]
pub enum ClientError {
    /// The server is at its admission cap.
    Busy {
        /// Connections the server reported as active.
        active: u32,
        /// The server's configured cap.
        cap: u32,
    },
    /// The server sent a terminal `Error` frame.
    Rejected {
        /// The server's `ERR_*` code.
        code: u16,
        /// The server's description.
        message: String,
    },
    /// A wire-protocol violation on the receive path.
    Protocol(ProtocolError),
    /// A socket error on the send path.
    Io(io::Error),
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Busy { active, cap } => {
                write!(f, "server busy ({active}/{cap} connections)")
            }
            ClientError::Rejected { code, message } => {
                write!(f, "server error {code}: {message}")
            }
            ClientError::Protocol(e) => write!(f, "protocol error: {e}"),
            ClientError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<ProtocolError> for ClientError {
    fn from(e: ProtocolError) -> Self {
        match e {
            ProtocolError::Io(io) => ClientError::Io(io),
            other => ClientError::Protocol(other),
        }
    }
}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> Self {
        ClientError::Io(e)
    }
}

/// One `Result` frame as received: the document id plus each subscribed
/// view's encoded batch, in view-table order. Payloads stay encoded so
/// callers can byte-compare against a reference encoding; decode with
/// [`protocol::decode_batch`] when rows are wanted.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultFrame {
    /// Echo of the submitted document id.
    pub doc_id: u64,
    /// `(view-table index, encoded batch)` pairs.
    pub views: Vec<(u16, Vec<u8>)>,
}

/// One `DocErr` frame as received: a per-document failure the server
/// contained (deadline expiry or quarantined panic) while the
/// connection kept serving. See `ERROR_TAXONOMY` in [`protocol`].
#[derive(Debug, Clone, PartialEq)]
pub struct DocErrFrame {
    /// Echo of the submitted document id.
    pub doc_id: u64,
    /// `ERR_DEADLINE` or `ERR_DOC_PANIC`.
    pub code: u16,
    /// The server's description of the failure.
    pub message: String,
}

/// What the background reader hands back when the connection ends.
struct ReaderOutcome {
    results: Vec<ResultFrame>,
    doc_errors: Vec<DocErrFrame>,
    done_docs: Option<u64>,
    corpus: Vec<(u16, Vec<u8>)>,
    error: Option<ClientError>,
}

/// A connected protocol client. Documents are pipelined: `send` does
/// not wait for results — a background thread collects them until the
/// server's `Done` arrives in [`Client::finish`].
pub struct Client {
    writer: BufWriter<TcpStream>,
    view_table: Vec<String>,
    reader: Option<JoinHandle<ReaderOutcome>>,
    sent: u64,
}

impl Client {
    /// Connect, send `Hello{queries, views}`, and wait for the server's
    /// verdict: `Welcome` yields a client, `Busy`/`Error` become the
    /// matching [`ClientError`].
    pub fn connect<A: ToSocketAddrs>(
        addr: A,
        queries: &[String],
        views: &[String],
    ) -> Result<Client, ClientError> {
        Client::connect_with_budget(addr, queries, views, None)
    }

    /// [`Client::connect`] with a default per-document deadline budget in
    /// milliseconds for every doc on this connection (`None` = no
    /// deadline). Expired documents come back as `DocErr` frames in
    /// [`ClientReport::doc_errors`], not results.
    pub fn connect_with_budget<A: ToSocketAddrs>(
        addr: A,
        queries: &[String],
        views: &[String],
        budget_ms: Option<u64>,
    ) -> Result<Client, ClientError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        protocol::write_frame(
            &mut writer,
            &Frame::Hello {
                queries: queries.to_vec(),
                views: views.to_vec(),
                budget_ms,
            },
        )?;
        writer.flush()?;
        let view_table = match protocol::read_frame(&mut reader)? {
            Some(Frame::Welcome { views }) => views,
            Some(Frame::Busy { active, cap }) => {
                return Err(ClientError::Busy { active, cap })
            }
            Some(Frame::Error { code, message }) => {
                return Err(ClientError::Rejected { code, message })
            }
            Some(_) => {
                return Err(ClientError::Protocol(ProtocolError::Malformed(
                    "expected Welcome, Busy, or Error after Hello",
                )))
            }
            None => return Err(ClientError::Protocol(ProtocolError::Truncated)),
        };
        let handle = std::thread::Builder::new()
            .name("serve-client-read".into())
            .spawn(move || read_results(reader))?;
        Ok(Client {
            writer,
            view_table,
            reader: Some(handle),
            sent: 0,
        })
    }

    /// The server's view table from `Welcome` — the order `Result`
    /// frames index their payloads.
    pub fn view_table(&self) -> &[String] {
        &self.view_table
    }

    /// Submit one document. Blocks only when the socket's send buffer is
    /// full (the server's per-connection backpressure reaching us).
    pub fn send(&mut self, id: u64, text: &str) -> io::Result<()> {
        self.send_frame(id, text, None)
    }

    /// [`Client::send`] with a per-document deadline budget in
    /// milliseconds, overriding the connection default for this doc.
    pub fn send_with_budget(&mut self, id: u64, text: &str, budget_ms: u64) -> io::Result<()> {
        self.send_frame(id, text, Some(budget_ms))
    }

    fn send_frame(&mut self, id: u64, text: &str, budget_ms: Option<u64>) -> io::Result<()> {
        protocol::write_frame(
            &mut self.writer,
            &Frame::Doc {
                id,
                budget_ms,
                bytes: text.as_bytes().to_vec(),
            },
        )?;
        self.writer.flush()?;
        self.sent += 1;
        Ok(())
    }

    /// Send `Finish` and wait for the server to drain: every pending
    /// `Result`, then `Done`. Returns all collected results.
    pub fn finish(mut self) -> Result<ClientReport, ClientError> {
        protocol::write_frame(&mut self.writer, &Frame::Finish)?;
        self.writer.flush()?;
        let handle = self.reader.take().expect("reader thread");
        let outcome = handle
            .join()
            .map_err(|_| ClientError::Protocol(ProtocolError::Truncated))?;
        if let Some(e) = outcome.error {
            return Err(e);
        }
        match outcome.done_docs {
            Some(done) => Ok(ClientReport {
                sent: self.sent,
                done,
                results: outcome.results,
                doc_errors: outcome.doc_errors,
                corpus: outcome.corpus,
                view_table: self.view_table.clone(),
            }),
            None => Err(ClientError::Protocol(ProtocolError::Truncated)),
        }
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        // abandoned client (no finish): shut the socket down so the
        // reader's blocking read fails, then give it a bounded window to
        // exit. If it still hasn't (a platform where shutdown doesn't
        // interrupt an in-flight read, or a wedged peer), detach it
        // rather than hang the dropping thread forever — the reader dies
        // with the process.
        if let Some(h) = self.reader.take() {
            drop(self.writer.get_ref().shutdown(std::net::Shutdown::Both));
            let deadline = Instant::now() + Duration::from_secs(2);
            while !h.is_finished() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(5));
            }
            if h.is_finished() {
                let _ = h.join();
            }
        }
    }
}

/// One finished connection's tally.
#[derive(Debug)]
pub struct ClientReport {
    /// Documents this client submitted.
    pub sent: u64,
    /// Documents the server reported in `Done` (successes + per-doc
    /// errors: every answered document).
    pub done: u64,
    /// Every `Result` frame received, in arrival order.
    pub results: Vec<ResultFrame>,
    /// Every `DocErr` frame received (shed/quarantined documents), in
    /// arrival order.
    pub doc_errors: Vec<DocErrFrame>,
    /// Finished corpus-level aggregate tables from the `Done` frame:
    /// `(view-table index, encoded batch)` per subscribed aggregate
    /// view. Decode with [`protocol::decode_batch`]. Empty when no
    /// subscribed view aggregates.
    pub corpus: Vec<(u16, Vec<u8>)>,
    /// The server's view table from `Welcome`.
    pub view_table: Vec<String>,
}

fn read_results(mut reader: BufReader<TcpStream>) -> ReaderOutcome {
    let mut results = Vec::new();
    let mut doc_errors = Vec::new();
    let finish = |results, doc_errors, done_docs, corpus, error| ReaderOutcome {
        results,
        doc_errors,
        done_docs,
        corpus,
        error,
    };
    loop {
        match protocol::read_frame(&mut reader) {
            Ok(Some(Frame::Result { doc_id, views })) => {
                results.push(ResultFrame { doc_id, views });
            }
            Ok(Some(Frame::DocErr {
                doc_id,
                code,
                message,
            })) => {
                doc_errors.push(DocErrFrame {
                    doc_id,
                    code,
                    message,
                });
            }
            Ok(Some(Frame::Done { docs, corpus })) => {
                return finish(results, doc_errors, Some(docs), corpus, None)
            }
            Ok(Some(Frame::Error { code, message })) => {
                return finish(
                    results,
                    doc_errors,
                    None,
                    Vec::new(),
                    Some(ClientError::Rejected { code, message }),
                )
            }
            Ok(Some(_)) => {
                return finish(
                    results,
                    doc_errors,
                    None,
                    Vec::new(),
                    Some(ClientError::Protocol(ProtocolError::Malformed(
                        "unexpected frame from server",
                    ))),
                )
            }
            Ok(None) => {
                return finish(
                    results,
                    doc_errors,
                    None,
                    Vec::new(),
                    Some(ClientError::Protocol(ProtocolError::Truncated)),
                )
            }
            Err(e) => return finish(results, doc_errors, None, Vec::new(), Some(e.into())),
        }
    }
}

/// One load-generation run's tally (see [`run_load`]).
#[derive(Debug)]
pub struct LoadReport {
    /// Concurrent client connections driven.
    pub clients: usize,
    /// Documents submitted across all clients.
    pub docs: usize,
    /// Payload bytes submitted across all clients.
    pub bytes: usize,
    /// Wall time from first connect to last `Done`.
    pub wall: Duration,
    /// Every client's results, merged; `(doc_id, views)` per document.
    pub results: Vec<ResultFrame>,
    /// Every client's `DocErr` frames, merged (shed/quarantined docs).
    pub doc_errors: Vec<DocErrFrame>,
    /// Each client's corpus-level aggregate tables from its `Done` frame,
    /// in client order. Per-connection sessions aggregate only the
    /// documents that connection submitted, so entries are per-client
    /// shards, not one corpus-wide table.
    pub corpus: Vec<Vec<(u16, Vec<u8>)>>,
    /// The server's view table (identical across clients by protocol).
    pub view_table: Vec<String>,
}

impl LoadReport {
    /// Documents per second over the whole run.
    pub fn docs_per_sec(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            self.docs as f64 / secs
        } else {
            0.0
        }
    }

    /// Submitted megabytes per second over the whole run.
    pub fn mb_per_sec(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            (self.bytes as f64 / 1.0e6) / secs
        } else {
            0.0
        }
    }
}

/// Drive `docs` through the server at `addr` over `clients` concurrent
/// connections (client *k* takes documents *k, k+clients, k+2·clients …*,
/// so every document is submitted exactly once). Returns the merged
/// results and throughput; any client's failure fails the run.
pub fn run_load(
    addr: std::net::SocketAddr,
    docs: &[Document],
    clients: usize,
    queries: &[String],
) -> Result<LoadReport, ClientError> {
    run_load_with_budget(addr, docs, clients, queries, None)
}

/// [`run_load`] with a per-document deadline budget in milliseconds sent
/// in every client's `Hello` (`None` = no deadline).
pub fn run_load_with_budget(
    addr: std::net::SocketAddr,
    docs: &[Document],
    clients: usize,
    queries: &[String],
    budget_ms: Option<u64>,
) -> Result<LoadReport, ClientError> {
    let clients = clients.max(1);
    let start = Instant::now();
    let reports: Vec<Result<ClientReport, ClientError>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|k| {
                scope.spawn(move || {
                    let mut client =
                        Client::connect_with_budget(addr, queries, &[], budget_ms)?;
                    for doc in docs.iter().skip(k).step_by(clients) {
                        client.send(doc.id, &doc.text)?;
                    }
                    client.finish()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err(ClientError::Protocol(ProtocolError::Truncated)),
            })
            .collect()
    });
    let wall = start.elapsed();

    let mut results = Vec::with_capacity(docs.len());
    let mut doc_errors = Vec::new();
    let mut corpus = Vec::with_capacity(clients);
    let mut view_table = Vec::new();
    for report in reports {
        let report = report?;
        if view_table.is_empty() {
            view_table = report.view_table;
        }
        results.extend(report.results);
        doc_errors.extend(report.doc_errors);
        corpus.push(report.corpus);
    }
    Ok(LoadReport {
        clients,
        docs: docs.len(),
        bytes: docs.iter().map(|d| d.len()).sum(),
        wall,
        results,
        doc_errors,
        corpus,
        view_table,
    })
}
