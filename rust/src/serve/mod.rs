//! `repro serve` — the network serving tier.
//!
//! The paper's operating point is many analytics queries multiplexed
//! onto one accelerated engine; this module lifts that one layer up the
//! stack: many *network clients* multiplexed onto one [`Engine`]
//! (`crate::coordinator::Engine`). A thread-per-connection TCP server
//! (std only — no async runtime) accepts length-prefixed binary frames,
//! runs each connection's documents through its own bounded-queue
//! [`Session`](crate::coordinator::Session) over the shared engine, and
//! streams per-view [`TupleBatch`](crate::exec::TupleBatch) results
//! back in the same columnar shape they have in memory — spans as i32
//! pairs, mirroring `accel/packing` — so results cross the wire without
//! re-materializing rows.
//!
//! The pieces:
//!
//! - [`protocol`] — frame layout, encode/decode, the columnar batch
//!   wire encoding, and the error taxonomy.
//! - [`server`] — listener, admission control (`Busy` past the cap),
//!   per-connection sessions, writer threads, per-connection
//!   backpressure over [`runtime::queue`](crate::runtime::queue).
//! - [`client`] — built-in protocol client and the K-connection load
//!   generator behind `repro serve --selftest`.
//! - [`admin`] — `GET /metrics` and `GET /healthz` over hand-rolled
//!   HTTP/1.0 on a second port: serving, queue, arena, block-pool,
//!   accelerator, breaker, and quarantine gauges as one JSON snapshot,
//!   plus a liveness verdict (200/503) from the engine watchdog.
//!
//! Per-tenant catalogs ride the supergraph: a client's `Hello` names
//! which registered queries (namespaces) it wants and optionally which
//! views; the server resolves them against the engine's catalog and the
//! connection only ever sees those views.

pub(crate) mod admin;
pub mod client;
pub mod protocol;
pub mod server;

pub use client::{
    run_load, run_load_with_budget, Client, ClientError, ClientReport, DocErrFrame, LoadReport,
    ResultFrame,
};
pub use protocol::{Frame, ProtocolError};
pub use server::{ConnSnapshot, ServeConfig, Server};
