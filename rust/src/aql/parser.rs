//! Recursive-descent parser for the AQL subset.

use std::fmt;

use crate::aog::expr::CmpOp;
use crate::dict::CaseMode;
use crate::text::span::ConsolidatePolicy;

use super::ast::*;
use super::lexer::{Token, TokenKind};

/// Parse error with source position.
#[derive(Debug, Clone)]
pub struct ParseErr {
    /// Byte offset of the offending token.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for ParseErr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "AQL parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseErr {}

struct P<'a> {
    toks: &'a [Token],
    i: usize,
    /// Inside a `score` clause bare identifiers are column references
    /// into the aggregate's output schema (there is no alias to qualify
    /// them with); everywhere else a bare identifier is an error.
    bare_cols: bool,
}

/// Parse a token stream into a [`Program`].
pub fn parse_program(toks: &[Token]) -> Result<Program, ParseErr> {
    let mut p = P {
        toks,
        i: 0,
        bare_cols: false,
    };
    let mut statements = Vec::new();
    while !p.at_end() {
        statements.push(p.statement()?);
    }
    Ok(Program { statements })
}

impl<'a> P<'a> {
    fn at_end(&self) -> bool {
        self.i >= self.toks.len()
    }

    fn pos(&self) -> usize {
        self.toks
            .get(self.i)
            .or_else(|| self.toks.last())
            .map(|t| t.pos)
            .unwrap_or(0)
    }

    fn err(&self, msg: impl Into<String>) -> ParseErr {
        ParseErr {
            pos: self.pos(),
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<&TokenKind> {
        self.toks.get(self.i).map(|t| &t.kind)
    }

    fn bump(&mut self) -> Option<&'a TokenKind> {
        let t = self.toks.get(self.i).map(|t| &t.kind);
        self.i += 1;
        t
    }

    fn eat_kw(&mut self, kw: &str) -> bool {
        if matches!(self.peek(), Some(TokenKind::Kw(k)) if k == kw) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect_kw(&mut self, kw: &str) -> Result<(), ParseErr> {
        if self.eat_kw(kw) {
            Ok(())
        } else {
            Err(self.err(format!("expected '{kw}'")))
        }
    }

    fn eat(&mut self, kind: &TokenKind) -> bool {
        if self.peek() == Some(kind) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, kind: &TokenKind, what: &str) -> Result<(), ParseErr> {
        if self.eat(kind) {
            Ok(())
        } else {
            Err(self.err(format!("expected {what}")))
        }
    }

    fn ident(&mut self) -> Result<String, ParseErr> {
        match self.bump() {
            Some(TokenKind::Ident(s)) => Ok(s.clone()),
            _ => {
                self.i -= 1;
                Err(self.err("expected identifier"))
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseErr> {
        match self.bump() {
            Some(TokenKind::Str(s)) => Ok(s.clone()),
            _ => {
                self.i -= 1;
                Err(self.err("expected string literal"))
            }
        }
    }

    fn statement(&mut self) -> Result<Statement, ParseErr> {
        if self.eat_kw("create") {
            if self.eat_kw("dictionary") {
                return self.create_dictionary();
            }
            if self.eat_kw("view") {
                return self.create_view();
            }
            return Err(self.err("expected 'dictionary' or 'view' after 'create'"));
        }
        if self.eat_kw("output") {
            self.expect_kw("view")?;
            let name = self.ident()?;
            self.expect(&TokenKind::Semi, "';'")?;
            return Ok(Statement::OutputView { name });
        }
        Err(self.err("expected 'create' or 'output'"))
    }

    fn create_dictionary(&mut self) -> Result<Statement, ParseErr> {
        let name = self.ident()?;
        let mut case = CaseMode::Insensitive; // SystemT default folds case
        if self.eat_kw("with") {
            self.expect_kw("case")?;
            if self.eat_kw("exact") {
                case = CaseMode::Exact;
            } else if self.eat_kw("insensitive") {
                case = CaseMode::Insensitive;
            } else {
                return Err(self.err("expected 'exact' or 'insensitive'"));
            }
        }
        if self.eat_kw("from") {
            self.expect_kw("file")?;
            let path = self.string()?;
            self.expect(&TokenKind::Semi, "';'")?;
            return Ok(Statement::CreateDictionaryFromFile { name, case, path });
        }
        self.expect_kw("as")?;
        self.expect(&TokenKind::LParen, "'('")?;
        let mut entries = Vec::new();
        loop {
            entries.push(self.string()?);
            if !self.eat(&TokenKind::Comma) {
                break;
            }
        }
        self.expect(&TokenKind::RParen, "')'")?;
        self.expect(&TokenKind::Semi, "';'")?;
        Ok(Statement::CreateDictionary {
            name,
            case,
            entries,
        })
    }

    fn create_view(&mut self) -> Result<Statement, ParseErr> {
        let name = self.ident()?;
        self.expect_kw("as")?;
        let body = self.view_body()?;
        self.expect(&TokenKind::Semi, "';'")?;
        Ok(Statement::CreateView { name, body })
    }

    fn view_body(&mut self) -> Result<ViewBody, ParseErr> {
        let first = self.select_or_extract()?;
        if matches!(self.peek(), Some(TokenKind::Kw(k)) if k == "union") {
            let mut parts = vec![first];
            while self.eat_kw("union") {
                self.expect_kw("all")?;
                parts.push(self.select_or_extract()?);
            }
            return Ok(ViewBody::Union(parts));
        }
        if self.eat_kw("minus") {
            let rhs = self.view_body()?;
            return Ok(ViewBody::Minus(Box::new(first), Box::new(rhs)));
        }
        Ok(first)
    }

    fn select_or_extract(&mut self) -> Result<ViewBody, ParseErr> {
        // allow parenthesized bodies inside unions
        if self.eat(&TokenKind::LParen) {
            let inner = self.view_body()?;
            self.expect(&TokenKind::RParen, "')'")?;
            return Ok(inner);
        }
        if self.eat_kw("extract") {
            return self.extract_stmt().map(ViewBody::Extract);
        }
        if self.eat_kw("select") {
            return self.select_stmt().map(ViewBody::Select);
        }
        if self.eat_kw("block") {
            return self.block_stmt().map(ViewBody::Block);
        }
        Err(self.err("expected 'select', 'extract' or 'block'"))
    }

    fn extract_stmt(&mut self) -> Result<ExtractStmt, ParseErr> {
        let kind = if self.eat_kw("regex") {
            let pattern = match self.bump() {
                Some(TokenKind::Regex(r)) => r.clone(),
                _ => {
                    self.i -= 1;
                    return Err(self.err("expected /regex/ literal"));
                }
            };
            let mut ci = false;
            if self.eat_kw("with") {
                self.expect_kw("flags")?;
                let flags = self.string()?;
                ci = flags.contains('i') || flags.contains("CASE_INSENSITIVE");
            }
            ExtractKind::Regex {
                pattern,
                case_insensitive: ci,
            }
        } else if self.eat_kw("dictionary") {
            let dict_name = self.string()?;
            ExtractKind::Dictionary { dict_name }
        } else {
            return Err(self.err("expected 'regex' or 'dictionary' after 'extract'"));
        };

        self.expect_kw("on")?;
        let input_alias = self.ident()?;
        self.expect(&TokenKind::Dot, "'.'")?;
        let input_col = self.ident_or_text()?;
        self.expect_kw("as")?;
        let out_name = self.ident()?;
        self.expect_kw("from")?;
        let source = self.source_ref()?;
        let src_alias = self.ident()?;
        if src_alias != input_alias {
            return Err(self.err(format!(
                "extract input alias '{input_alias}' does not match source alias '{src_alias}'"
            )));
        }
        Ok(ExtractStmt {
            kind,
            input_alias,
            input_col,
            out_name,
            source,
        })
    }

    /// `text` lexes as identifier; allow both identifiers and the keyword
    /// spelling for column names.
    fn ident_or_text(&mut self) -> Result<String, ParseErr> {
        match self.bump() {
            Some(TokenKind::Ident(s)) => Ok(s.clone()),
            Some(TokenKind::Kw(k)) => Ok(k.clone()),
            _ => {
                self.i -= 1;
                Err(self.err("expected column name"))
            }
        }
    }

    fn source_ref(&mut self) -> Result<SourceRef, ParseErr> {
        if self.eat_kw("document") {
            return Ok(SourceRef::Document);
        }
        Ok(SourceRef::View(self.ident()?))
    }

    fn select_stmt(&mut self) -> Result<SelectStmt, ParseErr> {
        let mut items = Vec::new();
        loop {
            let expr = self.expr()?;
            let name = if self.eat_kw("as") {
                self.ident()?
            } else {
                // default name: last path component of a column ref
                match &expr {
                    AqlExpr::ColRef { col, .. } => col.clone(),
                    _ => format!("c{}", items.len()),
                }
            };
            items.push(SelectItem { expr, name });
            if !self.eat(&TokenKind::Comma) {
                break;
            }
        }
        self.expect_kw("from")?;
        let mut sources = Vec::new();
        loop {
            let s = self.source_ref()?;
            let alias = self.ident()?;
            sources.push((s, alias));
            if !self.eat(&TokenKind::Comma) {
                break;
            }
        }
        let mut preds = Vec::new();
        if self.eat_kw("where") {
            // parse one boolean expression, then flatten top-level ANDs into
            // a conjunct list (the optimizer pushes conjuncts independently)
            fn flatten(e: AqlExpr, out: &mut Vec<AqlExpr>) {
                match e {
                    AqlExpr::And(a, b) => {
                        flatten(*a, out);
                        flatten(*b, out);
                    }
                    other => out.push(other),
                }
            }
            flatten(self.expr()?, &mut preds);
        }
        let mut consolidate = None;
        if self.eat_kw("consolidate") {
            self.expect_kw("on")?;
            // the target is an *output column* name (post-projection)
            let col = self.ident_or_text()?;
            let mut policy = ConsolidatePolicy::ContainedWithin;
            if self.eat_kw("using") {
                let pname = self.string()?;
                policy = ConsolidatePolicy::parse(&pname)
                    .ok_or_else(|| self.err(format!("unknown consolidation policy '{pname}'")))?;
            }
            consolidate = Some((col, policy));
        }
        let mut group_by = Vec::new();
        if self.eat_kw("group") {
            self.expect_kw("by")?;
            // targets are *output column* names (post-projection), like
            // consolidate and order by
            loop {
                group_by.push(self.ident_or_text()?);
                if !self.eat(&TokenKind::Comma) {
                    break;
                }
            }
        }
        let mut score = None;
        if self.eat_kw("score") {
            self.bare_cols = true;
            let e = self.expr();
            self.bare_cols = false;
            score = Some(e?);
        }
        let mut top_k = None;
        if self.eat_kw("top") {
            match self.bump() {
                // 0 parses; the compiler rejects it with a diagnostic
                Some(TokenKind::Int(n)) if *n >= 0 => top_k = Some(*n as usize),
                _ => {
                    self.i -= 1;
                    return Err(self.err("expected non-negative integer after 'top'"));
                }
            }
        }
        let mut order_by = Vec::new();
        if self.eat_kw("order") {
            self.expect_kw("by")?;
            loop {
                order_by.push(self.ident_or_text()?);
                if !self.eat(&TokenKind::Comma) {
                    break;
                }
            }
        }
        let mut limit = None;
        if self.eat_kw("limit") {
            match self.bump() {
                Some(TokenKind::Int(n)) if *n >= 0 => limit = Some(*n as usize),
                _ => {
                    self.i -= 1;
                    return Err(self.err("expected non-negative integer after 'limit'"));
                }
            }
        }
        Ok(SelectStmt {
            items,
            sources,
            preds,
            consolidate,
            group_by,
            score,
            top_k,
            order_by,
            limit,
        })
    }

    /// `block <alias>.<col> with gap <n> min <m> from <source> <alias>`
    fn block_stmt(&mut self) -> Result<BlockStmt, ParseErr> {
        let alias = self.ident()?;
        self.expect(&TokenKind::Dot, "'.'")?;
        let col = self.ident_or_text()?;
        self.expect_kw("with")?;
        self.expect_kw("gap")?;
        let gap = match self.bump() {
            Some(TokenKind::Int(n)) if *n >= 0 => *n as u32,
            _ => {
                self.i -= 1;
                return Err(self.err("expected gap bytes"));
            }
        };
        self.expect_kw("min")?;
        let min_size = match self.bump() {
            Some(TokenKind::Int(n)) if *n >= 1 => *n as usize,
            _ => {
                self.i -= 1;
                return Err(self.err("expected min block size >= 1"));
            }
        };
        self.expect_kw("from")?;
        let source = self.source_ref()?;
        let src_alias = self.ident()?;
        if src_alias != alias {
            return Err(self.err(format!(
                "block alias '{alias}' does not match source alias '{src_alias}'"
            )));
        }
        Ok(BlockStmt {
            alias,
            col,
            gap,
            min_size,
            source,
        })
    }

    /// Expression grammar: or-expr > and-expr > not > cmp > primary.
    fn expr(&mut self) -> Result<AqlExpr, ParseErr> {
        let mut lhs = self.and_expr()?;
        while self.eat_kw("or") {
            let rhs = self.and_expr()?;
            lhs = AqlExpr::Or(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<AqlExpr, ParseErr> {
        let mut lhs = self.not_expr()?;
        while self.eat_kw("and") {
            let rhs = self.not_expr()?;
            lhs = AqlExpr::And(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn not_expr(&mut self) -> Result<AqlExpr, ParseErr> {
        if self.eat_kw("not") {
            Ok(AqlExpr::Not(Box::new(self.not_expr()?)))
        } else {
            self.cmp_expr()
        }
    }

    fn cmp_expr(&mut self) -> Result<AqlExpr, ParseErr> {
        let lhs = self.primary()?;
        let op = match self.peek() {
            Some(TokenKind::Eq) => Some(CmpOp::Eq),
            Some(TokenKind::Ne) => Some(CmpOp::Ne),
            Some(TokenKind::Lt) => Some(CmpOp::Lt),
            Some(TokenKind::Le) => Some(CmpOp::Le),
            Some(TokenKind::Gt) => Some(CmpOp::Gt),
            Some(TokenKind::Ge) => Some(CmpOp::Ge),
            _ => None,
        };
        if let Some(op) = op {
            self.i += 1;
            let rhs = self.primary()?;
            return Ok(AqlExpr::Cmp {
                lhs: Box::new(lhs),
                op,
                rhs: Box::new(rhs),
            });
        }
        Ok(lhs)
    }

    fn primary(&mut self) -> Result<AqlExpr, ParseErr> {
        match self.peek().cloned() {
            Some(TokenKind::Int(n)) => {
                self.i += 1;
                Ok(AqlExpr::Int(n))
            }
            Some(TokenKind::Str(s)) => {
                self.i += 1;
                Ok(AqlExpr::Str(s))
            }
            Some(TokenKind::Kw(k)) if k == "true" || k == "false" => {
                self.i += 1;
                Ok(AqlExpr::Bool(k == "true"))
            }
            Some(TokenKind::LParen) => {
                self.i += 1;
                let inner = self.expr()?;
                self.expect(&TokenKind::RParen, "')'")?;
                Ok(inner)
            }
            Some(TokenKind::Ident(name)) => {
                self.i += 1;
                if self.eat(&TokenKind::Dot) {
                    let col = self.ident_or_text()?;
                    return Ok(AqlExpr::ColRef { alias: name, col });
                }
                if self.eat(&TokenKind::LParen) {
                    let mut args = Vec::new();
                    if self.peek() != Some(&TokenKind::RParen) {
                        loop {
                            args.push(self.expr()?);
                            if !self.eat(&TokenKind::Comma) {
                                break;
                            }
                        }
                    }
                    self.expect(&TokenKind::RParen, "')'")?;
                    return Ok(AqlExpr::Call { func: name, args });
                }
                if self.bare_cols {
                    return Ok(AqlExpr::ColRef {
                        alias: String::new(),
                        col: name,
                    });
                }
                Err(self.err(format!(
                    "bare identifier '{name}' — expected alias.column or Function(...)"
                )))
            }
            _ => Err(self.err("expected expression")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aql::lexer::lex;

    fn parse(src: &str) -> Program {
        parse_program(&lex(src).unwrap()).unwrap()
    }

    fn parse_err(src: &str) -> ParseErr {
        parse_program(&lex(src).unwrap()).unwrap_err()
    }

    #[test]
    fn dictionary_statement() {
        let p = parse("create dictionary D with case exact as ('a', 'b''c');");
        match &p.statements[0] {
            Statement::CreateDictionary { name, case, entries } => {
                assert_eq!(name, "D");
                assert_eq!(*case, CaseMode::Exact);
                assert_eq!(entries, &vec!["a".to_string(), "b'c".to_string()]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dictionary_default_case_insensitive() {
        let p = parse("create dictionary D as ('x');");
        match &p.statements[0] {
            Statement::CreateDictionary { case, .. } => {
                assert_eq!(*case, CaseMode::Insensitive)
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn extract_regex_view() {
        let p = parse(
            r"create view V as extract regex /[A-Z]\w+/ on d.text as m from Document d;",
        );
        match &p.statements[0] {
            Statement::CreateView { name, body } => {
                assert_eq!(name, "V");
                match body {
                    ViewBody::Extract(e) => {
                        assert_eq!(e.out_name, "m");
                        assert_eq!(e.source, SourceRef::Document);
                        assert!(matches!(&e.kind, ExtractKind::Regex { pattern, .. } if pattern == r"[A-Z]\w+"));
                    }
                    other => panic!("{other:?}"),
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn extract_regex_flags() {
        let p = parse(
            "create view V as extract regex /ibm/ with flags 'i' on d.text as m from Document d;",
        );
        match &p.statements[0] {
            Statement::CreateView {
                body: ViewBody::Extract(e),
                ..
            } => {
                assert!(
                    matches!(&e.kind, ExtractKind::Regex { case_insensitive: true, .. })
                );
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn extract_dictionary_view() {
        let p = parse(
            "create view V as extract dictionary 'Orgs' on d.text as m from Document d;",
        );
        match &p.statements[0] {
            Statement::CreateView {
                body: ViewBody::Extract(e),
                ..
            } => {
                assert!(matches!(&e.kind, ExtractKind::Dictionary { dict_name } if dict_name == "Orgs"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn select_with_everything() {
        let p = parse(
            "create view V as \
             select p.name as person, CombineSpans(p.name, o.m) as ctx \
             from P p, O o \
             where FollowsTok(p.name, o.m, 0, 3) and GetLength(p.name) > 4 \
             consolidate on ctx using 'ContainedWithin' \
             order by ctx \
             limit 10;",
        );
        match &p.statements[0] {
            Statement::CreateView {
                body: ViewBody::Select(s),
                ..
            } => {
                assert_eq!(s.items.len(), 2);
                assert_eq!(s.items[0].name, "person");
                assert_eq!(s.sources.len(), 2);
                assert_eq!(s.preds.len(), 2);
                assert_eq!(
                    s.consolidate,
                    Some(("ctx".to_string(), ConsolidatePolicy::ContainedWithin))
                );
                assert_eq!(s.order_by, vec!["ctx".to_string()]);
                assert_eq!(s.limit, Some(10));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn union_of_extracts() {
        let p = parse(
            "create view V as \
             (extract regex /a+/ on d.text as m from Document d) \
             union all \
             (extract regex /b+/ on d.text as m from Document d);",
        );
        match &p.statements[0] {
            Statement::CreateView {
                body: ViewBody::Union(parts),
                ..
            } => assert_eq!(parts.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn output_statement() {
        let p = parse("output view V;");
        assert_eq!(
            p.statements[0],
            Statement::OutputView { name: "V".into() }
        );
    }

    #[test]
    fn default_item_names() {
        let p = parse("create view V as select p.name, GetLength(p.name) from P p;");
        match &p.statements[0] {
            Statement::CreateView {
                body: ViewBody::Select(s),
                ..
            } => {
                assert_eq!(s.items[0].name, "name");
                assert_eq!(s.items[1].name, "c1");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn errors_have_positions() {
        let e = parse_err("create view V as select from P p;");
        assert!(e.pos > 0);
        assert!(parse_program(&lex("create banana;").unwrap()).is_err());
        assert!(parse_program(&lex("output view;").unwrap()).is_err());
        assert!(parse_program(&lex(
            "create view V as extract regex /a/ on d.text as m from Document x;"
        )
        .unwrap())
        .is_err());
    }

    #[test]
    fn group_by_score_top_clauses() {
        let p = parse(
            "create view V as \
             select GetText(e.m) as term, Count() as n, CountDocs() as docs \
             from E e \
             group by term \
             score n \
             top 10;",
        );
        match &p.statements[0] {
            Statement::CreateView {
                body: ViewBody::Select(s),
                ..
            } => {
                assert_eq!(s.group_by, vec!["term".to_string()]);
                assert_eq!(
                    s.score,
                    Some(AqlExpr::ColRef {
                        alias: String::new(),
                        col: "n".into()
                    })
                );
                assert_eq!(s.top_k, Some(10));
                assert!(matches!(
                    &s.items[1].expr,
                    AqlExpr::Call { func, args } if func == "Count" && args.is_empty()
                ));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn group_by_without_top() {
        let p = parse(
            "create view V as select h.d as dict, Count() as n from H h group by dict;",
        );
        match &p.statements[0] {
            Statement::CreateView {
                body: ViewBody::Select(s),
                ..
            } => {
                assert_eq!(s.group_by, vec!["dict".to_string()]);
                assert_eq!(s.score, None);
                assert_eq!(s.top_k, None);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn top_zero_parses_bare_identifiers_do_not_leak() {
        // `top 0` is a parse-level success (the compiler rejects it)
        let p = parse(
            "create view V as select GetText(e.m) as t, Count() as n from E e \
             group by t score n top 0;",
        );
        match &p.statements[0] {
            Statement::CreateView {
                body: ViewBody::Select(s),
                ..
            } => assert_eq!(s.top_k, Some(0)),
            other => panic!("{other:?}"),
        }
        // outside `score`, bare identifiers still error
        assert!(parse_program(&lex("create view V as select n from P p;").unwrap()).is_err());
        // and 'top' wants an integer
        assert!(parse_program(
            &lex("create view V as select Count() as n from P p group by n top x;").unwrap()
        )
        .is_err());
    }

    #[test]
    fn boolean_expression_precedence() {
        let p = parse(
            "create view V as select p.m from P p \
             where GetLength(p.m) > 1 or GetLength(p.m) < 5 and not SpanEquals(p.m, p.m);",
        );
        match &p.statements[0] {
            Statement::CreateView {
                body: ViewBody::Select(s),
                ..
            } => {
                // or binds loosest: Or(cmp, And(cmp, Not(..)))
                assert!(matches!(&s.preds[0], AqlExpr::Or(_, rhs)
                    if matches!(**rhs, AqlExpr::And(_, _))));
            }
            other => panic!("{other:?}"),
        }
    }
}
