//! Semantic analysis and lowering: AQL AST → operator graph.
//!
//! The lowering is deliberately naive — multi-source selects become
//! left-deep *cross joins* with one big `Select` on top. The optimizer
//! ([`crate::optimizer`]) then pushes predicates down and converts
//! cross-join+filter into predicated joins, mirroring SystemT's split
//! between rule translation and cost-based optimization.

use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;

use crate::aog::expr::{CmpOp, Expr, Func};
use crate::aog::{AggCol, FieldType, Graph, GraphError, NodeId, OpKind, Schema};
use crate::dict::{AhoCorasick, Dictionary};

use super::ast::*;

/// Compilation error.
#[derive(Debug)]
pub enum CompileError {
    /// Lexing failed.
    Lex(String),
    /// Parsing failed.
    Parse(String),
    /// A `from` clause referenced an undefined view.
    UnknownView(String),
    /// An extraction referenced an undefined dictionary.
    UnknownDictionary(String),
    /// A predicate called an unknown function.
    UnknownFunction(String),
    /// An expression referenced an unbound alias.
    UnknownAlias(String),
    /// An expression referenced a column its alias does not have.
    UnknownColumn {
        /// The alias as written.
        alias: String,
        /// The missing column.
        col: String,
    },
    /// A view or dictionary name was defined twice.
    DuplicateName(String),
    /// The regex literal failed to compile.
    Regex(String),
    /// Graph construction rejected the lowered operators. Carries the
    /// structured [`GraphError`] so callers see the node id and operator
    /// kind, not a flattened message.
    Graph(GraphError),
    /// `group by` named an output column the select list does not produce.
    GroupByUnknownColumn(String),
    /// A group key has a non-groupable type (span or float). Group keys
    /// must be Text, Integer or Boolean — wrap spans in `GetText(...)`.
    GroupByBadType {
        /// The offending output column name.
        col: String,
        /// Its inferred type.
        ty: String,
    },
    /// `top 0` — the bounded top-k needs k >= 1.
    TopKZero,
    /// The `score` expression does not evaluate to a number.
    ScoreNotNumeric(String),
    /// An aggregate was used where no aggregate may appear: `Count()` /
    /// `CountDocs()` outside a `group by` select list, `score`/`top`
    /// without `group by`, or a corpus-level (aggregated) view feeding a
    /// per-document context.
    AggregateContext(String),
    /// Syntactically valid AQL outside the supported subset.
    Unsupported(String),
}

impl fmt::Display for CompileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CompileError::Lex(m) => write!(f, "{m}"),
            CompileError::Parse(m) => write!(f, "{m}"),
            CompileError::UnknownView(v) => write!(f, "unknown view '{v}'"),
            CompileError::UnknownDictionary(d) => write!(f, "unknown dictionary '{d}'"),
            CompileError::UnknownFunction(x) => write!(f, "unknown function '{x}'"),
            CompileError::UnknownAlias(a) => write!(f, "unknown alias '{a}'"),
            CompileError::UnknownColumn { alias, col } => {
                write!(f, "unknown column '{alias}.{col}'")
            }
            CompileError::DuplicateName(n) => write!(f, "duplicate definition of '{n}'"),
            CompileError::Regex(m) => write!(f, "{m}"),
            CompileError::Graph(e) => write!(f, "{e}"),
            CompileError::GroupByUnknownColumn(c) => {
                write!(f, "group by references unknown output column '{c}'")
            }
            CompileError::GroupByBadType { col, ty } => write!(
                f,
                "group by column '{col}' has type {ty}; keys must be Text, Integer or \
                 Boolean (wrap spans in GetText)"
            ),
            CompileError::TopKZero => write!(f, "top k must be at least 1"),
            CompileError::ScoreNotNumeric(t) => {
                write!(f, "score expression has type {t}; want Integer or Float")
            }
            CompileError::AggregateContext(m) => write!(f, "aggregate misuse: {m}"),
            CompileError::Unsupported(m) => write!(f, "unsupported: {m}"),
        }
    }
}

impl std::error::Error for CompileError {}

/// Name-resolution state across statements.
pub struct Catalog {
    dicts: HashMap<String, (Arc<Dictionary>, Arc<AhoCorasick>)>,
    views: HashMap<String, NodeId>,
    doc_scan: Option<NodeId>,
}

impl Catalog {
    /// Empty catalog.
    pub fn new() -> Catalog {
        Catalog {
            dicts: HashMap::new(),
            views: HashMap::new(),
            doc_scan: None,
        }
    }

    /// The shared DocScan node (created on first use).
    fn doc_scan(&mut self, g: &mut Graph) -> NodeId {
        if let Some(d) = self.doc_scan {
            return d;
        }
        let d = g
            .add(OpKind::DocScan, vec![])
            .expect("DocScan cannot fail");
        self.doc_scan = Some(d);
        d
    }
}

impl Default for Catalog {
    fn default() -> Self {
        Self::new()
    }
}

/// Compile a parsed program into an operator graph.
pub fn compile_program(program: &Program) -> Result<Graph, CompileError> {
    compile_program_ns(program, None)
}

/// Compile a parsed program into an operator graph under an optional
/// **namespace** — the catalog-compilation entry point.
///
/// With `namespace = Some("t1")`, every view root and every registered
/// output is qualified as `t1.<View>`, while name resolution *inside* the
/// program stays unqualified (each registered program keeps its own
/// view/dictionary scope — two catalog entries may both define `Person`
/// without colliding). The [`CatalogBuilder`](crate::coordinator::CatalogBuilder)
/// compiles each registered program this way and merges the graphs with
/// [`Graph::merge_from`](crate::aog::Graph::merge_from).
pub fn compile_program_ns(
    program: &Program,
    namespace: Option<&str>,
) -> Result<Graph, CompileError> {
    let qualify = |name: &str| -> String {
        match namespace {
            Some(ns) => format!("{ns}.{name}"),
            None => name.to_string(),
        }
    };
    let mut g = Graph::new();
    let mut cat = Catalog::new();
    for stmt in &program.statements {
        match stmt {
            Statement::CreateDictionary {
                name,
                case,
                entries,
            } => {
                if cat.dicts.contains_key(name) {
                    return Err(CompileError::DuplicateName(name.clone()));
                }
                let d = Arc::new(Dictionary::new(name.clone(), entries.clone(), *case));
                let m = Arc::new(d.compile());
                cat.dicts.insert(name.clone(), (d, m));
            }
            Statement::CreateDictionaryFromFile { name, case, path } => {
                if cat.dicts.contains_key(name) {
                    return Err(CompileError::DuplicateName(name.clone()));
                }
                let content = std::fs::read_to_string(path).map_err(|e| {
                    CompileError::Unsupported(format!("dictionary file {path}: {e}"))
                })?;
                let entries: Vec<String> = content
                    .lines()
                    .map(|l| l.trim().to_string())
                    .filter(|l| !l.is_empty() && !l.starts_with('#'))
                    .collect();
                let d = Arc::new(Dictionary::new(name.clone(), entries, *case));
                let m = Arc::new(d.compile());
                cat.dicts.insert(name.clone(), (d, m));
            }
            Statement::CreateView { name, body } => {
                if cat.views.contains_key(name) {
                    return Err(CompileError::DuplicateName(name.clone()));
                }
                let node = compile_body(body, &mut g, &mut cat)?;
                g.name_view(node, qualify(name));
                cat.views.insert(name.clone(), node);
            }
            Statement::OutputView { name } => {
                let node = *cat
                    .views
                    .get(name)
                    .ok_or_else(|| CompileError::UnknownView(name.clone()))?;
                g.add_output(qualify(name), node)
                    .map_err(CompileError::Graph)?;
            }
        }
    }
    Ok(g)
}

fn compile_body(
    body: &ViewBody,
    g: &mut Graph,
    cat: &mut Catalog,
) -> Result<NodeId, CompileError> {
    match body {
        ViewBody::Extract(e) => compile_extract(e, g, cat),
        ViewBody::Select(s) => compile_select(s, g, cat),
        ViewBody::Union(parts) => {
            let nodes = parts
                .iter()
                .map(|p| compile_body(p, g, cat))
                .collect::<Result<Vec<_>, _>>()?;
            for &n in &nodes {
                if is_corpus_level(g, n) {
                    return Err(CompileError::AggregateContext(
                        "a corpus-level (group by) select cannot appear under union".into(),
                    ));
                }
            }
            g.add(OpKind::Union, nodes)
                .map_err(CompileError::Graph)
        }
        ViewBody::Minus(lhs, rhs) => {
            let l = compile_body(lhs, g, cat)?;
            let r = compile_body(rhs, g, cat)?;
            if is_corpus_level(g, l) || is_corpus_level(g, r) {
                return Err(CompileError::AggregateContext(
                    "a corpus-level (group by) select cannot appear under minus".into(),
                ));
            }
            g.add(OpKind::Difference, vec![l, r])
                .map_err(CompileError::Graph)
        }
        ViewBody::Block(b) => {
            let node = match &b.source {
                SourceRef::Document => {
                    return Err(CompileError::Unsupported(
                        "block over Document — block a view column".into(),
                    ))
                }
                SourceRef::View(v) => {
                    let n = *cat
                        .views
                        .get(v)
                        .ok_or_else(|| CompileError::UnknownView(v.clone()))?;
                    if is_corpus_level(g, n) {
                        return Err(CompileError::AggregateContext(format!(
                            "view '{v}' is corpus-level and cannot feed a per-document block"
                        )));
                    }
                    n
                }
            };
            let schema = &g.nodes[node].schema;
            let col = schema.index_of(&b.col).ok_or_else(|| {
                CompileError::UnknownColumn {
                    alias: b.alias.clone(),
                    col: b.col.clone(),
                }
            })?;
            g.add(
                OpKind::Block {
                    col,
                    max_gap: b.gap,
                    min_size: b.min_size,
                },
                vec![node],
            )
            .map_err(CompileError::Graph)
        }
    }
}

fn compile_extract(
    e: &ExtractStmt,
    g: &mut Graph,
    cat: &mut Catalog,
) -> Result<NodeId, CompileError> {
    match &e.source {
        SourceRef::Document => {}
        SourceRef::View(v) => {
            return Err(CompileError::Unsupported(format!(
                "extraction over view '{v}' — extraction operators read Document.text \
                 (as in the paper's queries)"
            )))
        }
    }
    if e.input_col != "text" {
        return Err(CompileError::UnknownColumn {
            alias: e.input_alias.clone(),
            col: e.input_col.clone(),
        });
    }
    let doc = cat.doc_scan(g);
    let kind = match &e.kind {
        ExtractKind::Regex {
            pattern,
            case_insensitive,
        } => {
            let re = crate::regex::compile(pattern, *case_insensitive)
                .map_err(|err| CompileError::Regex(err.to_string()))?;
            OpKind::RegexExtract {
                regex: Arc::new(re),
                out: e.out_name.clone(),
            }
        }
        ExtractKind::Dictionary { dict_name } => {
            let (d, m) = cat
                .dicts
                .get(dict_name)
                .ok_or_else(|| CompileError::UnknownDictionary(dict_name.clone()))?;
            OpKind::DictExtract {
                dict: d.clone(),
                matcher: m.clone(),
                out: e.out_name.clone(),
            }
        }
    };
    g.add(kind, vec![doc]).map_err(CompileError::Graph)
}

/// Alias resolution table: alias → (column offset, schema).
struct Scope {
    entries: Vec<(String, usize, Schema)>,
}

impl Scope {
    fn resolve(&self, alias: &str, col: &str) -> Result<usize, CompileError> {
        for (a, off, schema) in &self.entries {
            if a == alias {
                return schema
                    .index_of(col)
                    .map(|i| off + i)
                    .ok_or_else(|| CompileError::UnknownColumn {
                        alias: alias.to_string(),
                        col: col.to_string(),
                    });
            }
        }
        Err(CompileError::UnknownAlias(alias.to_string()))
    }
}

fn compile_select(
    s: &SelectStmt,
    g: &mut Graph,
    cat: &mut Catalog,
) -> Result<NodeId, CompileError> {
    if s.sources.is_empty() {
        return Err(CompileError::Unsupported("select with no sources".into()));
    }
    // Resolve sources to nodes.
    let mut scope = Scope { entries: Vec::new() };
    let mut nodes = Vec::new();
    let mut offset = 0usize;
    for (src, alias) in &s.sources {
        let node = match src {
            SourceRef::Document => cat.doc_scan(g),
            SourceRef::View(v) => {
                let n = *cat
                    .views
                    .get(v)
                    .ok_or_else(|| CompileError::UnknownView(v.clone()))?;
                if is_corpus_level(g, n) {
                    return Err(CompileError::AggregateContext(format!(
                        "view '{v}' is corpus-level and cannot feed a per-document select"
                    )));
                }
                n
            }
        };
        let schema = g.nodes[node].schema.clone();
        if scope.entries.iter().any(|(a, _, _)| a == alias) {
            return Err(CompileError::DuplicateName(alias.clone()));
        }
        scope.entries.push((alias.clone(), offset, schema.clone()));
        offset += schema.arity();
        nodes.push(node);
    }

    // Left-deep cross-join chain (optimizer rewrites into predicated joins).
    let mut cur = nodes[0];
    for &n in &nodes[1..] {
        cur = g
            .add(
                OpKind::Join {
                    pred: Expr::LitBool(true),
                },
                vec![cur, n],
            )
            .map_err(CompileError::Graph)?;
    }

    // Conjoin predicates into one Select.
    if !s.preds.is_empty() {
        let mut pred: Option<Expr> = None;
        for p in &s.preds {
            let e = resolve_expr(p, &scope)?;
            pred = Some(match pred {
                None => e,
                Some(acc) => Expr::And(Box::new(acc), Box::new(e)),
            });
        }
        cur = g
            .add(
                OpKind::Select {
                    pred: pred.unwrap(),
                },
                vec![cur],
            )
            .map_err(CompileError::Graph)?;
    }

    if !s.group_by.is_empty() || s.score.is_some() || s.top_k.is_some() {
        // Corpus-level aggregation: keys-only Project -> GroupAgg [-> TopK].
        cur = compile_aggregate(s, g, cur, &scope)?;
    } else {
        // Projection.
        let mut cols = Vec::with_capacity(s.items.len());
        for item in &s.items {
            cols.push((item.name.clone(), resolve_expr(&item.expr, &scope)?));
        }
        cur = g
            .add(OpKind::Project { cols }, vec![cur])
            .map_err(CompileError::Graph)?;

        // Consolidation over an output column.
        if let Some((col_name, policy)) = &s.consolidate {
            let schema = &g.nodes[cur].schema;
            let col = schema.index_of(col_name).ok_or_else(|| {
                CompileError::UnknownColumn {
                    alias: "<output>".into(),
                    col: col_name.clone(),
                }
            })?;
            cur = g
                .add(
                    OpKind::Consolidate {
                        col,
                        policy: *policy,
                    },
                    vec![cur],
                )
                .map_err(CompileError::Graph)?;
        }
    }

    // Order by / limit.
    if !s.order_by.is_empty() {
        let schema = &g.nodes[cur].schema;
        let keys = s
            .order_by
            .iter()
            .map(|n| {
                schema.index_of(n).ok_or_else(|| CompileError::UnknownColumn {
                    alias: "<output>".into(),
                    col: n.clone(),
                })
            })
            .collect::<Result<Vec<_>, _>>()?;
        cur = g
            .add(OpKind::Sort { keys }, vec![cur])
            .map_err(CompileError::Graph)?;
    }
    if let Some(n) = s.limit {
        cur = g
            .add(OpKind::Limit { n }, vec![cur])
            .map_err(CompileError::Graph)?;
    }
    Ok(cur)
}

/// Lower the `group by` / `score` / `top` tail of a select into a
/// keys-only [`OpKind::Project`], an [`OpKind::GroupAgg`], and (with
/// `top k`) an [`OpKind::TopK`]. `input` is the node after joins and
/// `where` filtering; `scope` resolves select-list expressions against it.
fn compile_aggregate(
    s: &SelectStmt,
    g: &mut Graph,
    input: NodeId,
    scope: &Scope,
) -> Result<NodeId, CompileError> {
    if s.group_by.is_empty() {
        return Err(CompileError::AggregateContext(
            "'score' and 'top' require a 'group by' clause".into(),
        ));
    }
    if s.consolidate.is_some() {
        return Err(CompileError::Unsupported(
            "consolidate combined with group by (consolidate spans in an upstream view)".into(),
        ));
    }
    // Classify the select list: aggregate calls vs group-key expressions.
    let as_agg = |e: &AqlExpr| -> Option<AggCol> {
        match e {
            AqlExpr::Call { func, args } if args.is_empty() && func == "Count" => {
                Some(AggCol::Count)
            }
            AqlExpr::Call { func, args } if args.is_empty() && func == "CountDocs" => {
                Some(AggCol::CountDocs)
            }
            _ => None,
        }
    };
    let mut key_cols: Vec<(String, Expr)> = Vec::new();
    let mut cols: Vec<(String, AggCol)> = Vec::with_capacity(s.items.len());
    for item in &s.items {
        if let Some(a) = as_agg(&item.expr) {
            cols.push((item.name.clone(), a));
        } else {
            if !s.group_by.contains(&item.name) {
                return Err(CompileError::AggregateContext(format!(
                    "select column '{}' is neither an aggregate nor listed in group by",
                    item.name
                )));
            }
            let e = resolve_expr(&item.expr, scope)?;
            cols.push((item.name.clone(), AggCol::Key(key_cols.len())));
            key_cols.push((item.name.clone(), e));
        }
    }
    for gcol in &s.group_by {
        if !s.items.iter().any(|i| &i.name == gcol) {
            return Err(CompileError::GroupByUnknownColumn(gcol.clone()));
        }
    }
    let first_agg = cols
        .iter()
        .position(|(_, c)| !matches!(c, AggCol::Key(_)))
        .ok_or_else(|| {
            CompileError::AggregateContext(
                "group by requires at least one aggregate (Count() or CountDocs()) in the \
                 select list"
                    .into(),
            )
        })?;
    // Key types must be groupable — checked here, before graph
    // construction, so the diagnostic names the AQL column.
    let in_schema = g.nodes[input].schema.clone();
    for (name, e) in &key_cols {
        let ty = e.infer_type(&in_schema).map_err(|err| {
            CompileError::Graph(GraphError::Type {
                node: g.nodes.len(),
                op: "GroupAgg",
                err,
            })
        })?;
        if !matches!(ty, FieldType::Str | FieldType::Int | FieldType::Bool) {
            return Err(CompileError::GroupByBadType {
                col: name.clone(),
                ty: ty.to_string(),
            });
        }
    }
    let proj = g
        .add(OpKind::Project { cols: key_cols }, vec![input])
        .map_err(CompileError::Graph)?;
    let mut cur = g
        .add(OpKind::GroupAgg { cols }, vec![proj])
        .map_err(CompileError::Graph)?;

    if s.top_k.is_none() && s.score.is_some() {
        return Err(CompileError::AggregateContext(
            "'score' without 'top k' has no effect — add 'top <k>' or drop the score".into(),
        ));
    }
    if let Some(k) = s.top_k {
        if k == 0 {
            return Err(CompileError::TopKZero);
        }
        let agg_schema = g.nodes[cur].schema.clone();
        let score = match &s.score {
            Some(e) => {
                // bare identifiers in the score clause resolve against the
                // aggregate's output schema (parser emits alias "")
                let sscope = Scope {
                    entries: vec![(String::new(), 0, agg_schema.clone())],
                };
                resolve_expr(e, &sscope)?
            }
            None => Expr::Col(first_agg),
        };
        let ty = score.infer_type(&agg_schema).map_err(|err| {
            CompileError::Graph(GraphError::Type {
                node: g.nodes.len(),
                op: "TopK",
                err,
            })
        })?;
        if !matches!(ty, FieldType::Int | FieldType::Float) {
            return Err(CompileError::ScoreNotNumeric(ty.to_string()));
        }
        cur = g
            .add(OpKind::TopK { k, score }, vec![cur])
            .map_err(CompileError::Graph)?;
    }
    Ok(cur)
}

/// True when `node`'s subtree contains a corpus-level operator
/// ([`OpKind::GroupAgg`] / [`OpKind::TopK`]) — such a view only exists
/// after the whole corpus is merged at `Session::finish()`, so it cannot
/// feed a per-document pipeline.
fn is_corpus_level(g: &Graph, node: NodeId) -> bool {
    let mut seen = vec![false; node + 1];
    let mut stack = vec![node];
    while let Some(n) = stack.pop() {
        if seen[n] {
            continue;
        }
        seen[n] = true;
        if matches!(
            g.nodes[n].kind,
            OpKind::GroupAgg { .. } | OpKind::TopK { .. }
        ) {
            return true;
        }
        stack.extend(g.nodes[n].inputs.iter().copied());
    }
    false
}

fn resolve_expr(e: &AqlExpr, scope: &Scope) -> Result<Expr, CompileError> {
    Ok(match e {
        AqlExpr::ColRef { alias, col } => Expr::Col(scope.resolve(alias, col)?),
        AqlExpr::Int(n) => Expr::LitInt(*n),
        AqlExpr::Str(s) => Expr::LitStr(s.as_str().into()),
        AqlExpr::Bool(b) => Expr::LitBool(*b),
        AqlExpr::Call { func, args } => {
            if func == "Count" || func == "CountDocs" {
                // aggregates are classified out of the select list by
                // compile_aggregate before expressions are resolved; one
                // reaching here sits in a per-document context
                return Err(CompileError::AggregateContext(format!(
                    "aggregate {func}() is only valid as a top-level select item with \
                     'group by'"
                )));
            }
            let f = Func::parse(func)
                .ok_or_else(|| CompileError::UnknownFunction(func.clone()))?;
            let args = args
                .iter()
                .map(|a| resolve_expr(a, scope))
                .collect::<Result<Vec<_>, _>>()?;
            Expr::Call(f, args)
        }
        AqlExpr::Cmp { lhs, op, rhs } => Expr::Cmp(
            Box::new(resolve_expr(lhs, scope)?),
            *op,
            Box::new(resolve_expr(rhs, scope)?),
        ),
        AqlExpr::And(a, b) => Expr::And(
            Box::new(resolve_expr(a, scope)?),
            Box::new(resolve_expr(b, scope)?),
        ),
        AqlExpr::Or(a, b) => Expr::Or(
            Box::new(resolve_expr(a, scope)?),
            Box::new(resolve_expr(b, scope)?),
        ),
        AqlExpr::Not(a) => Expr::Not(Box::new(resolve_expr(a, scope)?)),
    })
}

// keep CmpOp referenced (used via parser AST)
#[allow(unused)]
fn _cmp_witness(op: CmpOp) -> CmpOp {
    op
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aql::compile;

    const BASIC: &str = r#"
        create dictionary Orgs as ('IBM', 'IBM Research');
        create view Org as
          extract dictionary 'Orgs' on d.text as match from Document d;
        create view Person as
          extract regex /[A-Z][a-z]+/ on d.text as name from Document d;
        create view PersonOrg as
          select p.name as person, o.match as org,
                 CombineSpans(p.name, o.match) as ctx
          from Person p, Org o
          where FollowsTok(p.name, o.match, 0, 5)
          consolidate on ctx using 'ContainedWithin';
        output view PersonOrg;
    "#;

    #[test]
    fn compiles_basic_program() {
        let g = compile(BASIC).unwrap();
        assert_eq!(g.outputs.len(), 1);
        let counts = g.op_counts();
        assert_eq!(counts["Dictionary"], 1);
        assert_eq!(counts["RegularExpression"], 1);
        assert_eq!(counts["Join"], 1);
        assert_eq!(counts["Select"], 1);
        assert_eq!(counts["Project"], 1);
        assert_eq!(counts["Consolidate"], 1);
        // output schema: person, org, ctx — all spans
        let (_, out) = &g.outputs[0];
        assert_eq!(g.nodes[*out].schema.arity(), 3);
    }

    #[test]
    fn doc_scan_is_shared() {
        let g = compile(BASIC).unwrap();
        assert_eq!(g.op_counts()["DocScan"], 1);
    }

    #[test]
    fn union_compiles() {
        let g = compile(
            "create view V as \
             (extract regex /a+/ on d.text as m from Document d) \
             union all \
             (extract regex /b+/ on d.text as m from Document d); \
             output view V;",
        )
        .unwrap();
        assert_eq!(g.op_counts()["Union"], 1);
    }

    #[test]
    fn error_unknown_view() {
        let err = compile("output view Nope;").unwrap_err();
        assert!(matches!(err, CompileError::UnknownView(_)), "{err}");
    }

    #[test]
    fn error_unknown_dictionary() {
        let err = compile(
            "create view V as extract dictionary 'X' on d.text as m from Document d;",
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::UnknownDictionary(_)), "{err}");
    }

    #[test]
    fn error_unknown_function() {
        let err = compile(
            "create view A as extract regex /a/ on d.text as m from Document d; \
             create view V as select Zap(a.m) as z from A a;",
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::UnknownFunction(_)), "{err}");
    }

    #[test]
    fn error_unknown_column_and_alias() {
        let err = compile(
            "create view A as extract regex /a/ on d.text as m from Document d; \
             create view V as select a.zzz from A a;",
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::UnknownColumn { .. }), "{err}");

        let err = compile(
            "create view A as extract regex /a/ on d.text as m from Document d; \
             create view V as select q.m from A a;",
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::UnknownAlias(_)), "{err}");
    }

    #[test]
    fn error_duplicate_view() {
        let err = compile(
            "create view A as extract regex /a/ on d.text as m from Document d; \
             create view A as extract regex /b/ on d.text as m from Document d;",
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::DuplicateName(_)), "{err}");
    }

    #[test]
    fn error_bad_regex() {
        let err = compile(
            "create view A as extract regex /a{5,2}/ on d.text as m from Document d;",
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::Regex(_)), "{err}");
    }

    #[test]
    fn error_extract_over_view() {
        let err = compile(
            "create view A as extract regex /a/ on d.text as m from Document d; \
             create view B as extract regex /b/ on a.m as m from A a;",
        )
        .unwrap_err();
        assert!(matches!(err, CompileError::Unsupported(_)), "{err}");
    }

    #[test]
    fn select_from_document_direct() {
        let g = compile(
            "create view V as select d.text as t from Document d; output view V;",
        )
        .unwrap();
        let (_, out) = &g.outputs[0];
        assert_eq!(g.nodes[*out].schema.fields[0].name, "t");
    }

    #[test]
    fn three_way_join_builds_left_deep() {
        let g = compile(
            "create view A as extract regex /a/ on d.text as m from Document d; \
             create view B as extract regex /b/ on d.text as m from Document d; \
             create view C as extract regex /c/ on d.text as m from Document d; \
             create view V as select a.m as am, b.m as bm, c.m as cm \
             from A a, B b, C c \
             where Follows(a.m, b.m, 0, 9) and Follows(b.m, c.m, 0, 9); \
             output view V;",
        )
        .unwrap();
        assert_eq!(g.op_counts()["Join"], 2);
    }

    #[test]
    fn namespaced_compile_qualifies_views_and_outputs() {
        let program = crate::aql::parse(BASIC).unwrap();
        let g = compile_program_ns(&program, Some("q7")).unwrap();
        assert_eq!(g.outputs.len(), 1);
        assert_eq!(g.outputs[0].0, "q7.PersonOrg");
        // view roots are qualified too (for dumps and profile attribution)
        assert!(g
            .nodes
            .iter()
            .any(|n| n.view.as_deref() == Some("q7.Person")));
        // ...but in-program resolution stayed unqualified: the same source
        // compiles under any namespace
        assert!(compile_program_ns(&program, Some("other")).is_ok());
    }

    const AGG_PREFIX: &str = "create view E as \
         extract regex /[A-Z][a-z]+/ on d.text as m from Document d; ";

    #[test]
    fn group_by_top_k_lowers() {
        let g = compile(&format!(
            "{AGG_PREFIX}\
             create view Top as \
             select GetText(e.m) as term, Count() as n, CountDocs() as docs \
             from E e group by term score n top 10; \
             output view Top;"
        ))
        .unwrap();
        let counts = g.op_counts();
        assert_eq!(counts["GroupAgg"], 1);
        assert_eq!(counts["TopK"], 1);
        let (_, out) = &g.outputs[0];
        // term, n, docs, score
        assert_eq!(g.nodes[*out].schema.arity(), 4);
        assert_eq!(g.nodes[*out].schema.fields[3].name, "score");
    }

    #[test]
    fn group_by_without_top_lowers_to_group_agg_only() {
        let g = compile(&format!(
            "{AGG_PREFIX}\
             create view DF as select GetText(e.m) as t, CountDocs() as docs \
             from E e group by t; \
             output view DF;"
        ))
        .unwrap();
        assert_eq!(g.op_counts()["GroupAgg"], 1);
        assert!(!g.op_counts().contains_key("TopK"));
    }

    #[test]
    fn top_without_score_defaults_to_first_aggregate() {
        let g = compile(&format!(
            "{AGG_PREFIX}\
             create view T as select GetText(e.m) as t, Count() as n \
             from E e group by t top 3; \
             output view T;"
        ))
        .unwrap();
        let (_, out) = &g.outputs[0];
        assert!(matches!(
            &g.nodes[*out].kind,
            OpKind::TopK { k: 3, score: Expr::Col(1) }
        ));
    }

    #[test]
    fn error_group_by_unknown_column() {
        let err = compile(&format!(
            "{AGG_PREFIX}\
             create view V as select GetText(e.m) as t, Count() as n \
             from E e group by zzz;"
        ))
        .unwrap_err();
        assert!(matches!(err, CompileError::GroupByUnknownColumn(_)), "{err}");
    }

    #[test]
    fn error_group_by_span_column() {
        let err = compile(&format!(
            "{AGG_PREFIX}\
             create view V as select e.m as t, Count() as n from E e group by t;"
        ))
        .unwrap_err();
        assert!(matches!(err, CompileError::GroupByBadType { .. }), "{err}");
    }

    #[test]
    fn error_top_k_zero() {
        let err = compile(&format!(
            "{AGG_PREFIX}\
             create view V as select GetText(e.m) as t, Count() as n \
             from E e group by t top 0;"
        ))
        .unwrap_err();
        assert!(matches!(err, CompileError::TopKZero), "{err}");
    }

    #[test]
    fn error_score_not_numeric() {
        let err = compile(&format!(
            "{AGG_PREFIX}\
             create view V as select GetText(e.m) as t, Count() as n \
             from E e group by t score t top 5;"
        ))
        .unwrap_err();
        assert!(matches!(err, CompileError::ScoreNotNumeric(_)), "{err}");
    }

    #[test]
    fn error_aggregate_in_per_doc_context() {
        // Count() without group by
        let err = compile(&format!(
            "{AGG_PREFIX}create view V as select Count() as n from E e;"
        ))
        .unwrap_err();
        assert!(matches!(err, CompileError::AggregateContext(_)), "{err}");

        // aggregate view feeding a per-document select
        let err = compile(&format!(
            "{AGG_PREFIX}\
             create view DF as select GetText(e.m) as t, Count() as n \
             from E e group by t; \
             create view V as select a.t as t from DF a;"
        ))
        .unwrap_err();
        assert!(matches!(err, CompileError::AggregateContext(_)), "{err}");

        // score/top without group by
        let err = compile(&format!(
            "{AGG_PREFIX}create view V as select e.m as m from E e top 5;"
        ))
        .unwrap_err();
        assert!(matches!(err, CompileError::AggregateContext(_)), "{err}");
    }

    #[test]
    fn order_by_and_limit_lower() {
        let g = compile(
            "create view A as extract regex /a/ on d.text as m from Document d; \
             create view V as select a.m as m from A a order by m limit 5; \
             output view V;",
        )
        .unwrap();
        assert_eq!(g.op_counts()["Sort"], 1);
        assert_eq!(g.op_counts()["Limit"], 1);
    }
}
