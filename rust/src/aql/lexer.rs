//! AQL lexer.
//!
//! Keywords are case-insensitive (SQL heritage); identifiers are
//! case-sensitive. Regex literals are `/.../` with `\/` escaping; string
//! literals are `'...'` with `''` escaping. `--` starts a line comment.

use std::fmt;

/// Token kinds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokenKind {
    /// Keyword (stored lowercase).
    Kw(String),
    /// Identifier.
    Ident(String),
    /// 'string literal' (unescaped).
    Str(String),
    /// /regex literal/ (unescaped).
    Regex(String),
    /// Integer literal.
    Int(i64),
    /// `(`.
    LParen,
    /// `)`.
    RParen,
    /// `,`.
    Comma,
    /// `;`.
    Semi,
    /// `.`.
    Dot,
    /// `=`.
    Eq,
    /// `!=` / `<>`.
    Ne,
    /// `<`.
    Lt,
    /// `<=`.
    Le,
    /// `>`.
    Gt,
    /// `>=`.
    Ge,
}

/// A token with its source offset (for diagnostics).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token {
    /// What was lexed.
    pub kind: TokenKind,
    /// Byte offset in the source.
    pub pos: usize,
}

/// Lex error.
#[derive(Debug, Clone)]
pub struct LexError {
    /// Byte offset of the offending input.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "AQL lex error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for LexError {}

const KEYWORDS: &[&str] = &[
    "create", "view", "dictionary", "as", "extract", "regex", "on", "from", "select", "where",
    "and", "or", "not", "output", "consolidate", "using", "union", "all", "with", "case",
    "exact", "insensitive", "flags", "order", "by", "limit", "document", "true", "false", "minus", "block", "gap", "min", "file",
    "group", "top", "score",
];

/// Tokenize an AQL source string.
pub fn lex(src: &str) -> Result<Vec<Token>, LexError> {
    let b = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        match c {
            _ if c.is_ascii_whitespace() => i += 1,
            b'-' if b.get(i + 1) == Some(&b'-') => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            b'(' => {
                out.push(Token { kind: TokenKind::LParen, pos: i });
                i += 1;
            }
            b')' => {
                out.push(Token { kind: TokenKind::RParen, pos: i });
                i += 1;
            }
            b',' => {
                out.push(Token { kind: TokenKind::Comma, pos: i });
                i += 1;
            }
            b';' => {
                out.push(Token { kind: TokenKind::Semi, pos: i });
                i += 1;
            }
            b'.' => {
                out.push(Token { kind: TokenKind::Dot, pos: i });
                i += 1;
            }
            b'=' => {
                out.push(Token { kind: TokenKind::Eq, pos: i });
                i += 1;
            }
            b'!' if b.get(i + 1) == Some(&b'=') => {
                out.push(Token { kind: TokenKind::Ne, pos: i });
                i += 2;
            }
            b'<' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push(Token { kind: TokenKind::Le, pos: i });
                    i += 2;
                } else {
                    out.push(Token { kind: TokenKind::Lt, pos: i });
                    i += 1;
                }
            }
            b'>' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push(Token { kind: TokenKind::Ge, pos: i });
                    i += 2;
                } else {
                    out.push(Token { kind: TokenKind::Gt, pos: i });
                    i += 1;
                }
            }
            b'\'' => {
                let start = i;
                i += 1;
                let mut s = String::new();
                loop {
                    match b.get(i) {
                        None => {
                            return Err(LexError {
                                pos: start,
                                msg: "unterminated string literal".into(),
                            })
                        }
                        Some(b'\'') if b.get(i + 1) == Some(&b'\'') => {
                            s.push('\'');
                            i += 2;
                        }
                        Some(b'\'') => {
                            i += 1;
                            break;
                        }
                        Some(&c) => {
                            s.push(c as char);
                            i += 1;
                        }
                    }
                }
                out.push(Token { kind: TokenKind::Str(s), pos: start });
            }
            b'/' => {
                let start = i;
                i += 1;
                let mut s = String::new();
                loop {
                    match b.get(i) {
                        None => {
                            return Err(LexError {
                                pos: start,
                                msg: "unterminated regex literal".into(),
                            })
                        }
                        Some(b'\\') if b.get(i + 1) == Some(&b'/') => {
                            s.push('/');
                            i += 2;
                        }
                        Some(b'\\') => {
                            s.push('\\');
                            if let Some(&c) = b.get(i + 1) {
                                s.push(c as char);
                            }
                            i += 2;
                        }
                        Some(b'/') => {
                            i += 1;
                            break;
                        }
                        Some(&c) => {
                            s.push(c as char);
                            i += 1;
                        }
                    }
                }
                out.push(Token { kind: TokenKind::Regex(s), pos: start });
            }
            _ if c.is_ascii_digit() => {
                let start = i;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                let n: i64 = src[start..i].parse().map_err(|_| LexError {
                    pos: start,
                    msg: "integer literal too large".into(),
                })?;
                out.push(Token { kind: TokenKind::Int(n), pos: start });
            }
            _ if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                let word = &src[start..i];
                let lower = word.to_ascii_lowercase();
                let kind = if KEYWORDS.contains(&lower.as_str()) {
                    TokenKind::Kw(lower)
                } else {
                    TokenKind::Ident(word.to_string())
                };
                out.push(Token { kind, pos: start });
            }
            _ => {
                return Err(LexError {
                    pos: i,
                    msg: format!("unexpected character {:?}", c as char),
                })
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        lex(src).unwrap().into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn keywords_case_insensitive() {
        assert_eq!(
            kinds("CREATE View"),
            vec![TokenKind::Kw("create".into()), TokenKind::Kw("view".into())]
        );
    }

    #[test]
    fn identifiers_case_sensitive() {
        assert_eq!(kinds("Person"), vec![TokenKind::Ident("Person".into())]);
    }

    #[test]
    fn string_with_escape() {
        assert_eq!(
            kinds("'it''s'"),
            vec![TokenKind::Str("it's".into())]
        );
    }

    #[test]
    fn regex_literal_with_escaped_slash() {
        assert_eq!(
            kinds(r"/a\/b\d+/"),
            vec![TokenKind::Regex(r"a/b\d+".into())]
        );
    }

    #[test]
    fn operators() {
        assert_eq!(
            kinds("= != < <= > >="),
            vec![
                TokenKind::Eq,
                TokenKind::Ne,
                TokenKind::Lt,
                TokenKind::Le,
                TokenKind::Gt,
                TokenKind::Ge
            ]
        );
    }

    #[test]
    fn comments_skipped() {
        assert_eq!(
            kinds("a -- comment here\n b"),
            vec![TokenKind::Ident("a".into()), TokenKind::Ident("b".into())]
        );
    }

    #[test]
    fn numbers_and_punct() {
        assert_eq!(
            kinds("f(x.y, 42);"),
            vec![
                TokenKind::Ident("f".into()),
                TokenKind::LParen,
                TokenKind::Ident("x".into()),
                TokenKind::Dot,
                TokenKind::Ident("y".into()),
                TokenKind::Comma,
                TokenKind::Int(42),
                TokenKind::RParen,
                TokenKind::Semi,
            ]
        );
    }

    #[test]
    fn errors() {
        assert!(lex("'oops").is_err());
        assert!(lex("/oops").is_err());
        assert!(lex("#").is_err());
        assert!(lex("99999999999999999999").is_err());
    }

    #[test]
    fn full_statement_lexes() {
        let src = r#"
            create view V as
              extract regex /[A-Z][a-z]+/ on d.text as name from Document d;
            output view V;
        "#;
        assert!(lex(src).unwrap().len() > 15);
    }
}
