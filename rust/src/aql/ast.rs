//! AQL surface syntax tree (what the parser produces, before semantic
//! analysis and lowering to the operator graph).

use crate::dict::CaseMode;
use crate::text::span::ConsolidatePolicy;

/// A whole program: ordered statements.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    pub statements: Vec<Statement>,
}

/// Top-level statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Statement {
    CreateDictionary {
        name: String,
        case: CaseMode,
        entries: Vec<String>,
    },
    CreateDictionaryFromFile {
        name: String,
        case: CaseMode,
        path: String,
    },
    CreateView {
        name: String,
        body: ViewBody,
    },
    OutputView {
        name: String,
    },
}

/// View bodies: a single select/extract, or a union of them.
#[derive(Debug, Clone, PartialEq)]
pub enum ViewBody {
    Select(SelectStmt),
    Extract(ExtractStmt),
    Union(Vec<ViewBody>),
    /// `lhs minus rhs` — set difference.
    Minus(Box<ViewBody>, Box<ViewBody>),
    /// SystemT's BLOCK statement.
    Block(BlockStmt),
}

/// `block a.col with gap <n> min <m> from Source a`
#[derive(Debug, Clone, PartialEq)]
pub struct BlockStmt {
    pub alias: String,
    pub col: String,
    pub gap: u32,
    pub min_size: usize,
    pub source: SourceRef,
}

/// `extract ... on <alias>.<col> as <name> from <source> <alias>`
#[derive(Debug, Clone, PartialEq)]
pub struct ExtractStmt {
    pub kind: ExtractKind,
    pub input_alias: String,
    pub input_col: String,
    pub out_name: String,
    pub source: SourceRef,
}

/// The two extraction primitives.
#[derive(Debug, Clone, PartialEq)]
pub enum ExtractKind {
    Regex {
        pattern: String,
        case_insensitive: bool,
    },
    Dictionary {
        dict_name: String,
    },
}

/// `select items from sources [where preds] [consolidate ...] [order by] [limit]`
#[derive(Debug, Clone, PartialEq)]
pub struct SelectStmt {
    pub items: Vec<SelectItem>,
    pub sources: Vec<(SourceRef, String)>, // (source, alias)
    pub preds: Vec<AqlExpr>,               // conjunction
    pub consolidate: Option<(String, ConsolidatePolicy)>, // (output col name, policy)
    pub order_by: Vec<String>,             // output col names
    pub limit: Option<usize>,
}

/// One select-list item: an expression plus output name.
#[derive(Debug, Clone, PartialEq)]
pub struct SelectItem {
    pub expr: AqlExpr,
    pub name: String,
}

/// A `from` source.
#[derive(Debug, Clone, PartialEq)]
pub enum SourceRef {
    Document,
    View(String),
}

/// Surface expressions (resolved to `aog::Expr` by the compiler).
#[derive(Debug, Clone, PartialEq)]
pub enum AqlExpr {
    /// `alias.column`
    ColRef { alias: String, col: String },
    Int(i64),
    Str(String),
    Bool(bool),
    /// `Func(args...)`
    Call { func: String, args: Vec<AqlExpr> },
    Cmp {
        lhs: Box<AqlExpr>,
        op: crate::aog::expr::CmpOp,
        rhs: Box<AqlExpr>,
    },
    And(Box<AqlExpr>, Box<AqlExpr>),
    Or(Box<AqlExpr>, Box<AqlExpr>),
    Not(Box<AqlExpr>),
}

impl AqlExpr {
    /// Aliases referenced by this expression (for predicate pushdown at
    /// compile time).
    pub fn aliases(&self, out: &mut Vec<String>) {
        match self {
            AqlExpr::ColRef { alias, .. } => {
                if !out.contains(alias) {
                    out.push(alias.clone());
                }
            }
            AqlExpr::Int(_) | AqlExpr::Str(_) | AqlExpr::Bool(_) => {}
            AqlExpr::Call { args, .. } => {
                for a in args {
                    a.aliases(out);
                }
            }
            AqlExpr::Cmp { lhs, rhs, .. } => {
                lhs.aliases(out);
                rhs.aliases(out);
            }
            AqlExpr::And(a, b) | AqlExpr::Or(a, b) => {
                a.aliases(out);
                b.aliases(out);
            }
            AqlExpr::Not(a) => a.aliases(out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aliases_collected_once() {
        let e = AqlExpr::Call {
            func: "Follows".into(),
            args: vec![
                AqlExpr::ColRef {
                    alias: "p".into(),
                    col: "name".into(),
                },
                AqlExpr::ColRef {
                    alias: "o".into(),
                    col: "m".into(),
                },
                AqlExpr::ColRef {
                    alias: "p".into(),
                    col: "name".into(),
                },
                AqlExpr::Int(3),
            ],
        };
        let mut als = Vec::new();
        e.aliases(&mut als);
        assert_eq!(als, vec!["p".to_string(), "o".to_string()]);
    }
}
