//! AQL surface syntax tree (what the parser produces, before semantic
//! analysis and lowering to the operator graph).

use crate::dict::CaseMode;
use crate::text::span::ConsolidatePolicy;

/// A whole program: ordered statements.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    /// Statements in source order.
    pub statements: Vec<Statement>,
}

/// Top-level statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Statement {
    /// `create dictionary <name> as ('a', 'b', ...)`.
    CreateDictionary {
        /// Dictionary name.
        name: String,
        /// Case-folding policy.
        case: CaseMode,
        /// The literal entries.
        entries: Vec<String>,
    },
    /// `create dictionary <name> from file '<path>'`.
    CreateDictionaryFromFile {
        /// Dictionary name.
        name: String,
        /// Case-folding policy.
        case: CaseMode,
        /// File path as written in the program.
        path: String,
    },
    /// `create view <name> as <body>`.
    CreateView {
        /// View name.
        name: String,
        /// The view's defining body.
        body: ViewBody,
    },
    /// `output view <name>`.
    OutputView {
        /// Name of the view to output.
        name: String,
    },
}

/// View bodies: a single select/extract, or a union of them.
#[derive(Debug, Clone, PartialEq)]
pub enum ViewBody {
    /// A `select ... from ...` statement.
    Select(SelectStmt),
    /// An `extract regex/dictionary ...` statement.
    Extract(ExtractStmt),
    /// `(<body>) union all (<body>) ...`.
    Union(Vec<ViewBody>),
    /// `lhs minus rhs` — set difference.
    Minus(Box<ViewBody>, Box<ViewBody>),
    /// SystemT's BLOCK statement.
    Block(BlockStmt),
}

/// `block a.col with gap <n> min <m> from Source a`
#[derive(Debug, Clone, PartialEq)]
pub struct BlockStmt {
    /// Source alias.
    pub alias: String,
    /// Span column to block on.
    pub col: String,
    /// Maximum token gap between grouped spans.
    pub gap: u32,
    /// Minimum spans per emitted block.
    pub min_size: usize,
    /// The blocked source.
    pub source: SourceRef,
}

/// `extract ... on <alias>.<col> as <name> from <source> <alias>`
#[derive(Debug, Clone, PartialEq)]
pub struct ExtractStmt {
    /// Regex or dictionary extraction.
    pub kind: ExtractKind,
    /// Alias of the input source.
    pub input_alias: String,
    /// Input column scanned (`d.text`).
    pub input_col: String,
    /// Output column name (`as <name>`).
    pub out_name: String,
    /// The scanned source.
    pub source: SourceRef,
}

/// The two extraction primitives.
#[derive(Debug, Clone, PartialEq)]
pub enum ExtractKind {
    /// `extract regex /.../ ...`.
    Regex {
        /// The pattern between the slashes.
        pattern: String,
        /// `/.../i` flag.
        case_insensitive: bool,
    },
    /// `extract dictionary '<name>' ...`.
    Dictionary {
        /// The referenced dictionary's name.
        dict_name: String,
    },
}

/// `select items from sources [where preds] [consolidate ...]
/// [group by cols] [score expr] [top k] [order by] [limit]`
#[derive(Debug, Clone, PartialEq)]
pub struct SelectStmt {
    /// The select list.
    pub items: Vec<SelectItem>,
    /// `from` sources as `(source, alias)` pairs.
    pub sources: Vec<(SourceRef, String)>,
    /// `where` predicates (implicit conjunction).
    pub preds: Vec<AqlExpr>,
    /// `consolidate on <output col> using '<policy>'`.
    pub consolidate: Option<(String, ConsolidatePolicy)>,
    /// `group by` output column names (corpus-level aggregation).
    pub group_by: Vec<String>,
    /// `score <expr>` over the aggregate's output columns (bare
    /// identifiers; parsed with `alias: ""`).
    pub score: Option<AqlExpr>,
    /// `top <k>` — bounded top-k by score, descending.
    pub top_k: Option<usize>,
    /// `order by` output column names.
    pub order_by: Vec<String>,
    /// `limit <n>`.
    pub limit: Option<usize>,
}

/// One select-list item: an expression plus output name.
#[derive(Debug, Clone, PartialEq)]
pub struct SelectItem {
    /// The projected expression.
    pub expr: AqlExpr,
    /// Output column name (`as <name>`).
    pub name: String,
}

/// A `from` source.
#[derive(Debug, Clone, PartialEq)]
pub enum SourceRef {
    /// The built-in `Document d` relation.
    Document,
    /// A previously created view, by name.
    View(String),
}

/// Surface expressions (resolved to `aog::Expr` by the compiler).
#[derive(Debug, Clone, PartialEq)]
pub enum AqlExpr {
    /// `alias.column`
    ColRef {
        /// Source alias.
        alias: String,
        /// Column name.
        col: String,
    },
    /// Integer literal.
    Int(i64),
    /// String literal.
    Str(String),
    /// Boolean literal.
    Bool(bool),
    /// `Func(args...)`
    Call {
        /// Function name as written.
        func: String,
        /// Argument expressions.
        args: Vec<AqlExpr>,
    },
    /// Binary comparison.
    Cmp {
        /// Left operand.
        lhs: Box<AqlExpr>,
        /// Comparison operator.
        op: crate::aog::expr::CmpOp,
        /// Right operand.
        rhs: Box<AqlExpr>,
    },
    /// Logical conjunction.
    And(Box<AqlExpr>, Box<AqlExpr>),
    /// Logical disjunction.
    Or(Box<AqlExpr>, Box<AqlExpr>),
    /// Logical negation.
    Not(Box<AqlExpr>),
}

impl AqlExpr {
    /// Aliases referenced by this expression (for predicate pushdown at
    /// compile time).
    pub fn aliases(&self, out: &mut Vec<String>) {
        match self {
            AqlExpr::ColRef { alias, .. } => {
                if !out.contains(alias) {
                    out.push(alias.clone());
                }
            }
            AqlExpr::Int(_) | AqlExpr::Str(_) | AqlExpr::Bool(_) => {}
            AqlExpr::Call { args, .. } => {
                for a in args {
                    a.aliases(out);
                }
            }
            AqlExpr::Cmp { lhs, rhs, .. } => {
                lhs.aliases(out);
                rhs.aliases(out);
            }
            AqlExpr::And(a, b) | AqlExpr::Or(a, b) => {
                a.aliases(out);
                b.aliases(out);
            }
            AqlExpr::Not(a) => a.aliases(out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aliases_collected_once() {
        let e = AqlExpr::Call {
            func: "Follows".into(),
            args: vec![
                AqlExpr::ColRef {
                    alias: "p".into(),
                    col: "name".into(),
                },
                AqlExpr::ColRef {
                    alias: "o".into(),
                    col: "m".into(),
                },
                AqlExpr::ColRef {
                    alias: "p".into(),
                    col: "name".into(),
                },
                AqlExpr::Int(3),
            ],
        };
        let mut als = Vec::new();
        e.aliases(&mut als);
        assert_eq!(als, vec!["p".to_string(), "o".to_string()]);
    }
}
