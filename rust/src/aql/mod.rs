//! AQL — the Annotation Query Language (SystemT's declarative rule
//! language), as a practical subset.
//!
//! A query is a sequence of statements:
//!
//! ```aql
//! create dictionary OrgNames with case insensitive as
//!   ('IBM', 'IBM Research', 'Columbia University');
//!
//! create view Org as
//!   extract dictionary 'OrgNames' on d.text as match from Document d;
//!
//! create view Person as
//!   extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name
//!   from Document d;
//!
//! create view PersonOrg as
//!   select p.name as person, o.match as org,
//!          CombineSpans(p.name, o.match) as ctx
//!   from Person p, Org o
//!   where FollowsTok(p.name, o.match, 0, 5)
//!   consolidate on ctx using 'ContainedWithin';
//!
//! output view PersonOrg;
//! ```
//!
//! The compiler lowers statements to an [`crate::aog::Graph`]: extraction
//! statements become leaf extraction operators over the shared `DocScan`;
//! select statements become cross-join + select + project chains that the
//! optimizer then rewrites into proper join trees (cost-based rule
//! optimization is SystemT's calling card and what makes the supergraph
//! cheap enough to matter).

pub mod ast;
pub mod compiler;
pub mod lexer;
pub mod parser;

pub use ast::{Program, Statement};
pub use compiler::{compile_program, compile_program_ns, Catalog, CompileError};
pub use lexer::{lex, LexError, Token, TokenKind};
pub use parser::{parse_program, ParseErr};

/// Parse an AQL program (lex + parse, no lowering).
pub fn parse(src: &str) -> Result<Program, CompileError> {
    let tokens = lex(src).map_err(|e| CompileError::Lex(e.to_string()))?;
    parse_program(&tokens).map_err(|e| CompileError::Parse(e.to_string()))
}

/// Parse + compile an AQL program into an operator graph.
pub fn compile(src: &str) -> Result<crate::aog::Graph, CompileError> {
    compile_program(&parse(src)?)
}

/// Parse + compile under a namespace: view roots and outputs become
/// `<ns>.<View>` while in-program name resolution stays unqualified. This
/// is what [`crate::coordinator::CatalogBuilder`] runs per registered
/// query before merging the graphs into the shared supergraph.
pub fn compile_ns(src: &str, namespace: &str) -> Result<crate::aog::Graph, CompileError> {
    compile_program_ns(&parse(src)?, Some(namespace))
}
