//! The standard tokenizer and the per-document token index.
//!
//! SystemT's extraction primitives are token-aware: dictionaries match
//! whole-token phrases and the `FollowsTok` predicate measures distance in
//! tokens. The tokenizer here mirrors SystemT's "standard" tokenizer on
//! ASCII text: maximal alphanumeric runs are word tokens, every other
//! non-whitespace character is a single-character token, whitespace
//! separates tokens.

use super::span::Span;

/// Kind of a token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    /// `[A-Za-z0-9_]+` run.
    Word,
    /// Single non-word, non-whitespace character.
    Punct,
}

/// One token: a span plus its kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Token {
    /// The token's byte range.
    pub span: Span,
    /// Word or punctuation.
    pub kind: TokenKind,
}

/// The tokenizer. Only the standard configuration is currently exposed;
/// the struct exists so alternate tokenizers (e.g. whitespace-only) can be
/// added without touching call sites.
#[derive(Debug, Clone, Copy)]
pub struct Tokenizer {
    split_punct: bool,
}

#[inline]
pub(crate) fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

impl Tokenizer {
    /// SystemT-like standard tokenizer.
    pub fn standard() -> Self {
        Tokenizer { split_punct: true }
    }

    /// Whitespace-only tokenizer (punctuation glued to words).
    pub fn whitespace() -> Self {
        Tokenizer { split_punct: false }
    }

    /// Tokenize `text` into a [`TokenIndex`].
    pub fn tokenize(&self, text: &str) -> TokenIndex {
        let bytes = text.as_bytes();
        let mut tokens = Vec::with_capacity(bytes.len() / 5 + 1);
        let mut i = 0usize;
        while i < bytes.len() {
            let b = bytes[i];
            if b.is_ascii_whitespace() {
                i += 1;
            } else if is_word_byte(b) || !self.split_punct {
                let start = i;
                if self.split_punct {
                    while i < bytes.len() && is_word_byte(bytes[i]) {
                        i += 1;
                    }
                } else {
                    while i < bytes.len() && !bytes[i].is_ascii_whitespace() {
                        i += 1;
                    }
                }
                tokens.push(Token {
                    span: Span::new(start as u32, i as u32),
                    kind: TokenKind::Word,
                });
            } else {
                tokens.push(Token {
                    span: Span::new(i as u32, (i + 1) as u32),
                    kind: TokenKind::Punct,
                });
                i += 1;
            }
        }
        TokenIndex { tokens }
    }
}

/// Sorted token list for one document, with offset→token lookups used by
/// token-distance predicates and token-boundary checks.
#[derive(Debug, Clone, Default)]
pub struct TokenIndex {
    tokens: Vec<Token>,
}

impl TokenIndex {
    /// All tokens in document order.
    pub fn tokens(&self) -> &[Token] {
        &self.tokens
    }

    /// Number of tokens.
    pub fn token_count(&self) -> usize {
        self.tokens.len()
    }

    /// Index of the first token whose span begins at or after `offset`.
    pub fn first_token_at_or_after(&self, offset: u32) -> usize {
        self.tokens.partition_point(|t| t.span.begin < offset)
    }

    /// Index of the last token whose span ends at or before `offset`,
    /// or `None` if no token ends by `offset`.
    pub fn last_token_ending_by(&self, offset: u32) -> Option<usize> {
        let n = self.tokens.partition_point(|t| t.span.end <= offset);
        n.checked_sub(1)
    }

    /// Number of whole tokens strictly between byte offsets `a_end` and
    /// `b_begin` — the distance used by `FollowsTok(a, b, min, max)`.
    pub fn tokens_between(&self, a_end: u32, b_begin: u32) -> usize {
        if b_begin <= a_end {
            return 0;
        }
        let lo = self.first_token_at_or_after(a_end);
        let hi = self.tokens.partition_point(|t| t.span.end <= b_begin);
        hi.saturating_sub(lo)
    }

    /// True if `[begin, end)` lies exactly on token boundaries: `begin`
    /// starts a token and `end` ends a token. Token-based dictionary
    /// matches must satisfy this.
    pub fn on_token_boundaries(&self, begin: u32, end: u32) -> bool {
        let starts = self
            .tokens
            .binary_search_by(|t| t.span.begin.cmp(&begin))
            .is_ok();
        let ends = self
            .tokens
            .binary_search_by(|t| {
                // search by end; ends are non-decreasing for our tokenizers
                t.span.end.cmp(&end)
            })
            .is_ok();
        starts && ends
    }

    /// Span covering tokens `[from, to)` (token indices).
    pub fn cover(&self, from: usize, to: usize) -> Option<Span> {
        if from >= to || to > self.tokens.len() {
            return None;
        }
        Some(Span::new(
            self.tokens[from].span.begin,
            self.tokens[to - 1].span.end,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(text: &str) -> Vec<(String, TokenKind)> {
        Tokenizer::standard()
            .tokenize(text)
            .tokens()
            .iter()
            .map(|t| (t.span.text(text).to_string(), t.kind))
            .collect()
    }

    #[test]
    fn basic_words() {
        let t = toks("hello world");
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].0, "hello");
        assert_eq!(t[1].0, "world");
    }

    #[test]
    fn punct_split() {
        let t = toks("Hi, there!");
        let words: Vec<&str> = t.iter().map(|(s, _)| s.as_str()).collect();
        assert_eq!(words, vec!["Hi", ",", "there", "!"]);
        assert_eq!(t[1].1, TokenKind::Punct);
    }

    #[test]
    fn digits_and_underscore_are_words() {
        let t = toks("foo_bar 123 a1b2");
        let words: Vec<&str> = t.iter().map(|(s, _)| s.as_str()).collect();
        assert_eq!(words, vec!["foo_bar", "123", "a1b2"]);
    }

    #[test]
    fn empty_and_whitespace() {
        assert_eq!(toks("").len(), 0);
        assert_eq!(toks("   \t\n ").len(), 0);
    }

    #[test]
    fn whitespace_tokenizer_keeps_punct() {
        let idx = Tokenizer::whitespace().tokenize("Hi, there!");
        let words: Vec<&str> = idx
            .tokens()
            .iter()
            .map(|t| t.span.text("Hi, there!"))
            .collect();
        assert_eq!(words, vec!["Hi,", "there!"]);
    }

    #[test]
    fn tokens_between_counts() {
        let text = "a b c d e";
        let idx = Tokenizer::standard().tokenize(text);
        // span of "a" is [0,1), span of "e" is [8,9)
        assert_eq!(idx.tokens_between(1, 8), 3); // b c d
        assert_eq!(idx.tokens_between(1, 2), 0);
        assert_eq!(idx.tokens_between(1, 4), 1); // b
        assert_eq!(idx.tokens_between(5, 5), 0);
    }

    #[test]
    fn token_boundaries() {
        let text = "alpha beta";
        let idx = Tokenizer::standard().tokenize(text);
        assert!(idx.on_token_boundaries(0, 5));
        assert!(idx.on_token_boundaries(6, 10));
        assert!(idx.on_token_boundaries(0, 10)); // spans multiple tokens
        assert!(!idx.on_token_boundaries(1, 5));
        assert!(!idx.on_token_boundaries(0, 4));
    }

    #[test]
    fn cover_range() {
        let text = "a bb ccc";
        let idx = Tokenizer::standard().tokenize(text);
        assert_eq!(idx.cover(0, 2), Some(Span::new(0, 4)));
        assert_eq!(idx.cover(1, 2), Some(Span::new(2, 4)));
        assert_eq!(idx.cover(2, 2), None);
        assert_eq!(idx.cover(0, 9), None);
    }

    #[test]
    fn prop_tokens_cover_only_nonspace_and_sorted() {
        use crate::util::{prop, Prng};
        prop::check(
            31,
            300,
            |r: &mut Prng| prop::ascii_string(r, 120),
            |s| {
                let idx = Tokenizer::standard().tokenize(s);
                let toks = idx.tokens();
                // sorted, non-overlapping
                for w in toks.windows(2) {
                    if w[0].span.end > w[1].span.begin {
                        return false;
                    }
                }
                // each token non-empty, within bounds, no whitespace inside
                for t in toks {
                    if t.span.is_empty() || t.span.end as usize > s.len() {
                        return false;
                    }
                    if t.span.text(s).bytes().any(|b| b.is_ascii_whitespace()) {
                        return false;
                    }
                }
                // every non-whitespace byte is covered by exactly one token
                let covered: usize = toks.iter().map(|t| t.span.len() as usize).sum();
                let nonspace = s.bytes().filter(|b| !b.is_ascii_whitespace()).count();
                covered == nonspace
            },
        );
    }
}
