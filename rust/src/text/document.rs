//! Documents: the unit of work of the document-per-thread execution model.

use std::sync::Arc;

use super::tokenizer::{TokenIndex, Tokenizer};

/// An input document. Text is reference-counted so worker threads, the
/// communication thread, and result tuples can share it without copies —
/// the document is the only variable-length structure that crosses the
/// HW/SW interface (paper §3).
#[derive(Debug, Clone)]
pub struct Document {
    /// Stable identifier (position in the corpus).
    pub id: u64,
    /// The document text. ASCII in our synthetic corpora; the engine treats
    /// it as bytes with spans as byte offsets, like the paper's
    /// "sequence of ASCII characters".
    pub text: Arc<str>,
}

impl Document {
    /// Create a document from owned text.
    pub fn new(id: u64, text: impl Into<Arc<str>>) -> Self {
        Document {
            id,
            text: text.into(),
        }
    }

    /// Byte length.
    pub fn len(&self) -> usize {
        self.text.len()
    }

    /// True if the text is empty.
    pub fn is_empty(&self) -> bool {
        self.text.is_empty()
    }

    /// Tokenize with the standard tokenizer; cached per call site (the
    /// executor caches one index per document evaluation).
    pub fn token_index(&self) -> TokenIndex {
        Tokenizer::standard().tokenize(&self.text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_len() {
        let d = Document::new(7, "hello world");
        assert_eq!(d.id, 7);
        assert_eq!(d.len(), 11);
        assert!(!d.is_empty());
        assert!(Document::new(0, "").is_empty());
    }

    #[test]
    fn shared_text_is_cheap() {
        let d = Document::new(1, "abc".repeat(1000));
        let d2 = d.clone();
        assert!(Arc::ptr_eq(
            &(d.text.clone() as Arc<str>),
            &(d2.text.clone() as Arc<str>)
        ));
    }

    #[test]
    fn token_index_works() {
        let d = Document::new(2, "Alpha beta, gamma.");
        let idx = d.token_index();
        assert_eq!(idx.token_count(), 5); // Alpha beta , gamma .
    }
}
