//! The span type and its interval algebra.

use std::fmt;

/// A half-open `[begin, end)` byte-offset interval into a document's text.
///
/// Offsets are `u32` exactly as in the paper ("both of which are represented
/// as 32-bit integers"); documents are bounded to 4 GiB which is far beyond
/// any realistic annotation unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Span {
    /// Inclusive start byte offset.
    pub begin: u32,
    /// Exclusive end byte offset.
    pub end: u32,
}

impl Span {
    /// Construct a span; `begin <= end` is required.
    #[inline]
    pub fn new(begin: u32, end: u32) -> Self {
        debug_assert!(begin <= end, "span begin {begin} > end {end}");
        Span { begin, end }
    }

    /// Length in bytes.
    #[inline]
    pub fn len(&self) -> u32 {
        self.end - self.begin
    }

    /// True if the span covers zero bytes.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }

    /// The covered text.
    #[inline]
    pub fn text<'a>(&self, doc_text: &'a str) -> &'a str {
        &doc_text[self.begin as usize..self.end as usize]
    }

    /// True if `self` and `other` share at least one byte.
    #[inline]
    pub fn overlaps(&self, other: &Span) -> bool {
        self.begin < other.end && other.begin < self.end
    }

    /// True if `self` fully contains `other` (boundaries may coincide).
    #[inline]
    pub fn contains(&self, other: &Span) -> bool {
        self.begin <= other.begin && other.end <= self.end
    }

    /// True if `other` starts after `self` ends, with a gap of
    /// `min..=max` bytes — the AQL `Follows` predicate.
    #[inline]
    pub fn follows(&self, other: &Span, min: u32, max: u32) -> bool {
        other.begin >= self.end && {
            let gap = other.begin - self.end;
            gap >= min && gap <= max
        }
    }

    /// Smallest span covering both — the AQL `CombineSpans` function.
    #[inline]
    pub fn combine(&self, other: &Span) -> Span {
        Span::new(self.begin.min(other.begin), self.end.max(other.end))
    }

    /// Intersection, if non-empty overlap exists.
    pub fn intersect(&self, other: &Span) -> Option<Span> {
        if self.overlaps(other) {
            Some(Span::new(
                self.begin.max(other.begin),
                self.end.min(other.end),
            ))
        } else {
            None
        }
    }
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}, {})", self.begin, self.end)
    }
}

/// Consolidation policies — SystemT's `consolidate on ... using '...'`.
///
/// Consolidation removes redundant overlapping annotations; it is one of the
/// relational operators the paper offloads to hardware.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConsolidatePolicy {
    /// Discard spans contained inside another span (keep the longest).
    ContainedWithin,
    /// Keep only spans NOT containing another span (keep the shortest).
    NotContainedWithin,
    /// Collapse exact duplicates.
    ExactMatch,
    /// Greedy left-to-right non-overlap: sort by begin, keep a span if it
    /// does not overlap the previously kept one.
    LeftToRight,
}

impl ConsolidatePolicy {
    /// Parse the AQL policy-name string.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "ContainedWithin" => Some(Self::ContainedWithin),
            "NotContainedWithin" => Some(Self::NotContainedWithin),
            "ExactMatch" => Some(Self::ExactMatch),
            "LeftToRight" => Some(Self::LeftToRight),
            _ => None,
        }
    }

    /// Canonical AQL name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::ContainedWithin => "ContainedWithin",
            Self::NotContainedWithin => "NotContainedWithin",
            Self::ExactMatch => "ExactMatch",
            Self::LeftToRight => "LeftToRight",
        }
    }
}

/// Consolidate a set of spans under `policy`. Used by both the software
/// operator and the accelerator's relational post-stage; kept here so the
/// two share one implementation (and one set of tests).
pub fn consolidate(spans: &[Span], policy: ConsolidatePolicy) -> Vec<Span> {
    let mut sorted: Vec<Span> = spans.to_vec();
    // Order: by begin asc, then end DESC so containers precede containees.
    sorted.sort_by(|a, b| a.begin.cmp(&b.begin).then(b.end.cmp(&a.end)));
    match policy {
        ConsolidatePolicy::ExactMatch => {
            sorted.dedup();
            sorted
        }
        ConsolidatePolicy::ContainedWithin => {
            // Under (begin asc, end desc) order every kept span has
            // begin ≤ s.begin, so s is contained in some kept span iff the
            // running max end ≥ s.end; exact duplicates fall out the same
            // way. Single O(n) pass (this operator runs on the hot path of
            // every entity query).
            let mut out: Vec<Span> = Vec::new();
            let mut max_end: Option<u32> = None;
            for s in sorted {
                match max_end {
                    Some(me) if me >= s.end => {} // contained or duplicate
                    _ => {
                        out.push(s);
                        max_end = Some(s.end);
                    }
                }
            }
            out
        }
        ConsolidatePolicy::NotContainedWithin => {
            let mut out = Vec::new();
            for (i, s) in sorted.iter().enumerate() {
                let contains_other = sorted
                    .iter()
                    .enumerate()
                    .any(|(j, t)| i != j && s.contains(t) && s != t);
                if !contains_other && out.last() != Some(s) {
                    out.push(*s);
                }
            }
            out
        }
        ConsolidatePolicy::LeftToRight => {
            let mut out: Vec<Span> = Vec::new();
            for s in sorted {
                match out.last() {
                    Some(last) if last.overlaps(&s) => {}
                    _ => out.push(s),
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(b: u32, e: u32) -> Span {
        Span::new(b, e)
    }

    #[test]
    fn len_and_empty() {
        assert_eq!(s(3, 7).len(), 4);
        assert!(s(5, 5).is_empty());
        assert!(!s(5, 6).is_empty());
    }

    #[test]
    fn overlap_cases() {
        assert!(s(0, 5).overlaps(&s(4, 9)));
        assert!(s(4, 9).overlaps(&s(0, 5)));
        assert!(!s(0, 5).overlaps(&s(5, 9))); // touching is not overlap
        assert!(!s(0, 5).overlaps(&s(7, 9)));
        assert!(s(2, 3).overlaps(&s(0, 10)));
    }

    #[test]
    fn contains_cases() {
        assert!(s(0, 10).contains(&s(3, 7)));
        assert!(s(0, 10).contains(&s(0, 10)));
        assert!(!s(3, 7).contains(&s(0, 10)));
        assert!(!s(0, 5).contains(&s(4, 6)));
    }

    #[test]
    fn follows_gap() {
        assert!(s(0, 5).follows(&s(5, 8), 0, 0));
        assert!(s(0, 5).follows(&s(7, 8), 0, 5));
        assert!(!s(0, 5).follows(&s(7, 8), 0, 1));
        assert!(!s(0, 5).follows(&s(3, 8), 0, 100)); // overlapping: not follows
        assert!(!s(5, 8).follows(&s(0, 5), 0, 100)); // order matters
    }

    #[test]
    fn combine_covers() {
        assert_eq!(s(2, 5).combine(&s(7, 9)), s(2, 9));
        assert_eq!(s(7, 9).combine(&s(2, 5)), s(2, 9));
    }

    #[test]
    fn intersect_cases() {
        assert_eq!(s(0, 5).intersect(&s(3, 9)), Some(s(3, 5)));
        assert_eq!(s(0, 5).intersect(&s(5, 9)), None);
    }

    #[test]
    fn text_slicing() {
        let t = "hello world";
        assert_eq!(s(6, 11).text(t), "world");
    }

    #[test]
    fn consolidate_contained_within() {
        let spans = [s(0, 10), s(2, 5), s(12, 14), s(0, 10)];
        let out = consolidate(&spans, ConsolidatePolicy::ContainedWithin);
        assert_eq!(out, vec![s(0, 10), s(12, 14)]);
    }

    #[test]
    fn consolidate_not_contained_within() {
        let spans = [s(0, 10), s(2, 5), s(12, 14)];
        let out = consolidate(&spans, ConsolidatePolicy::NotContainedWithin);
        assert_eq!(out, vec![s(2, 5), s(12, 14)]);
    }

    #[test]
    fn consolidate_exact() {
        let spans = [s(1, 4), s(1, 4), s(2, 4)];
        let out = consolidate(&spans, ConsolidatePolicy::ExactMatch);
        assert_eq!(out, vec![s(1, 4), s(2, 4)]);
    }

    #[test]
    fn consolidate_left_to_right() {
        let spans = [s(0, 5), s(3, 8), s(6, 9)];
        let out = consolidate(&spans, ConsolidatePolicy::LeftToRight);
        assert_eq!(out, vec![s(0, 5), s(6, 9)]);
    }

    #[test]
    fn consolidate_empty() {
        assert!(consolidate(&[], ConsolidatePolicy::ContainedWithin).is_empty());
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            ConsolidatePolicy::ContainedWithin,
            ConsolidatePolicy::NotContainedWithin,
            ConsolidatePolicy::ExactMatch,
            ConsolidatePolicy::LeftToRight,
        ] {
            assert_eq!(ConsolidatePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(ConsolidatePolicy::parse("nope"), None);
    }

    #[test]
    fn prop_consolidate_subset_and_sorted() {
        use crate::util::{prop, Prng};
        prop::check(
            77,
            200,
            |r: &mut Prng| {
                let n = r.below(20);
                (0..n)
                    .map(|_| {
                        let b = r.below(50) as u32;
                        let l = r.below(10) as u32 + 1;
                        (b as usize, (b + l) as usize)
                    })
                    .collect::<Vec<(usize, usize)>>()
            },
            |pairs| {
                let spans: Vec<Span> = pairs
                    .iter()
                    .map(|&(b, e)| s(b as u32, e as u32))
                    .collect();
                for policy in [
                    ConsolidatePolicy::ContainedWithin,
                    ConsolidatePolicy::NotContainedWithin,
                    ConsolidatePolicy::ExactMatch,
                    ConsolidatePolicy::LeftToRight,
                ] {
                    let out = consolidate(&spans, policy);
                    // output ⊆ input
                    if !out.iter().all(|o| spans.contains(o)) {
                        return false;
                    }
                    // sorted by begin
                    if !out.windows(2).all(|w| w[0].begin <= w[1].begin) {
                        return false;
                    }
                    // idempotent
                    if consolidate(&out, policy) != out {
                        return false;
                    }
                }
                true
            },
        );
    }
}
