//! Text substrate: documents, spans, and the tokenizer.
//!
//! SystemT's central data structure is the *span* — a `[begin, end)` offset
//! pair into the document text (§3 of the paper: "a span is composed of a
//! start and an end offset, both of which are represented as 32-bit
//! integers"). All extraction and relational operators produce and consume
//! spans; the tokenizer provides the token index needed by token-distance
//! predicates (`FollowsTok`) and token-based dictionary matching.

pub mod document;
pub mod span;
pub mod tokenizer;

pub use document::Document;
pub use span::Span;
pub use tokenizer::{Token, TokenIndex, Tokenizer};
