//! The hardware query compiler — the paper's TAPAS (ref [23]) analogue.
//!
//! Takes a partitioned subgraph and produces an [`AccelConfig`]: the set of
//! *machines* (dense DFA transition tables — regex search DFAs and
//! dictionary Aho–Corasick automata share one layout) plus the relational
//! body that the accelerator's post-stage evaluates over the match streams.
//!
//! The paper generates a custom FPGA netlist per query; our reconfigurable
//! device is a fixed AOT-compiled Pallas kernel whose transition tables are
//! *inputs* (their ref [16]'s software-programmed-interface approach), so
//! "compiling" a query means packing tables into the padded tensor layout
//! of one of a small menu of artifact variants and validating that the
//! hardware semantics reproduce the software semantics for every machine.

use std::fmt;
use std::sync::Arc;

use crate::aog::{Graph, NodeId, OpKind};
use crate::dict::AhoCorasick;
use crate::partition::SubgraphSpec;
use crate::regex::CompiledRegex;
use crate::util::Prng;

/// Largest per-machine state budget any artifact variant provides; the
/// partitioner refuses to offload patterns beyond this (the FPGA's BRAM
/// budget, in the paper's terms).
pub const MAX_HW_STATES: usize = 1024;

/// Artifact menu: `(machines, states)` table-geometry variants. For each
/// geometry, AOT produces one HLO per block size in [`BLOCK_SIZES`]. Kept
/// in sync with `python/compile/aot.py` (`GEOMETRIES` there) by the
/// `artifact_key` naming convention and checked at runtime load.
///
/// The wide variants (16 and 32 machines) exist for the **multi-query
/// catalog**: folding T1–T5's deduplicated extraction leaves into one
/// shared image needs ~16 machines, the paper's single-FPGA-image
/// deployment shape (§III–IV).
pub const GEOMETRIES: &[(usize, usize)] = &[
    (4, 64),
    (8, 128),
    (8, 256),
    (4, 1024),
    (16, 256),
    (16, 1024),
    (32, 1024),
];

/// Work-package block sizes (bytes per stream) with compiled artifacts.
pub const BLOCK_SIZES: &[usize] = &[4096, 16384];

/// Number of parallel byte streams — fixed at the paper's four.
pub const STREAMS: usize = 4;

/// Identifies one compiled artifact variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// Padded machine count of the geometry.
    pub machines: usize,
    /// Padded per-machine state count.
    pub states: usize,
    /// Bytes per stream.
    pub block: usize,
}

impl ArtifactKey {
    /// File name under `artifacts/` (the aot.py naming convention).
    pub fn file_name(&self) -> String {
        format!(
            "dfa_m{}_s{}_b{}.hlo.txt",
            self.machines, self.states, self.block
        )
    }

    /// Expected dense tensor lengths for this variant:
    /// `(byte_lane_values, table_entries, accept_entries)`. Package
    /// engines validate incoming [`crate::runtime::PackedPackage`]s
    /// against these — a mismatch means a truncated or mis-packed
    /// transfer.
    pub fn tensor_sizes(&self) -> (usize, usize, usize) {
        (
            STREAMS * self.block,
            self.machines * self.states * 256,
            self.machines * self.states,
        )
    }
}

/// What a machine's hit stream means (how the post-stage reconstructs
/// spans from reported `(offset, state)` pairs).
#[derive(Clone)]
pub enum MatcherRef {
    /// Regex machine: ends are mapped back via the reverse DFA.
    Regex(Arc<CompiledRegex>),
    /// Dictionary machine: `(end, state)` pairs map to entry matches.
    Dict(Arc<AhoCorasick>),
}

impl fmt::Debug for MatcherRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MatcherRef::Regex(r) => write!(f, "Regex(/{}/)", r.pattern.source),
            MatcherRef::Dict(d) => write!(f, "Dict({} states)", d.num_states),
        }
    }
}

/// One configured machine: a dense table in the shared layout.
#[derive(Debug, Clone)]
pub struct Machine {
    /// Node in the subgraph body whose output this machine produces.
    pub body_node: NodeId,
    /// How the post-stage decodes this machine's hits.
    pub matcher: MatcherRef,
    /// `num_states × 256` table (state 0 dead, 1 start, NUL resets).
    pub table: Vec<u32>,
    /// States actually used (before geometry padding).
    pub num_states: usize,
    /// Per-state accept flags.
    pub accept: Vec<bool>,
}

/// A compiled accelerator configuration for one subgraph.
#[derive(Debug, Clone)]
pub struct AccelConfig {
    /// The subgraph this image serves.
    pub subgraph_id: usize,
    /// One machine per extraction leaf.
    pub machines: Vec<Machine>,
    /// The subgraph body (extraction leaves + relational operators).
    pub body: Arc<Graph>,
    /// Body output node ids in `output_idx` order (from the spec).
    pub outputs: Vec<NodeId>,
    /// Number of ExtInput slots.
    pub ext_inputs: usize,
    /// Chosen table geometry (machines, states) — block is chosen by the
    /// accelerator service per its package size.
    pub geometry: (usize, usize),
}

/// Hardware compilation failure.
#[derive(Debug)]
pub enum HwCompileError {
    /// Pattern's DFA exceeds every artifact geometry.
    TooManyStates { node: NodeId, states: usize },
    /// More extraction machines than any geometry provides.
    TooManyMachines { machines: usize },
    /// SW/HW semantics diverged on validation text (pattern rejected).
    SemanticsDiverge { node: NodeId, pattern: String },
}

impl fmt::Display for HwCompileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HwCompileError::TooManyStates { node, states } => write!(
                f,
                "node {node}: {states} DFA states exceed the largest artifact geometry"
            ),
            HwCompileError::TooManyMachines { machines } => {
                write!(f, "{machines} machines exceed the largest artifact geometry")
            }
            HwCompileError::SemanticsDiverge { node, pattern } => write!(
                f,
                "node {node}: hardware semantics diverge from software for /{pattern}/"
            ),
        }
    }
}

impl std::error::Error for HwCompileError {}

/// Compile a subgraph into an accelerator configuration.
pub fn compile_subgraph(spec: &SubgraphSpec) -> Result<AccelConfig, HwCompileError> {
    let mut machines = Vec::new();
    for node in &spec.body.nodes {
        match &node.kind {
            OpKind::RegexExtract { regex, .. } => {
                validate_regex_semantics(node.id, regex)?;
                let dfa = &regex.search;
                machines.push(Machine {
                    body_node: node.id,
                    matcher: MatcherRef::Regex(regex.clone()),
                    table: dfa.table.clone(),
                    num_states: dfa.num_states as usize,
                    accept: dfa.accept.clone(),
                });
            }
            OpKind::DictExtract { matcher, .. } => {
                // The software scanner folds INPUT bytes for
                // case-insensitive dictionaries (AhoCorasick::step); the
                // kernel is a pure table walk, so the fold must be baked
                // into the exported table: table'[s][b] = table[s][fold(b)].
                let s_n = matcher.num_states as usize;
                let fold = |b: usize| -> usize {
                    match matcher.case {
                        crate::dict::CaseMode::Exact => b,
                        crate::dict::CaseMode::Insensitive => {
                            (b as u8).to_ascii_lowercase() as usize
                        }
                    }
                };
                let mut table = vec![0u32; s_n * 256];
                for s in 0..s_n {
                    for b in 0..256 {
                        table[s * 256 + b] = matcher.table[s * 256 + fold(b)];
                    }
                }
                machines.push(Machine {
                    body_node: node.id,
                    matcher: MatcherRef::Dict(matcher.clone()),
                    table,
                    num_states: s_n,
                    accept: (0..matcher.num_states)
                        .map(|s| !matcher.outputs[s as usize].is_empty())
                        .collect(),
                });
            }
            _ => {}
        }
    }

    let max_states = machines.iter().map(|m| m.num_states).max().unwrap_or(2);
    let geometry = GEOMETRIES
        .iter()
        .copied()
        .filter(|&(m, s)| m >= machines.len().max(1) && s >= max_states)
        .min_by_key(|&(m, s)| m * s)
        .ok_or({
            if max_states > MAX_HW_STATES {
                HwCompileError::TooManyStates {
                    node: machines
                        .iter()
                        .find(|m| m.num_states == max_states)
                        .map(|m| m.body_node)
                        .unwrap_or(0),
                    states: max_states,
                }
            } else {
                HwCompileError::TooManyMachines {
                    machines: machines.len(),
                }
            }
        })?;

    Ok(AccelConfig {
        subgraph_id: spec.id,
        machines,
        body: Arc::new(spec.body.clone()),
        outputs: spec.outputs.clone(),
        ext_inputs: spec.ext_inputs,
        geometry,
    })
}

/// Validate on generated text that end-report + reverse-scan reconstruction
/// equals the software matcher for this pattern (the contract documented in
/// [`crate::regex::matcher`]). Deterministic: fixed seed.
fn validate_regex_semantics(
    node: NodeId,
    regex: &CompiledRegex,
) -> Result<(), HwCompileError> {
    let alphabet = regex.pattern.alphabet_sample();
    let mut rng = Prng::new(0xB005_7E11);
    let fixed = [
        "",
        "Alice met Bob at IBM Research on 2014-06-30; call (408) 555-9876 x22.",
        "aaa bbb aaa. aaab aab ab b a",
        "$1,234.56 and 99% of http://example.com/x?y=z emails: a.b@c-d.org",
    ];
    for t in fixed {
        if !regex.hw_semantics_agree(t) {
            return Err(HwCompileError::SemanticsDiverge {
                node,
                pattern: regex.pattern.source.clone(),
            });
        }
    }
    for _ in 0..64 {
        let len = rng.range(1, 160);
        let t = rng.string_over(&alphabet, len);
        if !regex.hw_semantics_agree(&t) {
            return Err(HwCompileError::SemanticsDiverge {
                node,
                pattern: regex.pattern.source.clone(),
            });
        }
    }
    Ok(())
}

impl AccelConfig {
    /// Pick the artifact for a given block size.
    pub fn artifact_key(&self, block: usize) -> ArtifactKey {
        ArtifactKey {
            machines: self.geometry.0,
            states: self.geometry.1,
            block,
        }
    }

    /// Pack the machines into the padded `[M, S, 256]` transition-table
    /// tensor and `[M, S]` accept tensor (row-major i32), matching the
    /// kernel's expected layout exactly. Padding rows/machines are all
    /// zeros (dead state, never accepting); every real row keeps the
    /// NUL→START reset.
    pub fn pack_tables(&self) -> (Vec<i32>, Vec<i32>) {
        let (m_pad, s_pad) = self.geometry;
        let mut tables = vec![0i32; m_pad * s_pad * 256];
        let mut accepts = vec![0i32; m_pad * s_pad];
        for (mi, m) in self.machines.iter().enumerate() {
            for s in 0..m.num_states {
                let src = s * 256;
                let dst = (mi * s_pad + s) * 256;
                for b in 0..256 {
                    tables[dst + b] = m.table[src + b] as i32;
                }
                accepts[mi * s_pad + s] = i32::from(m.accept[s]);
            }
        }
        (tables, accepts)
    }

    /// VMEM footprint estimate for the kernel working set at this geometry
    /// (tables + accepts + one byte-block tile), in bytes. Used by the
    /// DESIGN.md §Perf analysis, mirroring the paper's BRAM budgeting.
    pub fn vmem_estimate(&self, block: usize) -> usize {
        let (m, s) = self.geometry;
        m * s * 256 * 4 + m * s * 4 + STREAMS * block * 4 + m * STREAMS * block * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::{partition, PartitionMode};

    fn spec_for(aql: &str, mode: PartitionMode) -> Vec<SubgraphSpec> {
        let g = crate::optimizer::optimize(&crate::aql::compile(aql).unwrap());
        partition(&g, mode).subgraphs
    }

    const QUERY: &str = r#"
        create dictionary Orgs as ('IBM', 'IBM Research');
        create view Org as extract dictionary 'Orgs' on d.text as match from Document d;
        create view Person as extract regex /[A-Z][a-z]+ [A-Z][a-z]+/ on d.text as name from Document d;
        create view V as
          select p.name as person, o.match as org
          from Person p, Org o
          where FollowsTok(p.name, o.match, 0, 4);
        output view V;
    "#;

    #[test]
    fn compiles_two_machines() {
        let specs = spec_for(QUERY, PartitionMode::SingleSubgraph);
        assert_eq!(specs.len(), 1);
        let cfg = compile_subgraph(&specs[0]).unwrap();
        assert_eq!(cfg.machines.len(), 2);
        // one regex, one dict
        assert!(matches!(cfg.machines[0].matcher, MatcherRef::Dict(_))
            || matches!(cfg.machines[1].matcher, MatcherRef::Dict(_)));
        // geometry fits both machine count and state budget
        let (m, s) = cfg.geometry;
        assert!(m >= 2);
        assert!(s >= cfg.machines.iter().map(|x| x.num_states).max().unwrap());
    }

    #[test]
    fn packed_layout_roundtrips() {
        let specs = spec_for(QUERY, PartitionMode::ExtractOnly);
        let cfg = compile_subgraph(&specs[0]).unwrap();
        let (tables, accepts) = cfg.pack_tables();
        let (m_pad, s_pad) = cfg.geometry;
        assert_eq!(tables.len(), m_pad * s_pad * 256);
        assert_eq!(accepts.len(), m_pad * s_pad);
        // spot-check machine 0, state START(1): NUL resets to START
        assert_eq!(tables[(0 * s_pad + 1) * 256 + 0], 1);
        // padded machine rows are all zero
        let last = m_pad - 1;
        if last >= cfg.machines.len() {
            let base = (last * s_pad) * 256;
            assert!(tables[base..base + 256].iter().all(|&x| x == 0));
        }
        // accepts are 0/1
        assert!(accepts.iter().all(|&a| a == 0 || a == 1));
        // at least one accepting state exists per real machine
        for (mi, m) in cfg.machines.iter().enumerate() {
            let row = &accepts[mi * s_pad..mi * s_pad + m.num_states];
            assert!(row.iter().any(|&a| a == 1), "machine {mi} never accepts");
        }
    }

    #[test]
    fn artifact_key_names() {
        let k = ArtifactKey {
            machines: 8,
            states: 256,
            block: 4096,
        };
        assert_eq!(k.file_name(), "dfa_m8_s256_b4096.hlo.txt");
        assert_eq!(
            k.tensor_sizes(),
            (4 * 4096, 8 * 256 * 256, 8 * 256),
            "tensor sizes must match the kernel layout"
        );
    }

    #[test]
    fn geometry_menu_is_sane() {
        assert!(GEOMETRIES.iter().any(|&(_, s)| s == MAX_HW_STATES));
        for &(m, s) in GEOMETRIES {
            assert!(m >= 1 && s >= 2);
        }
    }

    #[test]
    fn too_many_machines_rejected() {
        // 17 distinct regexes > max geometry machines (8)
        let mut aql = String::new();
        let max_m = GEOMETRIES.iter().map(|&(m, _)| m).max().unwrap();
        for i in 0..=max_m {
            aql.push_str(&format!(
                "create view V{i} as extract regex /x{{{}}}y/ on d.text as m from Document d;\n",
                i + 1
            ));
        }
        for i in 0..=max_m {
            aql.push_str(&format!("output view V{i};\n"));
        }
        let specs = spec_for(&aql, PartitionMode::ExtractOnly);
        let err = compile_subgraph(&specs[0]).unwrap_err();
        assert!(matches!(err, HwCompileError::TooManyMachines { .. }), "{err}");
    }

    #[test]
    fn vmem_estimate_positive_and_monotone() {
        let specs = spec_for(QUERY, PartitionMode::ExtractOnly);
        let cfg = compile_subgraph(&specs[0]).unwrap();
        assert!(cfg.vmem_estimate(4096) > 0);
        assert!(cfg.vmem_estimate(16384) > cfg.vmem_estimate(4096));
    }

    #[test]
    fn semantic_validation_accepts_extraction_patterns() {
        // the realistic pattern families used by T1–T5 must all pass
        for pat in [
            r"[A-Z][a-z]+ [A-Z][a-z]+",
            r"\d{3}-\d{4}",
            r"(\(\d{3}\) )?\d{3}-\d{4}",
            r"[a-z0-9_]+@[a-z0-9]+\.[a-z]{2,4}",
            r"\$\d+(\.\d{2})?",
            r"[A-Z]{2,5}",
            r"\d{4}-\d{2}-\d{2}",
            r"http:\/\/[a-z0-9\.\/\-]+",
        ] {
            let re = crate::regex::compile(pat, false).unwrap();
            validate_regex_semantics(0, &re)
                .unwrap_or_else(|e| panic!("pattern {pat} rejected: {e}"));
        }
    }
}
