//! Shared entity pools: the corpus generator plants these and the built-in
//! queries' dictionaries match them, giving realistic selectivity.

/// Person first names (capitalized — the person regexes rely on shape).
pub const FIRST_NAMES: &[&str] = &[
    "Laura", "Raphael", "Kubilay", "Christoph", "Peter", "Frederick", "Eva", "Huaiyu",
    "Alice", "Robert", "Maria", "James", "Wei", "Priya", "Carlos", "Anna", "David",
    "Elena", "Thomas", "Grace", "Victor", "Nadia", "Oscar", "Irene",
];

/// Person last names.
pub const LAST_NAMES: &[&str] = &[
    "Polig", "Atasu", "Chiticariu", "Hagleitner", "Hofstee", "Reiss", "Sitaridi", "Zhu",
    "Smith", "Garcia", "Chen", "Miller", "Patel", "Ivanov", "Novak", "Costa", "Brown",
    "Keller", "Moreau", "Tanaka", "Singh", "Berg", "Rossi", "Haas",
];

/// Organizations (multi-token entries exercise phrase matching).
pub const ORGS: &[&str] = &[
    "IBM", "IBM Research", "Columbia University", "Acme Corp", "Globex", "Initech",
    "Stark Industries", "Wayne Enterprises", "Hooli", "Vandelay Industries",
    "Pied Piper", "Umbrella Corp", "Cyberdyne Systems", "Tyrell Corp", "Aperture Science",
    "Gringotts Bank", "Oscorp", "Massive Dynamic",
];

/// Locations.
pub const LOCATIONS: &[&str] = &[
    "Zurich", "Almaden", "Austin", "New York", "San Jose", "Tokyo", "London", "Paris",
    "Berlin", "Bangalore", "Sydney", "Toronto", "Singapore", "Dublin", "Haifa",
    "Sao Paulo", "Nairobi", "Oslo",
];

/// Verbs for sentence templates.
pub const VERBS: &[&str] = &[
    "announced", "disputed", "acquired", "launched", "reviewed", "cancelled", "praised",
    "rejected", "shipped", "delayed", "expanded", "restructured",
];

/// Nouns for sentence templates.
pub const NOUNS: &[&str] = &[
    "merger", "prototype", "quarterly report", "partnership", "layoff", "settlement",
    "acquisition", "dividend", "pipeline", "benchmark", "patent", "outage", "rollout",
    "audit", "forecast",
];

/// Months.
pub const MONTHS: &[&str] = &[
    "January", "February", "March", "April", "May", "June", "July", "August",
    "September", "October", "November", "December",
];

/// Hashtag-ish tags for tweets.
pub const TAGS: &[&str] = &[
    "bigdata", "textanalytics", "fpga", "hardware", "nlp", "tech", "finance", "news",
];

/// Sentiment words (T3's dictionaries).
pub const SENTIMENT: &[&str] = &[
    "amazing", "terrible", "great", "awful", "fantastic", "disappointing", "excellent",
    "broken", "impressive", "useless", "solid", "buggy",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pools_nonempty_and_ascii() {
        for pool in [
            FIRST_NAMES,
            LAST_NAMES,
            ORGS,
            LOCATIONS,
            VERBS,
            NOUNS,
            MONTHS,
            TAGS,
            SENTIMENT,
        ] {
            assert!(!pool.is_empty());
            for e in pool {
                assert!(e.is_ascii());
                assert!(!e.is_empty());
            }
        }
    }

    #[test]
    fn person_names_capitalized() {
        for n in FIRST_NAMES.iter().chain(LAST_NAMES) {
            assert!(n.chars().next().unwrap().is_ascii_uppercase(), "{n}");
        }
    }
}
